"""Benchmark timing utilities."""

from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 2, min_time_s: float = 0.4,
            max_iters: int = 50) -> float:
    """Median wall-clock seconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    total = 0.0
    while total < min_time_s and len(times) < max_iters:
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        times.append(dt)
        total += dt
    times.sort()
    return times[len(times) // 2]


def time_pair(
    fa: Callable, fb: Callable, *args, iters: int = 30, rounds: int = 3
) -> tuple:
    """Min wall-clock seconds per call for two callables, interleaved.

    A/B comparisons with back-to-back `time_fn` calls are at the mercy of
    load drift between the two measurement windows; interleaving the
    calls and taking per-side minima over several rounds cancels it.
    Stops early once the faster side is stable across rounds.
    """
    jax.block_until_ready(fa(*args))
    jax.block_until_ready(fb(*args))
    best_a = best_b = float("inf")
    last_sign = None
    for r in range(rounds):
        for i in range(iters):
            # alternate which side goes first: the second call of a pair
            # runs with caches warmed by the first, a systematic bias if
            # the order is fixed
            pair = ((fa, 0), (fb, 1)) if (i + r) % 2 == 0 else ((fb, 1), (fa, 0))
            for fn, side in pair:
                t0 = time.perf_counter()
                jax.block_until_ready(fn(*args))
                dt = time.perf_counter() - t0
                if side == 0:
                    best_a = min(best_a, dt)
                else:
                    best_b = min(best_b, dt)
        sign = best_a <= best_b
        if last_sign is not None and sign == last_sign:
            break
        last_sign = sign
    return best_a, best_b


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
