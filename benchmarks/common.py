"""Benchmark timing utilities."""

from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 2, min_time_s: float = 0.4,
            max_iters: int = 50) -> float:
    """Median wall-clock seconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    total = 0.0
    while total < min_time_s and len(times) < max_iters:
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        times.append(dt)
        total += dt
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
