"""Benchmark driver: one section per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/*.py).
    PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller batches")
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny-geometry CI smoke: catches dispatcher regressions that "
        "only bite at execution time (implies --only convserve unless "
        "--only is given)",
    )
    ap.add_argument(
        "--only", default=None,
        help="comma list: fig2,fig3,analysis,r_sweep,lm,roofline,convserve",
    )
    ap.add_argument(
        "--bench-json", default=None, metavar="PATH",
        help="where the convserve section writes its machine-readable "
        "results (default: BENCH_convserve.json in the cwd)",
    )
    args = ap.parse_args()
    batch = 1 if (args.quick or args.smoke) else 2
    if args.smoke and args.only is None:
        args.only = "convserve"
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    sections = []
    if want("analysis"):
        from benchmarks import analysis_table

        sections.append(("paper S5 analysis table", analysis_table.main, ()))
    if want("fig2"):
        from benchmarks import paper_fig2

        sections.append(
            ("paper Fig2 (VGG/ResNet layers)", paper_fig2.main, (batch,))
        )
    if want("fig3"):
        from benchmarks import paper_fig3

        sections.append(("paper Fig3 (i7 layers)", paper_fig3.main, (batch,)))
    if want("r_sweep"):
        from benchmarks import r_sweep

        sections.append(("R-parameter sweep (S4.1.2)", r_sweep.main, (batch,)))
    if want("lm"):
        from benchmarks import lm_bench

        sections.append(("LM framework benches", lm_bench.main, ()))
    if want("roofline"):
        from benchmarks import roofline_report

        sections.append(
            ("roofline table", roofline_report.main, ([],))
        )
    if want("convserve"):
        import pathlib

        from benchmarks import convserve_bench

        if args.bench_json:
            convserve_bench.BENCH_PATH = pathlib.Path(args.bench_json)
        sections.append(
            (
                "convserve engine (planned nets)",
                convserve_bench.main,
                (batch, 64, args.smoke),
            )
        )

    failures = 0
    for title, fn, fargs in sections:
        print(f"\n## {title}", flush=True)
        try:
            fn(*fargs)
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
