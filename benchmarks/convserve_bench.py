"""convserve engine benchmark: planned nets vs all-direct, cold vs warm,
fused vs unfused -- with a machine-readable JSON artifact.

Per net (the mixed-channel VGG and the stride-2 ResNet-style
downsampling net), CSV rows:

  convserve/<net>/plan    -- plan_net wall time (pure roofline model)
  convserve/<net>/cold    -- first wave: jit compile + kernel transforms
  convserve/<net>/warm    -- steady-state serving time, cache hot
  convserve/<net>/unfused -- same plan with fusion groups stripped
  convserve/<net>/direct  -- the same net all-direct (vendor baseline)
  convserve/<net>/stage/* -- per-stage wall times (separately jitted)

and everything lands in ``BENCH_convserve.json`` (per-net, per-stage
wall times + cache hit rates) so the perf trajectory is tracked across
PRs.

    PYTHONPATH=src python -m benchmarks.convserve_bench

`smoke=True` (the CI path, `benchmarks.run --smoke`) runs the tiny test
net at a tiny geometry and asserts fused == unfused == direct numerical
parity: it exists to catch dispatcher and fusion regressions that only
bite at execution time, not to produce meaningful numbers.
"""

from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn, time_pair
from repro.configs.convnets import (
    fft_fewchannel,
    resnet_downsample,
    tiny_testnet,
    vgg_mixed_channel,
)
from repro.convserve import Engine, init_weights, run_direct
from repro.convserve.obs import roofline as roofline_mod
from repro.convserve.planner import predict_stage_times
from repro.core import analysis, transforms, tune

BENCH_PATH = pathlib.Path("BENCH_convserve.json")

_HW: list = []  # one-shot cache of the calibrated model for this run


def bench_hw() -> analysis.HardwareModel:
    """The calibrated hardware model every bench number is predicted
    against: the paper-machine constants with compute/memory roofs
    replaced by the measured GEMM/stream microbenchmark (cached in the
    wisdom file, so repeat runs pay nothing).  Hardcoded SKYLAKE_X peaks
    on an arbitrary host made `measured_over_predicted` pure noise
    (80-440x); calibration is what makes the divergence signal usable."""
    if not _HW:
        _HW.append(analysis.calibrated_hw(analysis.SKYLAKE_X))
    return _HW[0]


def profile_stage_rows(net, x, hw) -> list:
    """Measured AND roofline-predicted seconds per stage -- the
    predicted-vs-measured delta is the cost-model divergence the adapt
    loop (convserve.adapt) acts on, surfaced in the bench artifact.
    Modeled stage times are per image; the measured pass runs the whole
    batch, so predictions are scaled by x's leading dim to compare
    like with like."""
    batch = int(x.shape[0])
    predicted = dict(predict_stage_times(net.program, hw))
    profile = list(net.profile_stages(x))
    rows = []
    for label, secs in profile:
        pred = predicted[label] * batch
        rows.append(
            {
                "label": label,
                "us": secs * 1e6,
                "predicted_us": pred * 1e6,
                "measured_over_predicted": (
                    secs / pred if pred > 0 else None
                ),
            }
        )
    return rows, profile


def bench_net(spec, batch: int, side: int, c_in: int, record: dict) -> None:
    ws = init_weights(spec, seed=0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(
        rng.standard_normal((batch, side, side, c_in)) * 0.1, jnp.float32
    )
    engine = Engine(hw=bench_hw())

    t0 = time.perf_counter()
    net = engine.compile(spec, ws, input_hw=(side, side))
    t_plan = time.perf_counter() - t0
    algos = ";".join(net.plan.algos())
    print(row(f"convserve/{spec.name}/plan", t_plan * 1e6, algos))

    t0 = time.perf_counter()
    jax.block_until_ready(net(x))
    t_cold = time.perf_counter() - t0
    print(row(f"convserve/{spec.name}/cold", t_cold * 1e6, f"batch{batch}"))

    # fused vs unfused interleaved (time_pair): the two programs differ
    # only in stage structure, so separate measurement windows would
    # compare load drift, not fusion
    unfused = engine.compile(spec, ws, input_hw=(side, side), fuse=False)
    t_warm, t_unfused = time_pair(net, unfused, x)
    cache = net.cache.stats()
    print(
        row(
            f"convserve/{spec.name}/warm", t_warm * 1e6,
            f"{t_warm * 1e3 / batch:.1f}ms/img;hits{cache['hits']}",
        )
    )
    print(
        row(
            f"convserve/{spec.name}/unfused", t_unfused * 1e6,
            f"{net.program.n_fused}groups",
        )
    )

    vendor = jax.jit(lambda x: run_direct(spec, ws, x))
    t_dir = time_fn(vendor, x)
    print(
        row(
            f"convserve/{spec.name}/direct", t_dir * 1e6,
            f"{t_dir * 1e3 / batch:.1f}ms/img",
        )
    )

    stages, profile = profile_stage_rows(net, x, engine.hw)
    for st in stages:
        print(
            row(
                f"convserve/{spec.name}/stage/{st['label']}", st["us"],
                f"pred{st['predicted_us']:.0f}us;"
                f"x{st['measured_over_predicted']:.2f}",
            )
        )

    record[spec.name] = {
        "algos": net.plan.algos(),
        "fusion_groups": [list(g.layers) for g in net.plan.groups],
        "plan_us": t_plan * 1e6,
        "cold_us": t_cold * 1e6,
        "warm_us": t_warm * 1e6,
        "warm_us_per_img": t_warm * 1e6 / batch,
        "unfused_warm_us": t_unfused * 1e6,
        "direct_us": t_dir * 1e6,
        "stages": stages,
        "roofline": roofline_mod.roofline_section(
            net.program, profile, engine.hw, batch=batch
        ),
        "cache": net.cache.stats(),
    }


def bench_fft_net(
    batch: int, side: int, record: dict, *, iters: int = 30
) -> None:
    """The FFT-selected few-channel net: the transform the planner picks
    when tiles are DRAM-bound (Zlateski et al.'s claim through our
    roofline), served as one FFT-backed fusion group.

    Asserts the plan (all fft_fused + >= 1 group) and fused-vs-direct
    parity, then times fused vs unfused interleaved (`time_pair`): the
    pair differ only in stage structure, so back-to-back medians would
    measure load drift, not fusion.
    """
    spec = fft_fewchannel(4)
    ws = init_weights(spec, seed=0)
    # block-autotune both engine families at this net's layer geometries
    # before planning: lookup_blocks then resolves at plan time and the
    # auto ranking prices the tuned engine (analysis.engine_cost_ta)
    # instead of the static idealization.  Repeat runs hit the stamped
    # wisdom entries and pay nothing.
    for c_in, c_out in sorted(
        {(l.c_in, l.c_out) for l in spec.layers if l.kind == "conv"}
    ):
        for tr in (
            transforms.WinogradTransform(m=5, k=3),
            transforms.FFTTransform(t=16, k=3),
        ):
            tune.tuned_blocks(side, side, c_in, c_out, transform=tr)
    engine = Engine(hw=bench_hw())
    fused = engine.compile(spec, ws, input_hw=(side, side))
    unfused = engine.compile(spec, ws, input_hw=(side, side), fuse=False)
    # every layer must resolve to a *fused transformed* realization; the
    # family is the calibrated cost model's call (the paper: FFT wins at
    # high channel counts, Winograd at few), so the gate is deliberately
    # family-agnostic -- the FFT family's parity is pinned by the
    # interpret-mode kernel matrix in tests/test_fused_tile.py
    fused_algos = {"fft_fused", "l3_fused"}
    assert all(a in fused_algos for a in fused.plan.algos()), (
        f"few-channel net did not plan fused transforms: {fused.plan.algos()}"
    )
    assert fused.program.n_fused >= 1, (
        f"FFT net planned no fusion groups: {fused.describe()}"
    )
    rng = np.random.default_rng(0)
    x = jnp.asarray(
        rng.standard_normal((batch, side, side, 4)) * 0.1, jnp.float32
    )
    ref = run_direct(spec, ws, x)
    scale = float(jnp.abs(ref).max())
    rel_fused = float(jnp.abs(fused(x) - ref).max()) / scale
    rel_pair = float(jnp.abs(fused(x) - unfused(x)).max()) / scale
    assert rel_fused < 1e-3, f"FFT fused vs direct diverged: {rel_fused}"
    assert rel_pair < 1e-4, f"FFT fused vs unfused diverged: {rel_pair}"

    t_fused, t_unfused = time_pair(fused, unfused, x, iters=iters)
    vendor = jax.jit(lambda x: run_direct(spec, ws, x))
    t_dir = time_fn(vendor, x)
    print(row(f"convserve/{spec.name}/warm", t_fused * 1e6,
              ";".join(fused.plan.algos())))
    print(row(f"convserve/{spec.name}/unfused", t_unfused * 1e6,
              f"{fused.program.n_fused}groups"))
    print(row(f"convserve/{spec.name}/direct", t_dir * 1e6))
    print(row(f"convserve/{spec.name}/fused_vs_direct", 0.0,
              f"rel{rel_fused:.2e}"))
    stages, profile = profile_stage_rows(fused, x, engine.hw)
    for st in stages:
        print(
            row(
                f"convserve/{spec.name}/stage/{st['label']}", st["us"],
                f"pred{st['predicted_us']:.0f}us;"
                f"x{st['measured_over_predicted']:.2f}",
            )
        )
    record[spec.name] = {
        "algos": fused.plan.algos(),
        "fusion_groups": [list(g.layers) for g in fused.plan.groups],
        "warm_us": t_fused * 1e6,
        "unfused_warm_us": t_unfused * 1e6,
        "direct_us": t_dir * 1e6,
        "fused_vs_direct_rel": rel_fused,
        "fused_vs_unfused_rel": rel_pair,
        "stages": stages,
        "roofline": roofline_mod.roofline_section(
            fused.program, profile, engine.hw, batch=batch
        ),
        "cache": fused.cache.stats(),
    }


def _smoke(record: dict) -> None:
    """Tiny geometry, full pipeline: a fused plan and its unfused strip
    must agree with the direct oracle (fusion-group parity gate)."""
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=0)
    engine = Engine(hw=bench_hw())
    fused = engine.compile(spec, ws, input_hw=(16, 16))
    unfused = engine.compile(spec, ws, input_hw=(16, 16), fuse=False)
    # without this the parity gate is vacuous: a planner regression that
    # stops fusing would compare two identical unfused programs
    assert fused.program.n_fused >= 1, (
        f"smoke net planned no fusion groups: {fused.describe()}"
    )
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 16, 16, 4)) * 0.1, jnp.float32)
    ref = run_direct(spec, ws, x)
    scale = float(jnp.abs(ref).max())
    rel_fused = float(jnp.abs(fused(x) - ref).max()) / scale
    rel_pair = float(jnp.abs(fused(x) - unfused(x)).max()) / scale
    print(row("convserve/smoke/fused_vs_direct", 0.0, f"rel{rel_fused:.2e}"))
    print(row("convserve/smoke/fused_vs_unfused", 0.0, f"rel{rel_pair:.2e}"))
    assert rel_fused < 1e-3, f"fused vs direct diverged: {rel_fused}"
    assert rel_pair < 1e-4, f"fused vs unfused diverged: {rel_pair}"
    record[spec.name] = {
        "smoke": True,
        "fused_vs_direct_rel": rel_fused,
        "fused_vs_unfused_rel": rel_pair,
        "fusion_groups": [list(g.layers) for g in fused.plan.groups],
        "cache": fused.cache.stats(),
    }


def main(batch: int = 2, side: int = 64, smoke: bool = False) -> None:
    record: dict = {}
    try:
        if smoke:  # CI: tiny geometry, fusion parity under time pressure
            _smoke(record)
            # the FFT-selected few-channel net, small geometry: asserts
            # the transform choice + FFT fusion-group parity, and records
            # the fused-vs-unfused warm pair
            bench_fft_net(batch, 48, record, iters=20)
        else:
            bench_net(
                vgg_mixed_channel(c_in=3), batch, side, c_in=3, record=record
            )
            bench_net(
                resnet_downsample(c_in=3), batch, side, c_in=3, record=record
            )
            bench_fft_net(batch, side, record)
    finally:
        # partial results still land on disk (and in the CI artifact)
        # when a parity gate fires mid-run
        hw = bench_hw()
        BENCH_PATH.write_text(
            json.dumps(
                {
                    "bench": "convserve",
                    "schema_version": roofline_mod.SCHEMA_VERSION,
                    "smoke": smoke,
                    "calibration": {
                        "hw": hw.name,
                        "peak_flops": hw.peak_flops,
                        "dram_bw": hw.dram_bw,
                    },
                    "nets": record,
                },
                indent=1,
                sort_keys=True,
            )
        )
        print(f"# wrote {BENCH_PATH}")


if __name__ == "__main__":
    main()
