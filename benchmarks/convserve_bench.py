"""convserve engine benchmark: planned net vs all-direct, cold vs warm.

Rows:
  convserve/plan  -- plan_net wall time (pure roofline model, no measuring)
  convserve/cold  -- first wave: jit compile + kernel transforms
  convserve/warm  -- steady-state per-image serving time, cache hot
  convserve/direct-- the same net all-direct (vendor baseline)

    PYTHONPATH=src python -m benchmarks.convserve_bench
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.configs.convnets import vgg_mixed_channel
from repro.convserve import NetExecutor, init_weights, plan_net, run_direct
from repro.core import analysis


def main(batch: int = 2, side: int = 64) -> None:
    spec = vgg_mixed_channel(c_in=3)
    ws = init_weights(spec, seed=0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(
        rng.standard_normal((batch, side, side, 3)) * 0.1, jnp.float32
    )

    t0 = time.perf_counter()
    plan = plan_net(spec, side, side, hw=analysis.SKYLAKE_X)
    t_plan = time.perf_counter() - t0
    print(row("convserve/plan", t_plan * 1e6, ";".join(plan.algos())))

    ex = NetExecutor(spec, ws, plan)
    t0 = time.perf_counter()
    jax.block_until_ready(ex(x))
    t_cold = time.perf_counter() - t0
    print(row("convserve/cold", t_cold * 1e6, f"batch{batch}"))

    t_warm = time_fn(ex, x)
    print(
        row(
            "convserve/warm", t_warm * 1e6,
            f"{t_warm * 1e3 / batch:.1f}ms/img;"
            f"hits{ex.cache.stats()['hits']}",
        )
    )

    vendor = jax.jit(lambda x: run_direct(spec, ws, x))
    t_dir = time_fn(vendor, x)
    print(
        row(
            "convserve/direct", t_dir * 1e6,
            f"{t_dir * 1e3 / batch:.1f}ms/img",
        )
    )


if __name__ == "__main__":
    main()
