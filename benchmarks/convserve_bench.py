"""convserve engine benchmark: planned nets vs all-direct, cold vs warm.

Per net (the mixed-channel VGG and the stride-2 ResNet-style
downsampling net), rows:

  convserve/<net>/plan  -- plan_net wall time (pure roofline model)
  convserve/<net>/cold  -- first wave: jit compile + kernel transforms
  convserve/<net>/warm  -- steady-state per-image serving time, cache hot
  convserve/<net>/direct-- the same net all-direct (vendor baseline)

    PYTHONPATH=src python -m benchmarks.convserve_bench

`smoke=True` (the CI path, `benchmarks.run --smoke`) runs the tiny test
net at a tiny geometry: it exists to catch dispatcher regressions that
only bite at execution time, not to produce meaningful numbers.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.configs.convnets import (
    resnet_downsample,
    tiny_testnet,
    vgg_mixed_channel,
)
from repro.convserve import NetExecutor, init_weights, plan_net, run_direct
from repro.core import analysis


def bench_net(spec, batch: int, side: int, c_in: int) -> None:
    ws = init_weights(spec, seed=0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(
        rng.standard_normal((batch, side, side, c_in)) * 0.1, jnp.float32
    )

    t0 = time.perf_counter()
    plan = plan_net(spec, side, side, hw=analysis.SKYLAKE_X)
    t_plan = time.perf_counter() - t0
    print(row(f"convserve/{spec.name}/plan", t_plan * 1e6,
              ";".join(plan.algos())))

    ex = NetExecutor(spec, ws, plan)
    t0 = time.perf_counter()
    jax.block_until_ready(ex(x))
    t_cold = time.perf_counter() - t0
    print(row(f"convserve/{spec.name}/cold", t_cold * 1e6, f"batch{batch}"))

    t_warm = time_fn(ex, x)
    print(
        row(
            f"convserve/{spec.name}/warm", t_warm * 1e6,
            f"{t_warm * 1e3 / batch:.1f}ms/img;"
            f"hits{ex.cache.stats()['hits']}",
        )
    )

    vendor = jax.jit(lambda x: run_direct(spec, ws, x))
    t_dir = time_fn(vendor, x)
    print(
        row(
            f"convserve/{spec.name}/direct", t_dir * 1e6,
            f"{t_dir * 1e3 / batch:.1f}ms/img",
        )
    )


def main(batch: int = 2, side: int = 64, smoke: bool = False) -> None:
    if smoke:  # CI: tiny geometry, dispatcher correctness under time
        bench_net(tiny_testnet(4), batch=1, side=16, c_in=4)
        return
    bench_net(vgg_mixed_channel(c_in=3), batch, side, c_in=3)
    bench_net(resnet_downsample(c_in=3), batch, side, c_in=3)


if __name__ == "__main__":
    main()
