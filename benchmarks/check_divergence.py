"""The sim-clock adaptive-replanning scenario, end to end, with real
measurements -- the CI gate for `repro.convserve.adapt`.

The runtime starts on the plan the roofline picks for the few-channel
FFT net (`fft-fewchannel` -- the documented misprediction: the model
says fused FFT, measurement says direct is ~2x faster on the paper's
CPU path).  The adapt controller measures the live stages, probes the
unfused and direct alternatives, and -- if measured divergence crosses
the threshold -- replans with measured costs, shadows the candidate
under live SimClock traffic, and promotes or rolls back.

Hard assertions (the zero-downtime contract):

  * every submitted request is served (zero drops),
  * every response matches the direct oracle within the documented
    cross-family tolerance (zero inexact responses),
  * shadow waves never appear in the client latency histograms,
  * the plan the loop settles on is measured-no-slower than the seed
    plan (interleaved `time_pair`, with slack for CI timer noise --
    when no promotion happened the two plans are identical and the
    check is an identity).

Everything (audit log, adapt counters, divergence rows, the seed vs
final timing pair) lands in ``BENCH_adapt.json`` in a finally block, so
a failing gate still ships the telemetry for triage.

    PYTHONPATH=src python -m benchmarks.check_divergence --smoke
"""

from __future__ import annotations

import json
import pathlib
import sys

import numpy as np

from benchmarks.common import row, time_pair
from repro.configs.convnets import fft_fewchannel
from repro.convserve import (
    AdaptConfig,
    AdaptController,
    Engine,
    ReplicaPool,
    RuntimeConfig,
    ServeRuntime,
    SimClock,
    init_weights,
    run_direct,
)
from repro.core import analysis

BENCH_PATH = pathlib.Path("BENCH_adapt.json")


def main(smoke: bool = False) -> None:
    # side 64 in BOTH modes: the documented misprediction (fused FFT
    # measured ~2x slower than direct) only manifests at >= 64; smoke
    # trims the request count, not the geometry
    side = 64
    n_requests = 8 if smoke else 32
    spec = fft_fewchannel(4)
    ws = init_weights(spec, seed=0)
    engine = Engine(hw=analysis.SKYLAKE_X)
    pool = ReplicaPool.build(
        engine, spec, ws, n=1, workers=0, input_hw=(side, side)
    )
    seed_plan = pool.executors[0].plan
    print(row("adapt/seed/algos", 0.0, ";".join(seed_plan.algos())))
    print(row("adapt/seed/groups", float(len(seed_plan.groups))))

    cfg = RuntimeConfig(
        max_batch=2, buckets=(side,), slo_s=10.0, service_est_s=1e-3
    )
    rt = ServeRuntime(pool, cfg, clock=SimClock())
    ac = AdaptController(
        rt, engine, spec, ws,
        AdaptConfig(
            # the measured fused-vs-direct gap at side 64 is ~1.5x on the
            # reference box; 1.25 keeps the demo firing under CI timer
            # noise while staying far above the ~1.0 of a matched plan
            divergence_ratio=1.25,
            shadow_fraction=1.0,
            shadow_min_waves=2,
            promote_margin=0.05,
            probe_bucket=side,
            probe_reps=3,
        ),
    )
    record: dict = {"smoke": smoke, "seed_algos": list(seed_plan.algos())}
    try:
        ac.measure()
        ac.probe_alternatives()
        reason = ac.check()
        print(row("adapt/replan_triggered", float(ac.replans_triggered),
                  reason or "within threshold"))

        rng = np.random.default_rng(0)
        imgs = {
            i: (rng.standard_normal((side, side, 4)) * 0.1).astype(np.float32)
            for i in range(n_requests)
        }
        for i in range(n_requests):
            rt.submit(imgs[i], rid=i)
            rt.poll()
        rt.drain()

        # ---- the zero-downtime contract
        missing = [i for i in range(n_requests) if i not in rt.results]
        assert not missing, f"dropped requests: {missing}"
        for i in range(n_requests):
            ref = np.asarray(run_direct(spec, ws, imgs[i][None]))[0]
            scale = max(float(np.abs(ref).max()), 1e-30)
            rel = float(np.abs(rt.results[i] - ref).max()) / scale
            assert rel < 1e-3, f"request {i} inexact: rel {rel}"
        snap = rt.stats()
        e2e_count = snap["latency"]["e2e"]["count"]
        assert e2e_count == n_requests, (
            f"shadow waves leaked into client latency: e2e count "
            f"{e2e_count} != {n_requests} requests"
        )

        final = rt.pool.executors[0]
        promoted = final.plan != seed_plan
        print(row("adapt/promotions", float(ac.promotions),
                  ";".join(final.plan.algos())))
        print(row("adapt/rollbacks", float(ac.rollbacks)))

        # ---- promoted plan measured-no-slower than the seed plan
        seed_net = engine.compile(spec, ws, plan=seed_plan, fuse=None)
        x = np.stack([imgs[i] for i in range(2)])
        t_final, t_seed = time_pair(final, seed_net, x)
        print(row("adapt/final_warm", t_final * 1e6,
                  "promoted" if promoted else "seed kept"))
        print(row("adapt/seed_warm", t_seed * 1e6))
        # 1.25x slack: CI timers are noisy and an identical-plan pair
        # should never flake; a genuinely slower promotion still fails
        assert t_final <= t_seed * 1.25, (
            f"promoted plan measured slower than seed: "
            f"{t_final * 1e6:.0f}us vs {t_seed * 1e6:.0f}us"
        )

        record.update(
            {
                "promoted": promoted,
                "final_algos": list(final.plan.algos()),
                "final_groups": [list(g.layers) for g in final.plan.groups],
                "final_warm_us": t_final * 1e6,
                "seed_warm_us": t_seed * 1e6,
                "requests": n_requests,
                "e2e_count": e2e_count,
            }
        )
    finally:
        record["adapt"] = ac.stats()
        record["counters"] = {
            k: v for k, v in rt.telemetry.snapshot()["counters"].items()
        }
        BENCH_PATH.write_text(json.dumps(record, indent=1, sort_keys=True,
                                         default=str))
        print(f"# wrote {BENCH_PATH}")


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
