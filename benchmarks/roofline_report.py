"""Live roofline-attribution report: measured stages vs their ceilings.

Renders the per-stage hierarchical-roofline table (achieved GFLOP/s,
binding level, fraction of roof, verdict, per-phase split for fused
stages) from any of the three artifact forms the stack emits:

  * a BENCH JSON carrying ``roofline`` sections (``BENCH_convserve.json``
    per net, ``BENCH_serve_runtime.json`` per net/variant),
  * a Chrome-trace ``.trace.json`` carrying ``roofline.stage`` instants
    (written by the serving runtime / FlightRecorder),
  * the legacy ``results/dryrun/*.json`` cells (``--dryrun``).

    PYTHONPATH=src python -m benchmarks.roofline_report
    PYTHONPATH=src python -m benchmarks.roofline_report --trace x.trace.json
"""

from __future__ import annotations

import argparse
import json
import pathlib

from repro.convserve.obs.export import roofline_table

DEFAULT_BENCHES = ("BENCH_convserve.json", "BENCH_serve_runtime.json")


def sections_from_bench(doc: dict, label: str) -> list:
    """Every ``roofline`` section in a bench artifact, with its scope
    name: ``[(scope, hw_name, rows), ...]``."""
    out = []

    def visit(node, scope):
        if not isinstance(node, dict):
            return
        rf = node.get("roofline")
        if isinstance(rf, dict) and "stages" in rf:
            out.append((scope, rf.get("hw", {}).get("name", ""), rf["stages"]))
        for key, child in node.items():
            if key != "roofline" and isinstance(child, dict):
                visit(child, f"{scope}/{key}")

    visit(doc, label)
    return out


def sections_from_trace(events, label: str) -> list:
    """The ``roofline.stage`` instants of an exported Chrome trace,
    regrouped into one table (per-phase splits live only in the bench
    form -- instants carry the flat row)."""
    rows = [
        e.get("args", {})
        for e in events
        if isinstance(e, dict)
        and e.get("ph") == "i"
        and e.get("name") == "roofline.stage"
    ]
    rows = [r for r in rows if "stage" in r]
    return [(label, "", rows)] if rows else []


def render(sections) -> str:
    parts = []
    for scope, hw_name, rows in sections:
        parts.append(f"== {scope} ==")
        parts.append(roofline_table(rows, hw_name=hw_name))
        parts.append("")
    return "\n".join(parts)


def legacy_dryrun_table(dirpath: str) -> str:
    """The pre-observability dry-run cell table (results/dryrun)."""
    recs = [
        json.loads(p.read_text())
        for p in sorted(pathlib.Path(dirpath).glob("*.json"))
    ]
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    failed = [r for r in recs if r.get("status") == "error"]
    lines = [
        f"# dry-run cells: {len(ok)} ok, {len(skipped)} skipped, "
        f"{len(failed)} failed",
        "cell,compile_s,t_compute_s,t_memory_s,t_collective_s,"
        "bottleneck,useful_ratio,roofline_frac",
    ]
    for r in ok:
        rf = r["roofline"]
        cell = f"{r['arch']}|{r['shape']}|{r['mesh']}"
        lines.append(
            f"{cell},{r['compile_s']},{rf['t_compute_s']:.4g},"
            f"{rf['t_memory_s']:.4g},{rf['t_collective_s']:.4g},"
            f"{rf['bottleneck']},{rf['useful_flops_ratio']:.3f},"
            f"{rf['roofline_fraction']:.4f}"
        )
    for r in failed:
        lines.append(f"{r['arch']}|{r['shape']}|{r['mesh']},FAILED,,,,,,")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--bench", action="append", default=None, metavar="PATH",
        help="BENCH JSON with roofline sections (repeatable; default: "
        f"whichever of {', '.join(DEFAULT_BENCHES)} exist)",
    )
    ap.add_argument(
        "--trace", action="append", default=None, metavar="PATH",
        help="exported .trace.json with roofline.stage instants",
    )
    ap.add_argument(
        "--dryrun", default=None, metavar="DIR",
        help="legacy results/dryrun cell table instead of live attribution",
    )
    ap.add_argument(
        "--out", default=None, metavar="PATH",
        help="also write the report here (e.g. ROOFLINE_report.txt)",
    )
    args = ap.parse_args(argv)

    if args.dryrun is not None:
        report = legacy_dryrun_table(args.dryrun)
        print(report)
        if args.out:
            pathlib.Path(args.out).write_text(report + "\n")
        return 0

    sections = []
    benches = args.bench
    if benches is None and args.trace is None:
        benches = [p for p in DEFAULT_BENCHES if pathlib.Path(p).exists()]
        # the legacy default: render dry-run cells when they are the
        # only artifact around (benchmarks.run --only roofline)
        if not benches and pathlib.Path("results/dryrun").is_dir():
            print(legacy_dryrun_table("results/dryrun"))
            return 0
    for p in benches or ():
        doc = json.loads(pathlib.Path(p).read_text())
        sections += sections_from_bench(doc, pathlib.Path(p).stem)
    for p in args.trace or ():
        events = json.loads(pathlib.Path(p).read_text())
        sections += sections_from_trace(events, pathlib.Path(p).name)

    if not sections:
        print("roofline_report: no roofline sections found (run "
              "benchmarks.convserve_bench / serve_runtime_bench first, "
              "or pass --bench/--trace)")
        return 1
    report = render(sections)
    print(report)
    if args.out:
        pathlib.Path(args.out).write_text(report + "\n")
        print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
