"""Render the roofline table from results/dryrun/*.json (deliverable g)."""

from __future__ import annotations

import json
import pathlib


def load(dirpath="results/dryrun"):
    recs = []
    for p in sorted(pathlib.Path(dirpath).glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def main(dirpath="results/dryrun"):
    recs = load(dirpath)
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    failed = [r for r in recs if r.get("status") == "error"]
    print(f"# dry-run cells: {len(ok)} ok, {len(skipped)} skipped, "
          f"{len(failed)} failed")
    hdr = (
        "cell,compile_s,t_compute_s,t_memory_s,t_collective_s,"
        "bottleneck,useful_ratio,roofline_frac"
    )
    print(hdr)
    for r in ok:
        rf = r["roofline"]
        cell = f"{r['arch']}|{r['shape']}|{r['mesh']}"
        print(
            f"{cell},{r['compile_s']},{rf['t_compute_s']:.4g},"
            f"{rf['t_memory_s']:.4g},{rf['t_collective_s']:.4g},"
            f"{rf['bottleneck']},{rf['useful_flops_ratio']:.3f},"
            f"{rf['roofline_fraction']:.4f}"
        )
    for r in failed:
        print(f"{r['arch']}|{r['shape']}|{r['mesh']},FAILED,,,,,,")
    return 0


if __name__ == "__main__":
    main()
