"""Paper Figure 3 reproduction: small-channel layers with R=8 (the paper's
i7 configuration -- closest to this 1-core container)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tiling
from repro.core.conv import conv2d_direct
from repro.core.fused import conv2d_l3_fused
from repro.core.three_stage import ThreeStageStaged, transform_kernels

from benchmarks.common import time_fn

I7_LAYERS = [
    ("i7_32ch_112", 32, 112),
    ("i7_64ch_56", 64, 56),
    ("i7_128ch_28", 128, 28),
    ("i7_256ch_14", 256, 14),
]

M = 5
R = 8  # paper's i7 setting


def main(batch: int = 2):
    rows = []
    for tag, c, d in I7_LAYERS:
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((batch, d, d, c)) * 0.1, jnp.float32)
        w = jnp.asarray(rng.standard_normal((3, 3, c, c)) * 0.1, jnp.float32)
        fused = jax.jit(functools.partial(conv2d_l3_fused, pad=1, m=M, r_tiles=R))
        direct = jax.jit(functools.partial(conv2d_direct, pad=1))
        plan = tiling.TilePlan.build(d, d, 3, 1, M + 2)
        staged = ThreeStageStaged(plan)
        wt = jax.jit(functools.partial(transform_kernels, m=M))(w)
        jax.block_until_ready(wt)
        t_f = time_fn(fused, x, w)
        t_d = time_fn(direct, x, w)
        t_s = time_fn(lambda xx: staged(xx, wt), x)
        rows.append((tag, t_f, t_s, t_d))
        print(
            f"fig3_{tag},{t_f * 1e6 / batch:.1f},"
            f"fused_ms/img={t_f * 1e3 / batch:.2f};"
            f"3stage_ms/img={t_s * 1e3 / batch:.2f};"
            f"vendor_ms/img={t_d * 1e3 / batch:.2f};"
            f"speedup={min(t_s, t_d) / t_f:.2f}",
            flush=True,
        )
    return rows


if __name__ == "__main__":
    main()
