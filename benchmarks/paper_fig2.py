"""Paper Figure 2 reproduction: VGG + ResNet layers, fused vs 3-stage vs
vendor (XLA direct) -- measured on this container's CPU.

The paper runs batch 64 on an 18-core 7980xe; this container has 1 core, so
we scale the batch down (default 2) and report per-image times.  The CLAIM
under test is the *trend*: L3-fused wins on 64/128-channel layers and the
advantage fades as channels grow (kernel matrices outgrow the fast level).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analysis as an
from repro.core import tiling
from repro.core.conv import conv2d_direct
from repro.core.fused import conv2d_l3_fused
from repro.core.three_stage import ThreeStageStaged, transform_kernels

from benchmarks.common import time_fn

# (tag, channels, spatial) -- kernel 3x3 pad 1 throughout (paper S6)
VGG_LAYERS = [
    ("vgg_64ch_224", 64, 224),
    ("vgg_128ch_112", 128, 112),
    ("vgg_256ch_56", 256, 56),
    ("vgg_512ch_28", 512, 28),
]
RESNET_LAYERS = [
    ("resnet_64ch_56", 64, 56),
    ("resnet_128ch_28", 128, 28),
    ("resnet_256ch_14", 256, 14),
    ("resnet_512ch_7", 512, 7),
]

M = 5  # T = 7, the paper's fixed benchmark configuration
R = 24  # the paper's SkylakeX setting


def bench_layer(tag: str, c: int, d: int, batch: int):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((batch, d, d, c)) * 0.1, jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, c, c)) * 0.1, jnp.float32)

    fused = jax.jit(
        functools.partial(conv2d_l3_fused, pad=1, m=M, r_tiles=R)
    )
    direct = jax.jit(functools.partial(conv2d_direct, pad=1))
    plan = tiling.TilePlan.build(d, d, 3, 1, M + 2)
    staged = ThreeStageStaged(plan)
    wt = jax.jit(functools.partial(transform_kernels, m=M))(w)
    jax.block_until_ready(wt)

    t_fused = time_fn(fused, x, w)
    t_direct = time_fn(direct, x, w)
    t_staged = time_fn(lambda xx: staged(xx, wt), x, warmup=2)

    best_other = min(t_direct, t_staged)
    return {
        "tag": tag,
        "fused_ms": t_fused * 1e3 / batch,
        "three_stage_ms": t_staged * 1e3 / batch,
        "direct_ms": t_direct * 1e3 / batch,
        "speedup_vs_best": best_other / t_fused,
        "predicted_fused_wins": an.choose_algo(an.SKYLAKE_X, c, c, M + 2)
        in ("l3_fused", "fft_fused"),
    }


def main(batch: int = 2, layers=None):
    rows = []
    for tag, c, d in layers or (VGG_LAYERS + RESNET_LAYERS):
        r = bench_layer(tag, c, d, batch)
        rows.append(r)
        print(
            f"fig2_{r['tag']},{r['fused_ms'] * 1e3:.1f},"
            f"fused_ms/img={r['fused_ms']:.2f};3stage_ms/img="
            f"{r['three_stage_ms']:.2f};vendor_ms/img={r['direct_ms']:.2f};"
            f"speedup={r['speedup_vs_best']:.2f};"
            f"paper_predicts_win={r['predicted_fused_wins']}",
            flush=True,
        )
    return rows


if __name__ == "__main__":
    main()
