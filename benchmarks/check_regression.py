"""Serving-performance regression gate against a committed baseline.

Compares a fresh ``BENCH_serve_runtime.json`` (produced by
``serve_runtime_bench``) to the reference numbers committed under
``benchmarks/baselines/``: per net, the fused path's throughput must
not fall below ``(1 - tol) x`` baseline and its p95 end-to-end latency
must not rise above ``(1 + tol) x`` baseline.  When a
``BENCH_convserve.json`` artifact is present, its per-stage wall times
(``us`` per ExecProgram stage) are additionally gated against the
committed stage baseline -- that is the level at which a kernel
regression actually shows up (one stage going 3x while the net total
hides it in noise).  The bands are wide by design -- CI machines vary
run to run -- so a trip means a real regression (an accidental
cold-compile in the serving path, a cache that stopped reusing
transforms, a tile-engine block shape gone pathological), not noise.

    PYTHONPATH=src python -m benchmarks.serve_runtime_bench --smoke
    PYTHONPATH=src python -m benchmarks.check_regression --smoke

``--smoke`` checks the smoke-mode baseline (the CI pairing); without it
the full-mode baseline is checked when one is committed, otherwise the
gate reports nothing-to-check and passes.  ``--update`` rewrites the
baseline from the current bench artifact (commit the result when a
deliberate change moves the reference).  Exit status 1 on regression.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

BENCH_PATH = pathlib.Path("BENCH_serve_runtime.json")
CONVSERVE_PATH = pathlib.Path("BENCH_convserve.json")
BASELINE_DIR = pathlib.Path(__file__).resolve().parent / "baselines"

# wide bands: the gate is for order-of-magnitude breakage, not jitter
DEFAULT_THROUGHPUT_TOL = 0.6  # fail below 40% of baseline throughput
DEFAULT_P95_TOL = 2.0  # fail above 3x baseline p95
DEFAULT_STAGE_TOL = 2.0  # fail above 3x baseline per-stage us
DEFAULT_TRACE_TOL = 0.5  # traced run must keep >= 50% of untraced rps


def baseline_path(smoke: bool) -> pathlib.Path:
    return BASELINE_DIR / (
        "serve_runtime_smoke.json" if smoke else "serve_runtime_full.json"
    )


def stage_baseline_path(smoke: bool) -> pathlib.Path:
    return BASELINE_DIR / (
        "convserve_stages_smoke.json" if smoke else "convserve_stages_full.json"
    )


def extract_stages(bench: dict) -> dict:
    """Per net, each ExecProgram stage's measured wall time in us."""
    out = {}
    for net, entry in bench.get("nets", {}).items():
        stages = entry.get("stages")
        if not stages:
            continue
        out[net] = {
            st["label"]: st["us"] for st in stages if st.get("us") is not None
        }
    return out


def compare_stages(current: dict, baseline: dict, *, tol: float) -> list:
    """Per-stage regression findings (empty = pass).  A stage present in
    the baseline but absent from the bench is a finding: replans renaming
    stages should move the baseline deliberately, not silently shrink the
    gate."""
    findings = []
    for net, base_stages in baseline.items():
        cur_stages = current.get(net)
        if cur_stages is None:
            findings.append(f"{net}: in stage baseline but missing from bench")
            continue
        for label, base_us in base_stages.items():
            cur_us = cur_stages.get(label)
            if cur_us is None:
                findings.append(
                    f"{net}/{label}: in stage baseline but missing from bench"
                )
                continue
            ceil_us = base_us * (1.0 + tol)
            if cur_us > ceil_us:
                findings.append(
                    f"{net}/{label}: stage {cur_us:.0f} us > ceiling "
                    f"{ceil_us:.0f} (baseline {base_us:.0f}, tol {tol:.0%})"
                )
    return findings


def compare_overhead(bench: dict, *, tol: float) -> list:
    """Tracing-overhead findings (empty = pass).  Self-contained within
    one artifact: the serve bench replays the same seeded trace with the
    recorder on (``traced`` entry) and off (``fused``), so the gate
    needs no committed baseline -- the recorder-on run must keep at
    least ``(1 - tol) x`` the recorder-off throughput."""
    findings = []
    for net, entry in bench.get("nets", {}).items():
        traced = entry.get("traced") if isinstance(entry, dict) else None
        overhead = (traced or {}).get("tracing_overhead")
        if not overhead or overhead.get("ratio") is None:
            continue
        floor = 1.0 - tol
        print(
            f"check_regression: {net}: traced throughput "
            f"{overhead['traced_rps']:.1f} rps vs untraced "
            f"{overhead['untraced_rps']:.1f} "
            f"(ratio {overhead['ratio']:.2f}, floor {floor:.2f})"
        )
        if overhead["ratio"] < floor:
            findings.append(
                f"{net}: tracing overhead: traced run kept only "
                f"{overhead['ratio']:.0%} of untraced throughput "
                f"(floor {floor:.0%})"
            )
    return findings


def extract(bench: dict) -> dict:
    """The comparable core of a bench artifact: per net, the fused
    path's throughput and p95 e2e."""
    out = {}
    for net, entry in bench.get("nets", {}).items():
        fused = entry.get("fused")
        if not fused:
            continue
        out[net] = {
            "throughput_rps": fused["throughput_rps"],
            "p95_e2e_s": fused["e2e"]["p95_s"],
        }
    return out


def compare(current: dict, baseline: dict, *, tput_tol: float,
            p95_tol: float) -> list:
    """Regression findings (empty = pass).  Nets present only on one
    side are reported as findings too: a silently vanished net would
    otherwise make the gate vacuous."""
    findings = []
    for net, base in baseline.items():
        cur = current.get(net)
        if cur is None:
            findings.append(f"{net}: in baseline but missing from bench")
            continue
        t_floor = base["throughput_rps"] * (1.0 - tput_tol)
        if cur["throughput_rps"] < t_floor:
            findings.append(
                f"{net}: fused throughput {cur['throughput_rps']:.1f} rps "
                f"< floor {t_floor:.1f} (baseline "
                f"{base['throughput_rps']:.1f}, tol {tput_tol:.0%})"
            )
        p_ceil = base["p95_e2e_s"] * (1.0 + p95_tol)
        if cur["p95_e2e_s"] > p_ceil:
            findings.append(
                f"{net}: fused p95 e2e {cur['p95_e2e_s'] * 1e3:.2f} ms "
                f"> ceiling {p_ceil * 1e3:.2f} (baseline "
                f"{base['p95_e2e_s'] * 1e3:.2f}, tol {p95_tol:.0%})"
            )
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="check against the smoke-mode baseline (CI)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current artifact")
    ap.add_argument("--bench", default=None, metavar="PATH",
                    help="bench artifact (default BENCH_serve_runtime.json)")
    ap.add_argument("--tol-throughput", type=float,
                    default=DEFAULT_THROUGHPUT_TOL)
    ap.add_argument("--tol-p95", type=float, default=DEFAULT_P95_TOL)
    ap.add_argument("--tol-stage", type=float, default=DEFAULT_STAGE_TOL)
    ap.add_argument("--tol-trace", type=float, default=DEFAULT_TRACE_TOL)
    ap.add_argument("--convserve-bench", default=None, metavar="PATH",
                    help="convserve bench artifact for the per-stage gate "
                         "(default BENCH_convserve.json; skipped if absent)")
    args = ap.parse_args(argv)

    bench_path = pathlib.Path(args.bench) if args.bench else BENCH_PATH
    if not bench_path.exists():
        print(f"check_regression: no bench artifact at {bench_path} -- "
              f"run serve_runtime_bench first")
        return 1
    bench = json.loads(bench_path.read_text())
    if bool(bench.get("smoke")) != args.smoke:
        print(
            f"check_regression: {bench_path} is "
            f"{'a smoke' if bench.get('smoke') else 'a full'} artifact but "
            f"the gate was asked to check "
            f"{'smoke' if args.smoke else 'full'} mode"
        )
        return 1
    current = extract(bench)

    cs_path = pathlib.Path(
        args.convserve_bench) if args.convserve_bench else CONVSERVE_PATH
    cs_bench = None
    if cs_path.exists():
        cs_bench = json.loads(cs_path.read_text())
        if bool(cs_bench.get("smoke")) != args.smoke:
            cs_bench = None  # artifact from the other mode: not comparable
    cur_stages = extract_stages(cs_bench) if cs_bench else {}

    # baseline-free gate: traced vs untraced throughput within this
    # very artifact (the recorder-on A/B the serve bench replays)
    overhead_findings = compare_overhead(bench, tol=args.tol_trace)

    path = baseline_path(args.smoke)
    st_path = stage_baseline_path(args.smoke)
    if args.update:
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(
            {"bench": "serve_runtime", "smoke": args.smoke,
             "nets": current},
            indent=1, sort_keys=True,
        ) + "\n")
        print(f"check_regression: baseline updated at {path}")
        if cs_bench:
            st_path.write_text(json.dumps(
                {"bench": "convserve_stages", "smoke": args.smoke,
                 "nets": cur_stages},
                indent=1, sort_keys=True,
            ) + "\n")
            print(f"check_regression: stage baseline updated at {st_path}")
        return 0
    if not path.exists():
        print(f"check_regression: no committed baseline at {path} -- "
              f"only the self-contained tracing-overhead gate applies")
        if overhead_findings:
            for f in overhead_findings:
                print(f"REGRESSION: {f}")
            return 1
        return 0
    baseline = json.loads(path.read_text())

    findings = overhead_findings + compare(
        current, baseline["nets"],
        tput_tol=args.tol_throughput, p95_tol=args.tol_p95,
    )
    if st_path.exists() and cs_bench:
        st_baseline = json.loads(st_path.read_text())
        findings += compare_stages(
            cur_stages, st_baseline["nets"], tol=args.tol_stage,
        )
        for net in sorted(st_baseline["nets"]):
            for label, base_us in sorted(st_baseline["nets"][net].items()):
                cur_us = cur_stages.get(net, {}).get(label, float("nan"))
                print(
                    f"check_regression: {net}/{label}: {cur_us:.0f} us "
                    f"(baseline {base_us:.0f})"
                )
    for net in sorted(baseline["nets"]):
        base, cur = baseline["nets"][net], current.get(net, {})
        print(
            f"check_regression: {net}: throughput "
            f"{cur.get('throughput_rps', float('nan')):.1f} rps "
            f"(baseline {base['throughput_rps']:.1f}), p95 "
            f"{cur.get('p95_e2e_s', float('nan')) * 1e3:.2f} ms "
            f"(baseline {base['p95_e2e_s'] * 1e3:.2f})"
        )
    if findings:
        for f in findings:
            print(f"REGRESSION: {f}")
        return 1
    print("check_regression: within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
