"""Online serving-runtime benchmark: seeded open-loop traffic against
the deadline-aware scheduler + replica pool, fused vs unfused.

The offline convserve bench measures steady-state wave compute; this
one measures the *service*: requests arrive on a Poisson (and, in full
runs, a bursty) schedule, the scheduler forms deadline-flushed waves,
replicas share one pre-transformed kernel cache, and the telemetry
document -- throughput, p50/p95/p99 queue/compute/end-to-end latency,
wave/partial-wave/reject counters, cache hit rates, per-stage rollup --
lands in ``BENCH_serve_runtime.json``.  The same seeded trace replays
against a fused and an unfused compile of the same net, so the A/B
isolates cross-layer fusion's effect on tail latency under load.

    PYTHONPATH=src python -m benchmarks.serve_runtime_bench [--smoke]

``--smoke`` (the CI path) serves the tiny test net for a few hundred
milliseconds and asserts the runtime's invariants -- every request
served or reason-rejected, outputs matching the direct oracle, cache
hits >= misses after warmup -- rather than producing meaningful
numbers.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax.numpy as jnp

from benchmarks.common import row
from repro.configs.convnets import tiny_testnet, vgg_mixed_channel
from repro.convserve import Engine, init_weights, run_direct
from repro.convserve.obs import (
    FlightRecorder,
    Tracer,
    roofline_table,
    validate_chrome_trace,
    write_trace,
)
from repro.convserve.obs.roofline import SCHEMA_VERSION
from repro.convserve.runtime import (
    ReplicaPool,
    RuntimeConfig,
    ServeRuntime,
    burst_trace,
    make_images,
    poisson_trace,
)
from repro.core import analysis

BENCH_PATH = pathlib.Path("BENCH_serve_runtime.json")
TRACE_PATH = pathlib.Path("serve_smoke.trace.json")
REPORT_PATH = pathlib.Path("ROOFLINE_report.txt")


def _summarize(doc: dict, served: int, makespan_s: float) -> dict:
    """Flatten a runtime stats() document into the bench record."""
    lat = doc["latency"]

    def pct(name):
        h = lat.get(name, {})
        return {
            k: h.get(k, 0.0)
            for k in ("count", "mean_s", "p50_s", "p95_s", "p99_s", "max_s")
        }

    return {
        "served": served,
        "makespan_s": makespan_s,
        "throughput_rps": served / makespan_s if makespan_s > 0 else 0.0,
        "e2e": pct("e2e"),
        "queue_wait": pct("queue_wait"),
        "compute": pct("compute"),
        "counters": doc["counters"],
        "scheduler": doc["scheduler"],
        "pool": {
            k: doc["pool"][k]
            for k in ("replicas", "dispatched", "compiled_programs")
        },
        "cache": doc["cache"],
        "stages": doc.get("stages"),
        "roofline": doc.get("roofline"),
        "trace": doc.get("trace"),
    }


def _run_variant(
    spec,
    ws,
    cfg: RuntimeConfig,
    trace,
    images,
    *,
    fuse: bool,
    replicas: int,
    input_hw,
    profile_bucket=None,
    tracer=None,
    recorder=None,
) -> dict:
    """One seeded trace against one compile (fused or unfused) of the
    net: warm the per-bucket programs + kernel cache, replay the trace
    open-loop, return the summarized telemetry document."""
    engine = Engine(hw=analysis.SKYLAKE_X)
    pool = ReplicaPool.build(
        engine, spec, ws, n=replicas, input_hw=input_hw, fuse=fuse
    )
    rt = ServeRuntime(pool, cfg, tracer=tracer, recorder=recorder)
    try:
        # compile the steady-state programs on every replica and prepare
        # the shared transforms, so the trace measures serving, not jit
        # compiles -- and so the acceptance check "hits >= misses after
        # warmup" is about reuse, not cold starts
        rt.warmup()
        warm_misses = pool.cache.stats()["misses"]

        t0 = time.perf_counter()
        rt.play(trace, images)
        makespan = time.perf_counter() - t0
        served = sum(1 for a in trace if a.rid in rt.results)
        doc = rt.stats(profile_bucket=profile_bucket)
        out = _summarize(doc, served, makespan)
        out["cache_misses_after_warmup"] = (
            doc["cache"]["misses"] - warm_misses
        )
        out["results"] = {a.rid: rt.results.get(a.rid) for a in trace}
        return out
    finally:
        rt.pool.shutdown()


def _check_exactness(spec, ws, record: dict, trace, images) -> None:
    """Every served output must equal the net run on that image alone."""
    worst = 0.0
    for a in trace:
        y = record["results"].get(a.rid)
        if y is None:
            continue
        ref = run_direct(spec, ws, jnp.asarray(images[a.rid])[None])[0]
        rel = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
        worst = max(worst, rel)
    assert worst < 1e-3, f"served output diverged from oracle: {worst}"
    record["oracle_rel"] = worst


def bench_net(
    spec,
    *,
    cfg: RuntimeConfig,
    trace,
    replicas: int,
    input_hw,
    record: dict,
    check_outputs: bool = False,
    require_hits: bool = False,
    trace_path=None,
    report_path=None,
) -> None:
    ws = init_weights(spec, seed=0)
    c0 = spec.conv_layers()[0][1].c_in
    images = make_images(trace, c0, seed=1)
    entry = {}
    for fuse in (True, False):
        r = _run_variant(
            spec, ws, cfg, trace, images,
            fuse=fuse, replicas=replicas, input_hw=input_hw,
            profile_bucket=(max(cfg.buckets) if fuse else None),
        )
        if check_outputs:
            _check_exactness(spec, ws, r, trace, images)
        n_total = len(trace)
        rejected = sum(r["scheduler"]["rejected"].values())
        assert r["served"] + rejected == n_total, (
            f"{n_total - r['served'] - rejected} requests vanished "
            f"(served {r['served']}, rejected {rejected})"
        )
        if require_hits:
            c = r["cache"]
            assert c["hits"] >= c["misses"], (
                f"cache reuse regressed: {c['hits']} hits < "
                f"{c['misses']} misses"
            )
        del r["results"]  # arrays don't belong in the JSON artifact
        name = "fused" if fuse else "unfused"
        entry[name] = r
        print(
            row(
                f"serve_runtime/{spec.name}/{name}/p99_e2e",
                r["e2e"]["p99_s"] * 1e6,
                f"{r['throughput_rps']:.1f}rps;"
                f"{r['scheduler']['partial_waves']}partial",
            )
        )
        print(
            row(
                f"serve_runtime/{spec.name}/{name}/p50_e2e",
                r["e2e"]["p50_s"] * 1e6,
                f"hits{r['cache']['hits']};misses{r['cache']['misses']}",
            )
        )
    if trace_path is not None:
        _traced_rerun(
            spec, ws, cfg, trace, images, entry,
            replicas=replicas, input_hw=input_hw,
            trace_path=trace_path, report_path=report_path,
        )
    record[spec.name] = entry


def _traced_rerun(
    spec, ws, cfg, trace, images, entry, *,
    replicas, input_hw, trace_path, report_path,
) -> None:
    """The recorder-on A/B (observability overhead gate): replay the
    same seeded trace against the fused compile with a full-rate Tracer
    + FlightRecorder attached, export + validate the Chrome trace, and
    record traced-vs-untraced throughput so check_regression can gate
    the tracing overhead inside one artifact."""
    tracer = Tracer()
    recorder = FlightRecorder(tracer, path_prefix=None)
    r = _run_variant(
        spec, ws, cfg, trace, images,
        fuse=True, replicas=replicas, input_hw=input_hw,
        profile_bucket=max(cfg.buckets),
        tracer=tracer, recorder=recorder,
    )
    del r["results"]
    base_rps = entry["fused"]["throughput_rps"]
    r["tracing_overhead"] = {
        "untraced_rps": base_rps,
        "traced_rps": r["throughput_rps"],
        "ratio": r["throughput_rps"] / base_rps if base_rps > 0 else None,
    }
    r["recorder"] = recorder.stats()
    entry["traced"] = r

    n = write_trace(tracer, trace_path)
    problems = validate_chrome_trace(
        json.loads(pathlib.Path(trace_path).read_text())
    )
    assert not problems, f"invalid exported trace: {problems[:5]}"
    print(row(
        f"serve_runtime/{spec.name}/traced/throughput", 0.0,
        f"{r['throughput_rps']:.1f}rps;{n}events;"
        f"x{r['tracing_overhead']['ratio']:.2f}",
    ))
    print(f"# wrote {trace_path} ({n} events, valid)")
    rf = r.get("roofline")
    if report_path is not None and rf:
        report = roofline_table(rf["stages"], hw_name=rf["hw"]["name"])
        pathlib.Path(report_path).write_text(report + "\n")
        print(f"# wrote {report_path}")


def main(
    smoke: bool = False,
    requests: int = 120,
    rate_hz: float = 40.0,
    replicas: int = 2,
    seed: int = 7,
) -> None:
    record: dict = {}
    try:
        if smoke:
            spec = tiny_testnet(4)
            cfg = RuntimeConfig(
                max_batch=4, buckets=(16, 32), queue_depth=64,
                slo_s=0.25, service_est_s=0.01,
            )
            trace = poisson_trace(
                150.0, 40, seed=seed, sizes=(16, 24, 32),
            )
            bench_net(
                spec, cfg=cfg, trace=trace, replicas=replicas,
                input_hw=(16, 16), record=record,
                check_outputs=True, require_hits=True,
                trace_path=TRACE_PATH, report_path=REPORT_PATH,
            )
        else:
            spec = vgg_mixed_channel(3)
            cfg = RuntimeConfig(
                max_batch=8, buckets=(32, 64), queue_depth=128,
                slo_s=1.0, service_est_s=0.05,
            )
            trace = poisson_trace(
                rate_hz, requests, seed=seed, sizes=(32, 48, 64),
            )
            bench_net(
                spec, cfg=cfg, trace=trace, replicas=replicas,
                input_hw=(64, 64), record=record, require_hits=True,
                trace_path=TRACE_PATH, report_path=REPORT_PATH,
            )
            # flash-crowd arrivals against a shallow queue: admission
            # control must shed load with reason-coded rejects instead
            # of letting the queue (and the tail) grow without bound
            burst_spec = tiny_testnet(4)
            burst_cfg = RuntimeConfig(
                max_batch=4, buckets=(16, 32), queue_depth=8,
                slo_s=0.25, service_est_s=0.01,
            )
            bench_net(
                burst_spec,
                cfg=burst_cfg,
                trace=burst_trace(
                    60, burst=20, period_s=0.3, seed=seed,
                    sizes=(16, 24, 32),
                ),
                replicas=replicas, input_hw=(16, 16),
                record=record,
            )
            record["burst"] = record.pop(burst_spec.name)
    finally:
        # partial results still land on disk (and in the CI artifact)
        # when an assert fires mid-run
        BENCH_PATH.write_text(
            json.dumps(
                {"bench": "serve_runtime", "schema_version": SCHEMA_VERSION,
                 "smoke": smoke, "seed": seed, "nets": record},
                indent=1,
                sort_keys=True,
            )
        )
        print(f"# wrote {BENCH_PATH}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI invariants run: tiny net, asserts exactness "
                    "and cache reuse")
    ap.add_argument("--requests", type=int, default=120)
    ap.add_argument("--rate", type=float, default=40.0)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="output path (default BENCH_serve_runtime.json)")
    args = ap.parse_args()
    if args.json:
        BENCH_PATH = pathlib.Path(args.json)
    main(
        smoke=args.smoke, requests=args.requests, rate_hz=args.rate,
        replicas=args.replicas, seed=args.seed,
    )
