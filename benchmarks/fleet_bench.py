"""Fleet serving benchmark: a simulated million-user day + scale-out.

The serve-runtime bench measures one pool on a real clock for a few
seconds; this one measures the *fleet* on a simulated clock for a whole
day.  Requests follow a diurnal curve (quiet night, busy noon) with
flash-crowd bursts superimposed; the elastic pool grows and shrinks on
the autoscaler's telemetry signals; injected faults crash a replica,
slow another, and corrupt the shared kernel cache mid-trace.  Outputs
are computed by the real executors while a deterministic service model
charges simulated replica time, so the day runs in minutes of wall time
with exact latency stamps.  Everything lands in ``BENCH_fleet.json``:

  * the day: served/lost/rejected accounting, SLO attainment, latency
    percentiles, autoscaler events, fault + repair counters;
  * the scale-out curve: throughput and p95 vs fleet size N under a
    saturating trace (the headline: T(4) >= 2.5 x T(1));
  * exactness: sharded N-replica serving is bit-identical to the
    single-replica oracle on the same trace, ragged waves included.

    PYTHONPATH=src python -m benchmarks.fleet_bench [--smoke]

``--smoke`` (the CI path) compresses the day to a minute of simulated
time at reduced request count, keeps the crash fault enabled, and
asserts the invariants: exact accounting (admitted == served + lost,
total == admitted + rejected), reason-coded losses only, bit-exact
outputs vs the oracle, at least one autoscale-up, and the scale-out
floor.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from benchmarks.common import row
from repro.configs.convnets import tiny_testnet
from repro.convserve import Engine, init_weights
from repro.convserve.fleet import (
    AutoscalerConfig,
    ElasticPool,
    FixedServiceModel,
    FleetRuntime,
    LOSS_REASONS,
)
from repro.convserve.runtime import (
    RuntimeConfig,
    SimClock,
    burst_trace,
    diurnal_trace,
    make_images,
    merge_traces,
    poisson_trace,
)
from repro.core import analysis
from repro.runtime.fault import FaultPlan, ReplicaFault

BENCH_PATH = pathlib.Path("BENCH_fleet.json")

HW = analysis.HardwareModel(
    name="fleet-host", peak_flops=1e12, dram_bw=1e11, fast_shared_bw=5e11,
    fast_shared_bytes=1 << 30, private_bytes=1 << 24,
)


class ImageBank:
    """Bounded pool of seeded images cycled by rid: a million-user day
    must not hold a million tensors (the fleet's accounting is by rid;
    the pixels only need to be deterministic per rid, which cycling
    preserves)."""

    def __init__(self, trace, c: int, *, seed: int, slots: int = 256):
        sizes = sorted({(a.h, a.w) for a in trace})
        rng = np.random.default_rng(seed)
        per = max(1, slots // max(1, len(sizes)))
        self._pool = {
            hw: [
                (rng.standard_normal((hw[0], hw[1], c)) * 0.1).astype(
                    np.float32
                )
                for _ in range(per)
            ]
            for hw in sizes
        }

    def get(self, arrival) -> np.ndarray:
        bucket = self._pool[(arrival.h, arrival.w)]
        return bucket[arrival.rid % len(bucket)]


def _percentiles(doc: dict, name: str) -> dict:
    h = doc["latency"].get(name, {})
    return {
        k: h.get(k, 0.0)
        for k in ("count", "mean_s", "p50_s", "p95_s", "p99_s", "max_s")
    }


def _replay(rt: FleetRuntime, trace, bank: ImageBank, *,
            keep_results: bool = False) -> float:
    """Open-loop replay on the simulated clock; returns the simulated
    makespan.  Results are dropped as they land unless kept -- a
    day-scale run must not accumulate a day of output tensors."""
    clock = rt.clock
    t0 = clock.now()
    for a in trace:
        rt.run_until(t0 + a.t)
        rt.submit(
            bank.get(a), rid=a.rid,
            priority=a.priority, deadline_s=a.deadline_s,
        )
        if not keep_results and len(rt.results) > 4096:
            rt.results.clear()
    rt.drain()
    return clock.now() - t0


def _build_fleet(spec, ws, *, n, clock, service_model, fault_plan=None,
                 startup_s, probe_interval_s=None, shards=1,
                 max_replicas=8):
    engine = Engine(hw=HW)
    return ElasticPool.build(
        engine, spec, ws, n=n, clock=clock, input_hw=(16, 16),
        shards=shards, service_model=service_model, fault_plan=fault_plan,
        startup_s=startup_s, probe_interval_s=probe_interval_s,
        max_replicas=max_replicas,
    )


def _accounting(rt: FleetRuntime, total: int) -> dict:
    c = rt.stats()["counters"]
    served = c.get("images", 0)
    lost = c.get("lost_images", 0)
    admitted = c.get("admitted", 0)
    rejected = c.get("rejected", 0)
    assert served + lost == admitted, (
        f"{admitted - served - lost} admitted requests vanished "
        f"(served {served}, lost {lost}, admitted {admitted})"
    )
    assert admitted + rejected == total, (
        f"{total - admitted - rejected} submitted requests unaccounted "
        f"(admitted {admitted}, rejected {rejected}, total {total})"
    )
    for reason in rt.pool.losses:
        assert reason in LOSS_REASONS, f"uncoded loss reason {reason!r}"
    return {
        "total": total, "admitted": admitted, "served": served,
        "lost": lost, "rejected": rejected,
        "deadline_miss": c.get("deadline_miss", 0),
        "slo_attainment": (
            1.0 - c.get("deadline_miss", 0) / served if served else 0.0
        ),
    }


# ------------------------------------------------------------- the day


def bench_day(record: dict, *, smoke: bool, requests: int,
              seed: int) -> None:
    """The diurnal day with bursts, autoscaling, and injected faults."""
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=0)
    day_s = 60.0 if smoke else 86400.0
    mean_hz = requests / (day_s * 0.72)  # thinning mean ~ requests/day
    base = diurnal_trace(
        mean_hz, requests, seed=seed, depth=0.8, period_s=day_s,
        sizes=(12, 16), deadline_s=None,
    )
    # flash crowds riding the daily curve; the service model is sized so
    # the noon peak needs more replicas than the night trough (the full
    # day uses a slower model -- at a million requests the absolute rate
    # is low, and elasticity should come from the rate SHAPE, not from
    # making the simulated hardware comically slow elsewhere)
    if smoke:
        bursts = burst_trace(
            max(requests // 10, 40), burst=max(requests // 50, 20),
            period_s=day_s / 8, seed=seed + 1, sizes=(16,),
        )
        service = FixedServiceModel(base_s=0.004, per_image_s=0.002)
    else:
        bursts = burst_trace(
            requests // 10, burst=400,
            period_s=day_s / 250, seed=seed + 1, sizes=(16,),
        )
        service = FixedServiceModel(base_s=0.05, per_image_s=0.025)
    trace = merge_traces(base, bursts)
    trace = [a for a in trace if a.t <= day_s * 1.5]
    clock = SimClock()
    # the drill: one replica crashes on the morning ramp, the shared
    # cache is corrupted at noon, an afternoon replica goes slow
    faults = FaultPlan([
        ReplicaFault(t=day_s * 0.30, kind="crash", replica=0),
        ReplicaFault(t=day_s * 0.50, kind="cache_corrupt"),
        ReplicaFault(t=day_s * 0.65, kind="slow", replica=1, factor=8.0),
    ], clock=clock)
    pool = _build_fleet(
        spec, ws, n=2, clock=clock, service_model=service,
        fault_plan=faults, startup_s=day_s / 100,
        probe_interval_s=day_s / 20, max_replicas=6,
    )
    cfg = RuntimeConfig(
        max_batch=8, buckets=(16,), queue_depth=512,
        slo_s=0.5, service_est_s=service.service_s(
            _probe_wave(), shards=1
        ),
    )
    auto = AutoscalerConfig(
        min_replicas=2, max_replicas=6,
        tick_interval_s=day_s / 200, cooldown_s=day_s / 50,
        queue_high=6.0, queue_low=0.5, slack_min_s=0.05,
        admission_queue_per_replica=256.0,
    )
    rt = FleetRuntime(pool, cfg, clock=clock, autoscaler=auto)
    rt.warmup()
    bank = ImageBank(trace, 4, seed=1)
    wall0 = time.perf_counter()
    makespan = _replay(rt, trace, bank)
    wall = time.perf_counter() - wall0

    doc = rt.stats()
    acct = _accounting(rt, len(trace))
    p = doc["pool"]
    entry = {
        "requests": len(trace),
        "sim_day_s": day_s,
        "sim_makespan_s": makespan,
        "wall_s": wall,
        "speedup_over_realtime": makespan / wall if wall > 0 else 0.0,
        "accounting": acct,
        "e2e": _percentiles(doc, "e2e"),
        "queue_wait": _percentiles(doc, "queue_wait"),
        "pool": {
            k: p[k]
            for k in ("replicas", "states", "dispatches", "retries",
                      "orphaned", "losses", "grown", "retired", "failures",
                      "quarantines", "cache_repairs", "probe_mismatches")
        },
        "faults": p["faults"],
        "autoscaler": {
            k: doc["autoscaler"][k]
            for k in ("ticks", "scale_ups", "scale_downs", "replacements",
                      "events")
        },
        "counters": doc["counters"],
    }
    record["day"] = entry

    assert p["failures"] >= 1, "the crash fault never fired"
    assert p["cache_repairs"] >= 1, (
        "cache corruption was never detected + repaired"
    )
    auto_stats = doc["autoscaler"]
    scaled = auto_stats["scale_ups"] + auto_stats["replacements"]
    assert scaled >= 1, "the day never triggered a scale event"
    if smoke:
        assert acct["slo_attainment"] >= 0.95, (
            f"SLO attainment {acct['slo_attainment']:.4f} < 0.95"
        )
    print(row(
        "fleet/day/p95_e2e", entry["e2e"]["p95_s"] * 1e6,
        f"{acct['served']}srv;{acct['lost']}lost;"
        f"slo{acct['slo_attainment']:.3f}",
    ))
    print(row(
        "fleet/day/makespan", makespan * 1e6,
        f"x{entry['speedup_over_realtime']:.0f}rt;"
        f"{auto_stats['scale_ups']}up;{auto_stats['scale_downs']}down",
    ))


def _probe_wave():
    """A stand-in full wave for sizing the initial service estimate."""
    class _W:
        requests = [None] * 8
    return _W()


# ------------------------------------------------------ scale-out curve


def bench_scaleout(record: dict, *, smoke: bool, seed: int) -> None:
    """Throughput and p95 vs fleet size under one saturating trace."""
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=0)
    n_requests = 480 if smoke else 4000
    service = FixedServiceModel(base_s=0.004, per_image_s=0.002)
    curve = {}
    for n in (1, 2, 4):
        trace = poisson_trace(
            5000.0, n_requests, seed=seed, sizes=(16,),
        )
        clock = SimClock()
        pool = _build_fleet(
            spec, ws, n=n, clock=clock, service_model=service,
            startup_s=1.0, max_replicas=n,
        )
        cfg = RuntimeConfig(
            max_batch=8, buckets=(16,), queue_depth=n_requests,
            slo_s=None, service_est_s=0.02,
        )
        rt = FleetRuntime(pool, cfg, clock=clock)
        rt.warmup()
        bank = ImageBank(trace, 4, seed=1)
        makespan = _replay(rt, trace, bank)
        acct = _accounting(rt, len(trace))
        doc = rt.stats()
        curve[str(n)] = {
            "replicas": n,
            "served": acct["served"],
            "sim_makespan_s": makespan,
            "throughput_rps": acct["served"] / makespan,
            "e2e": _percentiles(doc, "e2e"),
        }
        print(row(
            f"fleet/scaleout/n{n}", makespan * 1e6,
            f"{curve[str(n)]['throughput_rps']:.0f}rps",
        ))
    t1 = curve["1"]["throughput_rps"]
    t4 = curve["4"]["throughput_rps"]
    curve["speedup_4v1"] = t4 / t1 if t1 else 0.0
    record["scaleout"] = curve
    assert t4 >= 2.5 * t1, (
        f"scale-out floor missed: T(4)={t4:.0f}rps < 2.5 x T(1)={t1:.0f}rps"
    )


# ------------------------------------------------------------ exactness


def bench_exactness(record: dict, *, seed: int) -> None:
    """Sharded 3-replica fleet vs single-replica oracle: bit-identical
    outputs on the same trace, ragged/partial waves included."""
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=0)
    trace = poisson_trace(
        45.0, 60, seed=seed, sizes=(8, 12, 16), deadline_s=0.08,
    )
    images = make_images(trace, 4, seed=1)
    service = FixedServiceModel(base_s=0.004, per_image_s=0.002)

    def serve(n, shards):
        clock = SimClock()
        pool = _build_fleet(
            spec, ws, n=n, clock=clock, service_model=service,
            startup_s=1.0, shards=shards, max_replicas=n,
        )
        cfg = RuntimeConfig(
            max_batch=4, buckets=(16,), queue_depth=128,
            slo_s=0.1, service_est_s=0.01,
        )
        rt = FleetRuntime(pool, cfg, clock=clock)
        rt.warmup([2, 4])
        return rt.play(trace, images), rt.stats()

    fleet_out, fleet_doc = serve(3, shards=4)
    oracle_out, _ = serve(1, shards=1)
    assert fleet_out.keys() == oracle_out.keys(), "served sets differ"
    mismatch = [
        rid for rid in oracle_out
        if not np.array_equal(fleet_out[rid], oracle_out[rid])
    ]
    assert not mismatch, (
        f"{len(mismatch)} outputs differ from the single-replica oracle "
        f"(first: rid {mismatch[0]})"
    )
    record["exactness"] = {
        "requests": len(trace),
        "replicas": 3,
        "shards": 4,
        "bit_exact": True,
        "partial_waves": fleet_doc["scheduler"]["partial_waves"],
    }
    assert fleet_doc["scheduler"]["partial_waves"] >= 1, (
        "exactness trace formed no ragged/partial waves -- the check "
        "is not exercising reassembly"
    )
    print(row("fleet/exactness/requests", len(trace) * 1.0, "bit-exact"))


def main(smoke: bool = False, requests: int = 0, seed: int = 11) -> None:
    record: dict = {}
    if requests <= 0:
        requests = 6000 if smoke else 1_000_000
    try:
        bench_exactness(record, seed=seed)
        bench_scaleout(record, smoke=smoke, seed=seed)
        bench_day(record, smoke=smoke, requests=requests, seed=seed)
    finally:
        # partial results still land on disk (and in the CI artifact)
        # when an assert fires mid-run
        BENCH_PATH.write_text(
            json.dumps(
                {"bench": "fleet", "smoke": smoke, "seed": seed, **record},
                indent=1, sort_keys=True,
            )
        )
        print(f"# wrote {BENCH_PATH}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI invariants run: compressed day, reduced "
                    "request count, crash fault enabled")
    ap.add_argument("--requests", type=int, default=0,
                    help="day-trace request count (default: 6000 smoke, "
                    "1,000,000 full)")
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="output path (default BENCH_fleet.json)")
    args = ap.parse_args()
    if args.json:
        BENCH_PATH = pathlib.Path(args.json)
    main(smoke=args.smoke, requests=args.requests, seed=args.seed)
