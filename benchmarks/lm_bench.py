"""LM-framework micro-benchmarks on CPU (reduced configs): train-step
throughput + decode latency for a representative arch of each family."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import lm_decode_step, lm_prefill
from repro.train.step import TrainConfig, init_train_state, make_train_step

from benchmarks.common import time_fn

ARCHS = ["qwen2.5-14b", "mamba2-1.3b", "moonshot-v1-16b-a3b"]


def main():
    for name in ARCHS:
        cfg = get_arch(name).reduced()
        tcfg = TrainConfig(remat=False, microbatches=1)
        state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        step = jax.jit(make_train_step(cfg, tcfg))
        b, s = 4, 64
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
            "mask": jnp.ones((b, s), jnp.float32),
        }
        t = time_fn(lambda st: step(st, batch)[0], state, warmup=1, max_iters=5)
        toks = b * s / t
        print(f"lm_train_{name},{t * 1e6:.0f},tokens_per_s={toks:.0f}", flush=True)

        p = state["params"]
        toks_p = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
        _, st = lm_prefill(p, cfg, toks_p, 64)
        dec = jax.jit(
            lambda pp, tok, pos, ss: lm_decode_step(pp, cfg, tok, pos, ss)
        )
        tok = jnp.asarray([1, 2], jnp.int32)
        t = time_fn(
            lambda: dec(p, tok, jnp.int32(16), st), warmup=1, max_iters=10
        )
        print(f"lm_decode_{name},{t * 1e6:.0f},ms_per_token={t * 1e3:.2f}",
              flush=True)


if __name__ == "__main__":
    main()
