"""Paper S5 analytical table: predicted utilisation / feasible R per layer
and machine -- printed next to the measured Fig2/Fig3 numbers."""

from __future__ import annotations

from repro.core import analysis as an


def main():
    for hw in (an.SKYLAKE_X, an.MOBILE_I7, an.TPU_V5E):
        print(f"# {hw.name}: CMR_dram={hw.cmr_dram:.0f} CMR_fast={hw.cmr_fast:.0f} "
              f"minR={an.min_r(hw)}")
        for c in (32, 64, 128, 256, 512):
            t = 7
            feas = an.fused_is_feasible(hw, c, c, t)
            rmax = an.max_r(hw, c, c, t)
            util = an.predicted_utilization(hw, min(rmax, 24), c, c, t, t - 2)
            algo = an.choose_algo(hw, c, c, t)
            print(
                f"analysis_{hw.name.split()[0]}_{c}ch,0.0,"
                f"fits_fast_level={feas};max_R={rmax};"
                f"pred_util={util:.2f};chosen_algo={algo}"
            )
    return 0


if __name__ == "__main__":
    main()
