"""R-parameter sweep (paper S4.1.2 / S5): wall-clock vs R on one layer.

Validates the paper's two-sided constraint story: small R starves the
matmul arithmetic intensity; past the fast-level bound, larger R stops
helping (and on a real cache machine begins to hurt)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fused import conv2d_l3_fused

from benchmarks.common import time_fn


def main(batch: int = 2):
    c, d = 64, 56
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((batch, d, d, c)) * 0.1, jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, c, c)) * 0.1, jnp.float32)
    base = None
    for r in (1, 2, 4, 8, 16, 24, 32, 64):
        fn = jax.jit(functools.partial(conv2d_l3_fused, pad=1, m=5, r_tiles=r))
        t = time_fn(fn, x, w)
        base = base or t
        print(f"r_sweep_R{r},{t * 1e6:.1f},speedup_vs_R1={base / t:.2f}",
              flush=True)


if __name__ == "__main__":
    main()
