"""Path-based sharding rule engine.

One rule set covers all 10 heterogeneous architectures: each param / cache /
batch leaf gets a PartitionSpec derived from its key path and shape, with a
divisibility fallback (a dim that does not divide its mesh axis is
replicated instead of erroring) -- the property that lets e.g. 8 KV heads
coexist with a 16-way model axis.

Parallelism mapping (DESIGN.md S6):
  model axis   TP: attention heads / MLP hidden / experts (EP) / vocab
  data axis    DP for batch; FSDP (ZeRO-3 via GSPMD) for params+optimizer
  pod axis     joins FSDP for params/optimizer (hierarchical reduction);
               joins DP for batch
Sequence/context parallelism: for batch-1 long-context decode the KV-cache
sequence dim is sharded over `model` (GSPMD lowers the sharded-softmax to
the flash-decoding split-K pattern).
"""

from __future__ import annotations

import re
from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

# leaf-name -> index (from the leaf's trailing dims) of the tensor-parallel
# dim.  Negative indices count from the end, so stacked (scan) leading repeat
# dims need no special-casing.
_TP_DIM_RULES: Tuple[Tuple[str, int], ...] = (
    # embeddings / heads: vocab dim
    (r"\bembed$", -2),
    (r"\blm_head$", -1),
    # attention projections: head dim outward
    (r"\bwq$", -1), (r"\bwk$", -1), (r"\bwv$", -1), (r"\bwo$", -2),
    (r"\bbq$", -1), (r"\bbk$", -1), (r"\bbv$", -1),
    # MLA
    (r"\bwq_a$", -1), (r"\bwq_b$", -1),
    (r"\bwkv_a$", -1), (r"\bwk_b$", -1), (r"\bwv_b$", -1),
    # dense MLP
    (r"\bw1$", -1), (r"\bw3$", -1), (r"\bw2$", -2),
    (r"\bshared_w1$", -1), (r"\bshared_w3$", -1), (r"\bshared_w2$", -2),
    # mamba
    (r"\bin_proj$", -1), (r"\bout_proj$", -2), (r"\bconv_w$", -1),
    (r"\bconv_b$", -1),
    # MTP projection
    (r"\bproj$", -1),
)

# leaves that must stay replicated (small / f32-critical)
_REPLICATED = re.compile(
    r"(norm|ln1|ln2|ln_cross|router|dt_bias|A_log|\bD$|scale|lora_|count)"
)

# FSDP: shard the largest remaining dim over data (and pod, if present)
_FSDP_MIN_SIZE = 2**16  # don't bother sharding tiny tensors


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """PartitionSpec for a parameter leaf."""
    spec = [None] * len(shape)
    model = _axis_size(mesh, "model") if "model" in mesh.shape else 1

    if not _REPLICATED.search(path):
        for pat, dim in _TP_DIM_RULES:
            if re.search(pat, path):
                d = dim % len(shape) if dim < 0 else dim
                if len(shape) > d >= 0 and shape[d] % model == 0 and model > 1:
                    spec[d] = "model"
                break
        # MoE expert tables: expert dim is the first non-stacked dim
        if re.search(r"moe/(w1|w3|w2)$", path) or (
            re.search(r"\b(w1|w3|w2)$", path) and len(shape) >= 3
        ):
            # (..., E, D, F): put model on E instead (EP)
            e_dim = len(shape) - 3
            if shape[e_dim] % model == 0 and model > 1:
                spec = [None] * len(shape)
                spec[e_dim] = "model"

    # FSDP over (pod, data) on the largest remaining dim
    fsdp = _fsdp_axes(mesh)
    if fsdp and np.prod(shape) >= _FSDP_MIN_SIZE:
        fsdp_size = int(np.prod([_axis_size(mesh, a) for a in fsdp]))
        dims = sorted(range(len(shape)), key=lambda i: -shape[i])
        for d in dims:
            if spec[d] is None and shape[d] % fsdp_size == 0:
                spec[d] = fsdp if len(fsdp) > 1 else fsdp[0]
                break
    return P(*spec)


def cache_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """PartitionSpec for a decode-cache leaf.

    Layouts (with a leading stacked repeat dim):
      kv      (rep, B, len, Hkv, hd)   B->data; Hkv->model else len->model
      pos     (rep, B, len)
      mla     (rep, B, len, rank)      B->data; len->model
      conv    (rep, B, K-1, d_xbc)     B->data; d_xbc->model
      ssm     (rep, B, H, P, N)        B->data; H->model
    """
    spec = [None] * len(shape)
    model = _axis_size(mesh, "model") if "model" in mesh.shape else 1
    data_axes = _fsdp_axes(mesh)
    data_size = int(np.prod([_axis_size(mesh, a) for a in data_axes])) if data_axes else 1

    # batch dim: index 1 when stacked (rep leading), else 0
    b_dim = 1 if len(shape) >= 3 else 0
    if data_axes and shape[b_dim] % data_size == 0:
        spec[b_dim] = data_axes if len(data_axes) > 1 else data_axes[0]
    elif "data" in mesh.shape and shape[b_dim] % _axis_size(mesh, "data") == 0:
        spec[b_dim] = "data"

    if model > 1:
        if path.endswith("/k") or path.endswith("/v"):
            h_dim, len_dim = len(shape) - 2, len(shape) - 3
            if shape[h_dim] % model == 0:
                spec[h_dim] = "model"
            elif shape[len_dim] % model == 0:
                spec[len_dim] = "model"  # context parallelism
        elif path.endswith("/pos"):
            pass  # positions stay replicated along model
        elif path.endswith("/c_kv") or path.endswith("/k_rope"):
            len_dim = len(shape) - 2
            if shape[len_dim] % model == 0:
                spec[len_dim] = "model"
        elif path.endswith("/conv"):
            if shape[-1] % model == 0:
                spec[-1] = "model"
        elif path.endswith("/ssm"):
            h_dim = len(shape) - 3
            if shape[h_dim] % model == 0:
                spec[h_dim] = "model"
    return P(*spec)


def batch_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Input batch: batch dim over (pod, data) when divisible."""
    spec = [None] * len(shape)
    axes = _fsdp_axes(mesh)
    if not shape:
        return P()
    size = int(np.prod([_axis_size(mesh, a) for a in axes])) if axes else 1
    if axes and shape[0] % size == 0:
        spec[0] = axes if len(axes) > 1 else axes[0]
    elif "data" in mesh.shape and shape[0] % _axis_size(mesh, "data") == 0:
        spec[0] = "data"
    return P(*spec)


def _tree_shardings(tree: Pytree, mesh: Mesh, spec_fn) -> Pytree:
    def leaf(path, x):
        return NamedSharding(mesh, spec_fn(_path_str(path), x.shape, mesh))

    return jax.tree_util.tree_map_with_path(leaf, tree)


def _tree_bytes(tree: Pytree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def shard_params(shapes: Pytree, mesh: Mesh) -> Pytree:
    """Adaptive FSDP: trees small enough to replicate per chip skip the
    data-axis sharding entirely (no per-layer all-gather storms for models
    that fit -- EXPERIMENTS.md SPerf gemma3 iteration)."""
    from repro.models.runtime_flags import FLAGS

    if _tree_bytes(shapes) < FLAGS.fsdp_min_tree_bytes:
        return _tree_shardings(shapes, mesh, _tp_only_spec)
    return _tree_shardings(shapes, mesh, param_spec)


def shard_params_for_inference(shapes: Pytree, mesh: Mesh) -> Pytree:
    """Inference param sharding: there are no optimizer states to amortise,
    so data-axis (FSDP) sharding only buys per-layer all-gathers at decode
    (the collective-bound decode cells in EXPERIMENTS.md SPerf-beyond).
    Use TP-only whenever the TP-sharded tree fits per chip; fall back to
    2-D sharding for models that don't (deepseek-v3)."""
    from repro.models.runtime_flags import FLAGS

    if FLAGS.fsdp_min_tree_bytes == 0:  # baseline config: FSDP everything
        return _tree_shardings(shapes, mesh, param_spec)
    model = mesh.shape.get("model", 1)
    tp_bytes_per_chip = _tree_bytes(shapes) / max(model, 1)
    if tp_bytes_per_chip <= 6 << 30:
        return _tree_shardings(shapes, mesh, _tp_only_spec)
    return _tree_shardings(shapes, mesh, param_spec)


def _tp_only_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """param_spec without the FSDP pass (TP sharding only)."""
    spec = [None] * len(shape)
    model = _axis_size(mesh, "model") if "model" in mesh.shape else 1
    if not _REPLICATED.search(path):
        for pat, dim in _TP_DIM_RULES:
            if re.search(pat, path):
                d = dim % len(shape) if dim < 0 else dim
                if len(shape) > d >= 0 and shape[d] % model == 0 and model > 1:
                    spec[d] = "model"
                break
        if re.search(r"moe/(w1|w3|w2)$", path) or (
            re.search(r"\b(w1|w3|w2)$", path) and len(shape) >= 3
        ):
            e_dim = len(shape) - 3
            if shape[e_dim] % model == 0 and model > 1:
                spec = [None] * len(shape)
                spec[e_dim] = "model"
    return P(*spec)


def shard_cache(shapes: Pytree, mesh: Mesh) -> Pytree:
    return _tree_shardings(shapes, mesh, cache_spec)


def shard_batch(shapes: Pytree, mesh: Mesh) -> Pytree:
    return _tree_shardings(shapes, mesh, batch_spec)


def replicated(tree: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
