"""Compressed gradient collectives (shard_map) with error feedback.

int8 block-quantised all-reduce: each worker quantises its local gradient
shard to int8 (per-block f32 scales), all-reduces the int8 payload (summed
in int32), dequantises, and keeps the quantisation residual locally, adding
it to the next step's gradient (error feedback) -- bandwidth drops ~4x
vs f32 / ~2x vs bf16 at negligible quality cost.  Used on the `data`/`pod`
gradient-reduction axes; opt-in via TrainConfig in examples/train_lm.py.
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

# jax.shard_map graduated from jax.experimental in newer releases
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

Pytree = Any

_BLOCK = 256


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-20)).astype(jnp.int8)
    return q, scale[:, 0]


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape, size) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    return flat[:size].reshape(shape)


def compressed_psum(
    grad: jnp.ndarray, residual: jnp.ndarray, axis_name
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-tensor int8 all-reduce with error feedback, inside shard_map.

    Returns (mean gradient, new residual)."""
    g = grad.astype(jnp.float32) + residual
    flat = g.reshape(-1)
    pad = (-flat.size) % _BLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
    # agree on one scale per block across workers (pmax of f32 scales is
    # tiny traffic), then the int8 payload psum aggregates EXACTLY
    local_max = jnp.max(jnp.abs(blocks), axis=1)
    scale = jax.lax.pmax(local_max, axis_name) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.round(blocks / scale[:, None]).astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    red = (qsum.astype(jnp.float32) / n) * scale[:, None]
    g_red = red.reshape(-1)[: g.size].reshape(g.shape)
    # error feedback: this worker's own quantisation error feeds step t+1
    deq_local = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    new_residual = g - deq_local[: g.size].reshape(g.shape)
    return g_red, new_residual


def _requant_roundtrip(g: jnp.ndarray) -> jnp.ndarray:
    q, scale = _quantize(g)
    return _dequantize(q, scale, g.shape, g.size)


def make_compressed_allreduce(mesh: Mesh, axis: str = "data"):
    """Tree-level compressed mean-all-reduce over `axis` via shard_map.

    Inputs are sharded over `axis` on their leading dim (one slice per
    worker = that worker's local gradient); every worker's output slice is
    the compressed mean, residuals stay worker-local (error feedback).
    """

    def one(g, r):
        fn = _shard_map(
            functools.partial(compressed_psum, axis_name=axis),
            mesh=mesh,
            in_specs=(P(axis), P(axis)),
            out_specs=(P(axis), P(axis)),
        )
        return fn(g, r)

    def allreduce(grads: Pytree, residuals: Pytree) -> Tuple[Pytree, Pytree]:
        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = treedef.flatten_up_to(residuals)
        out_g, out_r = [], []
        for g, r in zip(flat_g, flat_r):
            gg, rr = one(g, r)
            out_g.append(gg)
            out_r.append(rr)
        return (
            jax.tree.unflatten(treedef, out_g),
            jax.tree.unflatten(treedef, out_r),
        )

    return allreduce


def init_residuals(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
