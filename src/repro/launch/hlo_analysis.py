"""Post-SPMD HLO analysis: collective-traffic accounting + roofline terms.

cost_analysis() gives FLOPs and bytes but NOT collective traffic; we parse
the compiled HLO text and sum operand sizes of every collective op
(DESIGN.md S7).  Async pairs (-start/-done) are counted once via -start.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_WHILE_RE = re.compile(
    r"while\(.*?\)(?:.*?condition=%?([\w.\-]+))(?:.*?body=%?([\w.\-]+))", re.S
)
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALLSITE_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)=\{?%?([\w.\-,%\s]+)\}?")


def _split_computations(hlo_text: str):
    comps: Dict[str, list] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.lstrip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def _line_collective(line: str):
    """(kind, bytes) if the line is a collective instruction, else None."""
    if "-done" in line:
        return None
    for kind in _COLLECTIVES:
        idx = line.find(f" {kind}(")
        is_start = False
        if idx < 0:
            idx = line.find(f" {kind}-start(")
            is_start = idx >= 0
        if idx < 0:
            continue
        result_sizes = [
            _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(line[:idx])
        ]
        if not result_sizes:
            return None
        nbytes = max(result_sizes) if is_start else sum(result_sizes)
        if kind == "reduce-scatter":
            m = _GROUPS_RE.search(line)
            if m:
                nbytes *= int(m.group(2))
        return kind, nbytes
    return None


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-device collective traffic from post-SPMD HLO text.

    Operand sizes are reconstructed from RESULT types (optimised HLO prints
    operands as bare %names): all-reduce / all-to-all / collective-permute
    move ~result bytes, all-gather receives ~result bytes, reduce-scatter
    sends ~result * group_size.  Collectives inside `while` bodies (layer
    scans, KV-chunk scans) are multiplied by the loop trip count, parsed
    from the loop-condition constant; nested loops multiply.
    """
    comps, entry = _split_computations(hlo_text)
    if entry is None:  # fall back: flat scan, no trip-count awareness
        comps, entry = {"<all>": hlo_text.splitlines()}, "<all>"

    def trip_count(cond_name: str) -> int:
        consts = [
            int(c)
            for line in comps.get(cond_name, ())
            for c in _CONST_RE.findall(line)
        ]
        return max(consts) if consts else 1

    by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count: Dict[str, int] = {k: 0 for k in _COLLECTIVES}

    def walk(comp: str, mult: int, seen):
        if comp not in comps or comp in seen:
            return
        seen = seen | {comp}
        for line in comps[comp]:
            hit = _line_collective(line)
            if hit:
                kind, nbytes = hit
                by_kind[kind] += nbytes * mult
                count[kind] += mult
            if " while(" in line:
                m_body = re.search(r"body=%?([\w.\-]+)", line)
                m_trip = _TRIP_RE.search(line)  # XLA's own trip analysis
                m_cond = re.search(r"condition=%?([\w.\-]+)", line)
                if m_body:
                    if m_trip:
                        n = int(m_trip.group(1))
                    else:
                        n = trip_count(m_cond.group(1)) if m_cond else 1
                    walk(m_body.group(1), mult * max(n, 1), seen)
            else:
                for m in re.finditer(
                    r"(?:calls|to_apply)=%?([\w.\-]+)", line
                ):
                    walk(m.group(1), mult, seen)
                m = re.search(r"branch_computations=\{([^}]*)\}", line)
                if m:
                    for b in m.group(1).split(","):
                        walk(b.strip().lstrip("%"), mult, seen)

    walk(entry, 1, frozenset())
    return CollectiveStats(by_kind, count)


# ---------------------------------------------------------------------------
# trip-count-aware FLOP / byte accounting from HLO text.
#
# XLA's HloCostAnalysis on the CPU backend counts while bodies ONCE
# (verified empirically), which under-counts scanned models by the layer
# count.  We therefore do our own pass: a symbol table of result shapes per
# instruction lets us compute dot FLOPs (2 * prod(result) * K) and per-op
# memory traffic (operands + result at fusion granularity), multiplied by
# XLA's own known_trip_count on each while loop.
# ---------------------------------------------------------------------------

_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"^((?:\([^=]*?\)|[a-z0-9\[\],{}]+)\s+)?([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

# ops that move no real memory
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "reshape", "copy", "after-all", "partition-id",
    "iota", "broadcast",
}


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    coll_bytes: float
    peak_arg_bytes: float = 0.0


def _parse_instr(line: str):
    """-> (name, [(dtype, dims)], opname, [operand names]) or None."""
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rhs = m.group(1), m.group(2)
    shapes = []
    # result type: everything before the op token
    op_m = re.match(r"^(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+([\w\-]+)", rhs)
    if not op_m:
        return None
    type_str, op = op_m.group(1), op_m.group(2)
    shapes = _SHAPE_RE.findall(type_str)
    # operands: %names inside the first (...) after the op name
    paren = rhs.find("(", op_m.end(2) - len(op_m.group(2)) + len(op_m.group(2)))
    operands = []
    if paren >= 0:
        depth, j = 0, paren
        while j < len(rhs):
            if rhs[j] == "(":
                depth += 1
            elif rhs[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        operands = _OPERANDS_RE.findall(rhs[paren : j + 1])
    return name, shapes, op, operands, rhs


def hlo_cost(hlo_text: str) -> HloCost:
    comps, entry = _split_computations(hlo_text)
    if entry is None:
        return HloCost(0.0, 0.0, 0.0)

    # global symbol table: instruction name -> (total bytes, first dims)
    sym_bytes: Dict[str, int] = {}
    sym_dims: Dict[str, tuple] = {}
    parsed: Dict[str, list] = {}
    slicing_comps = set()  # fused computations that dynamic-slice an operand
    for cname, lines in comps.items():
        plist = []
        for line in lines:
            if " dynamic-slice(" in line:
                slicing_comps.add(cname)
            pi = _parse_instr(line)
            if pi is None:
                continue
            name, shapes, op, operands, rhs = pi
            total = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
            sym_bytes[name] = total
            if shapes:
                dt, dims = shapes[0]
                sym_dims[name] = tuple(int(x) for x in dims.split(",") if x)
            plist.append((name, shapes, op, operands, rhs))
        parsed[cname] = plist

    flops = 0.0
    byts = 0.0

    def io_bytes(name, op, operands, rhs) -> int:
        """Memory traffic of one op, honouring in-place aliasing and sliced
        reads: dynamic-update-slice fusions move only the written slice;
        fusions that dynamic-slice an operand (e.g. the per-iteration layer
        slice of scan-stacked weights) read only result-sized bytes from
        the big operand, not the whole stacked tensor."""
        res = sym_bytes.get(name, 0)
        ops_b = [sym_bytes.get(o, 0) for o in operands]
        if op == "dynamic-update-slice" or (
            op == "fusion"
            and ("dynamic-update-slice" in name or "dynamic_update_slice" in name)
        ):
            if ops_b and max(ops_b) >= res > 0:
                # result aliases the largest operand in place: traffic is
                # the written slice, read + written (2x the small operands)
                return 2 * (sum(ops_b) - max(ops_b))
        if op == "dynamic-slice":
            return 2 * res  # reads only the sliced region
        if op == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", rhs)
            if m and m.group(1) in slicing_comps:
                return res + sum(min(b, res) for b in ops_b)
        return res + sum(ops_b)

    def op_flops(name, shapes, op, operands, rhs) -> float:
        if op == "dot":
            res_elems = 1
            for dt, dims in shapes:
                for d in dims.split(","):
                    if d:
                        res_elems *= int(d)
            k = 1
            m = _LHS_CONTRACT_RE.search(rhs)
            if m and operands:
                lhs_dims = sym_dims.get(operands[0], ())
                for c in m.group(1).split(","):
                    if c and int(c) < len(lhs_dims):
                        k *= lhs_dims[int(c)]
            return 2.0 * res_elems * k
        if op == "convolution":
            res_elems = 1
            for dt, dims in shapes:
                for d in dims.split(","):
                    if d:
                        res_elems *= int(d)
            # window size x input features from the rhs operand (kernel)
            kdims = sym_dims.get(operands[1], ()) if len(operands) > 1 else ()
            import numpy as _np

            kelems = int(_np.prod(kdims)) if kdims else 1
            kout = kdims[-1] if kdims else 1  # HWIO output features
            return 2.0 * res_elems * max(kelems // max(kout, 1), 1)
        return 0.0

    def walk(comp: str, mult: float, seen, count_bytes: bool):
        nonlocal flops, byts
        if comp not in parsed or comp in seen:
            return
        seen = seen | {comp}
        for name, shapes, op, operands, rhs in parsed[comp]:
            flops += mult * op_flops(name, shapes, op, operands, rhs)
            if count_bytes and op not in _FREE_OPS:
                byts += mult * io_bytes(name, op, operands, rhs)
            if op == "while":
                m_body = re.search(r"body=%?([\w.\-]+)", rhs)
                m_trip = _TRIP_RE.search(rhs)
                n = int(m_trip.group(1)) if m_trip else 1
                if m_body:
                    walk(m_body.group(1), mult * max(n, 1), seen, count_bytes)
            elif op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", rhs)
                if m:  # flops inside fusions count; bytes don't
                    walk(m.group(1), mult, seen, False)
            elif op == "conditional":
                m = re.search(r"branch_computations=\{([^}]*)\}", rhs)
                if m:
                    for b in m.group(1).split(","):
                        walk(b.strip().lstrip("%"), mult, seen, count_bytes)
            elif op == "call":
                m = re.search(r"to_apply=%?([\w.\-]+)", rhs)
                if m:
                    walk(m.group(1), mult, seen, count_bytes)

    walk(entry, 1.0, frozenset(), True)
    coll = collective_bytes(hlo_text)
    return HloCost(flops=flops, bytes=byts, coll_bytes=float(coll.total_bytes))


def hlo_top_offenders(hlo_text: str, k: int = 20):
    """Ranked (mult x cost) instructions -- the dry-run 'profile'.

    Returns {"flops": [(cost, mult, line)], "bytes": [...]} -- the tool the
    SPerf hypothesis loop reads instead of a wall-clock trace (DESIGN.md S7).
    """
    comps, entry = _split_computations(hlo_text)
    if entry is None:
        return {"flops": [], "bytes": []}
    sym_bytes: Dict[str, int] = {}
    sym_dims: Dict[str, tuple] = {}
    parsed: Dict[str, list] = {}
    slicing_comps = set()
    for cname, lines in comps.items():
        plist = []
        for line in lines:
            if " dynamic-slice(" in line:
                slicing_comps.add(cname)
            pi = _parse_instr(line)
            if pi is None:
                continue
            name, shapes, op, operands, rhs = pi
            sym_bytes[name] = sum(_shape_bytes(dt, d) for dt, d in shapes)
            if shapes:
                dt, dims = shapes[0]
                sym_dims[name] = tuple(int(x) for x in dims.split(",") if x)
            plist.append((name, shapes, op, operands, rhs))
        parsed[cname] = plist

    fl, by = [], []

    def dot_flops(shapes, operands, rhs):
        res_elems = 1
        for dt, dims in shapes:
            for d in dims.split(","):
                if d:
                    res_elems *= int(d)
        kk = 1
        m = _LHS_CONTRACT_RE.search(rhs)
        if m and operands:
            lhs_dims = sym_dims.get(operands[0], ())
            for c in m.group(1).split(","):
                if c and int(c) < len(lhs_dims):
                    kk *= lhs_dims[int(c)]
        return 2.0 * res_elems * kk

    def walk(comp, mult, seen, count_bytes):
        if comp not in parsed or comp in seen:
            return
        seen = seen | {comp}
        for name, shapes, op, operands, rhs in parsed[comp]:
            if op == "dot":
                fl.append((mult * dot_flops(shapes, operands, rhs), mult,
                           f"{comp}: {name} = {rhs[:160]}"))
            if count_bytes and op not in _FREE_OPS:
                res = sym_bytes.get(name, 0)
                ops_b = [sym_bytes.get(o, 0) for o in operands]
                if (
                    op == "dynamic-update-slice"
                    or (op == "fusion" and ("dynamic-update-slice" in name
                                            or "dynamic_update_slice" in name))
                ) and ops_b and max(ops_b) >= res > 0:
                    io = 2 * (sum(ops_b) - max(ops_b))
                elif op == "dynamic-slice":
                    io = 2 * res
                elif op == "fusion" and (
                    (mm := re.search(r"calls=%?([\w.\-]+)", rhs))
                    and mm.group(1) in slicing_comps
                ):
                    io = res + sum(min(b, res) for b in ops_b)
                else:
                    io = res + sum(ops_b)
                by.append((mult * io, mult, f"{comp}: {name} [{op}] = {rhs[:160]}"))
            if op == "while":
                m_body = re.search(r"body=%?([\w.\-]+)", rhs)
                m_trip = _TRIP_RE.search(rhs)
                n = int(m_trip.group(1)) if m_trip else 1
                if m_body:
                    walk(m_body.group(1), mult * max(n, 1), seen, count_bytes)
            elif op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", rhs)
                if m:
                    walk(m.group(1), mult, seen, False)
            elif op == "conditional":
                m = re.search(r"branch_computations=\{([^}]*)\}", rhs)
                if m:
                    for b in m.group(1).split(","):
                        walk(b.strip().lstrip("%"), mult, seen, count_bytes)
            elif op == "call":
                m = re.search(r"to_apply=%?([\w.\-]+)", rhs)
                if m:
                    walk(m.group(1), mult, seen, count_bytes)

    walk(entry, 1.0, frozenset(), True)
    fl.sort(key=lambda x: -x[0])
    by.sort(key=lambda x: -x[0])
    return {"flops": fl[:k], "bytes": by[:k]}


# TPU v5e hardware constants (per the brief)
PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


@dataclasses.dataclass
class Roofline:
    chips: int
    hlo_flops: float  # GLOBAL (all chips)
    hlo_bytes: float  # GLOBAL
    coll_bytes: float  # GLOBAL
    model_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: remat/redundancy waste detector."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU upper bound: useful compute time / bound time."""
        if self.t_bound <= 0:
            return 0.0
        return (self.model_flops / (self.chips * PEAK_FLOPS)) / self.t_bound

    def as_dict(self) -> Dict[str, float]:
        return {
            "chips": self.chips,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_train(cfg, shape) -> float:
    """6 N D (dense) / 6 N_active D (MoE) with N = active params, D = tokens."""
    n = active_param_count(cfg)
    tokens = shape.global_batch * shape.seq_len
    return 6.0 * n * tokens


def model_flops_infer(cfg, shape, *, decode: bool) -> float:
    n = active_param_count(cfg)
    tokens = shape.global_batch * (1 if decode else shape.seq_len)
    return 2.0 * n * tokens


def active_param_count(cfg) -> float:
    """Active (per-token) parameter count, estimated from the config."""
    d = cfg.d_model
    n = 0.0
    # embeddings (active at head, counted once)
    n += cfg.vocab_size * d
    per_layer = 0.0
    if cfg.family == "ssm" or cfg.ssm is not None:
        s = cfg.ssm
        d_inner = s.expand * d
        h = d_inner // s.head_dim
        d_xbc = d_inner + 2 * s.n_groups * s.d_state
        mamba = d * (d_inner + d_xbc + h) + d_inner * d
        if cfg.family == "ssm":
            per_layer = mamba
        else:  # hybrid: mamba blocks + amortised shared attn
            hd = cfg.resolved_head_dim
            attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd + cfg.n_heads * hd * d
            mlp = 3 * d * cfg.d_ff
            per_layer = mamba + (attn + mlp) / max(cfg.shared_attn_period or 6, 1)
    else:
        if cfg.mla:
            m = cfg.mla
            qd = m.qk_nope_dim + m.qk_rope_dim
            attn = (
                d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qd
                + d * (m.kv_lora_rank + m.qk_rope_dim)
                + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.v_head_dim)
                + cfg.n_heads * m.v_head_dim * d
            )
        else:
            hd = cfg.resolved_head_dim
            attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd + cfg.n_heads * hd * d
        if cfg.moe:
            active_e = cfg.moe.top_k + cfg.moe.n_shared
            mlp = 3 * d * cfg.d_ff * active_e
        else:
            mlp = 3 * d * cfg.d_ff
        per_layer = attn + mlp
    n += per_layer * cfg.n_layers
    if cfg.is_encoder_decoder:
        hd = cfg.resolved_head_dim
        enc = cfg.encoder_layers * (
            d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
            + cfg.n_heads * hd * d + 3 * d * cfg.d_ff
        )
        n += enc
    return n
