import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (the two lines above MUST precede any jax import)
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b \
        --shape train_4k [--multi-pod] [--out results/dryrun]

For each cell this lowers the appropriate step (train_step for train shapes,
prefill/decode for inference shapes) against the production mesh with
ShapeDtypeStruct inputs (no allocation), compiles it, and records:
memory_analysis (fits-per-chip proof), cost_analysis (FLOPs/bytes), and the
collective traffic parsed from the post-SPMD HLO -- the inputs to
EXPERIMENTS.md SDry-run and SRoofline.
"""

import argparse
import json
import pathlib
import sys
import time
import traceback

import jax

from repro.configs.base import SHAPES, cell_is_defined, get_arch, list_archs
from repro.distributed import sharding as shd
from repro.launch import specs as S
from repro.launch.hlo_analysis import (
    Roofline,
    collective_bytes,
    hlo_cost,
    model_flops_infer,
    model_flops_train,
)
from repro.launch.mesh import make_production_mesh


def _first(d, *keys, default=0.0):
    for k in keys:
        if k in d and d[k]:
            return float(d[k])
    return default


def lower_cell(arch_name: str, shape_name: str, *, multi_pod: bool):
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    with mesh:
        if shape.kind == "train":
            tcfg = S.train_config_for(cfg)
            state_shapes = S.train_state_shapes(cfg, tcfg)
            batch = S.batch_specs(cfg, shape)
            state_sh = {
                "params": shd.shard_params(state_shapes["params"], mesh),
                "opt": {
                    "m": shd.shard_params(state_shapes["opt"]["m"], mesh),
                    "v": shd.shard_params(state_shapes["opt"]["v"], mesh),
                    "count": shd.replicated(
                        state_shapes["opt"]["count"], mesh
                    ),
                },
                "step": shd.replicated(state_shapes["step"], mesh),
            }
            batch_sh = shd.shard_batch(batch, mesh)
            fn = S.train_fn(cfg, tcfg)
            jitted = jax.jit(
                fn,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_shapes, batch)
            mf = model_flops_train(cfg, shape)
        elif shape.kind == "prefill":
            params = S.param_shapes(cfg)
            params_sh = shd.shard_params_for_inference(params, mesh)
            batch = S.prefill_specs(cfg, shape)
            batch_sh = shd.shard_batch(batch, mesh)
            fn = S.prefill_fn(cfg, shape)
            jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params, batch)
            mf = model_flops_infer(cfg, shape, decode=False)
        else:  # decode
            params = S.param_shapes(cfg)
            params_sh = shd.shard_params_for_inference(params, mesh)
            dec = S.decode_specs(cfg, shape)
            state_shapes = S.decode_state_shapes(cfg, shape)
            state_sh = shd.shard_cache(state_shapes, mesh)
            fn = S.decode_fn(cfg)
            jitted = jax.jit(
                fn,
                in_shardings=(
                    params_sh,
                    shd.shard_batch({"t": dec["token"]}, mesh)["t"],
                    None,
                    state_sh,
                ),
                out_shardings=(None, state_sh),
                donate_argnums=(3,),
            )
            lowered = jitted.lower(
                params, dec["token"], dec["pos"], state_shapes
            )
            mf = model_flops_infer(cfg, shape, decode=True)

        t0 = time.monotonic()
        compiled = lowered.compile()
        compile_s = time.monotonic() - t0

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    # trip-count-aware per-device accounting from the HLO text; XLA's own
    # cost_analysis counts while bodies once (wrong for scanned stacks) and
    # is recorded only as a cross-check.
    hc = hlo_cost(hlo)
    rf = Roofline(
        chips=chips,
        hlo_flops=hc.flops * chips,
        hlo_bytes=hc.bytes * chips,
        coll_bytes=hc.coll_bytes * chips,
        model_flops=mf,
    )

    mem_rec = {}
    for attr in (
        "temp_size_in_bytes",
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        if mem is not None and hasattr(mem, attr):
            mem_rec[attr] = int(getattr(mem, attr))

    return {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "compile_s": round(compile_s, 1),
        "xla_cost_per_device": {  # cross-check only (no trip counts on CPU)
            "flops": _first(cost, "flops"),
            "bytes": _first(cost, "bytes accessed"),
        },
        "memory_analysis": mem_rec,
        "collectives": {
            "bytes_by_kind": coll.bytes_by_kind,
            "count_by_kind": coll.count_by_kind,
        },
        "roofline": rf.as_dict(),
        "status": "ok",
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument(
        "--impl", choices=("baseline", "optimized"), default="baseline",
        help="baseline = paper-faithful/naive; optimized = SPerf config",
    )
    args = ap.parse_args(argv)

    from repro.models import runtime_flags

    if args.impl == "optimized":
        runtime_flags.set_optimized()
    else:
        runtime_flags.set_baseline()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            ok, reason = cell_is_defined(get_arch(arch), SHAPES[shape])
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                path = outdir / f"{tag}.json"
                if not ok:
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "skipped", "reason": reason,
                    }
                    path.write_text(json.dumps(rec, indent=1))
                    print(f"[skip] {tag}: {reason}")
                    continue
                try:
                    rec = lower_cell(arch, shape, multi_pod=mp)
                    path.write_text(json.dumps(rec, indent=1))
                    r = rec["roofline"]
                    print(
                        f"[ok]   {tag}: compile={rec['compile_s']}s "
                        f"bottleneck={r['bottleneck']} "
                        f"t_bound={max(r['t_compute_s'], r['t_memory_s'], r['t_collective_s']):.4f}s "
                        f"useful={r['useful_flops_ratio']:.2f}",
                        flush=True,
                    )
                except Exception as e:  # a cell failure is a bug; record it
                    failures += 1
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    path.write_text(json.dumps(rec, indent=1))
                    print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:200]}",
                          flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
