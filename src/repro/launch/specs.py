"""ShapeDtypeStruct stand-ins for every model input (dry-run entry points).

No device allocation happens here: params / optimizer / caches come from
`jax.eval_shape` over the real init functions, inputs are constructed
directly.  Shardings attach via the rule engine in repro.distributed.
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import lm as lm_mod
from repro.optim.adamw import AdamWConfig
from repro.train.step import TrainConfig, init_train_state, make_train_step

Pytree = Any

# speech/vision frontend stub: precomputed frame/patch embedding length used
# for the encoder side of enc-dec cells
SRC_FRAMES = 1024


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_config_for(cfg: ArchConfig) -> TrainConfig:
    """Full-scale training config per arch (moment precision scales down as
    the model scales up -- DESIGN.md S6)."""
    approx_params = cfg.n_layers * cfg.d_model * cfg.d_model
    if cfg.moe:
        approx_params = (
            cfg.n_layers * cfg.moe.n_experts * 3 * cfg.d_model * cfg.d_ff
        )
    if approx_params > 2e11:
        moment = "int8"
    elif approx_params > 5e9:
        moment = "bfloat16"
    else:
        moment = "float32"
    return TrainConfig(optimizer=AdamWConfig(moment_dtype=moment), remat=True)


def param_shapes(cfg: ArchConfig) -> Pytree:
    return jax.eval_shape(
        lambda k: lm_mod.init_lm(k, cfg), jax.random.PRNGKey(0)
    )


def train_state_shapes(cfg: ArchConfig, tcfg: TrainConfig) -> Pytree:
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg, tcfg), jax.random.PRNGKey(0)
    )


def batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Training batch ShapeDtypeStructs."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        # split the budget: src frames + tgt tokens of s/2 each
        return {
            "src_embeds": sds((b, s // 2, cfg.d_model), cfg.dtype),
            "tokens": sds((b, s // 2), jnp.int32),
            "targets": sds((b, s // 2), jnp.int32),
            "mask": sds((b, s // 2), jnp.float32),
        }
    return {
        "tokens": sds((b, s), jnp.int32),
        "targets": sds((b, s), jnp.int32),
        "mask": sds((b, s), jnp.float32),
    }


def prefill_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        return {
            "src_embeds": sds((b, s, cfg.d_model), cfg.dtype),
            "tokens": sds((b, 128), jnp.int32),  # short decoder prompt
        }
    return {"tokens": sds((b, s), jnp.int32)}


def decode_state_shapes(cfg: ArchConfig, shape: ShapeConfig) -> Pytree:
    b, s = shape.global_batch, shape.seq_len
    return jax.eval_shape(
        functools.partial(
            lm_mod.init_decode_state, cfg, b, s, src_len=SRC_FRAMES
        )
    )


def decode_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b = shape.global_batch
    return {
        "token": sds((b,), jnp.int32),
        "pos": sds((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# lowering entry points (the functions the dry-run compiles)
# ---------------------------------------------------------------------------


def train_fn(cfg: ArchConfig, tcfg: TrainConfig):
    return make_train_step(cfg, tcfg)


def prefill_fn(cfg: ArchConfig, shape: ShapeConfig, model_axis: int = 16):
    from repro.models.runtime_flags import FLAGS, overrides

    # context-parallel prefill for archs whose head counts don't divide the
    # model axis (GSPMD otherwise replicates the whole attention computation
    # -- the qwen2.5 collective/memory pathology, EXPERIMENTS.md SPerf)
    use_cp = (
        FLAGS.attention_impl != "chunked"  # only in the optimized config
        and cfg.n_heads % model_axis != 0
        and not cfg.is_encoder_decoder
    )

    def fn(params, batch):
        kw = {}
        if cfg.is_encoder_decoder:
            kw["src_embeds"] = batch["src_embeds"]
        if use_cp:
            with overrides(attention_cp_axis="model", attention_impl="chunked"):
                return lm_mod.lm_prefill(
                    params, cfg, batch["tokens"], shape.seq_len, **kw
                )
        return lm_mod.lm_prefill(
            params, cfg, batch["tokens"], shape.seq_len, **kw
        )

    return fn


def decode_fn(cfg: ArchConfig):
    def fn(params, token, pos, state):
        return lm_mod.lm_decode_step(params, cfg, token, pos, state)

    return fn
