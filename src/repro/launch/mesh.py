"""Production mesh construction (single-pod 16x16 and 2-pod 2x16x16).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run pins the device count via XLA_FLAGS before any
jax initialisation).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the actually-available devices (tests / examples)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
