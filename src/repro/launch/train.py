"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --reduced \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On real hardware this process runs per host under the cluster scheduler
(jax.distributed.initialize is called when the env vars are present); in
this container it runs single-host on the CPU device.  Fault tolerance
(restore-on-failure, SIGTERM save) lives in repro.train.loop.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.pipeline import DataConfig, TokenStream
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, train_loop
from repro.train.step import TrainConfig, init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if "JAX_COORDINATOR" in os.environ:  # multi-host entry (real cluster)
        jax.distributed.initialize()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr),
        microbatches=args.microbatches,
        remat=True,
        warmup_steps=max(args.steps // 20, 5),
        total_steps=args.steps,
    )
    state = init_train_state(jax.random.PRNGKey(args.seed), cfg, tcfg)
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"[train] arch={cfg.name} params={n_params / 1e6:.2f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    stream = TokenStream(
        DataConfig(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    )

    def next_batch(step):
        return {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}

    train_loop(
        state=state,
        train_step=step_fn,
        next_batch=next_batch,
        cfg=LoopConfig(
            total_steps=args.steps,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every,
            log_every=10,
        ),
    )


if __name__ == "__main__":
    main()
