import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
"""Dry-run profiler: lower one cell, print the top FLOP / byte offenders.

    PYTHONPATH=src python -m repro.launch.inspect_cell --arch gemma3-1b \
        --shape train_4k [--multi-pod] [--save-hlo /tmp/cell.hlo]
"""

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--impl", choices=("baseline", "optimized"),
                    default="optimized")
    args = ap.parse_args()

    from repro.models import runtime_flags

    if args.impl == "optimized":
        runtime_flags.set_optimized()
    else:
        runtime_flags.set_baseline()

    from repro.launch.hlo_analysis import hlo_top_offenders
    from repro.launch.dryrun import lower_cell

    # re-run lowering manually to keep hlo text
    import json

    import jax

    from repro.configs.base import SHAPES, get_arch
    from repro.distributed import sharding as shd
    from repro.launch import specs as S
    from repro.launch.mesh import make_production_mesh

    rec = lower_cell(args.arch, args.shape, multi_pod=args.multi_pod)
    print(json.dumps(rec["roofline"], indent=1))

    # second lowering to extract text (lower_cell doesn't return it)
    cfg = get_arch(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    with mesh:
        if shape.kind == "train":
            tcfg = S.train_config_for(cfg)
            st = S.train_state_shapes(cfg, tcfg)
            batch = S.batch_specs(cfg, shape)
            st_sh = {
                "params": shd.shard_params(st["params"], mesh),
                "opt": {
                    "m": shd.shard_params(st["opt"]["m"], mesh),
                    "v": shd.shard_params(st["opt"]["v"], mesh),
                    "count": shd.replicated(st["opt"]["count"], mesh),
                },
                "step": shd.replicated(st["step"], mesh),
            }
            fn = S.train_fn(cfg, tcfg)
            hlo = (
                jax.jit(fn, in_shardings=(st_sh, shd.shard_batch(batch, mesh)),
                        out_shardings=(st_sh, None), donate_argnums=(0,))
                .lower(st, batch).compile().as_text()
            )
        elif shape.kind == "prefill":
            params = S.param_shapes(cfg)
            batch = S.prefill_specs(cfg, shape)
            hlo = (
                jax.jit(S.prefill_fn(cfg, shape),
                        in_shardings=(shd.shard_params_for_inference(params, mesh),
                                      shd.shard_batch(batch, mesh)))
                .lower(params, batch).compile().as_text()
            )
        else:
            params = S.param_shapes(cfg)
            dec = S.decode_specs(cfg, shape)
            stt = S.decode_state_shapes(cfg, shape)
            st_sh = shd.shard_cache(stt, mesh)
            hlo = (
                jax.jit(S.decode_fn(cfg),
                        in_shardings=(shd.shard_params_for_inference(params, mesh),
                                      shd.shard_batch({"t": dec["token"]}, mesh)["t"],
                                      None, st_sh),
                        out_shardings=(None, st_sh), donate_argnums=(3,))
                .lower(params, dec["token"], dec["pos"], stt)
                .compile().as_text()
            )

    if args.save_hlo:
        open(args.save_hlo, "w").write(hlo)
    top = hlo_top_offenders(hlo, args.top)
    print("\n=== top FLOPs (per-device, mult-adjusted) ===")
    for cost, mult, line in top["flops"]:
        print(f"{cost / 1e9:10.1f} GF  x{int(mult):5d}  {line[:150]}")
    print("\n=== top bytes (per-device, mult-adjusted) ===")
    for cost, mult, line in top["bytes"]:
        print(f"{cost / 1e9:10.2f} GB  x{int(mult):5d}  {line[:150]}")


if __name__ == "__main__":
    main()
