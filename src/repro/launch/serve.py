"""Serving launcher: batched requests against a (reduced) model.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --requests 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import init_lm
from repro.serve.engine import Engine, Request, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--trace", default=None, metavar="PATH",
        help="write a Chrome/Perfetto trace of the run here "
        "(e.g. serve.trace.json; view at https://ui.perfetto.dev)",
    )
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch).reduced()
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    eng = Engine(
        params, cfg,
        ServeConfig(max_batch=args.max_batch, max_len=256, temperature=0.0),
    )
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, rng.integers(4, 24)).astype(
                np.int32
            ),
            max_new_tokens=args.max_new,
        )
        for i in range(args.requests)
    ]
    tracer = None
    if args.trace:
        from repro.convserve.obs import Tracer

        tracer = Tracer()
    t0 = time.monotonic()
    if tracer is not None:
        with tracer.span(f"serve:{args.arch}", "request",
                         requests=len(reqs), max_batch=args.max_batch):
            results = eng.run(reqs, seed=args.seed)
    else:
        results = eng.run(reqs, seed=args.seed)
    dt = time.monotonic() - t0
    n_tok = sum(len(v) for v in results.values())
    print(f"[serve] {len(reqs)} requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok / dt:.1f} tok/s, batch={args.max_batch})")
    for rid in sorted(results)[:4]:
        print(f"  req {rid}: {results[rid][:12]}...")
    if tracer is not None:
        from repro.convserve.obs import write_trace

        for rid in sorted(results):
            tracer.instant(f"request:{rid}", "request",
                           tokens=len(results[rid]))
        n = write_trace(tracer, args.trace)
        print(f"[serve] wrote {args.trace} ({n} events)")


if __name__ == "__main__":
    main()
