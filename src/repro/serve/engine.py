"""Batched serving engine: continuous prefill + decode over request queues.

Small but real: requests arrive with prompts, get batched up to
`max_batch`, prefilled together (padded), then decoded step-by-step with
greedy/temperature sampling; finished sequences exit the batch.  The decode
step is a single jit-compiled function over the batch (the same function
the decode dry-run cells lower at scale).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import lm as lm_mod


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    out_tokens: Optional[List[int]] = None


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    temperature: float = 0.0
    eos_id: int = -1  # -1: never stop early


class Engine:
    def __init__(self, params, cfg: ArchConfig, scfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self._decode = jax.jit(
            lambda p, tok, pos, st: lm_mod.lm_decode_step(p, cfg, tok, pos, st)
        )

    def _prefill(self, tokens: jnp.ndarray):
        return lm_mod.lm_prefill(
            self.params, self.cfg, tokens, self.scfg.max_len
        )

    def _sample(self, logits: jnp.ndarray, rng) -> np.ndarray:
        if self.scfg.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        probs = jax.nn.softmax(logits / self.scfg.temperature, axis=-1)
        return np.array(
            [rng.choice(probs.shape[-1], p=np.asarray(pr)) for pr in probs],
            np.int32,
        )

    def run(self, requests: List[Request], seed: int = 0) -> Dict[int, List[int]]:
        """Serve a list of requests in batched waves."""
        rng = np.random.default_rng(seed)
        results: Dict[int, List[int]] = {}
        queue = list(requests)
        while queue:
            wave = queue[: self.scfg.max_batch]
            queue = queue[self.scfg.max_batch :]
            out = self._run_wave(wave, rng)
            results.update(out)
        return results

    def _run_wave(self, wave: List[Request], rng) -> Dict[int, List[int]]:
        b = len(wave)
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(wave):  # left-pad-free: right-align prompts
            toks[i, plen - len(r.prompt) :] = r.prompt
        logits, state = self._prefill(jnp.asarray(toks))
        outs: Dict[int, List[int]] = {r.rid: [] for r in wave}
        done = np.zeros(b, bool)
        cur = self._sample(logits, rng)
        max_new = max(r.max_new_tokens for r in wave)
        for t in range(max_new):
            for i, r in enumerate(wave):
                if not done[i] and t < r.max_new_tokens:
                    outs[r.rid].append(int(cur[i]))
                    if cur[i] == self.scfg.eos_id:
                        done[i] = True
            if done.all():
                break
            pos = jnp.int32(plen + t)
            logits, state = self._decode(
                self.params, jnp.asarray(cur), pos, state
            )
            cur = self._sample(logits, rng)
        return outs
