"""The state-of-the-art *non-fused* 3-stage transformed convolution.

This is the structure the paper attributes to DNNL / ZNN / LIBXSMM / FALCON
(and uses as its own baseline): each stage runs over ALL tiles before the
next begins, materialising the full transformed tensors

    U: (T*T, N_tile, C)     "left-hand matrices"
    M: (T*T, N_tile, C')    products

in main memory (HBM on TPU).  Stages 1 and 3 are memory-bound; stage 2 is
the only potentially compute-bound part (paper S3).

For honest CPU benchmarking the three stages can be jitted *separately*
(`three_stage_staged`), preventing XLA from fusing across stage boundaries,
which is exactly the materialisation behaviour of the vendor libraries.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analysis, registry, tiling, transforms


def transform_kernels(w: jnp.ndarray, m: int) -> jnp.ndarray:
    """HWIO kernels (K, K, C, C') -> right-hand matrices (T*T, C, C').

    Done once ahead of time (paper footnote 1: transformed kernels are
    precomputed and stored for inference; see also Liu et al. for training).
    """
    k = w.shape[0]
    _, g, _ = transforms.winograd_matrices(m, k)
    g = jnp.asarray(g, w.dtype)
    # W_t[x, y] = G W G^T per (C, C') pair
    wt = jnp.einsum("xi,ijcd,yj->xycd", g, w, g)
    t = m + k - 1
    return wt.reshape(t * t, w.shape[2], w.shape[3])


def stage1_input_transform(
    x_padded: jnp.ndarray, plan: tiling.TilePlan
) -> jnp.ndarray:
    """All input tiles -> U: (T*T, N_tile, C)."""
    bt_np, _, _ = _mats(plan)
    bt = jnp.asarray(bt_np, x_padded.dtype)
    tiles = tiling.extract_tiles(x_padded, plan)  # (B, nH, nW, T, T, C)
    b = tiles.shape[0]
    tiles = tiles.reshape(b * plan.tiles_per_image, plan.t, plan.t, -1)
    u = jnp.einsum("xi,nijc,yj->xync", bt, tiles, bt)
    n_tile = u.shape[2]
    return u.reshape(plan.t * plan.t, n_tile, -1)


def stage2_multiply(u: jnp.ndarray, wt: jnp.ndarray) -> jnp.ndarray:
    """T*T large matmuls: (T*T, N, C) @ (T*T, C, C') -> (T*T, N, C')."""
    return jnp.einsum("snc,scd->snd", u, wt)


def stage3_inverse_transform(
    m_tensor: jnp.ndarray, plan: tiling.TilePlan, batch: int
) -> jnp.ndarray:
    """M: (T*T, N_tile, C') -> assembled output (B, H', W', C')."""
    _, _, at_np = _mats(plan)
    at = jnp.asarray(at_np, m_tensor.dtype)
    n_tile = m_tensor.shape[1]
    z = m_tensor.reshape(plan.t, plan.t, n_tile, -1)
    y_tiles = jnp.einsum("xi,ijnc,yj->nxyc", at, z, at)
    y_tiles = y_tiles.reshape(
        batch, plan.n_tiles_h, plan.n_tiles_w, plan.t_out, plan.t_out, -1
    )
    return tiling.assemble_tiles(y_tiles, plan)


def _mats(plan: tiling.TilePlan):
    m = plan.t_out
    at, g, bt = transforms.winograd_matrices(m, plan.k)
    return bt, g, at


def conv2d_three_stage(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    pad: int = 0,
    m: Optional[int] = None,
    wt: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """NHWC x (B,H,W,C), HWIO w (K,K,C,C') -> (B,H',W',C'). Single-jit form."""
    k = w.shape[0]
    m = m if m is not None else 6  # T = 8 default
    plan = tiling.TilePlan.build(x.shape[1], x.shape[2], k, pad, m + k - 1)
    if wt is None:
        wt = transform_kernels(w, m)
    xp = tiling.pad_input(x, plan)
    u = stage1_input_transform(xp, plan)
    mm = stage2_multiply(u, wt)
    return stage3_inverse_transform(mm, plan, x.shape[0])


class ThreeStageAlgorithm(registry.Algorithm):
    """The vendor-structure baseline as a registry algorithm.

    Tier 1: always roofline-feasible (stages stream through DRAM), so it
    is the fallback whenever every fused path is infeasible -- but never
    beats a feasible fused path regardless of modeled cost, matching the
    paper's preference order.
    """

    name = "three_stage"
    tier = 1
    rank = 30
    consumes_wt = True
    weight_params = ("m",)
    default_m = 6  # T = 8, this module's historical default

    def supports(self, spec: registry.ConvSpec) -> bool:
        return spec.groups == 1

    def plan(self, spec, hw, *, hints=None, tune_r=False, wisdom_path=None):
        hints = hints or {}
        m = int(hints.get("m") or self.default_m)
        t = m + spec.k - 1
        # DRAM roofline bounds utilisation: U and M round-trip main memory.
        util = min(
            1.0, analysis.ai_dram(spec.c_in, spec.c_out, t, m) / hw.cmr_dram
        )
        cost = math.inf
        if spec.padded_min >= t:  # tile-fit heuristic gates auto only
            cost = (
                analysis.flops_per_output_px(t, m)
                / max(util, 1e-9)
                * spec.stride**2
            )
        return registry.AlgoPlan(
            self.name, spec, {"m": m}, predicted_util=util, cost=cost
        )

    def prepare_weights(self, w, plan):
        m = plan.params.get("m")
        if m is None:
            raise ValueError(f"{self.name} plan without m: {plan.params}")
        return transform_kernels(w, m)

    def execute(self, x, w, wt, plan):
        y = conv2d_three_stage(
            x, w, pad=plan.spec.pad, m=plan.params.get("m"), wt=wt
        )
        return registry.decimate(y, plan.spec.stride)


registry.register(ThreeStageAlgorithm())


class ThreeStageStaged:
    """Stage-separated (separately jitted) 3-stage pipeline.

    Mirrors vendor-library behaviour: each stage is an independent compiled
    program; U and M round-trip through main memory between stages.
    """

    def __init__(self, plan: tiling.TilePlan):
        self.plan = plan
        self._s1 = jax.jit(lambda xp: stage1_input_transform(xp, plan))
        self._s2 = jax.jit(stage2_multiply)
        self._s3 = jax.jit(
            lambda mt, b: stage3_inverse_transform(mt, plan, b), static_argnums=1
        )
        self._pad = jax.jit(lambda x: tiling.pad_input(x, plan))

    def __call__(self, x: jnp.ndarray, wt: jnp.ndarray) -> jnp.ndarray:
        xp = self._pad(x)
        u = jax.block_until_ready(self._s1(xp))
        mm = jax.block_until_ready(self._s2(u, wt))
        return jax.block_until_ready(self._s3(mm, x.shape[0]))
