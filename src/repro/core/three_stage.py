"""The state-of-the-art *non-fused* 3-stage transformed convolution.

This is the structure the paper attributes to DNNL / ZNN / LIBXSMM / FALCON
(and uses as its own baseline): each stage runs over ALL tiles before the
next begins, materialising the full transformed tensors (left-hand
matrices U and products M) in main memory (HBM on TPU).  Stages 1 and 3
are memory-bound; stage 2 is the only potentially compute-bound part
(paper S3).

The stages themselves come from the shared tile engine
(`repro.core.pipeline.staged_stage_fns`) driven by a `WinogradTransform`;
this module binds them to the Winograd family and registers the tier-1
fallback algorithm.  For honest CPU benchmarking the three stages can be
jitted *separately* (`ThreeStageStaged`), preventing XLA from fusing
across stage boundaries, which is exactly the materialisation behaviour
of the vendor libraries.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import analysis, pipeline, registry, tiling, transforms


def transform_kernels(w: jnp.ndarray, m: int) -> jnp.ndarray:
    """HWIO kernels (K, K, C, C') -> right-hand matrices (T*T, C, C').

    Done once ahead of time (paper footnote 1: transformed kernels are
    precomputed and stored for inference; see also Liu et al. for training).
    """
    return transforms.WinogradTransform(m=m, k=w.shape[0]).kernel_transform(w)


def conv2d_three_stage(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    pad: int = 0,
    m: Optional[int] = None,
    wt: Optional[jnp.ndarray] = None,
    groups: int = 1,
) -> jnp.ndarray:
    """NHWC x (B,H,W,C), HWIO w (K,K,C,C') -> (B,H',W',C'). Single-jit form."""
    m = m if m is not None else 6  # T = 8 default
    return pipeline.staged_tile_conv(
        x, w, transforms.WinogradTransform(m=m, k=w.shape[0]),
        pad=pad, wt=wt, groups=groups,
    )


class ThreeStageAlgorithm(pipeline.TransformedAlgorithm):
    """The vendor-structure baseline as a registry algorithm.

    Tier 1: always roofline-feasible (stages stream through DRAM), so it
    is the fallback whenever every fused path is infeasible -- but never
    beats a feasible fused path regardless of modeled cost, matching the
    paper's preference order.  `chain_family` stays None: the 3-stage
    baseline *is* the materializing structure, so it never joins fusion
    groups.
    """

    name = "three_stage"
    tier = 1
    rank = 30
    weight_params = ("m",)
    tile_param = "m"
    default_tile = 6  # T = 8, this module's historical default

    def make_transform(self, spec, params):
        return transforms.WinogradTransform(m=int(params["m"]), k=spec.k)

    def plan(self, spec, hw, *, hints=None, tune_r=False, wisdom_path=None):
        hints = hints or {}
        m = int(hints.get("m") or self.default_tile)
        ta = transforms.WinogradTransform(m=m, k=spec.k).algebra
        # DRAM roofline bounds utilisation: U and M round-trip main memory.
        util = min(
            1.0,
            analysis.ai_dram(
                spec.c_in, spec.c_out, ta.t, ta.t_out, ta.alpha, spec.groups
            )
            / hw.cmr_dram,
        )
        cost = math.inf
        if spec.padded_min >= ta.t:  # tile-fit heuristic gates auto only
            cost = (
                ta.flops_per_output_px() / max(util, 1e-9) * spec.stride**2
            )
        return registry.AlgoPlan(
            self.name, spec, {"m": m}, predicted_util=util, cost=cost
        )

    def _run(self, x, w, wt, plan, epilogue):
        # materializing structure: no task loop to fold an epilogue into
        # (the base fuse_epilogue applies it to the assembled output)
        tr = self.make_transform(plan.spec, plan.params)
        y = pipeline.staged_tile_conv(
            x, w, tr, pad=plan.spec.pad, wt=wt, groups=plan.spec.groups
        )
        return y if epilogue is None else epilogue(y)


registry.register(ThreeStageAlgorithm())


class ThreeStageStaged:
    """Stage-separated (separately jitted) 3-stage pipeline.

    Mirrors vendor-library behaviour: each stage is an independent compiled
    program; U and M round-trip through main memory between stages.
    """

    def __init__(self, plan: tiling.TilePlan):
        self.plan = plan
        s1, s2, s3 = pipeline.staged_stage_fns(
            transforms.WinogradTransform(m=plan.t_out, k=plan.k), plan
        )
        self._s1 = jax.jit(s1)
        self._s2 = jax.jit(s2)
        self._s3 = jax.jit(s3, static_argnums=1)
        self._pad = jax.jit(lambda x: tiling.pad_input(x, plan))

    def __call__(self, x: jnp.ndarray, wt: jnp.ndarray) -> jnp.ndarray:
        xp = self._pad(x)
        u = jax.block_until_ready(self._s1(xp))
        mm = jax.block_until_ready(self._s2(u, wt))
        return jax.block_until_ready(self._s3(mm, x.shape[0]))
