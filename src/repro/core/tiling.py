"""Overlap-add (OLA) tiling for transformed convolutions.

An input image of spatial size (H, W) with layer padding p and kernel K is
covered by tiles of size T x T placed on a stride of T' = T - K + 1 (the
output tile size).  Output tiles do not overlap; input tiles overlap by K-1.
We additionally right/bottom-pad so that the tile grid covers the padded
input exactly -- padded outputs are cropped at the end.
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Static geometry of an OLA tiling for one conv layer."""

    h: int  # input spatial height (unpadded)
    w: int
    k: int  # kernel size (isotropic)
    pad: int  # symmetric layer padding
    t: int  # tile size T
    # derived
    t_out: int  # T' = T - K + 1
    h_out: int  # true output height = H + 2p - K + 1
    w_out: int
    n_tiles_h: int
    n_tiles_w: int
    h_pad: int  # padded input height covered by the tile grid
    w_pad: int

    @staticmethod
    def build(h: int, w: int, k: int, pad: int, t: int) -> "TilePlan":
        if t < k:
            raise ValueError(f"tile size {t} smaller than kernel {k}")
        t_out = t - k + 1
        h_out = h + 2 * pad - k + 1
        w_out = w + 2 * pad - k + 1
        if h_out <= 0 or w_out <= 0:
            raise ValueError("kernel larger than padded input")
        n_th = math.ceil(h_out / t_out)
        n_tw = math.ceil(w_out / t_out)
        # the tile grid needs n*T' + K - 1 padded-input rows/cols
        h_pad = n_th * t_out + k - 1
        w_pad = n_tw * t_out + k - 1
        return TilePlan(
            h=h, w=w, k=k, pad=pad, t=t, t_out=t_out,
            h_out=h_out, w_out=w_out,
            n_tiles_h=n_th, n_tiles_w=n_tw,
            h_pad=h_pad, w_pad=w_pad,
        )

    @property
    def tiles_per_image(self) -> int:
        return self.n_tiles_h * self.n_tiles_w

    def n_tiles(self, batch: int) -> int:
        """N_tile = B * ceil((D-K+1)/T') * ceil((W-K+1)/T')  (paper, w/ padding)."""
        return batch * self.tiles_per_image


def pad_input(x: jnp.ndarray, plan: TilePlan) -> jnp.ndarray:
    """Pad NHWC input: `pad` on top/left, enough on bottom/right for the grid."""
    top = plan.pad
    bottom = plan.h_pad - plan.h - plan.pad
    left = plan.pad
    right = plan.w_pad - plan.w - plan.pad
    return jnp.pad(x, ((0, 0), (top, bottom), (left, right), (0, 0)))


def extract_tiles(x_padded: jnp.ndarray, plan: TilePlan) -> jnp.ndarray:
    """(B, H_pad, W_pad, C) -> (B, nH, nW, T, T, C) overlapping input tiles.

    Implemented as a pair of strided gathers (cheap on CPU/TPU; on the Pallas
    path this never materialises -- the kernel reads overlapping strips
    directly via `pl.Element` block dims).
    """
    b, hp, wp, c = x_padded.shape
    assert hp == plan.h_pad and wp == plan.w_pad, (x_padded.shape, plan)
    row_idx = (
        np.arange(plan.n_tiles_h)[:, None] * plan.t_out + np.arange(plan.t)[None, :]
    )  # (nH, T)
    col_idx = (
        np.arange(plan.n_tiles_w)[:, None] * plan.t_out + np.arange(plan.t)[None, :]
    )  # (nW, T)
    xt = x_padded[:, row_idx, :, :]  # (B, nH, T, W_pad, C)
    xt = xt[:, :, :, col_idx, :]  # (B, nH, T, nW, T, C)
    return xt.transpose(0, 1, 3, 2, 4, 5)  # (B, nH, nW, T, T, C)


def assemble_tiles(y_tiles: jnp.ndarray, plan: TilePlan) -> jnp.ndarray:
    """(B, nH, nW, T', T', C') -> (B, H_out, W_out, C') output assembly.

    Output tiles abut exactly (stride == size), so assembly is a transpose +
    reshape + crop; no scatter needed.
    """
    b, nh, nw, tp, tp2, c = y_tiles.shape
    assert (nh, nw, tp, tp2) == (plan.n_tiles_h, plan.n_tiles_w, plan.t_out, plan.t_out)
    y = y_tiles.transpose(0, 1, 3, 2, 4, 5).reshape(
        b, nh * plan.t_out, nw * plan.t_out, c
    )
    return y[:, : plan.h_out, : plan.w_out, :]
