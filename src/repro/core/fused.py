"""The paper's contribution: the L3-fused transformed convolution.

Instead of three full-layer stages, tiles are processed in N_task =
ceil(N_tile / R) independent *tasks*.  Each task

  1. forward-transforms R tile-groups            (R instances of step 1)
  2. performs the T^2 small matmuls (RxC)@(CxC') against the *stationary*
     right-hand (transformed-kernel) matrices
  3. inverse-transforms the R results

so the per-task intermediates (R x C and R x C' matrices, T^2 of each) stay
in fast private memory, and the T^2 right-hand matrices -- re-read by every
task -- stay hot in the fast shared level (L3 on CPU; VMEM-stationary on the
TPU Pallas path, see repro.kernels.fused_winograd).

This module is the pure-JAX expression of the algorithm: a `lax.scan` over
tasks models the per-core sequential task stream; tasks are embarrassingly
parallel across cores/chips (paper S4) -- on the TPU mesh, the tile axis is
sharded over the `data` axis and each chip scans its own tasks.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analysis, registry, tiling, transforms
from repro.core.three_stage import transform_kernels


def _tile_offsets(plan: tiling.TilePlan, batch: int) -> np.ndarray:
    """(N_tile, 3) int32: (batch, row0, col0) of every input tile, flat order."""
    b_idx, h_idx, w_idx = np.meshgrid(
        np.arange(batch),
        np.arange(plan.n_tiles_h) * plan.t_out,
        np.arange(plan.n_tiles_w) * plan.t_out,
        indexing="ij",
    )
    return np.stack(
        [b_idx.ravel(), h_idx.ravel(), w_idx.ravel()], axis=1
    ).astype(np.int32)


def _gather_tiles(x_padded: jnp.ndarray, offsets: jnp.ndarray, t: int) -> jnp.ndarray:
    """Gather R overlapping (T, T, C) tiles given (R, 3) offsets."""

    def one(off):
        return jax.lax.dynamic_slice(
            x_padded,
            (off[0], off[1], off[2], 0),
            (1, t, t, x_padded.shape[3]),
        )[0]

    return jax.vmap(one)(offsets)  # (R, T, T, C)


def conv2d_l3_fused(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    pad: int = 0,
    m: Optional[int] = None,
    r_tiles: int = 24,
    wt: Optional[jnp.ndarray] = None,
    epilogue=None,
) -> jnp.ndarray:
    """NHWC L3-fused transformed convolution.

    Args:
      x: (B, H, W, C) input.
      w: (K, K, C, C') kernels (HWIO); ignored if `wt` given.
      pad: symmetric spatial padding.
      m: Winograd output-tile size (T = m + K - 1).  Default m=5, T=7 --
         the paper's benchmark configuration.
      r_tiles: R, tiles per task (paper uses R=24 on SkylakeX, R=8 on i7).
      wt: pre-transformed kernels (T*T, C, C') -- the inference-time path.
      epilogue: optional elementwise callable applied to each task's
        output tiles inside the scan (bias/relu glue running on
        task-resident data); output tiles abut, so this equals applying
        it to the assembled output.
    """
    k = w.shape[0]
    m = m if m is not None else 5  # T = 7, the paper's fixed benchmark config
    t = m + k - 1
    plan = tiling.TilePlan.build(x.shape[1], x.shape[2], k, pad, t)
    if wt is None:
        wt = transform_kernels(w, m)
    batch, c_in = x.shape[0], x.shape[3]
    c_out = wt.shape[2]

    at_np, _, bt_np = transforms.winograd_matrices(m, k)
    at = jnp.asarray(at_np, x.dtype)
    bt = jnp.asarray(bt_np, x.dtype)

    xp = tiling.pad_input(x, plan)
    n_tile = plan.n_tiles(batch)
    r = min(r_tiles, n_tile)
    n_task = -(-n_tile // r)
    n_pad = n_task * r

    offsets = _tile_offsets(plan, batch)
    if n_pad > n_tile:  # pad the task list by repeating the last tile
        offsets = np.concatenate(
            [offsets, np.repeat(offsets[-1:], n_pad - n_tile, axis=0)], axis=0
        )
    offsets = jnp.asarray(offsets).reshape(n_task, r, 3)

    def task(carry_out_tiles, off_r):
        # step 1: gather + forward-transform R tiles -> (T^2, R, C)
        tiles = _gather_tiles(xp, off_r, t)  # (R, T, T, C)
        u = jnp.einsum("xi,rijc,yj->xyrc", bt, tiles, bt)
        u = u.reshape(t * t, r, c_in)
        # step 2: T^2 small matmuls against the stationary right-hand matrices
        mm = jnp.einsum("src,scd->srd", u, wt)  # (T^2, R, C')
        # step 3: inverse transform
        z = mm.reshape(t, t, r, c_out)
        y = jnp.einsum("xi,ijrc,yj->rxyc", at, z, at)  # (R, T', T', C')
        if epilogue is not None:
            y = epilogue(y)
        return carry_out_tiles, y

    _, y_tiles = jax.lax.scan(
        task, jnp.zeros((), x.dtype), offsets
    )  # (n_task, R, T', T', C')
    y_tiles = y_tiles.reshape(n_pad, plan.t_out, plan.t_out, c_out)[:n_tile]
    y_tiles = y_tiles.reshape(
        batch, plan.n_tiles_h, plan.n_tiles_w, plan.t_out, plan.t_out, c_out
    )
    return tiling.assemble_tiles(y_tiles, plan)


def resolve_wino_r(
    spec: registry.ConvSpec,
    hw: analysis.HardwareModel,
    *,
    m: int,
    hints,
    tune_r: bool = False,
    wisdom_path=None,
):
    """R for a Winograd-family plan: explicit hint > measured (tune_r) >
    wisdom-file lookup > analytic prediction.  Returns (r, tuned) where
    `tuned` marks an R that came from measurement (fresh or cached in the
    wisdom file) rather than the model."""
    from repro.core import tune  # deferred: tune times this module's conv

    r_hint = hints.get("r_tiles")
    if r_hint is not None:
        return int(r_hint), False
    if tune_r:
        r = tune.tuned_r(
            spec.h, spec.w, spec.c_in, spec.c_out, k=spec.k, m=m,
            wisdom_path=wisdom_path,
        )
        return int(r), True
    r = tune.lookup_r(
        spec.h, spec.w, spec.c_in, spec.c_out, k=spec.k, m=m,
        wisdom_path=wisdom_path,
    )
    if r is not None:
        # clamp a wisdom R measured elsewhere into this hw's feasible range
        r_max = analysis.max_r(hw, spec.c_in, spec.c_out, m + spec.k - 1)
        return (max(1, min(int(r), r_max)) if r_max >= 1 else int(r)), True
    return tune.predict_r(spec.c_in, spec.c_out, k=spec.k, m=m, hw=hw), False


def plan_wino_family(
    name: str,
    spec: registry.ConvSpec,
    hw: analysis.HardwareModel,
    *,
    default_m: int,
    hints,
    tune_r: bool = False,
    wisdom_path=None,
) -> registry.AlgoPlan:
    """Shared plan step for the Winograd-family algorithms (the pure-JAX
    l3_fused and the Pallas kernel): same m/T resolution, same wisdom-file
    R, same alpha=1 utilisation and auto-ranking cost."""
    hints = hints or {}
    m = int(hints.get("m") or default_m)
    t = m + spec.k - 1
    r, tuned = resolve_wino_r(
        spec, hw, m=m, hints=hints, tune_r=tune_r, wisdom_path=wisdom_path
    )
    util = analysis.predicted_utilization(
        hw, r, spec.c_in, spec.c_out, t, m, alpha=1
    )
    cost = registry.fused_auto_cost(
        spec, hw, t, 1, max(8, analysis.min_r(hw) // 2)
    )
    return registry.AlgoPlan(
        name, spec, {"m": m, "r_tiles": int(r)},
        predicted_util=util, cost=cost, tuned=tuned,
    )


class L3FusedAlgorithm(registry.Algorithm):
    """The paper's contribution as a registry algorithm (tier 0)."""

    name = "l3_fused"
    tier = 0
    rank = 10
    consumes_wt = True
    weight_params = ("m",)
    chain_family = "winograd"
    default_m = 5  # T = 7, the paper's benchmark configuration

    def supports(self, spec: registry.ConvSpec) -> bool:
        return spec.groups == 1

    def plan(self, spec, hw, *, hints=None, tune_r=False, wisdom_path=None):
        return plan_wino_family(
            self.name, spec, hw, default_m=self.default_m, hints=hints,
            tune_r=tune_r, wisdom_path=wisdom_path,
        )

    def prepare_weights(self, w, plan):
        m = plan.params.get("m")
        if m is None:
            raise ValueError(f"{self.name} plan without m: {plan.params}")
        return transform_kernels(w, m)

    def execute(self, x, w, wt, plan):
        y = conv2d_l3_fused(
            x, w, pad=plan.spec.pad, m=plan.params.get("m"),
            r_tiles=plan.params.get("r_tiles", 24), wt=wt,
        )
        return registry.decimate(y, plan.spec.stride)

    def fuse_epilogue(self, plan, epilogue):
        # fold the elementwise glue into the task scan: it runs on the
        # (R, T', T', C') tiles while they are still task-resident,
        # instead of as a separate pass over the assembled output
        def run(x, w, wt):
            y = conv2d_l3_fused(
                x, w, pad=plan.spec.pad, m=plan.params.get("m"),
                r_tiles=plan.params.get("r_tiles", 24), wt=wt,
                epilogue=epilogue,
            )
            return registry.decimate(y, plan.spec.stride)

        return run


registry.register(L3FusedAlgorithm())
