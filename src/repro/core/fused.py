"""The paper's contribution: the L3-fused transformed convolution.

Instead of three full-layer stages, tiles are processed in N_task =
ceil(N_tile / R) independent *tasks*.  Each task

  1. forward-transforms R tile-groups            (R instances of step 1)
  2. performs the T^2 small matmuls (RxC)@(CxC') against the *stationary*
     right-hand (transformed-kernel) matrices
  3. inverse-transforms the R results

so the per-task intermediates (R x C and R x C' matrices, T^2 of each) stay
in fast private memory, and the T^2 right-hand matrices -- re-read by every
task -- stay hot in the fast shared level (L3 on CPU; VMEM-stationary on the
TPU Pallas path, see repro.kernels.fused_winograd).

This module is the pure-JAX expression of the algorithm: a `lax.scan` over
tasks models the per-core sequential task stream; tasks are embarrassingly
parallel across cores/chips (paper S4) -- on the TPU mesh, the tile axis is
sharded over the `data` axis and each chip scans its own tasks.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tiling, transforms
from repro.core.three_stage import transform_kernels


def _tile_offsets(plan: tiling.TilePlan, batch: int) -> np.ndarray:
    """(N_tile, 3) int32: (batch, row0, col0) of every input tile, flat order."""
    b_idx, h_idx, w_idx = np.meshgrid(
        np.arange(batch),
        np.arange(plan.n_tiles_h) * plan.t_out,
        np.arange(plan.n_tiles_w) * plan.t_out,
        indexing="ij",
    )
    return np.stack(
        [b_idx.ravel(), h_idx.ravel(), w_idx.ravel()], axis=1
    ).astype(np.int32)


def _gather_tiles(x_padded: jnp.ndarray, offsets: jnp.ndarray, t: int) -> jnp.ndarray:
    """Gather R overlapping (T, T, C) tiles given (R, 3) offsets."""

    def one(off):
        return jax.lax.dynamic_slice(
            x_padded,
            (off[0], off[1], off[2], 0),
            (1, t, t, x_padded.shape[3]),
        )[0]

    return jax.vmap(one)(offsets)  # (R, T, T, C)


def conv2d_l3_fused(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    pad: int = 0,
    m: Optional[int] = None,
    r_tiles: int = 24,
    wt: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """NHWC L3-fused transformed convolution.

    Args:
      x: (B, H, W, C) input.
      w: (K, K, C, C') kernels (HWIO); ignored if `wt` given.
      pad: symmetric spatial padding.
      m: Winograd output-tile size (T = m + K - 1).  Default m=5, T=7 --
         the paper's benchmark configuration.
      r_tiles: R, tiles per task (paper uses R=24 on SkylakeX, R=8 on i7).
      wt: pre-transformed kernels (T*T, C, C') -- the inference-time path.
    """
    k = w.shape[0]
    m = m if m is not None else 5  # T = 7, the paper's fixed benchmark config
    t = m + k - 1
    plan = tiling.TilePlan.build(x.shape[1], x.shape[2], k, pad, t)
    if wt is None:
        wt = transform_kernels(w, m)
    batch, c_in = x.shape[0], x.shape[3]
    c_out = wt.shape[2]

    at_np, _, bt_np = transforms.winograd_matrices(m, k)
    at = jnp.asarray(at_np, x.dtype)
    bt = jnp.asarray(bt_np, x.dtype)

    xp = tiling.pad_input(x, plan)
    n_tile = plan.n_tiles(batch)
    r = min(r_tiles, n_tile)
    n_task = -(-n_tile // r)
    n_pad = n_task * r

    offsets = _tile_offsets(plan, batch)
    if n_pad > n_tile:  # pad the task list by repeating the last tile
        offsets = np.concatenate(
            [offsets, np.repeat(offsets[-1:], n_pad - n_tile, axis=0)], axis=0
        )
    offsets = jnp.asarray(offsets).reshape(n_task, r, 3)

    def task(carry_out_tiles, off_r):
        # step 1: gather + forward-transform R tiles -> (T^2, R, C)
        tiles = _gather_tiles(xp, off_r, t)  # (R, T, T, C)
        u = jnp.einsum("xi,rijc,yj->xyrc", bt, tiles, bt)
        u = u.reshape(t * t, r, c_in)
        # step 2: T^2 small matmuls against the stationary right-hand matrices
        mm = jnp.einsum("src,scd->srd", u, wt)  # (T^2, R, C')
        # step 3: inverse transform
        z = mm.reshape(t, t, r, c_out)
        y = jnp.einsum("xi,ijrc,yj->rxyc", at, z, at)  # (R, T', T', C')
        return carry_out_tiles, y

    _, y_tiles = jax.lax.scan(
        task, jnp.zeros((), x.dtype), offsets
    )  # (n_task, R, T', T', C')
    y_tiles = y_tiles.reshape(n_pad, plan.t_out, plan.t_out, c_out)[:n_tile]
    y_tiles = y_tiles.reshape(
        batch, plan.n_tiles_h, plan.n_tiles_w, plan.t_out, plan.t_out, c_out
    )
    return tiling.assemble_tiles(y_tiles, plan)
