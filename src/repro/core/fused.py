"""The paper's contribution: the L3-fused transformed convolution.

Instead of three full-layer stages, tiles are processed in N_task =
ceil(N_tile / R) independent *tasks* (gather + forward-transform R tiles,
T^2 small matmuls against the *stationary* right-hand matrices, inverse-
transform), so the per-task intermediates stay in fast private memory and
the right-hand matrices stay hot in the fast shared level (L3 on CPU;
VMEM-stationary on the TPU Pallas path, see repro.kernels.fused_winograd).

The task loop itself lives in `repro.core.pipeline` -- one engine shared
by every transform family -- and this module is just the Winograd-family
binding: `conv2d_l3_fused` drives the engine with a `WinogradTransform`,
and `L3FusedAlgorithm` registers it (tier 0).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import pipeline, registry, transforms


def conv2d_l3_fused(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    pad: int = 0,
    m: Optional[int] = None,
    r_tiles: int = 24,
    wt: Optional[jnp.ndarray] = None,
    groups: int = 1,
    epilogue=None,
) -> jnp.ndarray:
    """NHWC L3-fused Winograd convolution.

    Args:
      x: (B, H, W, C) input.
      w: (K, K, C/groups, C') kernels (HWIO); ignored if `wt` given.
      pad: symmetric spatial padding.
      m: Winograd output-tile size (T = m + K - 1).  Default m=5, T=7 --
         the paper's benchmark configuration.
      r_tiles: R, tiles per task (paper uses R=24 on SkylakeX, R=8 on i7).
      wt: pre-transformed kernels (T*T, C/groups, C') -- the inference-time
        path.
      groups: grouped convolution (block-diagonal channel mix).
      epilogue: optional elementwise callable applied to each task's
        output tiles inside the scan (bias/relu glue running on
        task-resident data); output tiles abut, so this equals applying
        it to the assembled output.
    """
    k = w.shape[0]
    m = m if m is not None else 5  # T = 7, the paper's fixed benchmark config
    return pipeline.fused_tile_conv(
        x, w, transforms.WinogradTransform(m=m, k=k),
        pad=pad, r_tiles=r_tiles, wt=wt, groups=groups, epilogue=epilogue,
    )


class L3FusedAlgorithm(pipeline.TransformedAlgorithm):
    """The paper's contribution as a registry algorithm (tier 0)."""

    name = "l3_fused"
    tier = 0
    rank = 10
    weight_params = ("m",)
    chain_family = "winograd"
    tile_param = "m"
    default_tile = 5  # T = 7, the paper's benchmark configuration
    r_floor_base = 8

    def make_transform(self, spec, params):
        return transforms.WinogradTransform(m=int(params["m"]), k=spec.k)


registry.register(L3FusedAlgorithm())
