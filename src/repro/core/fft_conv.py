"""FFT-based transformed convolution (the paper's second transform family).

Same OLA tiling and task structure as the Winograd path; the basis transform
is an rFFT over each T x T tile.  Cross-correlation via the correlation
theorem:  y = irfft2( rfft2(d) * conj(rfft2(g, s=(T,T))) )[:T', :T'] --
circular wrap-around only contaminates the last K-1 rows/cols, which OLA
discards.  rfft keeps T*(T/2+1) frequencies (the paper's conjugate
anti-symmetric ~2x saving); each frequency's channel-mix is a complex
matmul (alpha = 2 in the paper's FLOP accounting -- 4 real mults per MAC).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analysis, registry, tiling


def transform_kernels_fft(w: jnp.ndarray, t: int) -> jnp.ndarray:
    """HWIO (K, K, C, C') -> (T, T//2+1, C, C') complex right-hand matrices."""
    wf = jnp.fft.rfft2(w, s=(t, t), axes=(0, 1))
    return jnp.conj(wf)


def conv2d_fft_fused(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    pad: int = 0,
    t: int = 16,
    r_tiles: int = 16,
    wt: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """NHWC L3-fused FFT convolution (paper: T >= 16 works well for FFT)."""
    k = w.shape[0]
    plan = tiling.TilePlan.build(x.shape[1], x.shape[2], k, pad, t)
    if wt is None:
        wt = transform_kernels_fft(w, t)
    batch, c_in = x.shape[0], x.shape[3]
    c_out = wt.shape[3]

    xp = tiling.pad_input(x, plan)
    tiles = tiling.extract_tiles(xp, plan)  # (B, nH, nW, T, T, C)
    n_tile = batch * plan.tiles_per_image
    tiles = tiles.reshape(n_tile, t, t, c_in)

    r = min(r_tiles, n_tile)
    n_task = -(-n_tile // r)
    n_pad = n_task * r
    if n_pad > n_tile:
        tiles = jnp.concatenate(
            [tiles, jnp.zeros((n_pad - n_tile, t, t, c_in), tiles.dtype)], 0
        )
    tiles = tiles.reshape(n_task, r, t, t, c_in)

    def task(carry, tl):
        u = jnp.fft.rfft2(tl, axes=(1, 2))  # (R, T, F, C) complex
        mm = jnp.einsum("rxfc,xfcd->rxfd", u, wt)
        y = jnp.fft.irfft2(mm, s=(t, t), axes=(1, 2))
        return carry, y[:, : plan.t_out, : plan.t_out, :]

    _, y_tiles = jax.lax.scan(task, jnp.zeros((), x.dtype), tiles)
    y_tiles = y_tiles.reshape(n_pad, plan.t_out, plan.t_out, c_out)[:n_tile]
    y_tiles = y_tiles.reshape(
        batch, plan.n_tiles_h, plan.n_tiles_w, plan.t_out, plan.t_out, c_out
    )
    return tiling.assemble_tiles(y_tiles, plan).astype(x.dtype)


class FFTFusedAlgorithm(registry.Algorithm):
    """The FFT transform family as a registry algorithm (tier 0).

    alpha = 2 in the cost entry (complex channel-mix matmuls); feasible
    only when the padded input covers a full T_fft tile -- below that the
    tile is mostly padding and the flops-per-pixel comparison collapses.
    """

    name = "fft_fused"
    tier = 0
    rank = 20
    consumes_wt = True
    weight_params = ("t_fft",)
    chain_family = "fft"
    default_t = 16  # the paper: T >= 16 works well for FFT

    def supports(self, spec: registry.ConvSpec) -> bool:
        # lax.fft computes in f32/f64 only; bf16 problems go to the
        # Winograd family (capability-based fallback, not a cast)
        return spec.groups == 1 and spec.dtype in ("float32", "float64")

    def plan(self, spec, hw, *, hints=None, tune_r=False, wisdom_path=None):
        hints = hints or {}
        t = int(hints.get("t_fft") or self.default_t)
        from repro.core import tune  # deferred: tune imports core.fused

        r_hint = hints.get("r_tiles")
        r = (
            int(r_hint)
            if r_hint is not None
            else tune.predict_r(spec.c_in, spec.c_out, k=spec.k, t=t, hw=hw)
        )
        util = analysis.predicted_utilization(
            hw, r, spec.c_in, spec.c_out, t, t - spec.k + 1, alpha=2
        )
        cost = registry.fused_auto_cost(
            spec, hw, t, 2, max(4, analysis.min_r(hw) // 2)
        )
        return registry.AlgoPlan(
            self.name, spec, {"t_fft": t, "r_tiles": int(r)},
            predicted_util=util, cost=cost,
        )

    def prepare_weights(self, w, plan):
        t = plan.params.get("t_fft")
        if t is None:
            raise ValueError(f"{self.name} plan without t_fft: {plan.params}")
        return transform_kernels_fft(w, t)

    def execute(self, x, w, wt, plan):
        y = conv2d_fft_fused(
            x, w, pad=plan.spec.pad,
            t=plan.params.get("t_fft", self.default_t),
            r_tiles=plan.params.get("r_tiles", 16), wt=wt,
        )
        return registry.decimate(y, plan.spec.stride)


registry.register(FFTFusedAlgorithm())
