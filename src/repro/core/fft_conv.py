"""FFT-based transformed convolution (the paper's second transform family).

Same OLA tiling and task structure as the Winograd path -- literally the
same code now: the task loop lives in `repro.core.pipeline` and this
module drives it with an `FFTTransform` (rfft basis, channel mix per
frequency as a complex matmul; alpha = 2 in the paper's FLOP accounting).
Cross-correlation comes via the correlation theorem; the circular
wrap-around only contaminates the last K-1 rows/cols, which OLA discards.

Being engine-backed makes FFT a first-class fusion-group citizen: it
inherits in-task epilogue fusion (`fuse_epilogue`) and generic staged
chain execution (`execute_staged`), so the planner may build FFT-backed
cross-layer fusion groups exactly as it does Winograd ones.  bf16 inputs
take a real reduced-precision path (FFT computed in fp32, assembled
output cast back) rather than a capability fallback.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import pipeline, registry, transforms


def transform_kernels_fft(w: jnp.ndarray, t: int) -> jnp.ndarray:
    """HWIO (K, K, C, C') -> (T, T//2+1, C, C') complex right-hand matrices."""
    return transforms.FFTTransform(t=t, k=w.shape[0]).kernel_transform(w)


def conv2d_fft_fused(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    pad: int = 0,
    t: int = 16,
    r_tiles: int = 16,
    wt: Optional[jnp.ndarray] = None,
    groups: int = 1,
    epilogue=None,
) -> jnp.ndarray:
    """NHWC L3-fused FFT convolution (paper: T >= 16 works well for FFT)."""
    return pipeline.fused_tile_conv(
        x, w, transforms.FFTTransform(t=t, k=w.shape[0]),
        pad=pad, r_tiles=r_tiles, wt=wt, groups=groups, epilogue=epilogue,
    )


class FFTFusedAlgorithm(pipeline.TransformedAlgorithm):
    """The FFT transform family as a registry algorithm (tier 0).

    alpha = 2 in the cost entry (complex channel-mix matmuls) with the
    rfft half-spectrum's complex working set priced exactly through
    `TileAlgebra`; feasible only when the padded input covers a full
    T_fft tile -- below that the tile is mostly padding and the
    flops-per-pixel comparison collapses.
    """

    name = "fft_fused"
    tier = 0
    rank = 20
    weight_params = ("t_fft",)
    chain_family = "fft"
    tile_param = "t_fft"
    default_tile = 16  # the paper: T >= 16 works well for FFT
    r_floor_base = 4

    def supports(self, spec: registry.ConvSpec) -> bool:
        # lax.fft computes in f32/f64; bf16/fp16 ride the fp32 compute
        # path and are cast back after assembly (a real path, not a
        # fallback).  Temporal (1-D causal) specs have different pad
        # semantics and belong to the conv1d algorithm.
        return not spec.temporal and spec.dtype in (
            "float32", "float64", "bfloat16", "float16"
        )

    def make_transform(self, spec, params):
        return transforms.FFTTransform(t=int(params["t_fft"]), k=spec.k)


registry.register(FFTFusedAlgorithm())
