"""repro.core -- the paper's contribution: L3-fused transformed convolutions.

The public surface is `ConvSpec` (the problem), the algorithm registry
(`repro.core.registry`: plan/prepare/execute lifecycle), and `conv2d`
(the thin dispatcher).
"""

from repro.core.conv import conv1d_depthwise_causal, conv2d, conv2d_direct
from repro.core.fused import conv2d_l3_fused
from repro.core.registry import AlgoPlan, Algorithm, ConvSpec, plan_conv
from repro.core.three_stage import conv2d_three_stage

__all__ = [
    "Algorithm",
    "AlgoPlan",
    "ConvSpec",
    "plan_conv",
    "conv2d",
    "conv2d_direct",
    "conv2d_l3_fused",
    "conv2d_three_stage",
    "conv1d_depthwise_causal",
]
