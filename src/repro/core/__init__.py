"""repro.core -- the paper's contribution: L3-fused transformed convolutions."""

from repro.core.conv import conv1d_depthwise_causal, conv2d, conv2d_direct
from repro.core.fused import conv2d_l3_fused
from repro.core.three_stage import conv2d_three_stage

__all__ = [
    "conv2d",
    "conv2d_direct",
    "conv2d_l3_fused",
    "conv2d_three_stage",
    "conv1d_depthwise_causal",
]
