"""Small shared I/O helpers."""

from __future__ import annotations

import os
import pathlib
import tempfile


def atomic_write_text(path: pathlib.Path, text: str) -> None:
    """Write-temp-then-rename so concurrent writers never publish torn
    files (mkstemp gives each writer its own temp name)."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
