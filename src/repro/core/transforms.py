"""Winograd (Cook-Toom) and FFT transform construction.

Winograd F(m, r): computes m outputs of a valid 1-D correlation with an
r-tap filter from a tile of n = m + r - 1 inputs as

    y = A^T [ (G g) . (B^T d) ]            (Lavin & Gray form)

We construct the matrices exactly, over rationals, via the transpose/dual of
Toom-Cook polynomial multiplication with n-1 finite interpolation points and
one point at infinity:

  full linear convolution u = z * g (sizes m, r -> n) is exactly

      u = E^{-1} [ (Vz z) . (Vg g) ]

  where Vz[i,:] = [a_i^0 .. a_i^{m-1}]  (last row = leading-coeff / infinity),
        Vg[i,:] = [a_i^0 .. a_i^{r-1}]  (last row = leading-coeff),
        E[i,:]  = [a_i^0 .. a_i^{n-1}]  (last row = leading-coeff).

  The map z -> u for fixed g is M z with M[s, i] = g_{s-i}; its transpose
  M^T d computes (M^T d)_i = sum_k g_k d_{i+k} -- exactly the correlation.
  Transposing the Toom-Cook factorisation gives

      y = Vz^T diag(Vg g) E^{-T} d   =>   A^T = Vz^T,  G = Vg,  B^T = E^{-T}.

All arithmetic over `fractions.Fraction`, converted to float32/float64 at the
end, so the only rounding is the final representation -- the transform
matrices themselves are exact.
"""

from __future__ import annotations

import dataclasses
import functools
from fractions import Fraction
from typing import ClassVar, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

# Canonical interpolation-point sequence.  The ordering matters for numerical
# stability (Lavin & Gray; wincnn): small magnitudes and +/- pairs first.
_CANONICAL_POINTS: Tuple[Fraction, ...] = tuple(
    Fraction(p)
    for p in [
        0,
        1,
        -1,
        Fraction(1, 2),
        Fraction(-1, 2),
        2,
        -2,
        Fraction(1, 4),
        Fraction(-1, 4),
        4,
        -4,
        Fraction(3, 4),
        Fraction(-3, 4),
        Fraction(4, 3),
        Fraction(-4, 3),
        3,
        -3,
    ]
)


def interpolation_points(n_finite: int) -> Tuple[Fraction, ...]:
    """First `n_finite` canonical finite interpolation points."""
    if n_finite > len(_CANONICAL_POINTS):
        raise ValueError(
            f"need {n_finite} interpolation points, have "
            f"{len(_CANONICAL_POINTS)} canonical ones"
        )
    return _CANONICAL_POINTS[:n_finite]


def _vandermonde(points: Sequence[Fraction], width: int) -> list[list[Fraction]]:
    """Rows [a^0 .. a^{width-1}] per finite point, plus the infinity row."""
    rows = [[p ** j for j in range(width)] for p in points]
    rows.append([Fraction(0)] * (width - 1) + [Fraction(1)])
    return rows


def _invert_exact(mat: list[list[Fraction]]) -> list[list[Fraction]]:
    """Exact Gauss-Jordan inverse over Fractions."""
    n = len(mat)
    aug = [row[:] + [Fraction(int(i == j)) for j in range(n)] for i, row in enumerate(mat)]
    for col in range(n):
        pivot = next((r for r in range(col, n) if aug[r][col] != 0), None)
        if pivot is None:
            raise ValueError("singular interpolation matrix (repeated points?)")
        aug[col], aug[pivot] = aug[pivot], aug[col]
        inv_p = Fraction(1) / aug[col][col]
        aug[col] = [v * inv_p for v in aug[col]]
        for r in range(n):
            if r != col and aug[r][col] != 0:
                f = aug[r][col]
                aug[r] = [a - f * b for a, b in zip(aug[r], aug[col])]
    return [row[n:] for row in aug]


@functools.lru_cache(maxsize=None)
def winograd_matrices_exact(m: int, r: int):
    """Exact Fraction-valued (A^T, G, B^T) for F(m, r). Shapes (m,n),(n,r),(n,n)."""
    if m < 1 or r < 1:
        raise ValueError("m and r must be positive")
    n = m + r - 1
    if n == 1:  # degenerate 1x1 "conv"
        one = [[Fraction(1)]]
        return one, one, one
    pts = interpolation_points(n - 1)
    vz = _vandermonde(pts, m)  # n x m
    vg = _vandermonde(pts, r)  # n x r
    ev = _vandermonde(pts, n)  # n x n
    ev_inv = _invert_exact(ev)
    at = [[vz[j][i] for j in range(n)] for i in range(m)]  # Vz^T: m x n
    bt = [[ev_inv[j][i] for j in range(n)] for i in range(n)]  # E^{-T}: n x n
    return at, vg, bt


def _to_np(mat, dtype) -> np.ndarray:
    return np.array([[float(v) for v in row] for row in mat], dtype=dtype)


@functools.lru_cache(maxsize=None)
def winograd_matrices(m: int, r: int, dtype=np.float32):
    """(A^T, G, B^T) for F(m, r) as numpy arrays.

    A^T: (m, n)   output (inverse) transform
    G  : (n, r)   kernel transform
    B^T: (n, n)   input transform,  n = m + r - 1 (the tile size T)
    """
    at, g, bt = winograd_matrices_exact(m, r)
    return _to_np(at, dtype), _to_np(g, dtype), _to_np(bt, dtype)


def tile_size(m: int, r: int) -> int:
    return m + r - 1


def output_tile(t: int, r: int) -> int:
    """T' = T - K + 1."""
    return t - r + 1


# ---------------------------------------------------------------------------
# FFT transforms.  For tile size T, cross-correlation with a K-tap kernel is
# computed via the correlation theorem on a T-point (r)FFT:
#     y = irfft( rfft(d) * conj(rfft(g, n=T)) )[0 : T-K+1]
# The wrap-around of the circular correlation only contaminates the last K-1
# outputs, which the OLA tiling discards.  The transformed-kernel tensor is
# complex with T/2+1 frequencies per axis -- the paper's "conjugate
# anti-symmetric" ~2x saving falls out of using rfft directly.
# ---------------------------------------------------------------------------


def fft_num_freqs(t: int) -> int:
    return t // 2 + 1


def fft_flops_per_point() -> int:
    """Complex multiply-accumulate = 4 real mults + 4 adds (paper's alpha=2)."""
    return 8


# ---------------------------------------------------------------------------
# The Transform protocol.
#
# The paper's task pipeline -- gather R tiles, forward-transform, channel-mix
# against stationary right-hand matrices, inverse-transform, scatter -- is
# transform-agnostic: only the basis change and the domain the channel mix
# runs in differ between Winograd and FFT.  A `Transform` packages exactly
# that difference, so one tile engine (repro.core.pipeline) serves every
# family, and the cost model sees each family through its `TileAlgebra`.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TileAlgebra:
    """Cost/working-set terms of one transform family at one tile size.

    Everything the roofline model (core.analysis), the R-tuner (core.tune)
    and the fusion-group planner need to reason about a transform without
    knowing its math:

      alpha          real-MAC multiplier of the channel mix in the paper's
                     FLOP accounting (1 Winograd; 2 FFT -- the complex 4x
                     folded against the rfft half-spectrum)
      domain_points  stored domain elements per tile plane (T^2 Winograd,
                     T*(T/2+1) rfft frequencies)
      elem_bytes     bytes per stored domain element (4 real, 8 complex)
      planes         real planes per domain element as the tile kernel
                     stores them (1 real family, 2 complex re/im split)
    """

    family: str
    t: int
    t_out: int
    alpha: int
    domain_points: int
    elem_bytes: int = 4
    planes: int = 1

    def kernel_matrix_bytes(self, c_in: int, c_out: int, groups: int = 1) -> int:
        """Right-hand (transformed-kernel) matrices' resident footprint."""
        return self.elem_bytes * self.domain_points * (c_in // groups) * c_out

    def domain_tile_bytes(self, channels: int) -> int:
        """One transformed tile's bytes -- the per-tile working-set term."""
        return self.elem_bytes * self.domain_points * channels

    def flops_per_output_px(self) -> float:
        """Channel-mix FLOPs per output pixel, in units of C*C'."""
        return self.alpha * 2.0 * self.t * self.t / float(self.t_out**2)

    # ---- block-aware engine pricing -----------------------------------
    # The parametric tile kernel (kernels.fused_tile) runs every stage as
    # GEMMs: forward = (planes*S, T^2) basis matrix, mix = S batched
    # (P*C, P*C') products, inverse = (T'^2, planes*S).  These methods
    # count the MACs that kernel actually executes -- the terms the
    # calibrated roofline prices, replacing the mix-only idealization.

    def engine_macs_per_tile(
        self, c_in: int, c_out: int, groups: int = 1
    ) -> int:
        """Real MACs one input tile costs in the parametric tile kernel
        (forward basis GEMM + channel mix + inverse basis GEMM)."""
        p, s = self.planes, self.domain_points
        fwd = p * s * self.t * self.t * c_in
        mix = s * (p * c_in) * (p * c_out) // groups
        inv = self.t_out * self.t_out * p * s * c_out
        return fwd + mix + inv

    def engine_flops(
        self, out_h: int, out_w: int, c_in: int, c_out: int,
        groups: int = 1, batch: int = 1,
    ) -> int:
        """Total engine FLOPs covering an out_h x out_w output (the
        stride-1 tile grid -- strided convs decimate afterwards, so the
        full grid is the honest charge)."""
        n_tiles = -(-out_h // self.t_out) * (-(-out_w // self.t_out))
        return (
            2 * batch * n_tiles
            * self.engine_macs_per_tile(c_in, c_out, groups)
        )


@dataclasses.dataclass(frozen=True)
class TileKernelSpec:
    """One transform family compiled to the parametric tile kernel's
    matrix form (kernels.fused_tile).

    Every family's forward/inverse basis change is expressed as ONE real
    matrix acting on flattened (T*T) tiles -- the Kronecker (row (x)
    column) form -- with complex domains split into stacked re/im row
    planes.  The kernel then runs the identical gather -> fwd GEMM ->
    batched mix -> inv GEMM -> scatter program for Winograd and FFT:

      fwd  (planes*s_mix, T*T)      U_plane-major = fwd @ d_flat
      inv  (t_out*t_out, planes*s_mix)
      mix  s_mix batched (planes*C, planes*C') real GEMMs against
           `pack_rhs(wt)` -- the complex product spelled as the
           [[Wr, Wi], [-Wi, Wr]] real block form when planes == 2.

    Rows of `fwd` (and columns of `inv`) are PLANE-MAJOR: all s_mix
    re-rows, then all s_mix im-rows.  `pack_rhs` packs the cached
    family-native transformed kernels into the matching layout.
    """

    family: str
    t: int
    t_out: int
    k: int
    planes: int
    s_mix: int
    fwd: np.ndarray
    inv: np.ndarray

    def __post_init__(self):
        assert self.fwd.shape == (self.planes * self.s_mix, self.t * self.t)
        assert self.inv.shape == (
            self.t_out * self.t_out, self.planes * self.s_mix,
        )

    def pack_rhs(self, wt: jnp.ndarray, groups: int = 1) -> jnp.ndarray:
        """Family-native transformed kernels -> (s_mix, groups,
        planes*C/g, planes*C'/g) real mix matrices, group-blocked.

        Winograd wt: (S, C/g, C') real.  FFT wt: (T, F, C/g, C') complex
        (conjugated in `kernel_transform`); the complex channel mix
        U @ W becomes the real block form with plane-major channels.
        """
        s, g = self.s_mix, groups
        if self.planes == 1:
            w3 = wt.reshape(s, wt.shape[-2], wt.shape[-1])
            cg, c_out = w3.shape[1], w3.shape[2]
            return (
                w3.reshape(s, cg, g, c_out // g)
                .transpose(0, 2, 1, 3)
                .astype(jnp.float32)
            )
        w3 = wt.reshape(s, wt.shape[-2], wt.shape[-1])
        wr = jnp.real(w3).astype(jnp.float32)
        wi = jnp.imag(w3).astype(jnp.float32)
        blk = jnp.concatenate(
            [
                jnp.concatenate([wr, wi], axis=-1),
                jnp.concatenate([-wi, wr], axis=-1),
            ],
            axis=-2,
        )  # (s, 2*C/g, 2*C') plane-major both sides
        # group-block the columns *within* each plane: blk columns run
        # (plane, group, cgo) but each group's mix output must be
        # (plane, cgo) plane-major, matching the left-hand layout
        cg2, cgo = blk.shape[1], w3.shape[2] // g
        return (
            blk.reshape(s, cg2, 2, g, cgo)
            .transpose(0, 3, 1, 2, 4)
            .reshape(s, g, cg2, 2 * cgo)
        )

    def macs_per_tile(self, c_in: int, c_out: int, groups: int = 1) -> int:
        p, s = self.planes, self.s_mix
        return (
            p * s * self.t * self.t * c_in
            + s * (p * c_in) * (p * c_out) // groups
            + self.t_out * self.t_out * p * s * c_out
        )


@functools.lru_cache(maxsize=None)
def _winograd_kernel_spec(m: int, k: int) -> TileKernelSpec:
    at, _, bt = winograd_matrices(m, k)
    t = m + k - 1
    return TileKernelSpec(
        family="winograd", t=t, t_out=m, k=k, planes=1, s_mix=t * t,
        fwd=np.kron(bt, bt).astype(np.float32),
        inv=np.kron(at, at).astype(np.float32),
    )


@functools.lru_cache(maxsize=None)
def _fft_kernel_spec(t: int, k: int) -> TileKernelSpec:
    """rfft2 as explicit DFT GEMMs (the kernel's MXU-friendly spelling).

    Forward: U[x, f] = sum_{i,j} F[x,i] F[f,j] d[i,j] over the rfft
    half-spectrum f < F = T//2+1.  Inverse (irfft2 + crop, real part
    only): y[a,b] = Re( sum_{x,f} Grow[a,x] c_f Gcol[b,f] M[x,f] ) with
    c_f the hermitian doubling weights (1 at DC/Nyquist, 2 elsewhere).
    The kernel_transform wt already carries the correlation conjugate.
    """
    f = fft_num_freqs(t)
    t_out = t - k + 1
    ii = np.arange(t)
    dft = np.exp(-2j * np.pi * np.outer(ii, ii) / t)  # (T, T)
    kc = np.einsum("xi,fj->xfij", dft, dft[:f]).reshape(t * f, t * t)
    fwd = np.concatenate([kc.real, kc.imag], axis=0)
    grow = np.exp(2j * np.pi * np.outer(ii, ii) / t) / t
    cf = np.full(f, 2.0)
    cf[0] = 1.0
    if t % 2 == 0:
        cf[-1] = 1.0
    gcol = (np.exp(2j * np.pi * np.outer(ii, ii[:f]) / t) / t) * cf[None, :]
    kic = np.einsum(
        "ax,bf->abxf", grow[:t_out], gcol[:t_out]
    ).reshape(t_out * t_out, t * f)
    inv = np.concatenate([kic.real, -kic.imag], axis=1)
    return TileKernelSpec(
        family="fft", t=t, t_out=t_out, k=k, planes=2, s_mix=t * f,
        fwd=fwd.astype(np.float32), inv=inv.astype(np.float32),
    )


class Transform:
    """One transform family's basis change, as the tile engine drives it.

    Tiles flow (N, T, T, C) -> forward -> domain -> multiply (channel mix
    against right-hand matrices from `kernel_transform`) -> inverse ->
    (N, T', T', C').  `domain_dtype` names the dtype tiles occupy between
    forward and inverse; inputs outside the family's compute domain (bf16
    for FFT) are lifted in `forward` and restored by the engine after
    assembly.  `algebra` feeds the cost model.

    `kernel_spec` lowers the family to the parametric tile kernel's
    matrix form (`TileKernelSpec`); families without one (None) fall
    back to the interpreting scan engine.
    """

    family: ClassVar[str] = ""

    t: int
    k: int

    def kernel_spec(self) -> "TileKernelSpec | None":
        return None

    @property
    def t_out(self) -> int:
        return self.t - self.k + 1

    @property
    def algebra(self) -> TileAlgebra:
        raise NotImplementedError

    def forward(self, tiles: jnp.ndarray) -> jnp.ndarray:
        """(N, T, T, C) spatial tiles -> transform-domain tiles."""
        raise NotImplementedError

    def multiply(
        self, u: jnp.ndarray, wt: jnp.ndarray, groups: int = 1
    ) -> jnp.ndarray:
        """Channel mix in the transform domain; block-diagonal over groups."""
        raise NotImplementedError

    def inverse(self, u: jnp.ndarray) -> jnp.ndarray:
        """Domain tiles -> (N, T', T', C') output tiles."""
        raise NotImplementedError

    def kernel_transform(self, w: jnp.ndarray) -> jnp.ndarray:
        """HWIO kernels -> right-hand matrices (the ahead-of-time step)."""
        raise NotImplementedError

    def domain_dtype(self, dtype) -> jnp.dtype:
        """Dtype of transformed tiles for `dtype` inputs."""
        raise NotImplementedError


def _grouped_mix(u2, wt, groups, sub):
    """Block-diagonal channel mix: u2 (N, S, C), wt (S, C/g, C') where
    output channel j belongs to group j // (C'/g).  `sub` is the einsum
    over one group's channels."""
    n, s, c = u2.shape
    c_out = wt.shape[-1]
    ug = u2.reshape(n, s, groups, c // groups)
    wg = wt.reshape(s, c // groups, groups, c_out // groups)
    return jnp.einsum(sub, ug, wg).reshape(n, s, c_out)


@dataclasses.dataclass(frozen=True)
class WinogradTransform(Transform):
    """F(m, r) Cook-Toom basis: y = A^T [ (G g) . (B^T d) ] A."""

    m: int
    k: int

    family: ClassVar[str] = "winograd"

    @property
    def t(self) -> int:  # type: ignore[override]
        return self.m + self.k - 1

    @property
    def algebra(self) -> TileAlgebra:
        return TileAlgebra(
            family=self.family, t=self.t, t_out=self.m, alpha=1,
            domain_points=self.t * self.t, elem_bytes=4, planes=1,
        )

    def kernel_spec(self) -> TileKernelSpec:
        return _winograd_kernel_spec(self.m, self.k)

    def _mats(self, dtype):
        at, _, bt = winograd_matrices(self.m, self.k)
        return jnp.asarray(at, dtype), jnp.asarray(bt, dtype)

    def forward(self, tiles):
        _, bt = self._mats(tiles.dtype)
        return jnp.einsum("xi,nijc,yj->nxyc", bt, tiles, bt)

    def multiply(self, u, wt, groups: int = 1):
        n = u.shape[0]
        t = self.t
        u2 = u.reshape(n, t * t, -1)
        if groups == 1:
            mm = jnp.einsum("nsc,scd->nsd", u2, wt)
        else:
            mm = _grouped_mix(u2, wt, groups, "nsgc,scgd->nsgd")
        return mm.reshape(n, t, t, -1)

    def inverse(self, u):
        at, _ = self._mats(u.dtype)
        return jnp.einsum("xi,nijc,yj->nxyc", at, u, at)

    def kernel_transform(self, w):
        _, g, _ = winograd_matrices(self.m, self.k)
        g = jnp.asarray(g, w.dtype)
        wt = jnp.einsum("xi,ijcd,yj->xycd", g, w, g)
        return wt.reshape(self.t * self.t, w.shape[2], w.shape[3])

    def domain_dtype(self, dtype) -> jnp.dtype:
        return jnp.dtype(dtype)


@dataclasses.dataclass(frozen=True)
class FFTTransform(Transform):
    """T-point rfft basis; cross-correlation via the correlation theorem.

    Computes in fp32/fp64 regardless of the input dtype: sub-fp32 inputs
    (bf16, fp16) are lifted to fp32 in `forward` / `kernel_transform` and
    the engine casts the assembled output back -- a real reduced-precision
    path, not a capability fallback.
    """

    t: int
    k: int

    family: ClassVar[str] = "fft"

    @property
    def algebra(self) -> TileAlgebra:
        return TileAlgebra(
            family=self.family, t=self.t, t_out=self.t_out, alpha=2,
            domain_points=self.t * fft_num_freqs(self.t), elem_bytes=8,
            planes=2,
        )

    def kernel_spec(self) -> TileKernelSpec:
        return _fft_kernel_spec(self.t, self.k)

    @staticmethod
    def _lift(x):
        return x.astype(jnp.float32) if x.dtype not in (
            jnp.float32, jnp.float64
        ) else x

    def forward(self, tiles):
        return jnp.fft.rfft2(self._lift(tiles), axes=(1, 2))  # (N, T, F, C)

    def multiply(self, u, wt, groups: int = 1):
        if groups == 1:
            return jnp.einsum("nxfc,xfcd->nxfd", u, wt)
        n, x, f, _ = u.shape
        mm = _grouped_mix(
            u.reshape(n, x * f, -1), wt.reshape(x * f, *wt.shape[2:]),
            groups, "nsgc,scgd->nsgd",
        )
        return mm.reshape(n, x, f, -1)

    def inverse(self, u):
        y = jnp.fft.irfft2(u, s=(self.t, self.t), axes=(1, 2))
        return y[:, : self.t_out, : self.t_out, :]

    def kernel_transform(self, w):
        wf = jnp.fft.rfft2(self._lift(w), s=(self.t, self.t), axes=(0, 1))
        return jnp.conj(wf)  # (T, F, C, C')

    def domain_dtype(self, dtype) -> jnp.dtype:
        return jnp.dtype(
            jnp.complex128 if jnp.dtype(dtype) == jnp.float64 else jnp.complex64
        )
