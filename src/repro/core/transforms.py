"""Winograd (Cook-Toom) and FFT transform construction.

Winograd F(m, r): computes m outputs of a valid 1-D correlation with an
r-tap filter from a tile of n = m + r - 1 inputs as

    y = A^T [ (G g) . (B^T d) ]            (Lavin & Gray form)

We construct the matrices exactly, over rationals, via the transpose/dual of
Toom-Cook polynomial multiplication with n-1 finite interpolation points and
one point at infinity:

  full linear convolution u = z * g (sizes m, r -> n) is exactly

      u = E^{-1} [ (Vz z) . (Vg g) ]

  where Vz[i,:] = [a_i^0 .. a_i^{m-1}]  (last row = leading-coeff / infinity),
        Vg[i,:] = [a_i^0 .. a_i^{r-1}]  (last row = leading-coeff),
        E[i,:]  = [a_i^0 .. a_i^{n-1}]  (last row = leading-coeff).

  The map z -> u for fixed g is M z with M[s, i] = g_{s-i}; its transpose
  M^T d computes (M^T d)_i = sum_k g_k d_{i+k} -- exactly the correlation.
  Transposing the Toom-Cook factorisation gives

      y = Vz^T diag(Vg g) E^{-T} d   =>   A^T = Vz^T,  G = Vg,  B^T = E^{-T}.

All arithmetic over `fractions.Fraction`, converted to float32/float64 at the
end, so the only rounding is the final representation -- the transform
matrices themselves are exact.
"""

from __future__ import annotations

import functools
from fractions import Fraction
from typing import Sequence, Tuple

import numpy as np

# Canonical interpolation-point sequence.  The ordering matters for numerical
# stability (Lavin & Gray; wincnn): small magnitudes and +/- pairs first.
_CANONICAL_POINTS: Tuple[Fraction, ...] = tuple(
    Fraction(p)
    for p in [
        0,
        1,
        -1,
        Fraction(1, 2),
        Fraction(-1, 2),
        2,
        -2,
        Fraction(1, 4),
        Fraction(-1, 4),
        4,
        -4,
        Fraction(3, 4),
        Fraction(-3, 4),
        Fraction(4, 3),
        Fraction(-4, 3),
        3,
        -3,
    ]
)


def interpolation_points(n_finite: int) -> Tuple[Fraction, ...]:
    """First `n_finite` canonical finite interpolation points."""
    if n_finite > len(_CANONICAL_POINTS):
        raise ValueError(
            f"need {n_finite} interpolation points, have "
            f"{len(_CANONICAL_POINTS)} canonical ones"
        )
    return _CANONICAL_POINTS[:n_finite]


def _vandermonde(points: Sequence[Fraction], width: int) -> list[list[Fraction]]:
    """Rows [a^0 .. a^{width-1}] per finite point, plus the infinity row."""
    rows = [[p ** j for j in range(width)] for p in points]
    rows.append([Fraction(0)] * (width - 1) + [Fraction(1)])
    return rows


def _invert_exact(mat: list[list[Fraction]]) -> list[list[Fraction]]:
    """Exact Gauss-Jordan inverse over Fractions."""
    n = len(mat)
    aug = [row[:] + [Fraction(int(i == j)) for j in range(n)] for i, row in enumerate(mat)]
    for col in range(n):
        pivot = next((r for r in range(col, n) if aug[r][col] != 0), None)
        if pivot is None:
            raise ValueError("singular interpolation matrix (repeated points?)")
        aug[col], aug[pivot] = aug[pivot], aug[col]
        inv_p = Fraction(1) / aug[col][col]
        aug[col] = [v * inv_p for v in aug[col]]
        for r in range(n):
            if r != col and aug[r][col] != 0:
                f = aug[r][col]
                aug[r] = [a - f * b for a, b in zip(aug[r], aug[col])]
    return [row[n:] for row in aug]


@functools.lru_cache(maxsize=None)
def winograd_matrices_exact(m: int, r: int):
    """Exact Fraction-valued (A^T, G, B^T) for F(m, r). Shapes (m,n),(n,r),(n,n)."""
    if m < 1 or r < 1:
        raise ValueError("m and r must be positive")
    n = m + r - 1
    if n == 1:  # degenerate 1x1 "conv"
        one = [[Fraction(1)]]
        return one, one, one
    pts = interpolation_points(n - 1)
    vz = _vandermonde(pts, m)  # n x m
    vg = _vandermonde(pts, r)  # n x r
    ev = _vandermonde(pts, n)  # n x n
    ev_inv = _invert_exact(ev)
    at = [[vz[j][i] for j in range(n)] for i in range(m)]  # Vz^T: m x n
    bt = [[ev_inv[j][i] for j in range(n)] for i in range(n)]  # E^{-T}: n x n
    return at, vg, bt


def _to_np(mat, dtype) -> np.ndarray:
    return np.array([[float(v) for v in row] for row in mat], dtype=dtype)


@functools.lru_cache(maxsize=None)
def winograd_matrices(m: int, r: int, dtype=np.float32):
    """(A^T, G, B^T) for F(m, r) as numpy arrays.

    A^T: (m, n)   output (inverse) transform
    G  : (n, r)   kernel transform
    B^T: (n, n)   input transform,  n = m + r - 1 (the tile size T)
    """
    at, g, bt = winograd_matrices_exact(m, r)
    return _to_np(at, dtype), _to_np(g, dtype), _to_np(bt, dtype)


def tile_size(m: int, r: int) -> int:
    return m + r - 1


def output_tile(t: int, r: int) -> int:
    """T' = T - K + 1."""
    return t - r + 1


# ---------------------------------------------------------------------------
# FFT transforms.  For tile size T, cross-correlation with a K-tap kernel is
# computed via the correlation theorem on a T-point (r)FFT:
#     y = irfft( rfft(d) * conj(rfft(g, n=T)) )[0 : T-K+1]
# The wrap-around of the circular correlation only contaminates the last K-1
# outputs, which the OLA tiling discards.  The transformed-kernel tensor is
# complex with T/2+1 frequencies per axis -- the paper's "conjugate
# anti-symmetric" ~2x saving falls out of using rfft directly.
# ---------------------------------------------------------------------------


def fft_num_freqs(t: int) -> int:
    return t // 2 + 1


def fft_flops_per_point() -> int:
    """Complex multiply-accumulate = 4 real mults + 4 adds (paper's alpha=2)."""
    return 8
