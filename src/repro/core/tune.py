"""The paper's "wisdom file" (S7): measured R tuning, cached on disk.

    from repro.core.tune import tuned_r
    r = tuned_r(h=56, w=56, c_in=64, c_out=64)   # measures once, caches

The analytical bounds (core.analysis) give the feasible range; within it we
time the fused convolution at a few candidate R values and store the
winner keyed by (layer geometry, tile size, backend).
"""

from __future__ import annotations

import functools
import json
import pathlib
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analysis
from repro.core.fused import conv2d_l3_fused

_DEFAULT_WISDOM = pathlib.Path.home() / ".cache" / "repro_wisdom.json"
_CANDIDATES = (4, 8, 16, 24, 32, 48)


def _key(h, w, c_in, c_out, k, m) -> str:
    return f"{jax.default_backend()}:{h}x{w}x{c_in}->{c_out}:k{k}:m{m}"


def _load(path: pathlib.Path) -> dict:
    try:
        return json.loads(path.read_text())
    except (OSError, ValueError):
        return {}


def measure_r(
    h: int, w: int, c_in: int, c_out: int, *, k: int = 3, m: int = 5,
    batch: int = 1, candidates: Sequence[int] = _CANDIDATES, reps: int = 3,
) -> int:
    """Time the fused conv at each candidate R; return the fastest."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((batch, h, w, c_in)) * 0.1, jnp.float32)
    wk = jnp.asarray(rng.standard_normal((k, k, c_in, c_out)) * 0.1, jnp.float32)
    hw = analysis.TPU_V5E if jax.default_backend() == "tpu" else analysis.SKYLAKE_X
    r_max = analysis.max_r(hw, c_in, c_out, m + k - 1)
    best_r, best_t = None, float("inf")
    for r in candidates:
        if r > max(r_max, min(candidates)):
            continue
        fn = jax.jit(
            functools.partial(conv2d_l3_fused, pad=1, m=m, r_tiles=r)
        )
        jax.block_until_ready(fn(x, wk))  # compile
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x, wk))
            ts.append(time.perf_counter() - t0)
        t = sorted(ts)[len(ts) // 2]
        if t < best_t:
            best_r, best_t = r, t
    return best_r if best_r is not None else min(candidates)


def tuned_r(
    h: int, w: int, c_in: int, c_out: int, *, k: int = 3, m: int = 5,
    wisdom_path: Optional[pathlib.Path] = None,
) -> int:
    """Cached best R for this layer geometry (measures on first use)."""
    path = pathlib.Path(wisdom_path or _DEFAULT_WISDOM)
    wisdom = _load(path)
    key = _key(h, w, c_in, c_out, k, m)
    if key in wisdom:
        return int(wisdom[key])
    r = measure_r(h, w, c_in, c_out, k=k, m=m)
    wisdom[key] = int(r)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(wisdom, indent=1, sort_keys=True))
    return r
