"""The paper's "wisdom file" (S7): measured R tuning, cached on disk.

    from repro.core.tune import tuned_r, predict_r
    r = tuned_r(h=56, w=56, c_in=64, c_out=64)   # measures once, caches
    r = predict_r(c_in=64, c_out=64)             # analytic only, no timing

The analytical bounds (core.analysis) give the feasible range; within it we
time the fused convolution at a few candidate R values and store the
winner keyed by (transform family, tile size, layer geometry, backend) --
family in the key, so a Winograd-R and an FFT-T tune for the same layer
can never collide or overwrite each other.  `predict_r` is the
non-measuring path used by the convserve planner when tuning is disabled:
it picks the candidate that satisfies the R >= 2 CMR_fast lower bound while
staying within the (family-exact, `TileAlgebra`-priced) private-memory
upper bound.

Every entry point takes an optional `transform` (a `core.transforms`
Transform); the m/k keyword pair is the historical Winograd-only spelling
and resolves to `WinogradTransform(m, k)`.
"""

from __future__ import annotations

import functools
import json
import os
import pathlib
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analysis, transforms
from repro.core.ioutil import atomic_write_text
from repro.core.pipeline import fused_tile_conv
from repro.kernels.fused_tile.blocks import BlockConfig

_DEFAULT_WISDOM = pathlib.Path.home() / ".cache" / "repro_wisdom.json"
_CANDIDATES = (4, 8, 16, 24, 32, 48)
_WISDOM_ENV = "REPRO_WISDOM"


def _wisdom_path(wisdom_path=None) -> pathlib.Path:
    """Explicit path > $REPRO_WISDOM (the CI artifact seam) > default."""
    if wisdom_path is not None:
        return pathlib.Path(wisdom_path)
    env = os.environ.get(_WISDOM_ENV)
    return pathlib.Path(env) if env else _DEFAULT_WISDOM


def _resolve_transform(
    transform: Optional[transforms.Transform], k: int, m: int
) -> transforms.Transform:
    return (
        transform
        if transform is not None
        else transforms.WinogradTransform(m=m, k=k)
    )


def _key(tr: transforms.Transform, h, w, c_in, c_out) -> str:
    """Wisdom key: backend + transform family + tile size + geometry."""
    return (
        f"{jax.default_backend()}:{tr.family}:{h}x{w}x{c_in}->{c_out}"
        f":k{tr.k}:t{tr.t}"
    )


def _load(path: pathlib.Path) -> dict:
    try:
        return json.loads(path.read_text())
    except (OSError, ValueError):
        return {}


# Wisdom values are either a bare int R (legacy files) or a stamped entry
# {"r": int, "gen": int, "ts": float}.  `gen` is a monotonic generation
# counter per wisdom file; `ts` is wall-clock seconds.  Stamps let online
# measurement layers (convserve.adapt) and offline tuning expire each
# other's entries by age or generation instead of silently shadowing.


def _entry_r(value) -> Optional[int]:
    """R from a wisdom value; None when the entry carries only other
    dimensions (e.g. a block shape tuned before any R pass)."""
    if isinstance(value, dict):
        return int(value["r"]) if "r" in value else None
    return int(value)


def _entry_gen(value) -> int:
    return int(value.get("gen", 0)) if isinstance(value, dict) else 0


def _entry_ts(value) -> float:
    return float(value.get("ts", 0.0)) if isinstance(value, dict) else 0.0


def wisdom_generation(wisdom_path: Optional[pathlib.Path] = None) -> int:
    """Highest generation stamped in the wisdom file (0 when empty or
    fully legacy).  Writers stamp `wisdom_generation() + 1`."""
    path = _wisdom_path(wisdom_path)
    wisdom = _load_cached(path)
    return max((_entry_gen(v) for v in wisdom.values()), default=0)


def entry_info(
    h: int, w: int, c_in: int, c_out: int, *, k: int = 3, m: int = 5,
    transform: Optional[transforms.Transform] = None,
    wisdom_path: Optional[pathlib.Path] = None,
) -> Optional[dict]:
    """Full stamped view of one wisdom entry: {"r", "gen", "ts"}, with
    legacy bare-int entries normalized to gen 0 / ts 0.0.  None when the
    key has never been tuned."""
    path = _wisdom_path(wisdom_path)
    wisdom = _load_cached(path)
    key = _key(_resolve_transform(transform, k, m), h, w, c_in, c_out)
    if key not in wisdom:
        return None
    v = wisdom[key]
    return {"r": _entry_r(v), "gen": _entry_gen(v), "ts": _entry_ts(v)}


_WISDOM_CACHE: dict = {}  # path -> (mtime_ns, parsed wisdom)


def _load_cached(path: pathlib.Path) -> dict:
    """mtime-validated wisdom read: `lookup_r` runs on every auto-dispatch
    plan, so it must not re-read and re-parse the file per call.  Writers
    (`tuned_r`) go through the uncached `_load` -- the atomic replace
    bumps mtime_ns, which invalidates this cache."""
    try:
        stamp = path.stat().st_mtime_ns
    except OSError:
        stamp = None
    key = str(path)
    hit = _WISDOM_CACHE.get(key)
    if hit is not None and hit[0] == stamp:
        return hit[1]
    wisdom = _load(path) if stamp is not None else {}
    _WISDOM_CACHE[key] = (stamp, wisdom)
    return wisdom


def default_hw() -> analysis.HardwareModel:
    """Hardware model for the current backend (paper machines on CPU)."""
    return (
        analysis.TPU_V5E
        if jax.default_backend() == "tpu"
        else analysis.SKYLAKE_X
    )


def feasible_candidates(
    c_in: int, c_out: int, *, k: int = 3, m: int = 5,
    transform: Optional[transforms.Transform] = None,
    hw: Optional[analysis.HardwareModel] = None,
    candidates: Sequence[int] = _CANDIDATES,
) -> list:
    """Candidates within the private-memory upper bound; never empty --
    the smallest candidate survives even when the bound excludes all, so a
    degenerate geometry still tunes rather than erroring.  The bound is
    family-exact: complex FFT tiles halve the feasible R."""
    hw = hw or default_hw()
    tr = _resolve_transform(transform, k, m)
    r_max = analysis.max_r_ta(hw, c_in, c_out, tr.algebra)
    feas = [r for r in candidates if r <= r_max]
    return feas or [min(candidates)]


def predict_r(
    c_in: int, c_out: int, *, k: int = 3, m: int = 5,
    transform: Optional[transforms.Transform] = None,
    hw: Optional[analysis.HardwareModel] = None,
    candidates: Sequence[int] = _CANDIDATES,
) -> int:
    """Analytic (non-measuring) R choice: the smallest feasible candidate
    at or above the R >= 2 CMR_fast lower bound, else the largest feasible
    one.  Used when tuning is disabled; `tuned_r` refines it by timing."""
    hw = hw or default_hw()
    feas = feasible_candidates(
        c_in, c_out, k=k, m=m, transform=transform, hw=hw,
        candidates=candidates,
    )
    target = analysis.min_r(hw)
    at_or_above = [r for r in feas if r >= target]
    return min(at_or_above) if at_or_above else max(feas)


def lookup_r(
    h: int, w: int, c_in: int, c_out: int, *, k: int = 3, m: int = 5,
    transform: Optional[transforms.Transform] = None,
    wisdom_path: Optional[pathlib.Path] = None,
    max_age_s: Optional[float] = None,
    min_gen: int = 0,
    now: Optional[float] = None,
) -> Optional[int]:
    """Non-measuring wisdom read: the tuned R for this transform family +
    layer geometry if a previous `tuned_r` pass stored one, else None.
    This is how ``algo="auto"`` benefits from the wisdom file without
    ever paying a measurement at dispatch time.

    Staleness-aware: with `max_age_s` set, entries whose timestamp is
    older than ``now - max_age_s`` read as absent (legacy unstamped
    entries have ts 0.0 and therefore always expire); with `min_gen`
    set, entries stamped with an older generation read as absent."""
    path = _wisdom_path(wisdom_path)
    wisdom = _load_cached(path)
    key = _key(_resolve_transform(transform, k, m), h, w, c_in, c_out)
    if key not in wisdom:
        return None
    v = wisdom[key]
    if _entry_gen(v) < min_gen:
        return None
    if max_age_s is not None:
        now = time.time() if now is None else now
        ts = _entry_ts(v)
        # an age bound only admits entries of KNOWN age: legacy
        # unstamped entries (ts 0.0) read as absent unconditionally
        if ts <= 0.0 or ts < now - max_age_s:
            return None
    return _entry_r(v)


def measure_r(
    h: int, w: int, c_in: int, c_out: int, *, k: int = 3, m: int = 5,
    transform: Optional[transforms.Transform] = None,
    batch: int = 1, candidates: Sequence[int] = _CANDIDATES, reps: int = 3,
) -> int:
    """Time the fused conv at each candidate R; return the fastest.
    Transform-generic: the timed loop is the shared tile engine driven by
    `transform` (Winograd F(m, k) by default)."""
    tr = _resolve_transform(transform, k, m)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((batch, h, w, c_in)) * 0.1, jnp.float32)
    wk = jnp.asarray(
        rng.standard_normal((tr.k, tr.k, c_in, c_out)) * 0.1, jnp.float32
    )
    best_r, best_t = None, float("inf")
    for r in feasible_candidates(
        c_in, c_out, transform=tr, candidates=candidates
    ):
        fn = jax.jit(
            functools.partial(fused_tile_conv, transform=tr, pad=1, r_tiles=r)
        )
        jax.block_until_ready(fn(x, wk))  # compile
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x, wk))
            ts.append(time.perf_counter() - t0)
        t = sorted(ts)[len(ts) // 2]
        if t < best_t:
            best_r, best_t = r, t
    return best_r if best_r is not None else min(candidates)


def tuned_r(
    h: int, w: int, c_in: int, c_out: int, *, k: int = 3, m: int = 5,
    transform: Optional[transforms.Transform] = None,
    wisdom_path: Optional[pathlib.Path] = None,
) -> int:
    """Cached best R for this transform family + layer geometry (measures
    on first use)."""
    tr = _resolve_transform(transform, k, m)
    path = _wisdom_path(wisdom_path)
    wisdom = _load(path)
    key = _key(tr, h, w, c_in, c_out)
    if key in wisdom:
        hit = _entry_r(wisdom[key])
        if hit is not None:  # blocks-only entries still need an R pass
            return hit
    r = measure_r(h, w, c_in, c_out, transform=tr)
    wisdom = _load(path)  # re-read: another tuner may have written meanwhile
    gen = max((_entry_gen(v) for v in wisdom.values()), default=0) + 1
    entry = {"r": int(r), "gen": gen, "ts": time.time()}
    prev_blocks = _entry_blocks(wisdom.get(key))
    if prev_blocks is not None:  # merge, don't clobber, the other dimension
        entry["blocks"] = prev_blocks.to_wisdom()
    wisdom[key] = entry
    atomic_write_text(path, json.dumps(wisdom, indent=1, sort_keys=True))
    return r


# ---------------------------------------------------------------------------
# Block-shape wisdom for the parametric tile engine (kernels.fused_tile).
#
# A tuned entry's "blocks" field serializes a BlockConfig -- tile rows R,
# tasks-per-program (0 = the matrix path's unchunked sweep) and the mix
# unroll -- alongside the scan engine's "r".  Both ride the same
# backend:family:geometry key and the same stamped {gen, ts} envelope, so
# atomic rewrites and staleness logic treat them as one entry.
# ---------------------------------------------------------------------------


def block_candidates(
    c_in: int, c_out: int,
    transform: transforms.Transform,
    hw: Optional[analysis.HardwareModel] = None,
) -> list:
    """Candidate block shapes: feasible R values crossed with the
    unchunked sweep (tpp=0, the CPU default) and a chunked variant that
    bounds the transform-domain working set (what wins once the tile
    population outgrows the shared level)."""
    cands = []
    for r in feasible_candidates(
        c_in, c_out, transform=transform, hw=hw, candidates=(8, 16, 24, 32)
    ):
        cands.append(BlockConfig(r=r, tasks_per_program=0))
        cands.append(BlockConfig(r=r, tasks_per_program=8))
    return cands


def _entry_blocks(value) -> Optional[BlockConfig]:
    if isinstance(value, dict) and "blocks" in value:
        return BlockConfig.from_wisdom(value["blocks"])
    return None


def lookup_blocks(
    h: int, w: int, c_in: int, c_out: int, *, k: int = 3, m: int = 5,
    transform: Optional[transforms.Transform] = None,
    wisdom_path: Optional[pathlib.Path] = None,
) -> Optional[BlockConfig]:
    """Non-measuring read of the tuned block shape, None when untuned.
    Like `lookup_r`, this is the dispatch-time path: planning consults it
    on every auto plan and must never pay a measurement."""
    path = _wisdom_path(wisdom_path)
    wisdom = _load_cached(path)
    key = _key(_resolve_transform(transform, k, m), h, w, c_in, c_out)
    return _entry_blocks(wisdom.get(key))


def measure_blocks(
    h: int, w: int, c_in: int, c_out: int, *, k: int = 3, m: int = 5,
    transform: Optional[transforms.Transform] = None,
    batch: int = 1,
    candidates: Optional[Sequence[BlockConfig]] = None,
    reps: int = 3,
    backend: Optional[str] = None,
) -> BlockConfig:
    """Time the parametric tile engine at each candidate block shape on
    the real geometry; return the fastest.  `backend` overrides the
    engine backend (e.g. "pallas_interpret" so CPU CI tunes the exact
    kernel the accelerator runs)."""
    from repro.kernels import fused_tile as _ft

    tr = _resolve_transform(transform, k, m)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((batch, h, w, c_in)) * 0.1, jnp.float32)
    wk = jnp.asarray(
        rng.standard_normal((tr.k, tr.k, c_in, c_out)) * 0.1, jnp.float32
    )
    cands = list(candidates or block_candidates(c_in, c_out, tr))
    best, best_t = cands[0], float("inf")
    for blocks in cands:
        fn = jax.jit(
            functools.partial(
                _ft.conv2d_fused_tile, transform=tr, pad=1,
                blocks=blocks, backend=backend,
            )
        )
        try:
            jax.block_until_ready(fn(x, wk))  # compile
        except _ft.UnsupportedSpec:
            continue
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x, wk))
            ts.append(time.perf_counter() - t0)
        t = sorted(ts)[len(ts) // 2]
        if t < best_t:
            best, best_t = blocks, t
    return best


def tuned_blocks(
    h: int, w: int, c_in: int, c_out: int, *, k: int = 3, m: int = 5,
    transform: Optional[transforms.Transform] = None,
    wisdom_path: Optional[pathlib.Path] = None,
    backend: Optional[str] = None,
) -> BlockConfig:
    """Cached best block shape for this family + geometry (measures on
    first use).  Merges into the existing stamped entry -- a prior tuned
    R survives, and a concurrent tuner's writes are re-read before the
    atomic replace, mirroring `tuned_r`."""
    tr = _resolve_transform(transform, k, m)
    path = _wisdom_path(wisdom_path)
    key = _key(tr, h, w, c_in, c_out)
    hit = _entry_blocks(_load(path).get(key))
    if hit is not None:
        return hit
    blocks = measure_blocks(
        h, w, c_in, c_out, transform=tr, backend=backend
    )
    wisdom = _load(path)  # re-read: another tuner may have written meanwhile
    gen = max((_entry_gen(v) for v in wisdom.values()), default=0) + 1
    prev = wisdom.get(key)
    prev_r = _entry_r(prev) if prev is not None else None
    wisdom[key] = {
        "r": prev_r if prev_r is not None else int(blocks.r),
        "blocks": blocks.to_wisdom(),
        "gen": gen,
        "ts": time.time(),
    }
    atomic_write_text(path, json.dumps(wisdom, indent=1, sort_keys=True))
    return blocks


# ---------------------------------------------------------------------------
# Roofline calibration (one-shot GEMM / stream microbenchmark).
#
# The hardcoded paper machines (SKYLAKE_X et al.) describe 18-core AVX512
# boxes; on the actual host they can be orders of magnitude off, which
# turns `measured_over_predicted` into noise and poisons fusion-group
# decisions.  One measured {peak_flops, dram_bw} pair per backend, cached
# in the wisdom file under "calib:{backend}", anchors every roofline
# number to the machine the benchmarks actually run on.
# ---------------------------------------------------------------------------

_CALIB_PREFIX = "calib"
_CALIB_GEMM_N = 768
_CALIB_STREAM_MB = 32


def _calib_key() -> str:
    return f"{_CALIB_PREFIX}:{jax.default_backend()}"


def _time_best(fn, *args, reps: int = 5) -> float:
    jax.block_until_ready(fn(*args))  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def run_calibration() -> dict:
    """Measure achievable {peak_flops, dram_bw} on this host: a dense
    f32 GEMM for the compute roof, a big-array copy (read + write) for
    the memory roof.  Seconds to run, cached by `measure_calibration`."""
    n = _CALIB_GEMM_N
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((n, n)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal((n, n)) * 0.1, jnp.float32)
    t_gemm = _time_best(jax.jit(jnp.matmul), a, b)
    peak = 2.0 * n**3 / t_gemm
    m = _CALIB_STREAM_MB * 2**20 // 4
    x = jnp.ones((m,), jnp.float32)
    t_stream = _time_best(jax.jit(lambda v: v * 1.0001 + 0.5), x)
    bw = 2.0 * 4 * m / t_stream  # one read + one write per element
    return {"peak_flops": float(peak), "dram_bw": float(bw)}


def lookup_calibration(
    wisdom_path: Optional[pathlib.Path] = None,
) -> Optional[dict]:
    """Cached calibration for the current backend, None when never run."""
    entry = _load_cached(_wisdom_path(wisdom_path)).get(_calib_key())
    return dict(entry) if isinstance(entry, dict) else None


def measure_calibration(
    wisdom_path: Optional[pathlib.Path] = None, *, refresh: bool = False,
) -> dict:
    """Calibration with wisdom caching: measures once per backend per
    wisdom file, then serves the stamped cache (refresh=True re-runs)."""
    path = _wisdom_path(wisdom_path)
    if not refresh:
        hit = lookup_calibration(path)
        if hit is not None:
            return hit
    entry = run_calibration()
    wisdom = _load(path)
    gen = max((_entry_gen(v) for v in wisdom.values()), default=0) + 1
    entry = {**entry, "gen": gen, "ts": time.time()}
    wisdom[_calib_key()] = entry
    atomic_write_text(path, json.dumps(wisdom, indent=1, sort_keys=True))
    return entry
