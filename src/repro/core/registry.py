"""Unified convolution-algorithm registry.

The paper's central claim is that one transformed-conv *problem* admits
several interchangeable *realizations* (3-stage, L3-fused Winograd,
L3-fused FFT, direct) whose winner flips with layer geometry.  This module
makes that interchangeability first-class:

  * `ConvSpec` -- the problem: spatial dims, channels, kernel, pad,
    stride, groups, dtype.  Pure data, JSON-serializable.
  * `Algorithm` -- one realization: capabilities (`supports`), a cost
    entry wrapping the S5 roofline model, and the lifecycle

        plan(spec, hw)            -> AlgoPlan (algorithm-owned params)
        prepare_weights(w, plan)  -> right-hand matrices (or None)
        execute(x, w, wt, plan)   -> output

  * the registry itself -- `register`/`get`/`names`, and `plan_conv`,
    which resolves ``algo="auto"`` by ranking every supporting algorithm
    on (tier, modeled cost, rank) and resolves R through the wisdom file.

Adding an algorithm (or a new scenario: strided, grouped, ...) is a single
`register()` call -- `conv2d`, the convserve planner, the kernel cache,
and the executor all dispatch through here and never name algorithms.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core import analysis


# --------------------------------------------------------------- ConvSpec


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """A 2-D convolution problem: NHWC x HWIO -> NHWC.

    `h`/`w` are the (possibly non-square) input spatial dims the problem
    was posed at; executors may apply a plan to other runtime shapes --
    the structural fields (k, pad, stride, groups, dtype) are what the
    algorithms condition on.

    **Temporal specs** (``h == 1`` with ``k > 1``) pose a 1-D problem:
    the kernel is 1 x k along `w` (a length-`w` sequence of `c` channels)
    and `pad` is interpreted as CAUSAL left-only padding along `w` --
    ``pad = k - 1`` gives a same-length causal conv, the shape sequence
    models use.  2-D algorithms must decline temporal specs in
    `supports` (symmetric-pad k x k semantics do not apply); the fused
    conv1d kernel registers as their Algorithm.
    """

    h: int
    w: int
    c_in: int
    c_out: int
    k: int
    pad: int = 0
    stride: int = 1
    groups: int = 1
    dtype: str = "float32"

    def __post_init__(self):
        if min(self.h, self.w, self.c_in, self.c_out, self.k) < 1:
            raise ValueError(f"non-positive dimension in {self}")
        if self.pad < 0 or self.stride < 1 or self.groups < 1:
            raise ValueError(f"bad pad/stride/groups in {self}")
        if self.c_in % self.groups or self.c_out % self.groups:
            raise ValueError(
                f"channels ({self.c_in}->{self.c_out}) not divisible by "
                f"groups {self.groups}"
            )
        if self.temporal:
            if self.w + self.pad < self.k:
                raise ValueError(f"kernel larger than padded sequence: {self}")
        elif self.h + 2 * self.pad < self.k or self.w + 2 * self.pad < self.k:
            raise ValueError(f"kernel larger than padded input: {self}")

    @property
    def temporal(self) -> bool:
        """1-D (causal) problem posed on the `w` axis: h == 1, k > 1."""
        return self.h == 1 and self.k > 1

    @staticmethod
    def from_tensors(
        x, w, *, pad: int = 0, stride: int = 1, groups: int = 1
    ) -> "ConvSpec":
        """Describe the problem posed by concrete NHWC x / HWIO w tensors."""
        if x.ndim != 4 or w.ndim != 4:
            raise ValueError(f"expected NHWC x and HWIO w, got {x.shape}, {w.shape}")
        if w.shape[0] != w.shape[1]:
            raise ValueError(f"only square kernels supported, got {w.shape}")
        if w.shape[2] * groups != x.shape[3]:
            raise ValueError(
                f"kernel c_in {w.shape[2]} x groups {groups} != input "
                f"channels {x.shape[3]}"
            )
        return ConvSpec(
            h=int(x.shape[1]), w=int(x.shape[2]),
            c_in=int(x.shape[3]), c_out=int(w.shape[3]), k=int(w.shape[0]),
            pad=pad, stride=stride, groups=groups,
            dtype=jnp.dtype(x.dtype).name,
        )

    @property
    def out_hw(self) -> Tuple[int, int]:
        if self.temporal:  # causal left-only pad along w, h untouched
            return (1, (self.w + self.pad - self.k) // self.stride + 1)
        return (
            (self.h + 2 * self.pad - self.k) // self.stride + 1,
            (self.w + 2 * self.pad - self.k) // self.stride + 1,
        )

    @property
    def padded_min(self) -> int:
        """Smallest padded spatial extent -- the tile-fit criterion."""
        return min(self.h, self.w) + 2 * self.pad

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Mapping) -> "ConvSpec":
        return ConvSpec(**d)


# --------------------------------------------------------------- AlgoPlan


@dataclasses.dataclass(frozen=True)
class AlgoPlan:
    """One algorithm's resolved decision for one ConvSpec.

    `params` is algorithm-owned (m, t_fft, r_tiles, ...): nothing outside
    the owning algorithm interprets it, which is what lets the cache and
    executor stay algorithm-agnostic.  `cost` is the roofline-modeled time
    per output pixel used for auto ranking (inf == excluded from auto);
    it is not serialized.
    """

    algo: str
    spec: ConvSpec
    params: Dict[str, Any]
    predicted_util: float = 0.0
    cost: float = math.inf
    tuned: bool = False


def fused_auto_cost(
    spec: ConvSpec,
    hw: analysis.HardwareModel,
    ta,  # transforms.TileAlgebra
    r_floor: int,
    blocks=None,  # kernels.fused_tile.BlockConfig from wisdom, or None
) -> float:
    """Auto-ranking cost of one fused transform family on `spec`: inf when
    the padded input cannot cover a single T-tile or the roofline deems
    the family infeasible, else the modeled time per output pixel.

    With a wisdom-resolved block shape (`blocks`), the charge is the tile
    engine's actual MAC count at the tuned R (`analysis.engine_cost_ta`)
    -- decimation waste included via the per-final-pixel normalization,
    so no separate stride^2 penalty is added.  Without wisdom, the old
    analytic charge (`fused_cost_ta` x stride^2) stands as the fallback.
    Shared by every fused algorithm -- through each family's own
    `TileAlgebra` working-set terms -- so the feasibility gate cannot
    diverge and the planner's auto ranking picks the *transform* per
    layer, not just the algorithm."""
    if spec.padded_min < ta.t:
        return math.inf
    if blocks is not None:
        ec = analysis.engine_cost_ta(
            hw, spec.c_in, spec.c_out, ta, int(blocks.r),
            spec.groups, spec.stride,
        )
        if ec is not None:
            return ec
    fc = analysis.fused_cost_ta(
        hw, spec.c_in, spec.c_out, ta, r_floor, spec.groups
    )
    return math.inf if fc is None else fc * spec.stride**2


def decimate(y: jnp.ndarray, stride: int) -> jnp.ndarray:
    """Stride-s conv == stride-1 conv decimated: y_s[i,j] = y_1[s*i, s*j].

    The transformed algorithms (whose OLA tiling is inherently stride-1)
    gain strided output through this post-pass; their cost entries charge
    the stride^2 wasted pixels so auto ranking stays honest.
    """
    if stride == 1:
        return y
    return y[:, ::stride, ::stride, :]


# -------------------------------------------------------------- Algorithm


class ElementwiseOps:
    """Structured elementwise epilogue: a static op list plus its bias
    tensors, so fused kernels can fold the glue into their scatter phase
    instead of closing over arrays.

    `ops` is a tuple of ``("bias", jnp.ndarray(C',))`` and ``("relu",)``
    entries, applied in order.  Instances are callables ``y -> y`` --
    drop-in for the plain closures `ChainLink.elementwise` used to carry
    -- and `kernel_form()` exposes the (static op tags, stacked bias
    rows) pair the Pallas kernel consumes: arrays enter the kernel as a
    stationary input, tags stay Python-static.
    """

    def __init__(self, ops: Sequence[Tuple]):
        self.ops = tuple(
            (op[0], op[1]) if op[0] == "bias" else ("relu",) for op in ops
        )

    def __call__(self, y):
        for op in self.ops:
            y = y + op[1] if op[0] == "bias" else jnp.maximum(y, 0.0)
        return y

    def kernel_form(self):
        """(static op tuple, (n_bias, C') rows).  Bias entries become
        ("bias", row_index); rows is None when no biases appear."""
        tags, rows = [], []
        for op in self.ops:
            if op[0] == "bias":
                tags.append(("bias", len(rows)))
                rows.append(jnp.asarray(op[1]).reshape(-1))
            else:
                tags.append(("relu",))
        return tuple(tags), (jnp.stack(rows) if rows else None)


@dataclasses.dataclass(frozen=True)
class ChainLink:
    """One conv of a fusion-group chain, as `execute_staged` consumes it.

    `elementwise` is position-independent pointwise glue (bias, relu):
    a callable ``y -> y`` folded into the owning algorithm's task loop
    via `fuse_epilogue`, so inside a fused stage it runs on tile-resident
    data exactly as it does in a single stage.  `epilogue` is the
    position-*dependent* remainder (the ragged-batch extent mask): a
    callable ``(y, row0) -> y`` where `row0` is the global output-row
    offset of the region being computed -- tile-position-aware so ragged
    masking stays exact inside a fused stage.  Either may be None.
    """

    w: Optional[jnp.ndarray]
    wt: Optional[jnp.ndarray]
    plan: "AlgoPlan"
    epilogue: Optional[Callable[[jnp.ndarray, int], jnp.ndarray]] = None
    elementwise: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None


def _pad0_plan(plan: "AlgoPlan", h: int, w: int) -> "AlgoPlan":
    """A plan for executing the same conv on an already-row/col-extended
    slice: pad folded into the slice, spec re-posed at the slice dims."""
    return dataclasses.replace(
        plan, spec=dataclasses.replace(plan.spec, pad=0, h=h, w=w)
    )


class Algorithm:
    """Base class: one convolution realization.

    Class attributes:
      name           registry key (also the `algo=` string).
      tier           auto-resolution tier: 0 fused, 1 staged fallback,
                     2 direct.  Lower tier wins regardless of cost --
                     this encodes the paper's preference order (fused
                     where feasible, vendor structure as fallback).
      rank           deterministic tie-break within a tier.
      consumes_wt    execute() accepts pre-transformed kernels (`wt`);
                     False means a supplied wt is an error, never ignored.
      weight_params  param names that shape `prepare_weights` output --
                     the kernel cache keys transforms on exactly these.
      auto_candidate False for explicit-only algorithms (the Pallas
                     kernel: correct everywhere via interpret mode, but
                     only profitable on its native backend).
      chain_family   transform-tiling family for cross-layer fusion
                     groups; None means this algorithm never chains (the
                     3-stage baseline *is* the materializing structure,
                     direct has nothing to keep resident).
    """

    name: str = ""
    tier: int = 0
    rank: int = 0
    consumes_wt: bool = False
    weight_params: Tuple[str, ...] = ()
    auto_candidate: bool = True
    chain_family: Optional[str] = None

    def supports(self, spec: ConvSpec) -> bool:
        """Correctness domain: can this algorithm compute `spec` at all?"""
        raise NotImplementedError

    def plan(
        self,
        spec: ConvSpec,
        hw: analysis.HardwareModel,
        *,
        hints: Optional[Mapping[str, Any]] = None,
        tune_r: bool = False,
        wisdom_path=None,
    ) -> AlgoPlan:
        """Resolve algorithm-owned params (and modeled cost) for `spec`."""
        raise NotImplementedError

    def prepare_weights(self, w: jnp.ndarray, plan: AlgoPlan):
        """HWIO kernels -> right-hand matrices; None when the algorithm
        has no ahead-of-time transform (direct, the Pallas kernel)."""
        return None

    def execute(
        self,
        x: jnp.ndarray,
        w: Optional[jnp.ndarray],
        wt: Optional[jnp.ndarray],
        plan: AlgoPlan,
    ) -> jnp.ndarray:
        """Run the convolution.  Geometry comes from the runtime `x`
        (plans apply to whole shape buckets); structure (pad, stride,
        groups) and params come from the plan."""
        raise NotImplementedError

    def prepare_key(self, params: Mapping[str, Any]) -> Tuple:
        """The params subtuple that identifies `prepare_weights` output
        (cache key component).  R never fragments the cache."""
        return tuple((p, params.get(p)) for p in self.weight_params)

    def tile_algebra(self, plan: "AlgoPlan"):
        """The transform family's cost/working-set terms for this plan
        (`transforms.TileAlgebra`), or None for algorithms with no
        transform tiling (direct).  The fusion-group planner prices
        joint right-hand-matrix residency through this."""
        return None

    # ----- cross-layer fusion hooks (the ExecProgram staged contract)

    def can_chain(self, plan_a: "AlgoPlan", plan_b: "AlgoPlan") -> bool:
        """May a conv planned as `plan_a` (this algorithm) and the next
        conv planned as `plan_b` execute as one fusion-group stage?

        The default demands a shared tiling family and the geometry the
        generic `execute_staged` supports: unit stride and ungrouped
        channels on both sides.  Whether fusing *pays* (saved
        intermediate traffic vs halo recompute) is the planner's
        roofline call, not a capability question.
        """
        if self.chain_family is None:
            return False
        other = get(plan_b.algo)
        if other.chain_family != self.chain_family:
            return False
        for p in (plan_a, plan_b):
            if p.spec.stride != 1 or p.spec.groups != 1:
                return False
        return True

    def fuse_epilogue(
        self,
        plan: "AlgoPlan",
        epilogue: Optional[Callable[[jnp.ndarray], jnp.ndarray]],
    ) -> Callable:
        """Return ``(x, w, wt) -> y`` running this conv with the
        elementwise `epilogue` (bias/relu) folded in.  The base applies
        it after `execute`; fused algorithms override to fold it into
        their task loop so the glue runs on tile-resident data."""
        if epilogue is None:
            return lambda x, w, wt: self.execute(x, w, wt, plan)
        return lambda x, w, wt: epilogue(self.execute(x, w, wt, plan))

    def execute_staged(
        self,
        x: jnp.ndarray,
        chain: Sequence[ChainLink],
        *,
        tile_rows: int,
    ) -> jnp.ndarray:
        """Run a fusion-group chain of stride-1 convs over row super-tiles.

        The group's full intermediate activations are never materialized:
        each super-tile flows conv -> epilogue -> conv with a (K-1)-row
        halo recomputed at tile seams, so the live intermediate is
        bounded by `tile_rows` x W x C -- sized by the planner to stay
        resident in the fast shared level.  Borders are exact and free:
        each conv's zero padding is applied per-slice, and rows a window
        needs beyond a true tensor extent are supplied as that padding
        rather than computed -- the receptive-field recursion clamps to
        the true extent per level, so border tiles do no phantom work.

        Generic over any registered algorithm whose `execute` honours
        `plan.spec` pad at runtime shapes; overriding makes sense only
        for backends that fuse deeper than slice recompute.
        """
        convs = list(chain)
        if not convs:
            raise ValueError("empty fusion-group chain")
        heights = [int(x.shape[1])]
        for link in convs:
            s = link.plan.spec
            if s.stride != 1 or s.groups != 1:
                raise ValueError(
                    f"execute_staged supports stride-1 ungrouped chains, "
                    f"got {s}"
                )
            heights.append(heights[-1] + 2 * s.pad - s.k + 1)
        h_final = heights[-1]
        tile_rows = int(tile_rows) if tile_rows > 0 else h_final
        out_tiles = []
        a = 0
        while a < h_final:
            b = min(a + tile_rows, h_final)
            # receptive-field pass, clamped to each level's true extent:
            # rows a window needs beyond an extent are that conv's own
            # zero padding, re-supplied per slice below -- they are never
            # computed, so they need no inputs of their own.  `mat[i]` is
            # the row range of level i this tile materializes; `want[i]`
            # extends it by conv i's zero padding.
            mat = [(a, b)]
            want = [None] * len(convs)
            for i in reversed(range(len(convs))):
                s = convs[i].plan.spec
                lo, hi = mat[0]
                want[i] = (lo - s.pad, hi - s.pad + s.k - 1)
                mat.insert(
                    0, (max(want[i][0], 0), min(want[i][1], heights[i]))
                )
            t = x[:, mat[0][0] : mat[0][1]]
            for i, link in enumerate(convs):
                s = link.plan.spec
                (wlo, whi), (mlo, mhi) = want[i], mat[i]
                if (mlo - wlo, whi - mhi) == (s.pad, s.pad):
                    # the wanted halo is exactly the conv's own padding on
                    # both sides (whole-extent tiles): keep the plan's pad
                    # and skip the explicit copy -- identical structure to
                    # the unfused single stage
                    run_plan = dataclasses.replace(
                        link.plan,
                        spec=dataclasses.replace(
                            s, h=int(t.shape[1]), w=int(t.shape[2])
                        ),
                    )
                else:
                    # conv padding: wanted rows beyond the level's true
                    # extent, plus full-width column padding (tiles span W)
                    t = jnp.pad(
                        t,
                        (
                            (0, 0),
                            (mlo - wlo, whi - mhi),
                            (s.pad, s.pad),
                            (0, 0),
                        ),
                    )
                    run_plan = _pad0_plan(
                        link.plan, int(t.shape[1]), int(t.shape[2])
                    )
                alg = get(link.plan.algo)
                # the conv's elementwise glue folds into its task loop
                # exactly as in a single stage; the output covers exactly
                # mat[i + 1] (no phantom rows to crop)
                t = alg.fuse_epilogue(run_plan, link.elementwise)(
                    t, link.w, link.wt
                )
                if link.epilogue is not None:
                    t = link.epilogue(t, mat[i + 1][0])
            out_tiles.append(t)
            a = b
        return (
            out_tiles[0]
            if len(out_tiles) == 1
            else jnp.concatenate(out_tiles, axis=1)
        )


# --------------------------------------------------------------- registry


_REGISTRY: Dict[str, Algorithm] = {}


def register(alg: Algorithm) -> Algorithm:
    if not alg.name:
        raise ValueError(f"algorithm {alg!r} has no name")
    _REGISTRY[alg.name] = alg
    return alg


def _ensure_registered() -> None:
    """Algorithms self-register when their module is imported; importing
    the dispatcher pulls in every built-in algorithm module."""
    if "direct" not in _REGISTRY:
        import repro.core.conv  # noqa: F401


def get(name: str) -> Algorithm:
    _ensure_registered()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown algo {name!r}, expected one of {names()} or 'auto'"
        ) from None


def names() -> Tuple[str, ...]:
    _ensure_registered()
    return tuple(_REGISTRY)


def supporting(spec: ConvSpec) -> Tuple[str, ...]:
    """Names of algorithms whose correctness domain covers `spec`."""
    _ensure_registered()
    return tuple(n for n, a in _REGISTRY.items() if a.supports(spec))


def plan_conv(
    spec: ConvSpec,
    hw: analysis.HardwareModel,
    *,
    algo: str = "auto",
    hints: Optional[Mapping[str, Any]] = None,
    allowed: Optional[Sequence[str]] = None,
    tune_r: bool = False,
    wisdom_path=None,
) -> AlgoPlan:
    """Resolve `spec` to a concrete AlgoPlan.

    algo="auto" ranks every supporting, feasible algorithm by
    (tier, modeled cost, rank) -- the registry form of the paper's wisdom
    choice.  An explicit algo plans unconditionally (feasibility heuristics
    only gate auto); unsupported specs raise.  `tune_r` measures R for the
    winner only, never for losing candidates.
    """
    _ensure_registered()
    hints = dict(hints or {})
    if algo != "auto":
        alg = get(algo)
        if not alg.supports(spec):
            raise ValueError(
                f"algo {algo!r} does not support {spec} "
                f"(supported here: {supporting(spec)})"
            )
        return alg.plan(
            spec, hw, hints=hints, tune_r=tune_r, wisdom_path=wisdom_path
        )
    best: Optional[AlgoPlan] = None
    best_key = None
    for name in (allowed if allowed is not None else names()):
        alg = get(name)
        if not alg.auto_candidate or not alg.supports(spec):
            continue
        cand = alg.plan(spec, hw, hints=hints, wisdom_path=wisdom_path)
        if not math.isfinite(cand.cost):
            continue  # roofline-infeasible: excluded from auto
        key = (alg.tier, cand.cost, alg.rank)
        if best_key is None or key < best_key:
            best, best_key = cand, key
    if best is None:
        raise ValueError(
            f"auto found no feasible algorithm for {spec}: supporting "
            f"algorithms are {supporting(spec)}, but the candidate set "
            f"was restricted to {tuple(allowed) if allowed is not None else names()} "
            "and roofline-infeasible candidates are excluded -- widen "
            "`allowed` or request an algorithm explicitly"
        )
    if tune_r:  # measure only the winner (the wisdom-file pass)
        best = get(best.algo).plan(
            spec, hw, hints=hints, tune_r=True, wisdom_path=wisdom_path
        )
    return best
