"""The paper's S5 analytical (roofline) model, as executable code.

Used three ways:
  * tests assert the algebra (AI_L3 == R/2, channel conditions, ...)
  * `choose_algo` implements the paper's "wisdom file" remark: pick the
    fused algorithm exactly where the model predicts it wins
  * benchmarks/analysis_table.py prints predicted utilisation next to the
    measured Fig-2/Fig-3 reproductions.
"""

from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    name: str
    peak_flops: float  # FLOP/s (fp32 on CPUs, bf16 on TPU)
    dram_bw: float  # bytes/s main memory (HBM on TPU)
    fast_shared_bw: float  # bytes/s of the shared fast level (L3 / VMEM feed)
    fast_shared_bytes: int  # capacity of that level
    private_bytes: int  # per-core private working memory (L2 / VMEM budget)

    @property
    def cmr_dram(self) -> float:
        return self.peak_flops / self.dram_bw

    @property
    def cmr_fast(self) -> float:
        return self.peak_flops / self.fast_shared_bw


# The two machines of the paper's S6, numbers from the text.
SKYLAKE_X = HardwareModel(
    name="i9-7980xe (18c, AVX512)",
    peak_flops=2.6e9 * 18 * 2 * 16 * 2,  # 2 FMA ports x 16 fp32 lanes
    dram_bw=4 * 21.3e9,
    fast_shared_bw=(2.6e9 * 18 * 2 * 16 * 2) / 10.0,  # paper: CMR_L3 ~ 10
    fast_shared_bytes=20 * 2**20,
    private_bytes=1 * 2**20,
)
# AVX-heavy code downclocks below the 3.1 GHz nominal: the paper reports
# CMR_dram = 13, implying ~2.6 GHz effective (13 * 25.6 GB/s = 332.8 GFLOP/s).
_I7_PEAK = 13.0 * (2 * 12.8e9)
MOBILE_I7 = HardwareModel(
    name="i7 MacBookPro (4c, AVX2)",
    peak_flops=_I7_PEAK,
    dram_bw=2 * 12.8e9,
    fast_shared_bw=_I7_PEAK / 4.0,  # paper: CMR_L3 ~ 4
    fast_shared_bytes=8 * 2**20,
    private_bytes=256 * 2**10,
)
# TPU v5e, the adaptation target.  The "fast shared" level is VMEM; its feed
# bandwidth is effectively the VREG load rate -- we conservatively model the
# VMEM->compute CMR as ~2 (VMEM streams near compute rate), which makes the
# L3-lower-bound on R mild; the binding constraints on TPU are the HBM AI and
# the VMEM capacity budget.
TPU_V5E = HardwareModel(
    name="TPU v5e (per chip)",
    peak_flops=197e12,
    dram_bw=819e9,
    fast_shared_bw=197e12 / 2.0,
    fast_shared_bytes=64 * 2**20,
    private_bytes=32 * 2**20,
)


def calibrated_hw(
    base: "HardwareModel | None" = None,
    wisdom_path=None,
    *,
    measure: bool = True,
) -> HardwareModel:
    """`base` with its compute and memory roofs replaced by the one-shot
    GEMM/stream microbenchmark (`tune.measure_calibration`, cached in the
    wisdom file per backend).

    Only the absolute roofs change: `fast_shared_bw` is rescaled to
    preserve the base model's CMR_fast, so the *structure* of planning
    (min_r, the R bounds, fusion-group thresholds) is untouched while
    every absolute time prediction is anchored to this host.  With
    `measure=False` only a cached calibration is consulted (never pays
    the microbenchmark) and `base` is returned verbatim when none exists.
    """
    from repro.core import tune  # deferred: tune imports this module

    base = base or tune.default_hw()
    entry = (
        tune.measure_calibration(wisdom_path)
        if measure
        else tune.lookup_calibration(wisdom_path)
    )
    if not entry:
        return base
    peak = float(entry["peak_flops"])
    return dataclasses.replace(
        base,
        name=base.name + ":calibrated",
        peak_flops=peak,
        dram_bw=float(entry["dram_bw"]),
        fast_shared_bw=peak / base.cmr_fast,
    )


def kernel_matrix_bytes(c_in: int, c_out: int, t: int) -> int:
    """Right-hand matrices: 4 C C' T^2 bytes (the fp32 Winograd case; the
    family-exact figure -- complex pairs over the rfft half-spectrum for
    FFT, grouped block-diagonal -- is `TileAlgebra.kernel_matrix_bytes`)."""
    return 4 * c_in * c_out * t * t


def task_flops(r: int, c_in: int, c_out: int, t: int, alpha: int = 1) -> int:
    """alpha 2 R C C' T^2 -- matmul FLOPs per task (alpha=1 Wino, 2 FFT)."""
    return alpha * 2 * r * c_in * c_out * t * t


def ai_fast_level(r: int) -> float:
    """Arithmetic intensity against the shared fast level == R/2 (paper S5.1)."""
    return r / 2.0


def ai_dram(
    c_in: int, c_out: int, t: int, t_out: int, alpha: int = 1, groups: int = 1
) -> float:
    """AI against main memory: FLOPs / (input+output tile bytes).

    Activations stream through DRAM as real fp32 regardless of transform
    family (the complex domain lives only in fast memory), so the byte
    term is family-independent; grouped channel mixes are block-diagonal,
    dividing the FLOP term by `groups`.
    """
    flops = alpha * 2 * c_in * c_out * t * t // groups
    byts = 4 * t * t * c_in + 4 * t_out * t_out * c_out
    return flops / byts


def min_r(hw: HardwareModel) -> int:
    """Lower bound: R >= 2 CMR_fast for full utilisation at the shared level."""
    import math

    return int(math.ceil(2 * hw.cmr_fast))


def max_r(hw: HardwareModel, c_in: int, c_out: int, t: int) -> int:
    """Upper bound from the shared buffer fitting half the private memory."""
    from repro.core.sharedbuf import max_r_for_budget

    return max_r_for_budget(hw.private_bytes // 2, c_in, c_out, t)


def max_r_ta(hw: HardwareModel, c_in: int, c_out: int, ta) -> int:
    """Family-exact R upper bound: the shared-buffer working set -- sized
    by the transform's domain points and element width (`TileAlgebra`) --
    must fit half the private memory.  Buffers hold full-width channels
    even for grouped problems (tiles are gathered whole), so no `groups`
    term here."""
    from repro.core.sharedbuf import max_r_for_budget

    return max_r_for_budget(
        hw.private_bytes // 2, c_in, c_out, ta.t,
        points=ta.domain_points, elem_bytes=ta.elem_bytes,
    )


def predicted_utilization(
    hw: HardwareModel, r: int, c_in: int, c_out: int, t: int, t_out: int,
    alpha: int = 1, groups: int = 1,
) -> float:
    """min over memory levels of AI/CMR, capped at 1 (paper S2.3)."""
    u_fast = ai_fast_level(r) / hw.cmr_fast
    u_dram = ai_dram(c_in, c_out, t, t_out, alpha, groups) / hw.cmr_dram
    return min(1.0, u_fast, u_dram)


def conv_time_s(
    hw: HardwareModel,
    *,
    out_h: int,
    out_w: int,
    c_in: int,
    c_out: int,
    k: int,
    groups: int = 1,
    predicted_util: float = 1.0,
) -> float:
    """Modeled wall time of one conv: direct FLOP count over peak,
    derated by the predicted utilization (floored at 5% so a degenerate
    utilization estimate never produces an infinite time).  This is the
    roofline prediction that `convserve.adapt` compares measured stage
    times against."""
    flops = 2 * out_h * out_w * c_in * c_out * k * k // groups
    return flops / (hw.peak_flops * max(predicted_util, 0.05))


MATRIX_RESIDENCY_FRAC = 0.5  # paper S4.1.1's constant fraction -- the ONE
# copy: fused_is_feasible, fused_cost_ta, and the convserve fusion-group
# planner all gate on this same threshold


def fused_is_feasible(
    hw: HardwareModel,
    c_in: int,
    c_out: int,
    t: int,
    frac: float = MATRIX_RESIDENCY_FRAC,
) -> bool:
    """Right-hand matrices must occupy <= a constant fraction of shared fast
    memory (paper S4.1.1)."""
    return kernel_matrix_bytes(c_in, c_out, t) <= frac * hw.fast_shared_bytes


def flops_per_output_px(t: int, t_out: int, alpha: int = 1) -> float:
    """Matmul FLOPs per output pixel, in units of C*C' (the common factor):
    alpha 2 T^2 / T'^2.  Lets transform families with different tile sizes
    and alpha be compared on equal footing (time ~ flops/px / utilisation)."""
    return alpha * 2.0 * t * t / float(t_out * t_out)


def fused_cost_ta(
    hw: HardwareModel, c_in: int, c_out: int, ta, r_floor: int,
    groups: int = 1,
):
    """(algo-feasibility, modeled cost) of one fused transform family,
    seen through its `TileAlgebra` -- the entry the registry algorithms
    and the convserve planner share, so every family (and any future one)
    is costed by the same roofline with family-exact working-set terms.

    Cost is time per output pixel up to the common C*C' factor: flops/px
    divided by predicted utilisation at the best feasible R.  Returns
    None when infeasible (matrices overflow the shared level, or no
    useful R fits the private-memory budget).
    """
    if ta.t_out < 1:
        return None
    matrix = ta.kernel_matrix_bytes(c_in, c_out, groups)
    if matrix > MATRIX_RESIDENCY_FRAC * hw.fast_shared_bytes:
        return None
    r_hi = max_r_ta(hw, c_in, c_out, ta)
    if r_hi < r_floor:
        return None
    r = min(r_hi, max(min_r(hw), r_floor))
    u = predicted_utilization(
        hw, r, c_in, c_out, ta.t, ta.t_out, ta.alpha, groups
    )
    return ta.flops_per_output_px() / max(u, 1e-9)


def engine_cost_ta(
    hw: HardwareModel, c_in: int, c_out: int, ta, r: int,
    groups: int = 1, stride: int = 1,
):
    """Block-aware fused cost: the parametric tile engine's *actual* MAC
    count (forward basis GEMM + channel mix + inverse basis GEMM, see
    `TileAlgebra.engine_macs_per_tile`) per final output pixel, in the
    same C*C' units as `fused_cost_ta`, at the *tuned* block's R
    utilisation.  The engine always computes the full stride-1 tile grid
    and decimates, so strided problems simply have stride^2 fewer final
    pixels per tile -- the decimation waste falls out of the
    normalization instead of being bolted on as a separate penalty.
    Returns None when infeasible (same residency gate as the analytic
    path)."""
    if ta.t_out < 1:
        return None
    matrix = ta.kernel_matrix_bytes(c_in, c_out, groups)
    if matrix > MATRIX_RESIDENCY_FRAC * hw.fast_shared_bytes:
        return None
    u = predicted_utilization(
        hw, max(1, r), c_in, c_out, ta.t, ta.t_out, ta.alpha, groups
    )
    px_units = (
        2.0 * ta.engine_macs_per_tile(c_in, c_out, groups) * stride**2
        / (ta.t_out**2 * c_in * c_out)
    )
    return px_units / max(u, 1e-9)


def fused_cost(
    hw: HardwareModel, c_in: int, c_out: int, t: int, k: int, alpha: int,
    r_floor: int,
):
    """Closed-form (t, k, alpha) view of `fused_cost_ta`, kept for
    `choose_algo` (the paper-table three-way choice) and the algebra
    tests.  alpha selects the family's TileAlgebra."""
    from repro.core import transforms

    if t <= k:
        return None
    ta = (
        transforms.FFTTransform(t=t, k=k)
        if alpha == 2
        else transforms.WinogradTransform(m=t - k + 1, k=k)
    ).algebra
    return fused_cost_ta(hw, c_in, c_out, ta, r_floor)


def choose_algo(
    hw: HardwareModel,
    c_in: int,
    c_out: int,
    t: int,
    *,
    k: int = 3,
    t_fft: int = 16,
    consider_fft: bool = True,
) -> Literal["l3_fused", "fft_fused", "three_stage"]:
    """The "wisdom file" choice across all three transformed paths.

    Winograd-fused and FFT-fused are feasible where their right-hand
    matrices fit the shared level AND a useful R exists between the bounds;
    among feasible fused paths the one with the lower modeled time per
    output pixel (alpha=2 FLOP accounting for FFT) wins.  When no fused
    path is feasible the vendor 3-stage structure is the fallback.
    """
    wino = fused_cost(hw, c_in, c_out, t, k, 1, max(8, min_r(hw) // 2))
    fft = None
    if consider_fft:
        fft = fused_cost(
            hw, c_in, c_out, t_fft, k, 2, max(4, min_r(hw) // 2)
        )
    if wino is None and fft is None:
        return "three_stage"
    if fft is None or (wino is not None and wino <= fft):
        return "l3_fused"
    return "fft_fused"
