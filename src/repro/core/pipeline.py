"""The transform-generic tile-pipeline engine.

One engine, every transform family.  `fused.py`, `three_stage.py`,
`fft_conv.py` and the Pallas wrapper used to each hand-roll their own
OLA gather -> transform -> matmul -> inverse -> scatter loop; this module
is the single implementation they all drive with a `Transform` object
(core.transforms) instead of inlined math:

  * `fused_tile_conv` -- the paper's L3-fused task structure: a
    `lax.scan` over tasks of R tiles, each task gathering, forward-
    transforming, channel-mixing against the stationary right-hand
    matrices, inverse-transforming, and (optionally) running the fused
    elementwise epilogue while the tiles are still task-resident.  The
    per-task working set follows the shared-buffer layout accounting of
    `core.sharedbuf` (`shared_buffer_plan`); the R bound the planner
    derives from it is family-exact through `TileAlgebra`.
  * `staged_tile_conv` -- the vendor 3-stage structure: every stage runs
    over ALL tiles before the next begins, materializing the transformed
    tensors (what DNNL/ZNN/LIBXSMM do, and the paper's baseline).
    `staged_stage_fns` exposes the three stages separately for honest
    stage-boundary benchmarking.

Grouped convolutions are handled once, here, for every family: tiles are
gathered with full channel width and the channel mix runs block-diagonal
(`Transform.multiply(groups=...)`), so registering a transform family
never re-implements groups.

`TransformedAlgorithm` is the registry face of the engine: a shared
plan/prepare/execute/fuse_epilogue lifecycle parameterized only by a
transform factory, so a concrete algorithm (`l3_fused`, `fft_fused`,
`three_stage`) is little more than a family + tier declaration.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import analysis, registry, tiling, transforms
from repro.core.sharedbuf import SharedBufferPlan


def _tile_offsets(plan: tiling.TilePlan, batch: int) -> np.ndarray:
    """(N_tile, 3) int32: (batch, row0, col0) of every input tile, flat order."""
    b_idx, h_idx, w_idx = np.meshgrid(
        np.arange(batch),
        np.arange(plan.n_tiles_h) * plan.t_out,
        np.arange(plan.n_tiles_w) * plan.t_out,
        indexing="ij",
    )
    return np.stack(
        [b_idx.ravel(), h_idx.ravel(), w_idx.ravel()], axis=1
    ).astype(np.int32)


def _gather_tiles(x_padded: jnp.ndarray, offsets: jnp.ndarray, t: int) -> jnp.ndarray:
    """Gather R overlapping (T, T, C) tiles given (R, 3) offsets."""

    def one(off):
        return jax.lax.dynamic_slice(
            x_padded,
            (off[0], off[1], off[2], 0),
            (1, t, t, x_padded.shape[3]),
        )[0]

    return jax.vmap(one)(offsets)  # (R, T, T, C)


def _assemble(y_tiles, plan: tiling.TilePlan, batch: int, n_tile: int, dtype):
    """(n_pad, T', T', C') task output -> assembled, cropped NHWC output."""
    c_out = y_tiles.shape[-1]
    y_tiles = y_tiles.reshape(-1, plan.t_out, plan.t_out, c_out)[:n_tile]
    y_tiles = y_tiles.reshape(
        batch, plan.n_tiles_h, plan.n_tiles_w, plan.t_out, plan.t_out, c_out
    )
    return tiling.assemble_tiles(y_tiles, plan).astype(dtype)


def shared_buffer_plan(
    transform: transforms.Transform, r: int, c_in: int, c_out: int
) -> SharedBufferPlan:
    """The paper-S4.2 shared-buffer layout of one task's working set, in
    the transform's own domain (rfft half-spectrum, complex width for
    FFT).  The Pallas kernel materializes this layout in VMEM; the
    analytic R bound (`analysis.max_r_ta`) prices it."""
    ta = transform.algebra
    return SharedBufferPlan(
        r=r, c_in=c_in, c_out=c_out,
        t2=ta.domain_points, elem_bytes=ta.elem_bytes,
    )


def fused_tile_conv(
    x: jnp.ndarray,
    w: Optional[jnp.ndarray],
    transform: transforms.Transform,
    *,
    pad: int = 0,
    r_tiles: int = 24,
    wt: Optional[jnp.ndarray] = None,
    groups: int = 1,
    epilogue=None,
    blocks=None,
    backend: Optional[str] = None,
) -> jnp.ndarray:
    """NHWC L3-fused transformed convolution, any transform family.

    Dispatches to the parametric tile engine (`repro.kernels.fused_tile`)
    whenever the family lowers to a `TileKernelSpec`: the same gather ->
    forward GEMM -> batched mix -> inverse GEMM -> scatter program either
    as a Pallas kernel (TPU / interpret) or as the XLA matrix path (CPU).
    Families without a spec -- and f64 inputs, whose basis matrices would
    lose precision in f32 -- run the interpreting `scan_tile_conv` below.

    `blocks` (a `kernels.fused_tile.BlockConfig`) carries the autotuned
    block shape; `r_tiles` alone seeds an unchunked default.  `backend`
    overrides the engine backend (see `fused_tile.resolve_backend`).
    """
    from repro.kernels import fused_tile as _ft  # deferred: jax warm-up

    b = _ft.resolve_backend(backend)
    if b != "scan" and _ft.engine_supported(transform, x.dtype):
        try:
            return _ft.conv2d_fused_tile(
                x, w, transform,
                pad=pad,
                blocks=blocks or _ft.BlockConfig(r=int(r_tiles)),
                wt=wt, groups=groups, epilogue=epilogue, backend=b,
            )
        except _ft.UnsupportedSpec:
            pass
    return scan_tile_conv(
        x, w, transform,
        pad=pad, r_tiles=r_tiles, wt=wt, groups=groups, epilogue=epilogue,
    )


def scan_tile_conv(
    x: jnp.ndarray,
    w: Optional[jnp.ndarray],
    transform: transforms.Transform,
    *,
    pad: int = 0,
    r_tiles: int = 24,
    wt: Optional[jnp.ndarray] = None,
    groups: int = 1,
    epilogue=None,
) -> jnp.ndarray:
    """The interpreting task-scan engine (the oracle the parametric
    kernel is tested against, and the fallback for families/dtypes it
    cannot lower).

    Tiles are processed in N_task = ceil(N_tile / R) independent tasks;
    each task's intermediates stay in fast private memory while the
    right-hand matrices -- re-read by every task -- stay hot in the fast
    shared level (the paper's contribution).  `epilogue`, when given, is
    an elementwise callable applied to each task's (R, T', T', C') output
    tiles inside the scan: output tiles abut, so this equals applying it
    to the assembled output, but the glue runs on task-resident data.
    """
    t = transform.t
    plan = tiling.TilePlan.build(x.shape[1], x.shape[2], transform.k, pad, t)
    if wt is None:
        wt = transform.kernel_transform(w)
    batch = x.shape[0]

    xp = tiling.pad_input(x, plan)
    n_tile = plan.n_tiles(batch)
    r = min(r_tiles, n_tile)
    n_task = -(-n_tile // r)
    n_pad = n_task * r

    offsets = _tile_offsets(plan, batch)
    if n_pad > n_tile:  # pad the task list by repeating the last tile
        offsets = np.concatenate(
            [offsets, np.repeat(offsets[-1:], n_pad - n_tile, axis=0)], axis=0
        )
    offsets = jnp.asarray(offsets).reshape(n_task, r, 3)

    def task(carry, off_r):
        tiles = _gather_tiles(xp, off_r, t)  # (R, T, T, C)
        u = transform.forward(tiles)  # step 1: basis change
        # the declared compute domain is a checked contract: the
        # working-set algebra (elem_bytes) and the cached right-hand
        # matrices' dtype both key off it, so a transform whose forward
        # diverges from its declaration must fail here, at trace time
        assert u.dtype == transform.domain_dtype(x.dtype), (
            f"{transform.family} forward produced {u.dtype}, "
            f"declared domain {transform.domain_dtype(x.dtype)}"
        )
        mm = transform.multiply(u, wt, groups)  # step 2: channel mix
        y = transform.inverse(mm)  # step 3: back to (R, T', T', C')
        if epilogue is not None:
            y = epilogue(y)
        return carry, y

    _, y_tiles = jax.lax.scan(task, jnp.zeros((), x.dtype), offsets)
    return _assemble(y_tiles, plan, batch, n_tile, x.dtype)


def staged_stage_fns(
    transform: transforms.Transform,
    plan: tiling.TilePlan,
    groups: int = 1,
):
    """The three materializing stages as separate callables.

    stage 1: padded input -> all transformed tiles (N_tile, domain, C)
    stage 2: channel mix against the right-hand matrices
    stage 3: inverse transform + assembly -> (B, H', W', C')

    Used whole by `staged_tile_conv` and separately jitted by
    `ThreeStageStaged` so U and M demonstrably round-trip main memory at
    stage boundaries, mirroring the vendor libraries.
    """

    def stage1(xp):
        tiles = tiling.extract_tiles(xp, plan)  # (B, nH, nW, T, T, C)
        b = tiles.shape[0]
        tiles = tiles.reshape(
            b * plan.tiles_per_image, plan.t, plan.t, tiles.shape[-1]
        )
        return transform.forward(tiles)

    def stage2(u, wt):
        return transform.multiply(u, wt, groups)

    def stage3(mm, batch):
        y_tiles = transform.inverse(mm)  # (N_tile, T', T', C')
        n_tile = y_tiles.shape[0]
        return _assemble(y_tiles, plan, batch, n_tile, y_tiles.dtype)

    return stage1, stage2, stage3


def staged_tile_conv(
    x: jnp.ndarray,
    w: Optional[jnp.ndarray],
    transform: transforms.Transform,
    *,
    pad: int = 0,
    wt: Optional[jnp.ndarray] = None,
    groups: int = 1,
) -> jnp.ndarray:
    """The non-fused 3-stage structure (each stage over ALL tiles),
    single-jit form."""
    plan = tiling.TilePlan.build(
        x.shape[1], x.shape[2], transform.k, pad, transform.t
    )
    if wt is None:
        wt = transform.kernel_transform(w)
    s1, s2, s3 = staged_stage_fns(transform, plan, groups)
    xp = tiling.pad_input(x, plan)
    return s3(s2(s1(xp), wt), x.shape[0]).astype(x.dtype)


# ------------------------------------------------------------------------
# Registry face: the shared lifecycle of every transformed algorithm.
# ------------------------------------------------------------------------


def resolve_r(
    spec: registry.ConvSpec,
    hw: analysis.HardwareModel,
    transform: transforms.Transform,
    *,
    hints,
    tune_r: bool = False,
    wisdom_path=None,
):
    """R for a transformed plan: explicit hint > measured (tune_r) >
    wisdom-file lookup > analytic prediction.  Wisdom entries are keyed
    by transform family + tile size + geometry, so Winograd-R and FFT-T
    tunes for the same layer never collide.  Returns (r, tuned) where
    `tuned` marks an R that came from measurement (fresh or cached in
    the wisdom file) rather than the model."""
    from repro.core import tune  # deferred: tune times this module's conv

    r_hint = hints.get("r_tiles")
    if r_hint is not None:
        return int(r_hint), False
    if tune_r:
        r = tune.tuned_r(
            spec.h, spec.w, spec.c_in, spec.c_out,
            transform=transform, wisdom_path=wisdom_path,
        )
        return int(r), True
    r = tune.lookup_r(
        spec.h, spec.w, spec.c_in, spec.c_out,
        transform=transform, wisdom_path=wisdom_path,
    )
    if r is not None:
        # clamp a wisdom R measured elsewhere into this hw's feasible range
        r_max = analysis.max_r_ta(hw, spec.c_in, spec.c_out, transform.algebra)
        return (max(1, min(int(r), r_max)) if r_max >= 1 else int(r)), True
    return (
        tune.predict_r(spec.c_in, spec.c_out, transform=transform, hw=hw),
        False,
    )


class TransformedAlgorithm(registry.Algorithm):
    """Base class for algorithms realized by the shared tile engine.

    A subclass declares its transform family (`make_transform` + the
    name of its tile-size param) and its registry identity; planning,
    weight pre-transforms, execution, grouped support, stride-decimation
    and in-task epilogue fusion are all inherited.  `execute_staged`
    (cross-layer fusion groups) comes from `registry.Algorithm` and is
    generic over any engine-backed execute, which makes every transform
    family a first-class fusion-group citizen.
    """

    consumes_wt = True
    tile_param: str = ""  # "m" (Winograd) or "t_fft" (FFT)
    default_tile: int = 0  # default value of that param
    r_floor_base: int = 8  # family floor on a useful task width

    def make_transform(
        self, spec: registry.ConvSpec, params
    ) -> transforms.Transform:
        """The family's Transform at this plan's tile size."""
        raise NotImplementedError

    def supports(self, spec: registry.ConvSpec) -> bool:
        # the engine handles stride (decimation), groups (block-diagonal
        # mix) and ragged geometry for every family; dtype domains may
        # narrow this in subclasses.  Temporal (1-D causal) specs have
        # left-only pad semantics outside the 2-D tiling engine.
        return not spec.temporal

    def r_floor(self, hw: analysis.HardwareModel) -> int:
        return max(self.r_floor_base, analysis.min_r(hw) // 2)

    def plan(self, spec, hw, *, hints=None, tune_r=False, wisdom_path=None):
        hints = hints or {}
        tile = int(hints.get(self.tile_param) or self.default_tile)
        params = {self.tile_param: tile}
        tr = self.make_transform(spec, params)
        r, tuned = resolve_r(
            spec, hw, tr, hints=hints, tune_r=tune_r, wisdom_path=wisdom_path
        )
        ta = tr.algebra
        util = analysis.predicted_utilization(
            hw, r, spec.c_in, spec.c_out, ta.t, ta.t_out, ta.alpha,
            spec.groups,
        )
        params = {**params, "r_tiles": int(r)}
        from repro.core import tune  # deferred: tune times this module

        blocks = tune.lookup_blocks(
            spec.h, spec.w, spec.c_in, spec.c_out,
            transform=tr, wisdom_path=wisdom_path,
        )
        if blocks is not None:
            params["blocks"] = blocks.to_wisdom()
        cost = registry.fused_auto_cost(
            spec, hw, ta, self.r_floor(hw), blocks=blocks
        )
        return registry.AlgoPlan(
            self.name, spec, params,
            predicted_util=util, cost=cost, tuned=tuned,
        )

    def tile_algebra(self, plan: registry.AlgoPlan):
        return self.make_transform(plan.spec, plan.params).algebra

    def prepare_weights(self, w, plan):
        if self.tile_param not in plan.params:
            raise ValueError(
                f"{self.name} plan without {self.tile_param}: {plan.params}"
            )
        return self.make_transform(plan.spec, plan.params).kernel_transform(w)

    def _run(self, x, w, wt, plan, epilogue):
        tr = self.make_transform(plan.spec, plan.params)
        blocks = None
        if "blocks" in plan.params:
            from repro.kernels.fused_tile import BlockConfig

            blocks = BlockConfig.from_wisdom(plan.params["blocks"])
        return fused_tile_conv(
            x, w, tr,
            pad=plan.spec.pad,
            r_tiles=int(plan.params.get("r_tiles", 24)),
            wt=wt,
            groups=plan.spec.groups,
            epilogue=epilogue,
            blocks=blocks,
        )

    def execute(self, x, w, wt, plan):
        return registry.decimate(
            self._run(x, w, wt, plan, None), plan.spec.stride
        )

    def fuse_epilogue(self, plan, epilogue):
        # fold the elementwise glue into the task scan: it runs on the
        # (R, T', T', C') tiles while they are still task-resident,
        # instead of as a separate pass over the assembled output
        def run(x, w, wt):
            return registry.decimate(
                self._run(x, w, wt, plan, epilogue), plan.spec.stride
            )

        return run
