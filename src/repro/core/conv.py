"""Public convolution API with algorithm selection.

    conv2d(x, w, pad=1, algo="l3_fused")      # the paper's contribution
    conv2d(x, w, pad=1, algo="three_stage")   # vendor-structure baseline
    conv2d(x, w, pad=1, algo="direct")        # XLA direct conv (the "DNNL"
                                              # stand-in on this backend)
    conv2d(x, w, pad=1, algo="fft_fused")     # FFT-basis fused variant
    conv2d(x, w, pad=1, algo="l3_fused_pallas")  # the Pallas TPU kernel
    conv2d(x, w, pad=1, algo="auto")          # paper's wisdom-file choice
    conv2d(x, w, plan=layer_plan, wt=cached)  # convserve engine path: a
                                              # planned layer with its
                                              # pre-transformed kernels

Layout: NHWC activations, HWIO kernels (TPU-native).  `conv1d` covers the
depthwise-causal short convs of the SSM architectures.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp

from repro.core import analysis
from repro.core.fft_conv import conv2d_fft_fused
from repro.core.fused import conv2d_l3_fused
from repro.core.three_stage import conv2d_three_stage

if TYPE_CHECKING:  # convserve imports core; keep the runtime edge one-way
    from repro.convserve.plan import LayerPlan

ALGOS = ("direct", "three_stage", "l3_fused", "fft_fused", "l3_fused_pallas", "auto")


def conv2d_direct(x: jnp.ndarray, w: jnp.ndarray, *, pad: int = 0) -> jnp.ndarray:
    """XLA's own convolution -- the vendor-library stand-in."""
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(1, 1),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    pad: int = 0,
    algo: str = "auto",
    m: Optional[int] = None,
    t_fft: int = 16,
    r_tiles: int = 24,
    hw: analysis.HardwareModel = analysis.TPU_V5E,
    plan: "Optional[LayerPlan]" = None,
    wt: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """2-D convolution, NHWC x HWIO -> NHWC.

    A `plan` (convserve.plan.LayerPlan) overrides algo/pad/tile/R with the
    planner's per-layer decision; `wt` supplies pre-transformed right-hand
    matrices (the inference-time kernel-cache path) for the transformed
    algorithms and is ignored by `direct`.
    """
    if plan is not None:
        algo, pad, r_tiles = plan.algo, plan.pad, plan.r_tiles
        if plan.m is not None:
            m = plan.m
        if plan.t_fft is not None:
            t_fft = plan.t_fft
    if algo not in ALGOS:
        raise ValueError(f"unknown algo {algo!r}, expected one of {ALGOS}")
    if algo == "auto":
        k = w.shape[0]
        t = (m if m is not None else 5) + k - 1
        algo = analysis.choose_algo(hw, x.shape[3], w.shape[3], t, k=k, t_fft=t_fft)
    if algo == "direct":
        return conv2d_direct(x, w, pad=pad)
    if algo == "three_stage":
        return conv2d_three_stage(x, w, pad=pad, m=m, wt=wt)
    if algo == "l3_fused":
        return conv2d_l3_fused(x, w, pad=pad, m=m, r_tiles=r_tiles, wt=wt)
    if algo == "fft_fused":
        return conv2d_fft_fused(x, w, pad=pad, t=t_fft, r_tiles=r_tiles, wt=wt)
    if algo == "l3_fused_pallas":
        from repro.kernels.fused_winograd import ops as fw_ops

        return fw_ops.conv2d_fused_pallas(x, w, pad=pad, m=m, r_tiles=r_tiles)
    raise AssertionError(algo)


def conv1d_depthwise_causal(
    x: jnp.ndarray, w: jnp.ndarray, *, use_pallas: bool = False
) -> jnp.ndarray:
    """Depthwise causal conv1d: x (B, L, D), w (K, D) -> (B, L, D).

    The Mamba-family short conv.  `use_pallas` selects the fused VMEM kernel
    (repro.kernels.conv1d_fused); default is the jnp reference, which XLA
    fuses adequately on CPU.
    """
    if use_pallas:
        from repro.kernels.conv1d_fused import ops as c1_ops

        return c1_ops.conv1d_fused(x, w)
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # K is tiny (4); unrolled shifted MACs
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out
