"""Public convolution API: a thin dispatcher over the algorithm registry.

    conv2d(x, w, pad=1)                        # algo="auto": registry cost
                                               # model + wisdom file
    conv2d(x, w, pad=1, algo="l3_fused")       # the paper's contribution
    conv2d(x, w, pad=1, algo="three_stage")    # vendor-structure baseline
    conv2d(x, w, pad=1, algo="fft_fused")      # FFT-basis fused variant
    conv2d(x, w, pad=1, algo="l3_fused_pallas")# the Pallas TPU kernel
    conv2d(x, w, pad=1, algo="direct")         # XLA direct conv
    conv2d(x, w, pad=1, stride=2)              # strided (ResNet downsample)
    conv2d(x, w, pad=1, groups=4)              # grouped (ResNeXt-style)
    conv2d(x, w, plan=layer_plan, wt=cached)   # convserve engine path: a
                                               # planned layer with its
                                               # pre-transformed kernels

`conv2d` itself knows no algorithm: every path -- capability checks, the
roofline cost ranking, R resolution through the wisdom file, weight
pre-transforms, execution -- goes through `repro.core.registry`.  Adding
an algorithm is a single `registry.register()` call; this module never
changes.

Layout: NHWC activations, HWIO kernels (TPU-native).  `conv1d` covers the
depthwise-causal short convs of the SSM architectures.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import analysis, registry
from repro.core.fft_conv import conv2d_fft_fused  # noqa: F401  (re-export +
from repro.core.fused import conv2d_l3_fused  # noqa: F401      registers the
from repro.core.three_stage import conv2d_three_stage  # noqa: F401  algos)
from repro.kernels.conv1d_fused import ops as _conv1d_ops  # noqa: F401
from repro.kernels.fused_winograd import ops as _pallas_ops  # noqa: F401

if TYPE_CHECKING:  # convserve imports core; keep the runtime edge one-way
    from repro.convserve.plan import LayerPlan


def conv2d_direct(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    pad: int = 0,
    stride: int = 1,
    groups: int = 1,
) -> jnp.ndarray:
    """XLA's own convolution -- the vendor-library stand-in.

    Supports the full problem space: strided, grouped (HWIO kernels carry
    C/groups input channels), non-square, any float dtype.
    """
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


class DirectAlgorithm(registry.Algorithm):
    """Tier 2: the universal fallback.  Supports everything (stride,
    groups, non-square, any dtype); chosen by auto only when no
    transformed path is roofline-feasible (e.g. spatial dims too small
    to cover one tile)."""

    name = "direct"
    tier = 2
    rank = 50
    consumes_wt = False

    def supports(self, spec: registry.ConvSpec) -> bool:
        # temporal (1-D causal) specs carry left-only pad semantics the
        # symmetric-pad 2-D path cannot express
        return not spec.temporal

    def plan(self, spec, hw, *, hints=None, tune_r=False, wisdom_path=None):
        return registry.AlgoPlan(
            self.name, spec, {}, predicted_util=1.0, cost=0.0
        )

    def execute(self, x, w, wt, plan):
        return conv2d_direct(
            x, w,
            pad=plan.spec.pad, stride=plan.spec.stride,
            groups=plan.spec.groups,
        )


registry.register(DirectAlgorithm())


def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    pad: int = 0,
    stride: int = 1,
    groups: int = 1,
    algo: str = "auto",
    m: Optional[int] = None,
    t_fft: Optional[int] = None,
    r_tiles: Optional[int] = None,
    hw: analysis.HardwareModel = analysis.TPU_V5E,
    plan: "Optional[Union[LayerPlan, registry.AlgoPlan]]" = None,
    wt: Optional[jnp.ndarray] = None,
    wisdom_path=None,
) -> jnp.ndarray:
    """2-D convolution, NHWC x HWIO -> NHWC.

    With algo="auto" the registry ranks every feasible algorithm by the
    S5 roofline model and resolves R through the wisdom file (a tuned R
    for this geometry is used when one exists; `tune.predict_r`
    otherwise).  `m`/`t_fft`/`r_tiles` are optional hints overriding the
    planned algorithm's own defaults.

    A `plan` (convserve LayerPlan or a registry AlgoPlan) overrides
    algo/pad/stride/groups and all params with the planner's per-layer
    decision; `wt` supplies pre-transformed right-hand matrices (the
    inference-time kernel-cache path).  Supplying `wt` to an algorithm
    that cannot consume it (direct, the Pallas kernel) is an error --
    precomputed work is never silently dropped.
    """
    if plan is not None:
        aplan = plan.algo_plan() if hasattr(plan, "algo_plan") else plan
    else:
        spec = registry.ConvSpec.from_tensors(
            x, w, pad=pad, stride=stride, groups=groups
        )
        hints = {
            name: val
            for name, val in (("m", m), ("t_fft", t_fft), ("r_tiles", r_tiles))
            if val is not None
        }
        aplan = registry.plan_conv(
            spec, hw, algo=algo, hints=hints, wisdom_path=wisdom_path
        )
    alg = registry.get(aplan.algo)
    if wt is not None and not alg.consumes_wt:
        raise ValueError(
            f"algo {aplan.algo!r} does not consume pre-transformed kernels: "
            "a supplied `wt` would silently drop precomputed work.  Pass "
            "wt=None, or plan an algorithm with consumes_wt=True."
        )
    return alg.execute(x, w, wt, aplan)


def conv1d_depthwise_causal(
    x: jnp.ndarray, w: jnp.ndarray, *, use_pallas: bool = False
) -> jnp.ndarray:
    """Depthwise causal conv1d: x (B, L, D), w (K, D) -> (B, L, D).

    The Mamba-family short conv.  `use_pallas` selects the fused VMEM kernel
    (repro.kernels.conv1d_fused); default is the jnp reference, which XLA
    fuses adequately on CPU.
    """
    if use_pallas:
        from repro.kernels.conv1d_fused import ops as c1_ops

        return c1_ops.conv1d_fused(x, w)
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # K is tiny (4); unrolled shifted MACs
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out
