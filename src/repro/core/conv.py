"""Public convolution API with algorithm selection.

    conv2d(x, w, pad=1, algo="l3_fused")      # the paper's contribution
    conv2d(x, w, pad=1, algo="three_stage")   # vendor-structure baseline
    conv2d(x, w, pad=1, algo="direct")        # XLA direct conv (the "DNNL"
                                              # stand-in on this backend)
    conv2d(x, w, pad=1, algo="fft_fused")     # FFT-basis fused variant
    conv2d(x, w, pad=1, algo="l3_fused_pallas")  # the Pallas TPU kernel
    conv2d(x, w, pad=1, algo="auto")          # paper's wisdom-file choice

Layout: NHWC activations, HWIO kernels (TPU-native).  `conv1d` covers the
depthwise-causal short convs of the SSM architectures.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import analysis
from repro.core.fft_conv import conv2d_fft_fused
from repro.core.fused import conv2d_l3_fused
from repro.core.three_stage import conv2d_three_stage

ALGOS = ("direct", "three_stage", "l3_fused", "fft_fused", "l3_fused_pallas", "auto")


def conv2d_direct(x: jnp.ndarray, w: jnp.ndarray, *, pad: int = 0) -> jnp.ndarray:
    """XLA's own convolution -- the vendor-library stand-in."""
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(1, 1),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    pad: int = 0,
    algo: str = "auto",
    m: Optional[int] = None,
    r_tiles: int = 24,
    hw: analysis.HardwareModel = analysis.TPU_V5E,
) -> jnp.ndarray:
    """2-D convolution, NHWC x HWIO -> NHWC."""
    if algo not in ALGOS:
        raise ValueError(f"unknown algo {algo!r}, expected one of {ALGOS}")
    if algo == "auto":
        k = w.shape[0]
        t = (m if m is not None else 5) + k - 1
        algo = analysis.choose_algo(hw, x.shape[3], w.shape[3], t)
    if algo == "direct":
        return conv2d_direct(x, w, pad=pad)
    if algo == "three_stage":
        return conv2d_three_stage(x, w, pad=pad, m=m)
    if algo == "l3_fused":
        return conv2d_l3_fused(x, w, pad=pad, m=m, r_tiles=r_tiles)
    if algo == "fft_fused":
        return conv2d_fft_fused(x, w, pad=pad, r_tiles=r_tiles)
    if algo == "l3_fused_pallas":
        from repro.kernels.fused_winograd import ops as fw_ops

        return fw_ops.conv2d_fused_pallas(x, w, pad=pad, m=m, r_tiles=r_tiles)
    raise AssertionError(algo)


def conv1d_depthwise_causal(
    x: jnp.ndarray, w: jnp.ndarray, *, use_pallas: bool = False
) -> jnp.ndarray:
    """Depthwise causal conv1d: x (B, L, D), w (K, D) -> (B, L, D).

    The Mamba-family short conv.  `use_pallas` selects the fused VMEM kernel
    (repro.kernels.conv1d_fused); default is the jnp reference, which XLA
    fuses adequately on CPU.
    """
    if use_pallas:
        from repro.kernels.conv1d_fused import ops as c1_ops

        return c1_ops.conv1d_fused(x, w)
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # K is tiny (4); unrolled shifted MACs
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out
