"""Shared-buffer planner (paper S4.2).

The i-th matmul's result may overwrite left-hand matrices < i, never >= i
(matmuls cannot run in place).  Storing left-hand matrices right-aligned in
one buffer and writing results from the start reduces the fast-memory
working set from  T^2 (S_max + S_min)  to  T^2 S_max + S_min,
S_max = max(4RC, 4RC'), S_min = min(4RC, 4RC') -- almost 2x when C == C',
which in turn permits an ~2x larger R (paper: "relaxing the upper bound
almost by a factor of two").

We use a row-granular variant suited to 2-D scratch buffers (Pallas VMEM
wants >=2-D refs):  buffer shape ((T^2 + 1) * R, W) with W = max(C, C');
left-hand matrix s occupies rows [(s+1)R, (s+2)R) cols [0, C); result s is
written to rows [sR, (s+1)R) cols [0, C') -- landing exactly on the rows of
left-hand matrix s-1, which the s-th matmul no longer needs.  Space:
(T^2+1) * R * 4W = T^2 S_max + S_max; equal to the paper's bound when
C == C' and within S_max - S_min of it otherwise.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SharedBufferPlan:
    r: int
    c_in: int
    c_out: int
    t2: int  # domain matmuls: T^2 (Winograd) or T*(T/2+1) (rfft)
    elem_bytes: int = 4  # 4 for real domains, 8 for complex (FFT)

    @property
    def width(self) -> int:
        return max(self.c_in, self.c_out)

    @property
    def rows(self) -> int:
        return (self.t2 + 1) * self.r

    def lhs_row(self, s: int) -> int:
        """First buffer row of left-hand matrix s (s in [0, T^2))."""
        return (s + 1) * self.r

    def result_row(self, s: int) -> int:
        """First buffer row of result matrix s."""
        return s * self.r

    @property
    def bytes(self) -> int:
        return self.elem_bytes * self.rows * self.width

    @property
    def naive_bytes(self) -> int:
        """Separate-buffer working set: T^2 * (4RC + 4RC')."""
        return self.elem_bytes * self.t2 * self.r * (self.c_in + self.c_out)

    @property
    def paper_bound_bytes(self) -> int:
        """T^2 S_max + S_min (byte-granular bound from the paper)."""
        s_max = self.elem_bytes * self.r * max(self.c_in, self.c_out)
        s_min = self.elem_bytes * self.r * min(self.c_in, self.c_out)
        return self.t2 * s_max + s_min

    @property
    def savings(self) -> float:
        return 1.0 - self.bytes / self.naive_bytes

    def validate(self) -> None:
        """Prove the aliasing invariant: result s never touches lhs >= s."""
        for s in range(self.t2):
            res_end = self.result_row(s) + self.r
            assert res_end <= self.lhs_row(s), (
                f"result {s} rows [{self.result_row(s)}, {res_end}) overlap "
                f"lhs {s} rows starting {self.lhs_row(s)}"
            )


def max_r_for_budget(
    budget_bytes: int,
    c_in: int,
    c_out: int,
    t: int,
    *,
    shared: bool = True,
    points: int = 0,
    elem_bytes: int = 4,
) -> int:
    """Largest R whose working set fits `budget_bytes` (paper S5.2).

    `points`/`elem_bytes` generalize beyond fp32 Winograd: the number of
    stored domain elements per tile plane (defaults to T^2) and their
    width (8 for the FFT's complex domain) -- `TileAlgebra` supplies both.
    """
    t2 = points if points else t * t
    w = max(c_in, c_out)
    if shared:
        denom = elem_bytes * (t2 + 1) * w
    else:
        denom = elem_bytes * t2 * (c_in + c_out)
    return max(1, budget_bytes // denom)
