"""Fault tolerance: failure injection, straggler watchdog, supervised retry.

On a real cluster the coordinator restarts failed workers and the job
resumes from the last committed checkpoint; in this container the same
control flow is exercised with injected failures (tests/test_fault.py).
Two consumers share this module:

  * the training loop (`FailureInjector` + `run_supervised`): step-keyed
    node-loss injection with restore-from-checkpoint, and
  * the fleet serving pool (`FaultPlan`): a *time*-keyed schedule of
    replica crashes, slowdowns, and shared-cache corruption, routed
    through the injected `Clock` so the same drill replays identically
    under a `SimClock` (deterministic fault instants on the simulated
    timeline) and a `RealClock`.

All `FaultPlan` state is lock-guarded: the serving pool consults it from
replica completion threads as well as the dispatch path.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable, List, Optional, Sequence


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at the given steps (simulated node loss)."""

    fail_at_steps: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected node failure at step {step}")


# fault kinds the fleet pool understands (a closed vocabulary, like the
# admission-reject reasons: telemetry and loss accounting count by it)
FAULT_CRASH = "crash"
FAULT_SLOW = "slow"
FAULT_CACHE_CORRUPT = "cache_corrupt"
FAULT_KINDS = (FAULT_CRASH, FAULT_SLOW, FAULT_CACHE_CORRUPT)


@dataclasses.dataclass(frozen=True)
class ReplicaFault:
    """One scheduled fault: at clock time `t`, do `kind` to `replica`.

    `replica` is the pool's replica index (`None` targets the shared
    kernel cache for ``cache_corrupt``; crash/slow require a target).
    `factor` is the service-time multiplier for ``slow`` faults."""

    t: float
    kind: str
    replica: Optional[int] = None
    factor: float = 4.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{FAULT_KINDS}"
            )
        if self.kind in (FAULT_CRASH, FAULT_SLOW) and self.replica is None:
            raise ValueError(f"{self.kind} fault needs a target replica")


class FaultPlan:
    """A deterministic, clock-routed schedule of injected faults.

    The pool polls ``due()`` as its event loop advances; each fault is
    handed out exactly once, in schedule order, the first time the
    injected clock reaches its instant.  ``next_t()`` lets a simulated
    event loop step the clock exactly onto the next fault (so a crash
    lands at a provable simulated instant, not "sometime during the
    trace")."""

    def __init__(
        self,
        faults: Sequence[ReplicaFault] = (),
        *,
        clock=None,
    ):
        self.clock = clock  # injected Clock; None = caller supplies `now`
        self._lock = threading.Lock()
        self._pending: List[ReplicaFault] = sorted(  # guarded-by: _lock
            faults, key=lambda f: f.t
        )
        self.fired: List[ReplicaFault] = []  # guarded-by: _lock

    def _now(self, now: Optional[float]) -> float:
        if now is not None:
            return now
        if self.clock is None:
            raise ValueError(
                "FaultPlan has no injected clock: pass `now` explicitly"
            )
        return self.clock.now()

    def due(self, now: Optional[float] = None) -> List[ReplicaFault]:
        """Pop every fault scheduled at or before `now` (the injected
        clock's reading when omitted), oldest first, each exactly once."""
        t = self._now(now)
        with self._lock:
            ripe = [f for f in self._pending if f.t <= t]
            if ripe:
                self._pending = [f for f in self._pending if f.t > t]
                self.fired.extend(ripe)
            return ripe

    def next_t(self) -> float:
        """Clock time of the next scheduled fault (inf when exhausted)."""
        with self._lock:
            return self._pending[0].t if self._pending else float("inf")

    def pending(self) -> int:
        with self._lock:
            return len(self._pending)

    def stats(self) -> dict:
        with self._lock:
            return {
                "pending": len(self._pending),
                "fired": [
                    {"t": f.t, "kind": f.kind, "replica": f.replica}
                    for f in self.fired
                ],
            }


class StragglerWatchdog:
    """Step-time tracker: alarms when a step exceeds k x trailing p50.

    On a real deployment the alarm triggers work re-assignment / node
    replacement; here it records events for the supervisor + tests.
    With an injected `clock`, alarms are stamped with the clock's time,
    so a SimClock drill yields deterministic alarm timelines.
    """

    def __init__(self, factor: float = 3.0, window: int = 50,
                 min_steps: int = 5, *, clock=None):
        self.factor = factor
        self.window = window
        self.min_steps = min_steps
        self.clock = clock
        self.times: List[float] = []
        self.alarms: List[dict] = []

    def observe(self, step: int, seconds: float) -> Optional[dict]:
        alarm = None
        if len(self.times) >= self.min_steps:
            hist = sorted(self.times[-self.window :])
            p50 = hist[len(hist) // 2]
            if seconds > self.factor * p50:
                alarm = {"step": step, "seconds": seconds, "p50": p50}
                if self.clock is not None:
                    alarm["t"] = self.clock.now()
                self.alarms.append(alarm)
        self.times.append(seconds)
        return alarm


def run_supervised(
    work: Callable[[int], int],
    *,
    start_step: int,
    total_steps: int,
    restore: Callable[[], int],
    max_restarts: int = 5,
) -> int:
    """Supervisor loop: run `work(step) -> next_step` until total_steps,
    restoring from the last checkpoint (via `restore() -> step`) on failure.
    Models the cluster-level restart-from-checkpoint policy.
    """
    step = start_step
    restarts = 0
    while step < total_steps:
        try:
            step = work(step)
        except InjectedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            step = restore()
    return step
