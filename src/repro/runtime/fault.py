"""Fault tolerance: failure injection, straggler watchdog, supervised retry.

On a real cluster the coordinator restarts failed workers and the job
resumes from the last committed checkpoint; in this container the same
control flow is exercised with injected failures (tests/test_fault.py).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at the given steps (simulated node loss)."""

    fail_at_steps: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected node failure at step {step}")


class StragglerWatchdog:
    """Step-time tracker: alarms when a step exceeds k x trailing p50.

    On a real deployment the alarm triggers work re-assignment / node
    replacement; here it records events for the supervisor + tests.
    """

    def __init__(self, factor: float = 3.0, window: int = 50, min_steps: int = 5):
        self.factor = factor
        self.window = window
        self.min_steps = min_steps
        self.times: List[float] = []
        self.alarms: List[dict] = []

    def observe(self, step: int, seconds: float) -> Optional[dict]:
        alarm = None
        if len(self.times) >= self.min_steps:
            hist = sorted(self.times[-self.window :])
            p50 = hist[len(hist) // 2]
            if seconds > self.factor * p50:
                alarm = {"step": step, "seconds": seconds, "p50": p50}
                self.alarms.append(alarm)
        self.times.append(seconds)
        return alarm


def run_supervised(
    work: Callable[[int], int],
    *,
    start_step: int,
    total_steps: int,
    restore: Callable[[], int],
    max_restarts: int = 5,
) -> int:
    """Supervisor loop: run `work(step) -> next_step` until total_steps,
    restoring from the last checkpoint (via `restore() -> step`) on failure.
    Models the cluster-level restart-from-checkpoint policy.
    """
    step = start_step
    restarts = 0
    while step < total_steps:
        try:
            step = work(step)
        except InjectedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            step = restore()
    return step
