"""Pallas TPU kernel for the L3-fused Winograd convolution.

TPU adaptation of the paper's algorithm (DESIGN.md S2):

  * the T^2 right-hand (transformed-kernel) matrices get a *constant
    BlockSpec index map* -> DMA'd HBM->VMEM once and stationary across all
    grid steps.  This is the paper's "kernel matrices stay hot in shared L3",
    with residency *guaranteed* rather than relied upon via cache heuristics.
  * one grid step == one task: R output tiles along a row-strip.  The input
    strip is read with `pl.Element` block dims (offset stride T' < extent T),
    expressing the overlap-add overlap without materialising tiles in HBM.
  * per-task intermediates live in a single VMEM scratch laid out per the
    paper's shared-buffer scheme (repro.core.sharedbuf): buffer
    (T^2 + 1, R, max(C, C')); left-hand matrix s occupies block s+1, the
    s-th product is written to block s -- overwriting only left-hand
    matrices already consumed.  This halves the VMEM working set and thus
    permits a ~2x larger R, exactly the paper's S4.2 claim transplanted.

Grid: (batch, tile_rows, tile_col_blocks); the T^2 matmuls run on the MXU as
(R x C) @ (C x C') with R a multiple of 8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import transforms
from repro.core.sharedbuf import SharedBufferPlan


def _kernel_body(
    x_ref, wt_ref, bt_ref, at_ref, o_ref, sb_ref,
    *, m: int, k: int, c_in: int, c_out: int, r: int
):
    t = m + k - 1
    t2 = t * t
    bt = bt_ref[...]  # (T, T) input transform
    at = at_ref[...]  # (T', T) output transform

    strip = x_ref[0].astype(jnp.float32)  # (T, R*T' + K - 1, C)

    # -- step 1: forward-transform R tiles; scatter rows into the shared
    # buffer as left-hand matrices (blocks 1 .. T^2).  Static unroll: each
    # tile is a static slice of the strip (stride T', extent T).
    for tix in range(r):
        tile = strip[:, tix * m : tix * m + t, :]  # (T, T, C)
        u = jnp.einsum(
            "xi,ijc,yj->xyc", bt, tile, bt, preferred_element_type=jnp.float32
        )
        sb_ref[1:, tix, :c_in] = u.reshape(t2, c_in)

    # -- step 2: T^2 small matmuls against the stationary right-hand
    # matrices.  Result s lands on block s = the rows of left-hand matrix
    # s-1, which is no longer needed (shared-buffer aliasing, paper S4.2).
    def mm(s, _):
        lhs = sb_ref[s + 1, :, :c_in]  # (R, C)
        res = jax.lax.dot_general(
            lhs,
            wt_ref[s],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        sb_ref[s, :, :c_out] = res
        return 0

    jax.lax.fori_loop(0, t2, mm, 0, unroll=False)

    # -- step 3: inverse-transform all R results; write the output strip.
    z = sb_ref[:t2, :, :c_out].reshape(t, t, r, c_out)
    y = jnp.einsum("xi,ijrc,yj->rxyc", at, z, at, preferred_element_type=jnp.float32)
    # (R, T', T', C') -> (T', R*T', C')
    o_ref[0] = y.transpose(1, 0, 2, 3).reshape(m, r * m, c_out).astype(o_ref.dtype)


def fused_winograd_call(
    xp: jnp.ndarray,
    wt: jnp.ndarray,
    *,
    m: int,
    k: int,
    n_tiles_h: int,
    n_tiles_w: int,
    r: int,
    interpret: bool = True,
):
    """Invoke the fused kernel.

    xp: (B, H_pad, W_pad, C) pre-padded input with H_pad = nH*T' + K - 1,
        W_pad = nW*T' + K - 1 and nW divisible by r.
    wt: (T*T, C, C') transformed kernels.
    returns: (B, nH*T', nW*T', C') assembled output tiles.
    """
    b, h_pad, w_pad, c_in = xp.shape
    t = m + k - 1
    t2 = t * t
    c_out = wt.shape[2]
    assert wt.shape == (t2, c_in, c_out), (wt.shape, t2, c_in, c_out)
    assert n_tiles_w % r == 0, (n_tiles_w, r)
    assert h_pad == n_tiles_h * m + k - 1, (h_pad, n_tiles_h, m, k)
    assert w_pad == n_tiles_w * m + k - 1, (w_pad, n_tiles_w, m, k)
    n_col_blocks = n_tiles_w // r
    sb = SharedBufferPlan(r=r, c_in=c_in, c_out=c_out, t2=t2)
    sb.validate()

    at_np, _, bt_np = transforms.winograd_matrices(m, k)
    bt = jnp.asarray(bt_np, jnp.float32)
    at = jnp.asarray(at_np, jnp.float32)

    body = functools.partial(
        _kernel_body, m=m, k=k, c_in=c_in, c_out=c_out, r=r
    )
    strip_w = r * m + k - 1
    # The input strip is element-indexed (offset stride T' < extent T, the
    # overlap-add overlap).  Newer jax spells this per-dim via pl.Element;
    # older releases only offer whole-spec unblocked indexing -- equivalent
    # here because the blocked dims are size-1 (batch) or zero-offset
    # (channels), so the same element-offset index map serves both.
    if hasattr(pl, "Element"):
        strip_spec = pl.BlockSpec(
            (1, pl.Element(t), pl.Element(strip_w), c_in),
            lambda bi, i, j: (bi, i * m, j * (r * m), 0),
        )
    else:
        strip_spec = pl.BlockSpec(
            (1, t, strip_w, c_in),
            lambda bi, i, j: (bi, i * m, j * (r * m), 0),
            indexing_mode=pl.unblocked,
        )
    return pl.pallas_call(
        body,
        grid=(b, n_tiles_h, n_col_blocks),
        in_specs=[
            strip_spec,
            # constant index map == VMEM-stationary right-hand matrices
            pl.BlockSpec((t2, c_in, c_out), lambda bi, i, j: (0, 0, 0)),
            pl.BlockSpec((t, t), lambda bi, i, j: (0, 0)),
            pl.BlockSpec((m, t), lambda bi, i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, m, r * m, c_out), lambda bi, i, j: (bi, i, j, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (b, n_tiles_h * m, n_tiles_w * m, c_out), xp.dtype
        ),
        scratch_shapes=[
            pltpu.VMEM((t2 + 1, r, max(c_in, c_out)), jnp.float32)
        ],
        interpret=interpret,
    )(xp, wt, bt, at)
