from repro.kernels.fused_winograd.ops import conv2d_fused_pallas
from repro.kernels.fused_winograd.ref import conv2d_ref

__all__ = ["conv2d_fused_pallas", "conv2d_ref"]
