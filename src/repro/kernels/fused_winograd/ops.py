"""The fused Winograd Pallas kernel, as a thin instantiation.

The bespoke kernel this package used to carry is retired: the parametric
tile engine (`repro.kernels.fused_tile`) runs the identical gather ->
forward GEMM -> batched mix -> inverse GEMM -> scatter program for every
transform family, so the Winograd Pallas path is now `conv2d_fused_tile`
driven by a `WinogradTransform` with the Kronecker-form basis matrices.
`conv2d_fused_pallas` keeps its historical signature for direct users;
see the README migration note.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import registry, transforms
from repro.core.fused import L3FusedAlgorithm
from repro.kernels.fused_tile import BlockConfig, conv2d_fused_tile


@functools.partial(
    jax.jit, static_argnames=("pad", "m", "r_tiles", "groups", "interpret")
)
def conv2d_fused_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    pad: int = 0,
    m: Optional[int] = None,
    r_tiles: int = 16,
    groups: int = 1,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """NHWC (B,H,W,C) x HWIO (K,K,C/g,C') -> NHWC, via the parametric
    fused tile kernel instantiated with the Winograd transform.

    interpret=None auto-selects: real lowering on TPU, interpreter
    elsewhere.  Grouped convolutions run block-diagonal inside the one
    kernel (no per-group dispatch).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tr = transforms.WinogradTransform(
        m=m if m is not None else 5, k=w.shape[0]
    )
    return conv2d_fused_tile(
        x, w, tr,
        pad=pad,
        blocks=BlockConfig(r=int(r_tiles), tasks_per_program=1),
        groups=groups,
        backend="pallas_interpret" if interpret else "pallas",
    )


class L3FusedPallasAlgorithm(L3FusedAlgorithm):
    """The Pallas instantiation of the tile engine as a registry algorithm.

    Shares the Winograd family's plan step (same transform, same
    family-keyed wisdom R: a tuned R for l3_fused is the best available
    estimate for the kernel's task width too) but is explicit-only
    (`auto_candidate = False`): correct on every backend via interpret
    mode, yet only profitable where the kernel lowers natively -- auto
    resolution should not hand CPU hosts an interpreted kernel.  The
    kernel transforms its own weights inside the jit (constant-folded per
    compile), so it has no ahead-of-time prepare step and never consumes a
    cached `wt`.
    """

    name = "l3_fused_pallas"
    tier = 0
    rank = 15
    consumes_wt = False
    weight_params = ()
    auto_candidate = False
    chain_family = "winograd"  # chains with the pure-JAX Winograd path

    def prepare_weights(self, w, plan):
        return None

    def execute(self, x, w, wt, plan):
        y = conv2d_fused_pallas(
            x, w, pad=plan.spec.pad, m=plan.params.get("m"),
            r_tiles=int(plan.params.get("r_tiles", 16)),
            groups=plan.spec.groups,
        )
        return registry.decimate(y, plan.spec.stride)

    def fuse_epilogue(self, plan, epilogue):
        # structured glue folds into the kernel's scatter phase through
        # the engine; opaque callables post-pass (base Algorithm path)
        if isinstance(epilogue, registry.ElementwiseOps):
            tr = transforms.WinogradTransform(
                m=int(plan.params.get("m") or 5), k=plan.spec.k
            )
            interpret = jax.default_backend() != "tpu"

            def run(x, w, wt):
                y = conv2d_fused_tile(
                    x, w, tr,
                    pad=plan.spec.pad,
                    blocks=BlockConfig(
                        r=int(plan.params.get("r_tiles", 16)),
                        tasks_per_program=1,
                    ),
                    groups=plan.spec.groups,
                    epilogue=epilogue,
                    backend="pallas_interpret" if interpret else "pallas",
                )
                return registry.decimate(y, plan.spec.stride)

            return run
        return registry.Algorithm.fuse_epilogue(self, plan, epilogue)


registry.register(L3FusedPallasAlgorithm())
