"""Jitted public wrapper around the fused Winograd Pallas kernel."""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import registry, tiling
from repro.core.fused import plan_wino_family
from repro.core.three_stage import transform_kernels
from repro.kernels.fused_winograd.kernel import fused_winograd_call


def _extended_plan(plan: tiling.TilePlan, r: int) -> tiling.TilePlan:
    """Extend the tile grid so n_tiles_w is a multiple of R (task width)."""
    n_tw = -(-plan.n_tiles_w // r) * r
    return dataclasses.replace(
        plan, n_tiles_w=n_tw, w_pad=n_tw * plan.t_out + plan.k - 1
    )


@functools.partial(
    jax.jit, static_argnames=("pad", "m", "r_tiles", "interpret")
)
def conv2d_fused_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    pad: int = 0,
    m: Optional[int] = None,
    r_tiles: int = 16,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """NHWC (B,H,W,C) x HWIO (K,K,C,C') -> NHWC, via the Pallas fused kernel.

    interpret=None auto-selects: real lowering on TPU, interpreter elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    k = w.shape[0]
    m = m if m is not None else 5
    t = m + k - 1
    plan = tiling.TilePlan.build(x.shape[1], x.shape[2], k, pad, t)
    r = min(r_tiles, plan.n_tiles_w)
    plan = _extended_plan(plan, r)
    xp = tiling.pad_input(x, plan)
    wt = transform_kernels(w, m)
    y = fused_winograd_call(
        xp,
        wt,
        m=m,
        k=k,
        n_tiles_h=plan.n_tiles_h,
        n_tiles_w=plan.n_tiles_w,
        r=r,
        interpret=interpret,
    )
    return y[:, : plan.h_out, : plan.w_out, :]


class L3FusedPallasAlgorithm(registry.Algorithm):
    """The hand-written Pallas TPU kernel as a registry algorithm.

    Explicit-only (`auto_candidate = False`): correct on every backend via
    interpret mode, but only profitable where the kernel lowers natively --
    auto resolution should not hand CPU hosts an interpreted kernel.  The
    kernel transforms its own weights inside the jit (constant-folded per
    compile), so it has no ahead-of-time prepare step and never consumes a
    cached `wt`.
    """

    name = "l3_fused_pallas"
    tier = 0
    rank = 15
    consumes_wt = False
    auto_candidate = False
    chain_family = "winograd"  # chains with the pure-JAX Winograd path
    default_m = 5

    def supports(self, spec: registry.ConvSpec) -> bool:
        return spec.groups == 1

    def plan(self, spec, hw, *, hints=None, tune_r=False, wisdom_path=None):
        # shares the Winograd wisdom family: a tuned R for l3_fused is the
        # best available estimate for the kernel's task width too
        return plan_wino_family(
            self.name, spec, hw, default_m=self.default_m, hints=hints,
            tune_r=tune_r, wisdom_path=wisdom_path,
        )

    def execute(self, x, w, wt, plan):
        y = conv2d_fused_pallas(
            x, w, pad=plan.spec.pad, m=plan.params.get("m"),
            r_tiles=plan.params.get("r_tiles", 16),
        )
        return registry.decimate(y, plan.spec.stride)


registry.register(L3FusedPallasAlgorithm())
