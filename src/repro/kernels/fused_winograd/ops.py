"""Jitted public wrapper around the fused Winograd Pallas kernel."""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import registry, tiling, transforms
from repro.core.fused import L3FusedAlgorithm
from repro.kernels.fused_winograd.kernel import fused_winograd_call


def _extended_plan(plan: tiling.TilePlan, r: int) -> tiling.TilePlan:
    """Extend the tile grid so n_tiles_w is a multiple of R (task width)."""
    n_tw = -(-plan.n_tiles_w // r) * r
    return dataclasses.replace(
        plan, n_tiles_w=n_tw, w_pad=n_tw * plan.t_out + plan.k - 1
    )


@functools.partial(
    jax.jit, static_argnames=("pad", "m", "r_tiles", "groups", "interpret")
)
def conv2d_fused_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    pad: int = 0,
    m: Optional[int] = None,
    r_tiles: int = 16,
    groups: int = 1,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """NHWC (B,H,W,C) x HWIO (K,K,C/g,C') -> NHWC, via the Pallas fused kernel.

    interpret=None auto-selects: real lowering on TPU, interpreter elsewhere.
    Grouped convolutions run the kernel once per group over the group's
    channel slices (the kernel itself computes a dense channel mix).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if groups > 1:
        cg_in = x.shape[3] // groups
        cg_out = w.shape[3] // groups
        run = functools.partial(
            conv2d_fused_pallas,
            pad=pad, m=m, r_tiles=r_tiles, groups=1, interpret=interpret,
        )
        return jnp.concatenate(
            [
                run(
                    x[..., g * cg_in : (g + 1) * cg_in],
                    w[..., g * cg_out : (g + 1) * cg_out],
                )
                for g in range(groups)
            ],
            axis=-1,
        )
    tr = transforms.WinogradTransform(m=m if m is not None else 5, k=w.shape[0])
    plan = tiling.TilePlan.build(x.shape[1], x.shape[2], tr.k, pad, tr.t)
    r = min(r_tiles, plan.n_tiles_w)
    plan = _extended_plan(plan, r)
    xp = tiling.pad_input(x, plan)
    wt = tr.kernel_transform(w)
    y = fused_winograd_call(
        xp,
        wt,
        m=tr.m,
        k=tr.k,
        n_tiles_h=plan.n_tiles_h,
        n_tiles_w=plan.n_tiles_w,
        r=r,
        interpret=interpret,
    )
    return y[:, : plan.h_out, : plan.w_out, :]


class L3FusedPallasAlgorithm(L3FusedAlgorithm):
    """The hand-written Pallas TPU kernel as a registry algorithm.

    Shares the Winograd family's plan step (same transform, same
    family-keyed wisdom R: a tuned R for l3_fused is the best available
    estimate for the kernel's task width too) but is explicit-only
    (`auto_candidate = False`): correct on every backend via interpret
    mode, yet only profitable where the kernel lowers natively -- auto
    resolution should not hand CPU hosts an interpreted kernel.  The
    kernel transforms its own weights inside the jit (constant-folded per
    compile), so it has no ahead-of-time prepare step and never consumes a
    cached `wt`.
    """

    name = "l3_fused_pallas"
    tier = 0
    rank = 15
    consumes_wt = False
    weight_params = ()
    auto_candidate = False
    chain_family = "winograd"  # chains with the pure-JAX Winograd path

    def prepare_weights(self, w, plan):
        return None

    def execute(self, x, w, wt, plan):
        y = conv2d_fused_pallas(
            x, w, pad=plan.spec.pad, m=plan.params.get("m"),
            r_tiles=int(plan.params.get("r_tiles", 16)),
            groups=plan.spec.groups,
        )
        return registry.decimate(y, plan.spec.stride)

    def fuse_epilogue(self, plan, epilogue):
        # the kernel's task loop is hand-written: elementwise glue runs on
        # the assembled output rather than in-scan (base Algorithm path)
        return registry.Algorithm.fuse_epilogue(self, plan, epilogue)


registry.register(L3FusedPallasAlgorithm())
