"""Pure-jnp oracle for the fused Winograd kernel: direct correlation."""

from __future__ import annotations

import jax.numpy as jnp


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, *, pad: int = 0) -> jnp.ndarray:
    """Direct 2-D correlation, NHWC x HWIO -> NHWC, float32 accumulation.

    Implemented as K*K shifted matmuls (no lax.conv), so it is an
    independent oracle for both the Pallas kernel and the transformed paths.
    """
    b, h, wi, c = x.shape
    k = w.shape[0]
    c_out = w.shape[3]
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0))).astype(jnp.float32)
    h_out = h + 2 * pad - k + 1
    w_out = wi + 2 * pad - k + 1
    acc = jnp.zeros((b, h_out, w_out, c_out), jnp.float32)
    for ki in range(k):
        for kj in range(k):
            patch = xp[:, ki : ki + h_out, kj : kj + w_out, :]
            acc = acc + patch @ w[ki, kj].astype(jnp.float32)
    return acc.astype(x.dtype)
