"""Jitted wrapper for the Pallas flash-attention kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd_pallas


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_blk", "kv_blk", "interpret")
)
def flash_attention_pallas(
    q: jnp.ndarray,  # (B, Hq, Sq, hd)
    k: jnp.ndarray,  # (B, Hkv, Sk, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_blk: int = 128,
    kv_blk: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, hq, sq, hd = q.shape
    sk = k.shape[2]
    q_blk = min(q_blk, sq)
    kv_blk = min(kv_blk, sk)
    pad_q = (-sq) % q_blk
    pad_k = (-sk) % kv_blk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # padded KV rows must never win the softmax: rely on causal mask for
        # padded-q rows; for padded-k, causal (kp <= qp) masks them for all
        # real q rows only when causal -- for non-causal, mask via window=0
        # is unavailable, so we require causal or exact multiples.
        assert causal or pad_k == 0, "non-causal needs Sk % kv_blk == 0"
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = flash_attention_fwd_pallas(
        q, k, v, causal=causal, window=window,
        q_blk=q_blk, kv_blk=kv_blk, interpret=interpret,
    )
    return out[:, :, :sq]
