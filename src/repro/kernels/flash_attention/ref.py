"""Pure-jnp oracle for the Pallas flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,  # (B, Hq, Sq, hd)
    k: jnp.ndarray,  # (B, Hkv, Sk, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
) -> jnp.ndarray:
    b, hq, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * hd ** -0.5
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= kp <= qp
    if window > 0:
        ok &= qp - kp < window
    s = jnp.where(ok, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(
        q.dtype
    )
