"""Pallas TPU flash-attention kernel (forward).

The paper's L3-fusion principle applied to attention: the probability tile
P = softmax(q_i k_j^T) is the "left-hand matrix" of the moment -- it lives
only in VMEM scratch between the QK and PV matmuls (never HBM), while the
KV stream plays the input-tile role.  GQA is expressed in the BlockSpec
index map (kv head = q head // group) so shared KV heads are DMA'd once,
not materialised per query head.

Grid: (batch, q_heads, q_blocks, kv_blocks) -- kv innermost; the online
softmax state (m, l, acc) lives in VMEM scratch across the kv loop.
Causal / sliding-window tiles outside the band are skipped with pl.when
(no MXU work issued).

The pure-JAX custom-VJP twin (repro.models.flash_attention) is what the
dry-run lowers; this kernel is the TPU-native form, validated against the
same oracle in interpret mode (tests/test_kernel_flash.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _body(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, q_blk: int, kv_blk: int, causal: bool, window: int, scale: float,
):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = i * q_blk
    k_lo = j * kv_blk
    # band check (static per grid step at trace time it's dynamic -- cheap
    # scalar compare; skipped tiles issue no MXU work)
    in_band = jnp.asarray(True)
    if causal:
        in_band = jnp.logical_and(in_band, k_lo <= q_lo + q_blk - 1)
    if window > 0:
        in_band = jnp.logical_and(
            in_band, k_lo + kv_blk - 1 >= q_lo - window + 1
        )

    @pl.when(in_band)
    def _():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (q_blk, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (kv_blk, hd)
        v = v_ref[0, 0]  # (kv_blk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (q_blk, kv_blk)
        qp = q_lo + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 0)
        kp = k_lo + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 1)
        ok = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            ok = jnp.logical_and(ok, kp <= qp)
        if window > 0:
            ok = jnp.logical_and(ok, qp - kp < window)
        s = jnp.where(ok, s, -jnp.inf)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(ok, jnp.exp(s - m_safe[:, None]), 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v.astype(v.dtype),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_fwd_pallas(
    q: jnp.ndarray,  # (B, Hq, Sq, hd)
    k: jnp.ndarray,  # (B, Hkv, Sk, hd)
    v: jnp.ndarray,  # (B, Hkv, Sk, hd)
    *,
    causal: bool = True,
    window: int = 0,
    q_blk: int = 128,
    kv_blk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, hq, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    assert sq % q_blk == 0 and sk % kv_blk == 0, (sq, q_blk, sk, kv_blk)
    body = functools.partial(
        _body, q_blk=q_blk, kv_blk=kv_blk, causal=causal,
        window=int(window), scale=hd ** -0.5,
    )
    return pl.pallas_call(
        body,
        grid=(b, hq, sq // q_blk, sk // kv_blk),
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, hd), lambda b_, h, i, j: (b_, h, i, 0)),
            # GQA in the index map: kv head = q head // group
            pl.BlockSpec(
                (1, 1, kv_blk, hd), lambda b_, h, i, j: (b_, h // g, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, kv_blk, hd), lambda b_, h, i, j: (b_, h // g, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, q_blk, hd), lambda b_, h, i, j: (b_, h, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk,), jnp.float32),  # m
            pltpu.VMEM((q_blk,), jnp.float32),  # l
            pltpu.VMEM((q_blk, hd), jnp.float32),  # acc: P never leaves VMEM
        ],
        interpret=interpret,
    )(q, k, v)
