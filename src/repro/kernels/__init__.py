"""Pallas TPU kernels (validated via interpret=True on CPU).

fused_winograd -- the paper's L3-fused algorithm as a TPU kernel
conv1d_fused   -- Mamba-family short causal conv, fused taps-stationary
decode_mlp     -- beyond-paper: weight-stationary fused SwiGLU decode MLP
"""
