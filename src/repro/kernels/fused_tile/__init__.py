from repro.kernels.fused_tile.blocks import BlockConfig
from repro.kernels.fused_tile.kernel import fused_tile_call
from repro.kernels.fused_tile.matrix import (
    matrix_tile_conv,
    pallas_block_geometry,
    staged_matrix_fns,
)
from repro.kernels.fused_tile.ops import (
    UnsupportedSpec,
    conv2d_fused_tile,
    engine_supported,
    resolve_backend,
)

__all__ = [
    "BlockConfig",
    "UnsupportedSpec",
    "conv2d_fused_tile",
    "engine_supported",
    "fused_tile_call",
    "matrix_tile_conv",
    "pallas_block_geometry",
    "resolve_backend",
    "staged_matrix_fns",
]
