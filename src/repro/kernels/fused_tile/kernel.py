"""The parametric Pallas tile kernel: one body for every transform family.

Generalizes the retired bespoke Winograd kernel to any `TileKernelSpec`
(core.transforms): the forward and inverse basis changes enter as *data*
-- the (planes*S, T^2) and (T'^2, planes*S) Kronecker-form matrices --
so Winograd, FFT (re/im split planes) and any future family compile to
the same gather -> fwd GEMM -> batched mix -> inv GEMM -> scatter task
loop.  The paper-S4.2 shared-buffer aliasing is preserved exactly:
per-task intermediates live in one VMEM scratch of (S + 1) R-row blocks,
left-hand matrix s at block s+1, the s-th mix product overwriting block
s (only left-hand rows already consumed).

Structure per grid step (one program):

  * the input strip is read with `pl.Element` block dims (offset stride
    T' < extent T -- the overlap-add overlap, never materialized in HBM)
  * `tasks_per_program` tasks of R tiles run as a static loop, so block
    autotuning can trade grid size against per-program working set
  * the S channel-mix GEMMs run under `fori_loop` with `unroll=mix_block`
  * the epilogue (bias/relu from `ElementwiseOps`) is applied to the
    task's output tiles before the strip store -- fused stages never
    round-trip intermediates through HBM for elementwise glue

Right-hand matrices, basis matrices and bias vectors all use constant
BlockSpec index maps: DMA'd once, VMEM-stationary across the whole grid
(the paper's "kernel matrices stay hot in shared memory" with residency
guaranteed rather than hoped for).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import transforms


def _apply_ep(y, ep_ops, biases_ref):
    """Static epilogue op list on (..., C') tiles; biases are rows of the
    stationary biases input."""
    for op in ep_ops:
        if op[0] == "bias":
            y = y + biases_ref[op[1]]
        else:  # relu
            y = jnp.maximum(y, 0.0)
    return y


def _kernel_body(
    x_ref, rhs_ref, kf_ref, ki_ref, biases_ref, o_ref, sb_ref,
    *,
    spec: transforms.TileKernelSpec,
    c_in: int,
    c_out: int,
    groups: int,
    r: int,
    tasks_per_program: int,
    mix_block: int,
    ep_ops: tuple,
):
    t, t_out, p, s = spec.t, spec.t_out, spec.planes, spec.s_mix
    cgi, cgo = c_in // groups, c_out // groups
    kf = kf_ref[...]  # (P*S, T*T) forward basis
    ki = ki_ref[...]  # (T'^2, P*S) inverse basis

    strip = x_ref[0].astype(jnp.float32)  # (T, tpp*R*T' + K - 1, C)

    for task in range(tasks_per_program):
        base = task * r * t_out

        # -- step 1: forward-transform R tiles in ONE basis GEMM; scatter
        # rows into the shared buffer as left-hand matrices (blocks
        # 1 .. S).  Tiles are static slices of the strip (stride T',
        # extent T); the flattened (T^2, R*C) stack feeds the MXU.
        cols = [
            strip[:, base + i * t_out : base + i * t_out + t, :].reshape(
                t * t, c_in
            )
            for i in range(r)
        ]
        d = jnp.concatenate(cols, axis=1)  # (T^2, R*C)
        u = jax.lax.dot_general(
            kf, d, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (P*S, R*C)
        # plane-major rows -> per-point left-hand matrices (R, g, P*Cg)
        lhs = (
            u.reshape(p, s, r, groups, cgi)
            .transpose(1, 2, 3, 0, 4)
            .reshape(s, r, groups * p * cgi)
        )
        sb_ref[1:, :, : p * c_in] = lhs

        # -- step 2: S channel-mix GEMMs against the stationary
        # right-hand matrices; result s lands on block s (the rows of
        # left-hand matrix s-1, already consumed -- shared-buffer
        # aliasing, paper S4.2).
        def mm(s_idx, _):
            lh = sb_ref[s_idx + 1, :, : p * c_in].reshape(
                r, groups, p * cgi
            )
            outs = [
                jax.lax.dot_general(
                    lh[:, gi],
                    rhs_ref[s_idx, gi],
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                )
                for gi in range(groups)
            ]
            res = (
                outs[0]
                if groups == 1
                else jnp.stack(outs, axis=1).reshape(r, groups * p * cgo)
            )
            sb_ref[s_idx, :, : p * c_out] = res
            return 0

        jax.lax.fori_loop(0, s, mm, 0, unroll=max(1, mix_block))

        # -- step 3: inverse-transform all R results in ONE basis GEMM;
        # epilogue on task-resident tiles; write the output strip slice.
        z = (
            sb_ref[:s, :, : p * c_out]
            .reshape(s, r, groups, p, cgo)
            .transpose(3, 0, 1, 2, 4)
            .reshape(p * s, r * c_out)
        )
        y = jax.lax.dot_general(
            ki, z, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (T'^2, R*C')
        yt = y.reshape(t_out, t_out, r, c_out)
        yt = _apply_ep(yt, ep_ops, biases_ref)
        # (T', T', R, C') -> (T', R*T', C')
        o_ref[0, :, base : base + r * t_out, :] = (
            yt.transpose(0, 2, 1, 3)
            .reshape(t_out, r * t_out, c_out)
            .astype(o_ref.dtype)
        )


def fused_tile_call(
    xp: jnp.ndarray,
    rhs: jnp.ndarray,
    biases: jnp.ndarray,
    *,
    spec: transforms.TileKernelSpec,
    n_tiles_h: int,
    n_tiles_w: int,
    r: int,
    tasks_per_program: int = 1,
    mix_block: int = 8,
    groups: int = 1,
    ep_ops: tuple = (),
    interpret: bool = True,
) -> jnp.ndarray:
    """Invoke the parametric fused kernel.

    xp:  (B, H_pad, W_pad, C) pre-padded input, H_pad = nH*T' + K - 1,
         W_pad = nW*T' + K - 1, nW divisible by r*tasks_per_program.
    rhs: (S, g, P*C/g, P*C'/g) packed right-hand matrices
         (`TileKernelSpec.pack_rhs`).
    biases: (n_bias, C') rows referenced by ("bias", idx) epilogue ops
         (pass shape (1, C') zeros when unused).
    returns: (B, nH*T', nW*T', C') assembled output tiles.
    """
    b, h_pad, w_pad, c_in = xp.shape
    t, t_out, p, s = spec.t, spec.t_out, spec.planes, spec.s_mix
    c_out = rhs.shape[1] * rhs.shape[3] // p
    tpp = max(1, tasks_per_program)
    assert n_tiles_w % (r * tpp) == 0, (n_tiles_w, r, tpp)
    assert h_pad == n_tiles_h * t_out + spec.k - 1, (h_pad, n_tiles_h)
    assert w_pad == n_tiles_w * t_out + spec.k - 1, (w_pad, n_tiles_w)
    n_col_blocks = n_tiles_w // (r * tpp)

    kf = jnp.asarray(spec.fwd)
    ki = jnp.asarray(spec.inv)

    body = functools.partial(
        _kernel_body,
        spec=spec, c_in=c_in, c_out=c_out, groups=groups, r=r,
        tasks_per_program=tpp, mix_block=mix_block, ep_ops=tuple(ep_ops),
    )
    strip_w = tpp * r * t_out + spec.k - 1
    # element-indexed strip: offset stride T' < extent T (the OLA
    # overlap); see kernels.fused_winograd history for the fallback
    if hasattr(pl, "Element"):
        strip_spec = pl.BlockSpec(
            (1, pl.Element(t), pl.Element(strip_w), c_in),
            lambda bi, i, j: (bi, i * t_out, j * (tpp * r * t_out), 0),
        )
    else:
        strip_spec = pl.BlockSpec(
            (1, t, strip_w, c_in),
            lambda bi, i, j: (bi, i * t_out, j * (tpp * r * t_out), 0),
            indexing_mode=pl.unblocked,
        )
    const = lambda *shape: pl.BlockSpec(  # noqa: E731
        shape, lambda bi, i, j: (0,) * len(shape)
    )
    return pl.pallas_call(
        body,
        grid=(b, n_tiles_h, n_col_blocks),
        in_specs=[
            strip_spec,
            const(*rhs.shape),  # stationary right-hand matrices
            const(p * s, t * t),  # forward basis
            const(t_out * t_out, p * s),  # inverse basis
            const(*biases.shape),
        ],
        out_specs=pl.BlockSpec(
            (1, t_out, tpp * r * t_out, c_out),
            lambda bi, i, j: (bi, i, j, 0),
        ),
        out_shape=jax.ShapeDtypeStruct(
            (b, n_tiles_h * t_out, n_tiles_w * t_out, c_out), xp.dtype
        ),
        scratch_shapes=[
            pltpu.VMEM((s + 1, r, p * max(c_in, c_out)), jnp.float32)
        ],
        interpret=interpret,
    )(xp, rhs, kf, ki, biases)
