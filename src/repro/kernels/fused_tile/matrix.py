"""XLA matrix path of the parametric tile kernel (the CPU fast path).

The exact same math as the Pallas kernel body -- forward basis GEMM,
batched channel mix, inverse basis GEMM, all from one `TileKernelSpec`
-- spelled as three wide GEMMs over the whole tile population instead of
a per-task grid.  On CPUs this is the fastest formulation we measured:
one (P*S, T^2) x (T^2, N*C) forward GEMM keeps Eigen at full rate where
separable per-axis transforms and per-task scans run an order of
magnitude below peak.

`chunk` bounds the transform-domain working set exactly like R*tasks
bound it in the on-chip kernel: tiles are processed in chunks of that
many (lax.map over equal chunks), which is what the block autotuner
trades off against per-chunk overhead on cache-constrained geometries.
Chunk 0 (the default) runs the whole population in one sweep.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import tiling, transforms


def _run_tiles(
    d: jnp.ndarray,  # (N, T*T, C) f32 flattened spatial tiles
    rhs: jnp.ndarray,  # (S, g, P*C/g, P*C'/g)
    kf: jnp.ndarray,  # (P*S, T*T)
    ki: jnp.ndarray,  # (T'^2, P*S)
    spec: transforms.TileKernelSpec,
    groups: int,
    epilogue,
) -> jnp.ndarray:
    """One sweep: (N, T*T, C) -> (N, T', T', C') output tiles."""
    n, _, c_in = d.shape
    t, t_out, p, s = spec.t, spec.t_out, spec.planes, spec.s_mix
    cgi = c_in // groups
    c_out = rhs.shape[1] * rhs.shape[3] // p
    cgo = c_out // groups

    t1 = d.transpose(1, 0, 2).reshape(t * t, n * c_in)
    u = (kf @ t1).reshape(p, s, n, groups, cgi)
    lhs = u.transpose(1, 3, 2, 0, 4).reshape(s, groups, n, p * cgi)
    mm = jnp.einsum("sgnc,sgcd->sgnd", lhs, rhs)  # (S, g, N, P*C'/g)
    z = (
        mm.reshape(s, groups, n, p, cgo)
        .transpose(3, 0, 2, 1, 4)
        .reshape(p * s, n * c_out)
    )
    y = (ki @ z).reshape(t_out, t_out, n, c_out).transpose(2, 0, 1, 3)
    if epilogue is not None:
        # output tiles abut, so elementwise glue on tiles == on the
        # assembled output -- same contract as the task-scan engine
        y = epilogue(y)
    return y


def matrix_tile_conv(
    xp: jnp.ndarray,
    rhs: jnp.ndarray,
    plan: tiling.TilePlan,
    spec: transforms.TileKernelSpec,
    *,
    groups: int = 1,
    epilogue=None,
    chunk: int = 0,
) -> jnp.ndarray:
    """(B, H_pad, W_pad, C) padded input -> (B, H_out, W_out, C')."""
    batch = xp.shape[0]
    c_in = xp.shape[-1]
    t, t_out = spec.t, spec.t_out
    tiles = tiling.extract_tiles(xp, plan)  # (B, nH, nW, T, T, C)
    n = batch * plan.tiles_per_image
    d = tiles.reshape(n, t * t, c_in).astype(jnp.float32)

    if chunk and chunk < n:
        n_chunks = -(-n // chunk)
        n_pad = n_chunks * chunk
        if n_pad > n:
            d = jnp.concatenate(
                [d, jnp.zeros((n_pad - n, t * t, c_in), d.dtype)], axis=0
            )
        y = jax.lax.map(
            lambda blk: _run_tiles(blk, rhs, jnp.asarray(spec.fwd),
                                   jnp.asarray(spec.inv), spec, groups,
                                   epilogue),
            d.reshape(n_chunks, chunk, t * t, c_in),
        ).reshape(n_pad, t_out, t_out, -1)[:n]
    else:
        y = _run_tiles(
            d, rhs, jnp.asarray(spec.fwd), jnp.asarray(spec.inv), spec,
            groups, epilogue,
        )

    c_out = y.shape[-1]
    y6 = y.reshape(
        batch, plan.n_tiles_h, plan.n_tiles_w, t_out, t_out, c_out
    )
    return tiling.assemble_tiles(y6, plan)


def staged_matrix_fns(
    plan: tiling.TilePlan,
    spec: transforms.TileKernelSpec,
    groups: int = 1,
) -> Tuple:
    """The vendor three-stage structure through the same kernel math:
    stage 1 = gather + forward basis GEMM (materializes U), stage 2 =
    packed channel mix (materializes M), stage 3 = inverse basis GEMM +
    assembly.  Each stage runs over ALL tiles -- the materializing
    baseline the fused path is measured against -- yet all three consume
    the same `TileKernelSpec` as the fused kernel.

    Returned stage signatures mirror `pipeline.staged_stage_fns`:
    stage2 takes the *family-native* wt and packs it, so cached kernel
    transforms stay backend-agnostic.
    """
    t, t_out, p, s = spec.t, spec.t_out, spec.planes, spec.s_mix
    kf = jnp.asarray(spec.fwd)
    ki = jnp.asarray(spec.inv)

    def stage1(xp):
        tiles = tiling.extract_tiles(xp, plan)
        b = tiles.shape[0]
        c_in = tiles.shape[-1]
        n = b * plan.tiles_per_image
        d = tiles.reshape(n, t * t, c_in).astype(jnp.float32)
        u = kf @ d.transpose(1, 0, 2).reshape(t * t, n * c_in)
        return u.reshape(p * s, n, c_in)  # transformed tiles, plane-major

    def stage2(u, wt):
        rhs = spec.pack_rhs(wt, groups)
        _, n, c_in = u.shape
        cgi = c_in // groups
        lhs = (
            u.reshape(p, s, n, groups, cgi)
            .transpose(1, 3, 2, 0, 4)
            .reshape(s, groups, n, p * cgi)
        )
        return jnp.einsum("sgnc,sgcd->sgnd", lhs, rhs)

    def stage3(mm, batch):
        s_, g, n, pcgo = mm.shape
        cgo = pcgo // p
        c_out = g * cgo
        z = (
            mm.reshape(s, g, n, p, cgo)
            .transpose(3, 0, 2, 1, 4)
            .reshape(p * s, n * c_out)
        )
        y = (ki @ z).reshape(t_out, t_out, n, c_out).transpose(2, 0, 1, 3)
        y6 = y.reshape(
            batch, plan.n_tiles_h, plan.n_tiles_w, t_out, t_out, c_out
        )
        return tiling.assemble_tiles(y6, plan)

    return stage1, stage2, stage3


def pallas_block_geometry(
    plan: tiling.TilePlan, r: int, tasks_per_program: int
) -> Optional[tiling.TilePlan]:
    """Extended plan whose column tile count divides r*tasks_per_program
    (the Pallas grid requirement); None when already aligned."""
    span = r * max(1, tasks_per_program)
    n_tw = -(-plan.n_tiles_w // span) * span
    if n_tw == plan.n_tiles_w:
        return None
    return tiling.TilePlan(
        h=plan.h, w=plan.w, k=plan.k, pad=plan.pad, t=plan.t,
        t_out=plan.t_out, h_out=plan.h_out, w_out=plan.w_out,
        n_tiles_h=plan.n_tiles_h, n_tiles_w=n_tw,
        h_pad=plan.h_pad, w_pad=n_tw * plan.t_out + plan.k - 1,
    )
