"""Block shapes for the parametric tile kernel.

One frozen record carries everything the autotuner can move:

  * ``r``                 -- tiles per task (row-block of the mix GEMMs;
                             the paper's R, bounded by shared-memory
                             capacity via ``analysis.max_r_ta``)
  * ``tasks_per_program`` -- tasks fused into one Pallas program
                             (grid-size vs working-set trade).  On the
                             XLA matrix path the product
                             ``r * tasks_per_program`` becomes the tile
                             chunk of one sweep; the sentinel 0 means
                             "unchunked" -- the whole tile population in
                             one GEMM chain, which is what wins on large
                             cache-friendly CPUs.
  * ``mix_block``         -- unroll factor of the S-point channel-mix
                             loop (GEMM block over the K-of-S dimension)

Serialized as a plain dict under the ``"blocks"`` field of a wisdom
entry so it rides the existing ``backend:family:geometry`` keys and
survives ``tune.py`` atomic rewrites unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    r: int
    tasks_per_program: int = 0
    mix_block: int = 8

    def chunk(self) -> int:
        """Tiles per sweep on the matrix path (0 = whole population)."""
        if self.tasks_per_program <= 0:
            return 0
        return self.r * self.tasks_per_program

    def to_wisdom(self) -> dict:
        return {
            "r": int(self.r),
            "tpp": int(self.tasks_per_program),
            "mix": int(self.mix_block),
        }

    @classmethod
    def from_wisdom(cls, d: Mapping) -> Optional["BlockConfig"]:
        try:
            return cls(
                r=int(d["r"]),
                tasks_per_program=int(d.get("tpp", 0)),
                mix_block=int(d.get("mix", 8)),
            )
        except (KeyError, TypeError, ValueError):
            return None
