"""Entry points of the parametric fused tile engine.

`conv2d_fused_tile` runs one transformed convolution through a
`TileKernelSpec` on the backend of choice:

  * ``xla``               -- the matrix path (`matrix_tile_conv`): the
                             same kernel math as three wide GEMMs, the
                             CPU fast path
  * ``pallas``            -- the on-chip task-loop kernel (`kernel.py`),
                             compiled (TPU and friends)
  * ``pallas_interpret``  -- the identical Pallas kernel in interpret
                             mode, so CPU CI executes the exact program
                             the accelerator runs

Backend resolution: explicit argument > ``REPRO_TILE_BACKEND`` env var >
``pallas`` on TPU, ``xla`` elsewhere.  f64 inputs have no f32 basis
matrices and raise `UnsupportedSpec`, which the pipeline catches to fall
back to the interpreting scan engine.
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import registry, tiling, transforms
from repro.kernels.fused_tile import kernel as _kernel
from repro.kernels.fused_tile import matrix as _matrix
from repro.kernels.fused_tile.blocks import BlockConfig

_BACKENDS = ("xla", "pallas", "pallas_interpret")
_ENV_BACKEND = "REPRO_TILE_BACKEND"

# The tile engine's logical phases, in execution order.  One fused
# dispatch runs all five inside a single compiled program, so they are
# announced (via the phase hook) rather than separately timed; the
# observability layer splits measured stage time across the GEMM phases
# by their MAC counts.
_PHASES = ("gather", "forward_gemm", "mix", "inverse_gemm", "scatter")

# Observability hook: when set (see obs.trace.capture_tile_phases), each
# conv2d_fused_tile dispatch calls it once per logical phase with
# (phase, info) where info carries the resolved backend + geometry.
# Fires at dispatch/trace time, not inside the jitted kernel.
_PHASE_HOOK = None


def set_phase_hook(hook):
    """Install the phase announcement hook; returns the previous one so
    callers can restore it (see `obs.trace.capture_tile_phases`)."""
    global _PHASE_HOOK
    prev = _PHASE_HOOK
    _PHASE_HOOK = hook
    return prev


class UnsupportedSpec(Exception):
    """The parametric engine cannot run this problem; callers fall back
    to the interpreting scan engine."""


def resolve_backend(backend: Optional[str] = None) -> str:
    b = backend or os.environ.get(_ENV_BACKEND)
    if b is None:
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if b not in _BACKENDS + ("scan",):
        raise ValueError(f"unknown tile backend {b!r}, expected {_BACKENDS}")
    return b


def engine_supported(transform: transforms.Transform, dtype) -> bool:
    """Can the parametric engine (any backend) run this family/dtype?"""
    if transform.kernel_spec() is None:
        return False
    # the f32 basis matrices would silently downgrade f64 precision
    return jnp.dtype(dtype) != jnp.float64


def conv2d_fused_tile(
    x: jnp.ndarray,
    w: Optional[jnp.ndarray],
    transform: transforms.Transform,
    *,
    pad: int = 0,
    blocks: Optional[BlockConfig] = None,
    wt: Optional[jnp.ndarray] = None,
    groups: int = 1,
    epilogue=None,
    backend: Optional[str] = None,
) -> jnp.ndarray:
    """NHWC fused transformed convolution through the parametric kernel.

    `wt` is the *family-native* transformed kernel (what
    `Transform.kernel_transform` returns and the kernel cache stores);
    packing into the engine's real mix layout happens here.  `epilogue`
    may be a `registry.ElementwiseOps` (folded into the kernel's scatter
    phase on the Pallas paths) or any elementwise callable (applied to
    output tiles on the matrix path, post-pass otherwise).
    """
    spec = transform.kernel_spec()
    if spec is None:
        raise UnsupportedSpec(f"{transform.family} has no TileKernelSpec")
    if jnp.dtype(x.dtype) == jnp.float64:
        raise UnsupportedSpec("f64 inputs: basis matrices are f32")
    b = resolve_backend(backend)
    if b == "scan":
        raise UnsupportedSpec("scan backend requested")
    if wt is None:
        wt = transform.kernel_transform(w)
    rhs = spec.pack_rhs(wt, groups)
    blocks = blocks or BlockConfig(r=24)

    plan = tiling.TilePlan.build(x.shape[1], x.shape[2], spec.k, pad, spec.t)

    if _PHASE_HOOK is not None:
        info = {
            "backend": b,
            "family": transform.family,
            "t": spec.t,
            "t_out": spec.t_out,
            "planes": spec.planes,
            "n_tiles_h": plan.n_tiles_h,
            "n_tiles_w": plan.n_tiles_w,
            "groups": groups,
        }
        for phase in _PHASES:
            _PHASE_HOOK(phase, info)

    if b == "xla":
        xp = tiling.pad_input(x, plan)
        y = _matrix.matrix_tile_conv(
            xp, rhs, plan, spec, groups=groups, epilogue=epilogue,
            chunk=blocks.chunk(),
        )
        return y.astype(x.dtype)

    # Pallas paths: align the column tile count to r * tasks_per_program
    # (extra zero columns, cropped after assembly) and lower the epilogue
    # to its kernel form.
    r = max(1, min(blocks.r, plan.n_tiles_w))
    tpp = max(1, blocks.tasks_per_program)
    while plan.n_tiles_w < r * tpp and tpp > 1:
        tpp -= 1
    ext = _matrix.pallas_block_geometry(plan, r, tpp)
    run_plan = ext or plan
    xp = tiling.pad_input(x, run_plan)

    ep_ops: tuple = ()
    biases = None
    post = None
    if isinstance(epilogue, registry.ElementwiseOps):
        ep_ops, biases = epilogue.kernel_form()
    elif epilogue is not None:
        post = epilogue  # opaque callable: post-pass on assembled output
    c_out = rhs.shape[1] * rhs.shape[3] // spec.planes
    if biases is None:
        biases = jnp.zeros((1, c_out), jnp.float32)

    y = _kernel.fused_tile_call(
        xp.astype(jnp.float32), rhs, biases,
        spec=spec,
        n_tiles_h=run_plan.n_tiles_h,
        n_tiles_w=run_plan.n_tiles_w,
        r=r,
        tasks_per_program=tpp,
        mix_block=blocks.mix_block,
        groups=groups,
        ep_ops=ep_ops,
        interpret=(b == "pallas_interpret"),
    )
    y = y[:, : plan.h_out, : plan.w_out, :]
    if post is not None:
        y = post(y)
    return y.astype(x.dtype)
