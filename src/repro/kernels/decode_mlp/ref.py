"""Pure-jnp oracle for the fused decode MLP."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_mlp_ref(
    x: jnp.ndarray, w1: jnp.ndarray, w3: jnp.ndarray, w2: jnp.ndarray
) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    h1 = xf @ w1.astype(jnp.float32)
    h3 = xf @ w3.astype(jnp.float32)
    h = jax.nn.silu(h1) * h3
    return (h @ w2.astype(jnp.float32)).astype(x.dtype)
