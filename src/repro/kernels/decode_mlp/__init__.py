from repro.kernels.decode_mlp.ops import decode_mlp
from repro.kernels.decode_mlp.ref import decode_mlp_ref

__all__ = ["decode_mlp", "decode_mlp_ref"]
