"""Pallas kernel: weight-stationary fused SwiGLU MLP (beyond-paper).

The paper's principle -- keep the operand every task re-reads resident in
fast memory, stream the rest in R-sized blocks, fuse producer -> GEMM ->
consumer -- applied to the LM decode hot loop:

    y = (silu(x W1) * (x W3)) W2

At decode, x is a short (R x d_model) token block while W1/W3/W2 are large
and re-read for every token batch; the roles are *inverted* relative to the
conv case (weights play the input-tile role in bytes, but the kernel-matrix
role in reuse).  We tile d_ff: grid (batch_blocks, ff_blocks); per step the
(d_model x Fb) slices of W1/W3 and (Fb x d_model) slice of W2 stream through
VMEM while the x block and the f32 accumulator stay put -- the intermediate
h = silu(xW1)*(xW3) never exists in HBM (fusion), mirroring the paper's
elimination of the U and M round-trips.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _body(x_ref, w1_ref, w3_ref, w2_ref, o_ref, acc_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)  # (R, d)  stationary over j
    h1 = jax.lax.dot(x, w1_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    h3 = jax.lax.dot(x, w3_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    h = h1 * jax.nn.sigmoid(h1) * h3  # silu(xW1) * (xW3), (R, Fb)
    acc_ref[...] += jax.lax.dot(h, w2_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def decode_mlp_call(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    w3: jnp.ndarray,
    w2: jnp.ndarray,
    *,
    rb: int,
    fb: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """x (B, d), w1/w3 (d, f), w2 (f, d) -> (B, d). B % rb == 0, f % fb == 0."""
    bsz, d = x.shape
    f = w1.shape[1]
    assert bsz % rb == 0 and f % fb == 0, (bsz, rb, f, fb)
    return pl.pallas_call(
        _body,
        grid=(bsz // rb, f // fb),
        in_specs=[
            pl.BlockSpec((rb, d), lambda i, j: (i, 0)),  # stationary over j
            pl.BlockSpec((d, fb), lambda i, j: (0, j)),
            pl.BlockSpec((d, fb), lambda i, j: (0, j)),
            pl.BlockSpec((fb, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((rb, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((rb, d), jnp.float32)],
        interpret=interpret,
    )(x, w1, w3, w2)
