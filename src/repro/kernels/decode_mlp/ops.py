"""Jitted wrapper for the fused decode-MLP Pallas kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_mlp.kernel import decode_mlp_call


@functools.partial(jax.jit, static_argnames=("rb", "fb", "interpret"))
def decode_mlp(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    w3: jnp.ndarray,
    w2: jnp.ndarray,
    *,
    rb: int = 8,
    fb: int = 512,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Fused SwiGLU MLP y = (silu(xW1) * xW3) W2 for decode-sized x (B, d)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bsz, d = x.shape
    f = w1.shape[1]
    rb = min(rb, bsz)
    fb = min(fb, f)
    pad_b = (-bsz) % rb
    if pad_b:
        x = jnp.pad(x, ((0, pad_b), (0, 0)))
    pad_f = (-f) % fb
    if pad_f:
        w1 = jnp.pad(w1, ((0, 0), (0, pad_f)))
        w3 = jnp.pad(w3, ((0, 0), (0, pad_f)))
        w2 = jnp.pad(w2, ((0, pad_f), (0, 0)))
    y = decode_mlp_call(x, w1, w3, w2, rb=rb, fb=fb, interpret=interpret)
    return y[:bsz]
