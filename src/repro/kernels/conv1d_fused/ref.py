"""Pure-jnp oracle for the fused causal conv1d."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv1d_ref(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *, activation: str = "silu"
) -> jnp.ndarray:
    """x (B, L, D), w (K, D), b (D,) -> (B, L, D) causal depthwise conv."""
    k = w.shape[0]
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (k - 1, 0), (0, 0)))
    acc = jnp.zeros(x.shape, jnp.float32)
    for i in range(k):
        acc = acc + xp[:, i : i + x.shape[1], :] * w[i].astype(jnp.float32)
    acc = acc + b.astype(jnp.float32)
    if activation == "silu":
        acc = acc * jax.nn.sigmoid(acc)
    return acc.astype(x.dtype)
