"""Jitted wrapper for the fused causal conv1d Pallas kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.conv1d_fused.kernel import conv1d_fused_call


@functools.partial(jax.jit, static_argnames=("activation", "lb", "interpret"))
def conv1d_fused(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    activation: str = "silu",
    lb: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Causal depthwise conv1d + bias + activation. x (B,L,D), w (K,D)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bsz, l, d = x.shape
    k = w.shape[0]
    if b is None:
        b = jnp.zeros((d,), x.dtype)
    lb = min(lb, l)
    pad_l = (-l) % lb
    # front-pad K-1 (causality); back-pad to a multiple of the block length
    xp = jnp.pad(x, ((0, 0), (k - 1, pad_l), (0, 0)))
    y = conv1d_fused_call(
        xp,
        w,
        b,
        lb=lb,
        activation=activation,
        interpret=interpret,
    )
    return y[:, :l, :]
