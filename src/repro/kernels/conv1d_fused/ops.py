"""Jitted wrapper for the fused causal conv1d Pallas kernel, plus its
registry `Algorithm`: temporal `ConvSpec`s (h == 1, causal left pad
along w) plan and execute through the same planner as the 2-D paths."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import registry
from repro.kernels.conv1d_fused.kernel import conv1d_fused_call


@functools.partial(jax.jit, static_argnames=("activation", "lb", "interpret"))
def conv1d_fused(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    activation: str = "silu",
    lb: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Causal depthwise conv1d + bias + activation. x (B,L,D), w (K,D)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bsz, l, d = x.shape
    k = w.shape[0]
    if b is None:
        b = jnp.zeros((d,), x.dtype)
    lb = min(lb, l)
    pad_l = (-l) % lb
    # front-pad K-1 (causality); back-pad to a multiple of the block length
    xp = jnp.pad(x, ((0, 0), (k - 1, pad_l), (0, 0)))
    y = conv1d_fused_call(
        xp,
        w,
        b,
        lb=lb,
        activation=activation,
        interpret=interpret,
    )
    return y[:, :l, :]


class Conv1dFusedAlgorithm(registry.Algorithm):
    """Temporal (1-D causal depthwise) convs through the registry.

    Domain: `ConvSpec.temporal` specs with depthwise channels
    (groups == c_in == c_out), unit stride, and same-length causal
    padding (pad == k - 1) -- the Mamba-family short conv.  The kernel
    fuses conv + bias in VMEM; bias/activation epilogues arrive through
    the generic `fuse_epilogue` path, so the executor treats this
    exactly like any other algorithm.  Memory-bound by construction
    (k MACs per element moved), priced as such for auto ranking.
    """

    name = "conv1d_fused"
    tier = 0
    rank = 5
    consumes_wt = False
    auto_candidate = True
    chain_family = None  # 1-D stages never chain with the 2-D tiling

    def supports(self, spec: registry.ConvSpec) -> bool:
        return (
            spec.temporal
            and spec.groups == spec.c_in == spec.c_out
            and spec.stride == 1
            and spec.pad == spec.k - 1
            and spec.dtype in ("float32", "bfloat16")
        )

    def plan(self, spec, hw, *, hints=None, tune_r=False, wisdom_path=None):
        hints = dict(hints or {})
        # AI: 2K flops per element against an 8-byte load+store round trip
        ai = 2.0 * spec.k / 8.0
        util = min(1.0, ai / hw.cmr_dram)
        return registry.AlgoPlan(
            self.name, spec,
            {"lb": int(hints.get("lb", 128))},
            predicted_util=util,
            cost=2.0 * spec.k / max(util, 0.05),
        )

    def execute(self, x, w, wt, plan):
        if wt is not None:
            raise ValueError("conv1d_fused consumes no pre-transformed wt")
        if x.shape[1] != 1:
            raise ValueError(
                f"temporal conv expects (B, 1, L, D) input, got {x.shape}"
            )
        xs = x[:, 0]  # (B, L, D)
        wk = w[0, :, 0, :]  # HWIO (1, k, 1, D) -> (k, D)
        y = conv1d_fused(
            xs, wk, activation="none", lb=int(plan.params.get("lb", 128))
        )
        return y[:, None, :, :]


registry.register(Conv1dFusedAlgorithm())
