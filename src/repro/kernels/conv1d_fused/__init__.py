from repro.kernels.conv1d_fused.ops import conv1d_fused
from repro.kernels.conv1d_fused.ref import conv1d_ref

__all__ = ["conv1d_fused", "conv1d_ref"]
