"""Pallas kernel: fused depthwise-causal conv1d + bias + SiLU (Mamba short conv).

The Mamba2 conv (K=4, depthwise) is memory-bound: 2K FLOPs per loaded
element against a TPU CMR of ~240.  Winograd gains nothing here (depthwise
convs have no C x C' product to amortise transforms over -- DESIGN.md S5);
what the paper's *fusion* insight buys is (a) the taps + bias stationary in
VMEM via a constant index map and (b) conv + bias + SiLU fused into one
HBM pass instead of three.

Grid: (batch, seq_blocks).  The input block overlaps by K-1 (pl.Element
dims, stride Lb, extent Lb + K - 1) on a front-padded sequence -- the same
overlap-add structure as the 2-D kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _body(x_ref, w_ref, b_ref, o_ref, *, k: int, lb: int, activation: str):
    xblk = x_ref[0].astype(jnp.float32)  # (Lb + K - 1, D)
    w = w_ref[...].astype(jnp.float32)  # (K, D)
    acc = jnp.zeros((lb, xblk.shape[1]), jnp.float32)
    for i in range(k):  # K is tiny; unrolled shifted MACs
        acc = acc + xblk[i : i + lb, :] * w[i]
    acc = acc + b_ref[...].astype(jnp.float32)
    if activation == "silu":
        acc = acc * jax.nn.sigmoid(acc)
    o_ref[0] = acc.astype(o_ref.dtype)


def conv1d_fused_call(
    xp: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    *,
    lb: int,
    activation: str = "silu",
    interpret: bool = True,
) -> jnp.ndarray:
    """xp: (B, L + K - 1, D) front-padded input; w: (K, D); b: (D,) -> (B, L, D)."""
    bsz, lpad, d = xp.shape
    k = w.shape[0]
    l = lpad - (k - 1)
    assert l % lb == 0, (l, lb)
    body = functools.partial(_body, k=k, lb=lb, activation=activation)
    # Overlapping (element-indexed) input blocks: per-dim pl.Element on
    # newer jax, whole-spec unblocked indexing on older releases (the
    # blocked dims are size-1 batch / zero-offset channels, so the same
    # element-offset index map serves both).
    if hasattr(pl, "Element"):
        in_spec = pl.BlockSpec(
            (1, pl.Element(lb + k - 1), d), lambda bi, li: (bi, li * lb, 0)
        )
    else:
        in_spec = pl.BlockSpec(
            (1, lb + k - 1, d),
            lambda bi, li: (bi, li * lb, 0),
            indexing_mode=pl.unblocked,
        )
    return pl.pallas_call(
        body,
        grid=(bsz, l // lb),
        in_specs=[
            in_spec,
            # stationary taps + bias (constant index maps)
            pl.BlockSpec((k, d), lambda bi, li: (0, 0)),
            pl.BlockSpec((d,), lambda bi, li: (0,)),
        ],
        out_specs=pl.BlockSpec((1, lb, d), lambda bi, li: (bi, li, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, l, d), xp.dtype),
        interpret=interpret,
    )(xp, w, b)
