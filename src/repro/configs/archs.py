"""The 10 assigned architectures, exact configs from the assignment sheet.

Sources in brackets per the sheet; deviations documented in DESIGN.md S5
(e.g. deepseek-v3 uses uniform MoE layers per the sheet's d_ff=2048).
"""

from __future__ import annotations

from repro.configs.base import (
    ArchConfig,
    MLAConfig,
    MoEConfig,
    SSMConfig,
    register,
)


@register("chameleon-34b")
def chameleon_34b() -> ArchConfig:
    # [vlm] early-fusion; VQ image tokens share the 65536 vocab; frontend
    # stubbed (tokens arrive pre-quantised).  qk-norm per Chameleon.
    return ArchConfig(
        name="chameleon-34b", family="vlm",
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22016, vocab_size=65536, qk_norm=True,
        notes="arXiv:2405.09818; early fusion, VQ image tokens",
    )


@register("mamba2-1.3b")
def mamba2_1_3b() -> ArchConfig:
    # [ssm] attention-free SSD; d_ff=0 (no MLP blocks).
    return ArchConfig(
        name="mamba2-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab_size=50280,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64),
        tie_embeddings=True,
        notes="arXiv:2405.21060; SSD (state-space duality)",
    )


@register("moonshot-v1-16b-a3b")
def moonshot_v1_16b_a3b() -> ArchConfig:
    # [moe] 64 routed experts, top-6, per-expert d_ff=1408.
    return ArchConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=163840,
        moe=MoEConfig(n_experts=64, top_k=6),
        notes="hf:moonshotai/Moonlight-16B-A3B; kimi/moonlight 64e top-6",
    )


@register("deepseek-v3-671b")
def deepseek_v3_671b() -> ArchConfig:
    # [moe] MLA + 1 shared + 256 routed top-8 + MTP.  Sheet gives d_ff=2048
    # uniformly (the HF model's 3 dense first layers are not modelled).
    return ArchConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=2048, vocab_size=129280,
        moe=MoEConfig(n_experts=256, top_k=8, n_shared=1),
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
        mtp=True,
        notes="arXiv:2412.19437; MLA, 1 shared + 256 routed top-8, MTP",
    )


@register("seamless-m4t-medium")
def seamless_m4t_medium() -> ArchConfig:
    # [audio] encoder-decoder; speech frontend stubbed to frame embeddings.
    return ArchConfig(
        name="seamless-m4t-medium", family="audio",
        n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab_size=256206,
        encoder_layers=12,
        notes="arXiv:2308.11596; enc-dec, multimodal (frontend stub)",
    )


@register("deepseek-67b")
def deepseek_67b() -> ArchConfig:
    return ArchConfig(
        name="deepseek-67b", family="dense",
        n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22016, vocab_size=102400,
        notes="arXiv:2401.02954; llama-arch",
    )


@register("stablelm-3b")
def stablelm_3b() -> ArchConfig:
    return ArchConfig(
        name="stablelm-3b", family="dense",
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=6912, vocab_size=50304,
        notes="hf:stabilityai/stablelm-2-1_6b family",
    )


@register("gemma3-1b")
def gemma3_1b() -> ArchConfig:
    # 5 local : 1 global, 512-token sliding window, head_dim 256.
    return ArchConfig(
        name="gemma3-1b", family="dense",
        n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
        d_ff=6912, vocab_size=262144, head_dim=256,
        sliding_window=512, local_global_period=6,
        tie_embeddings=True,
        notes="hf:google/gemma-3-1b-pt; 5:1 local:global, 128k context",
    )


@register("qwen2.5-14b")
def qwen2_5_14b() -> ArchConfig:
    return ArchConfig(
        name="qwen2.5-14b", family="dense",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=13824, vocab_size=152064, qkv_bias=True,
        notes="hf:Qwen/Qwen2.5 family; GQA, QKV bias",
    )


@register("zamba2-7b")
def zamba2_7b() -> ArchConfig:
    # [hybrid] 81 Mamba2 blocks + shared attention block every 6, with
    # per-invocation LoRA (Zamba2 design).
    return ArchConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab_size=32000,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64),
        shared_attn_period=6, shared_attn_lora_rank=128,
        notes="arXiv:2411.15242; Mamba2 + shared attn blocks",
    )
