"""Architecture + shape configuration.

Every assigned architecture is a frozen `ArchConfig`; the four assigned
input-shape sets are `ShapeConfig`s.  `REGISTRY` maps --arch ids to configs;
`SHAPES` maps shape ids.  Reduced (smoke) variants are derived with
`.reduced()` -- same family/structure, tiny dims -- per the brief.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0  # shared (always-on) experts
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    z_loss_coef: float = 1e-3


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # defaults to d_model // n_heads
    # attention flavour
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None  # window length for local layers
    local_global_period: Optional[int] = None  # e.g. 6 => 5 local : 1 global
    # substructure
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    shared_attn_period: Optional[int] = None  # zamba2: shared block every p
    shared_attn_lora_rank: int = 0
    # encoder-decoder (seamless)
    encoder_layers: int = 0
    # extras
    mtp: bool = False  # deepseek-v3 multi-token-prediction head
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"  # activation/param dtype for full-scale runs
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic memory path exists (SSM / hybrid / sliding window)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.local_global_period is not None
        )

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        small_moe = (
            dataclasses.replace(self.moe, n_experts=min(8, self.moe.n_experts))
            if self.moe
            else None
        )
        small_mla = (
            dataclasses.replace(
                self.mla, q_lora_rank=32, kv_lora_rank=16,
                qk_nope_dim=8, qk_rope_dim=8, v_head_dim=8,
            )
            if self.mla
            else None
        )
        small_ssm = (
            dataclasses.replace(self.ssm, d_state=16, head_dim=8, chunk=16)
            if self.ssm
            else None
        )
        if self.shared_attn_period:
            n_layers = 5  # at least one shared-attn insertion (period -> 2)
        elif self.local_global_period:
            n_layers = self.local_global_period + 2  # one full period + tail
        else:
            n_layers = min(4, self.n_layers)
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            d_ff=96 if not self.moe else 32,
            head_dim=16,
            vocab_size=256,
            sliding_window=16 if self.sliding_window else None,
            local_global_period=self.local_global_period,
            moe=small_moe,
            mla=small_mla,
            ssm=small_ssm,
            shared_attn_period=2 if self.shared_attn_period else None,
            shared_attn_lora_rank=4 if self.shared_attn_lora_rank else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_arch(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # import the config modules lazily so registration happens
        import repro.configs.archs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> Tuple[str, ...]:
    import repro.configs.archs  # noqa: F401

    return tuple(sorted(_REGISTRY))


def cell_is_defined(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch x shape) is a runnable cell; else the skip reason."""
    if shape.name == "long_500k" and not arch.supports_long_context:
        return False, "pure full-attention arch: 512k dense KV cache excluded by design (DESIGN.md S5)"
    return True, ""
