from repro.configs.base import (
    SHAPES,
    ArchConfig,
    MLAConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    cell_is_defined,
    get_arch,
    list_archs,
)

__all__ = [
    "ArchConfig", "ShapeConfig", "MoEConfig", "MLAConfig", "SSMConfig",
    "SHAPES", "get_arch", "list_archs", "cell_is_defined",
]
