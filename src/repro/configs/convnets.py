"""ConvNet specs for the convserve engine (VGG-style stage pipelines).

The mixed-channel nets are the paper's motivating case: early wide-image/
few-channel layers favour the L3-fused path, late many-channel layers
overflow the shared fast level and fall back to the 3-stage structure --
so a single whole-net plan exercises multiple algorithms.
"""

from __future__ import annotations

from typing import Sequence

from repro.convserve.graph import NetSpec, bias, conv, maxpool, relu


def vgg_style(
    name: str,
    c_in: int,
    widths: Sequence[int],
    convs_per_stage: int = 2,
    k: int = 3,
    with_bias: bool = False,
) -> NetSpec:
    """Stages of `convs_per_stage` same-padded convs (+ optional bias)
    + ReLU, then 2x2 pool."""
    layers = []
    c = c_in
    for width in widths:
        for _ in range(convs_per_stage):
            layers.append(conv(c, width, k=k))
            if with_bias:
                layers.append(bias(width))
            layers.append(relu())
            c = width
        layers.append(maxpool(2))
    return NetSpec(name=name, layers=tuple(layers))


def vgg_mixed_channel(c_in: int = 3) -> NetSpec:
    """The demo net: 64 -> 128 -> 256 channels across three pooled stages.

    On the paper's CPU models the 64/128-channel stages plan as l3_fused
    and the 256-channel stage's 4 C C' T^2 kernel matrices overflow the
    shared level, planning as three_stage.
    """
    return vgg_style("vgg-mixed", c_in, widths=(64, 128, 256))


def tiny_testnet(c_in: int = 4) -> NetSpec:
    """Small 4-conv net for tests: two stages, channel step 8 -> 16."""
    return vgg_style("tiny-testnet", c_in, widths=(8, 16))


def resnet_downsample(c_in: int = 3) -> NetSpec:
    """ResNet-style stem: stride-2 convs downsample instead of pooling.

    The new-scenario net for the registry API: its stride-2 layers reach
    the transformed paths through tile-decimation (the planner charges the
    stride^2 decimation waste in the cost model), and on the paper's CPU
    models the 64/128-channel stages still plan fused.
    """
    layers = (
        conv(c_in, 64), relu(),
        conv(64, 64), relu(),
        conv(64, 128, stride=2), relu(),  # /2 downsample
        conv(128, 128), relu(),
        conv(128, 256, stride=2), relu(),  # /4 total
        conv(256, 256), relu(),
    )
    return NetSpec(name="resnet-downsample", layers=layers)


def resnext_grouped(c_in: int = 4, groups: int = 4) -> NetSpec:
    """Grouped-conv (ResNeXt-style) net.  Grouped layers reach the
    transformed paths through the shared tile engine's block-diagonal
    channel mix (every registered transform family handles groups); the
    planner charges the 1/groups FLOP saving in the cost model."""
    layers = (
        conv(c_in, 32), relu(),
        conv(32, 32, groups=groups), relu(),
        conv(32, 64, stride=2, groups=groups), relu(),
    )
    return NetSpec(name="resnext-grouped", layers=layers)


def fft_fewchannel(c_in: int = 4) -> NetSpec:
    """Few-channel, wide-image net where the FFT transform wins.

    Zlateski et al.'s observation, through our roofline: with few
    channels the task stream is DRAM-bound, and the FFT's larger tile
    (T=16 vs Winograd's T=7) amortizes the K-1 halo over ~4x the output
    pixels -- the alpha=2 complex FLOPs cancel out of the DRAM-bound cost
    ratio.  Three same-padded chained convs with bias+relu glue and no
    pools, so the planner can fold the whole net into one FFT-backed
    fusion group.
    """
    layers = (
        conv(c_in, 8), bias(8), relu(),
        conv(8, 8), bias(8), relu(),
        conv(8, 8), bias(8), relu(),
    )
    return NetSpec(name="fft-fewchannel", layers=layers)
