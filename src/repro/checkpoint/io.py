"""Sharded checkpointing: atomic, async, keep-k, resumable.

Layout per checkpoint:
    <dir>/step_<N>/host_<i>.npz     flattened leaves (this host's shards)
    <dir>/step_<N>/meta.json        step, leaf paths/shapes/dtypes, done flag
    <dir>/step_<N>.done             commit marker (atomic rename)

On a real multi-host cluster each host writes only its addressable shards;
in this single-host container that is the whole array.  Restore is
sharding-agnostic: arrays are `jax.device_put` against whatever mesh the
*restoring* job runs (elastic re-scaling = restore on a different mesh --
see repro/checkpoint/elastic.py and tests/test_checkpoint.py).
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np

Pytree = Any


def _flatten(tree: Pytree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat = {}
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz has no bf16: store f32
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat, treedef


def save(
    ckpt_dir: str | os.PathLike,
    step: int,
    tree: Pytree,
    *,
    host_id: int = 0,
    keep: int = 3,
) -> pathlib.Path:
    """Synchronous atomic save."""
    root = pathlib.Path(ckpt_dir)
    tmp = root / f"step_{step}.tmp"
    final = root / f"step_{step}"
    tmp.mkdir(parents=True, exist_ok=True)
    flat, _ = _flatten(tree)
    np.savez(tmp / f"host_{host_id}.npz", **flat)
    meta = {
        "step": int(step),
        "leaves": {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in flat.items()
        },
    }
    (tmp / "meta.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    (root / f"step_{step}.done").touch()
    _gc(root, keep)
    return final


class AsyncCheckpointer:
    """Overlap checkpoint I/O with training: save() returns immediately;
    the previous save is joined before a new one starts (one in flight)."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3, host_id: int = 0):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.host_id = host_id
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree: Pytree) -> None:
        self.wait()
        # materialise to host memory on the caller's thread (cheap, bounded)
        host_tree = jax.tree.map(np.asarray, tree)

        def run():
            try:
                save(
                    self.ckpt_dir, step, host_tree,
                    host_id=self.host_id, keep=self.keep,
                )
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err


def latest_step(ckpt_dir: str | os.PathLike) -> Optional[int]:
    root = pathlib.Path(ckpt_dir)
    if not root.exists():
        return None
    steps = [
        int(p.stem.split("_")[1])
        for p in root.glob("step_*.done")
    ]
    return max(steps) if steps else None


def restore(
    ckpt_dir: str | os.PathLike,
    step: Optional[int],
    like: Pytree,
    *,
    shardings: Optional[Pytree] = None,
    host_id: int = 0,
) -> Tuple[Pytree, int]:
    """Restore into the structure of `like`; optionally device_put against
    `shardings` (which may describe a DIFFERENT mesh than the one that
    saved -- elastic restore)."""
    root = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    data = np.load(root / f"step_{step}" / f"host_{host_id}.npz")
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    flat_sh = (
        treedef.flatten_up_to(shardings) if shardings is not None else None
    )
    for i, (path, leaf) in enumerate(leaves):
        key = jax.tree_util.keystr(path)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        want = str(leaf.dtype) if hasattr(leaf, "dtype") else str(arr.dtype)
        if want == "bfloat16":  # stored as f32; cast back on device
            import ml_dtypes

            arr = arr.astype(ml_dtypes.bfloat16)
        if flat_sh is not None:
            out.append(jax.device_put(arr, flat_sh[i]))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


def _gc(root: pathlib.Path, keep: int) -> None:
    steps = sorted(
        int(p.stem.split("_")[1]) for p in root.glob("step_*.done")
    )
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(root / f"step_{s}", ignore_errors=True)
        (root / f"step_{s}.done").unlink(missing_ok=True)
