"""AdamW from scratch, with low-precision moment options.

Moment dtypes:
  float32         textbook
  bfloat16        halves optimizer HBM (DeepSeek-V3-style low-precision)
  int8            block-wise-quantised moments (8-bit-Adam style): int8
                  payload + one f32 scale per block of 256 -- 4x smaller
                  than f32; needed for the 671B config to fit 256 x 16 GB
                  (DESIGN.md S6).

The update math always runs in f32; only storage is quantised.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

_QBLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"  # float32 | bfloat16 | int8


# ---------------------------------------------------------------------------
# block-wise int8 moment codec
# ---------------------------------------------------------------------------


def _q8_encode(x: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    flat = x.reshape(-1)
    pad = (-flat.size) % _QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-20)).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)[:, 0]}


def _q8_decode(enc: Dict[str, jnp.ndarray], shape, size) -> jnp.ndarray:
    flat = (enc["q"].astype(jnp.float32) * enc["scale"][:, None]).reshape(-1)
    return flat[:size].reshape(shape)


def _moment_init(p: jnp.ndarray, dtype: str):
    if dtype == "int8":
        return _q8_encode(jnp.zeros_like(p, jnp.float32))
    return jnp.zeros_like(p, jnp.dtype(dtype))


def _moment_read(m, p: jnp.ndarray, dtype: str, sqrt_domain: bool = False):
    if dtype == "int8":
        val = _q8_decode(m, p.shape, p.size)
        # the second moment is quantised in sqrt space (halved dynamic
        # range => far better small-value resolution for 1/sqrt(v))
        return val * val if sqrt_domain else val
    return m.astype(jnp.float32)


def _moment_write(val: jnp.ndarray, dtype: str, sqrt_domain: bool = False):
    if dtype == "int8":
        return _q8_encode(jnp.sqrt(val) if sqrt_domain else val)
    return val.astype(jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# init / update
# ---------------------------------------------------------------------------


def adamw_init(params: Pytree, cfg: AdamWConfig) -> Dict[str, Pytree]:
    return {
        "m": jax.tree.map(lambda p: _moment_init(p, cfg.moment_dtype), params),
        "v": jax.tree.map(lambda p: _moment_init(p, cfg.moment_dtype), params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    params: Pytree,
    grads: Pytree,
    opt_state: Dict[str, Pytree],
    cfg: AdamWConfig,
    lr_scale: jnp.ndarray | float = 1.0,
) -> Tuple[Pytree, Dict[str, Pytree], Dict[str, jnp.ndarray]]:
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    t = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    # moments are a separate tree structure for int8 (dict leaves); walk the
    # param tree and index the moment trees with the same treedef
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])

    # exact Adam bounds |update| by ~1/sqrt(1-b2); quantised moments can
    # break that when a v-block underflows to 0, so clamp (a no-op for
    # exact moments, the safety rail for int8 ones)
    update_cap = 2.0 / float(np.sqrt(1.0 - cfg.b2))

    new_p, new_m, new_v = [], [], []
    for p, g, m_enc, v_enc in zip(flat_p, flat_g, flat_m, flat_v):
        g32 = g.astype(jnp.float32) * clip
        m = _moment_read(m_enc, p, cfg.moment_dtype)
        v = _moment_read(v_enc, p, cfg.moment_dtype, sqrt_domain=True)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        update = jnp.clip(update, -update_cap, update_cap)
        p32 = p.astype(jnp.float32)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            p32 = p32 * (1.0 - lr * cfg.weight_decay)
        p32 = p32 - lr * update
        new_p.append(p32.astype(p.dtype))
        new_m.append(_moment_write(m, cfg.moment_dtype))
        new_v.append(_moment_write(v, cfg.moment_dtype, sqrt_domain=True))

    params = jax.tree.unflatten(treedef, new_p)
    opt_state = {
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "count": count,
    }
    return params, opt_state, {"grad_norm": gnorm, "clip": clip}


def warmup_cosine(step, *, peak: float = 1.0, warmup: int = 100, total: int = 10000):
    """lr multiplier schedule (multiplies AdamWConfig.lr)."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    progress = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(np.pi * progress))
    return peak * warm * (0.1 + 0.9 * cos)
