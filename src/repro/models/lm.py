"""Model-level API: init / loss / prefill / decode for every assigned arch.

    params = init_lm(key, cfg)
    loss, metrics = lm_loss(params, cfg, batch)          # train step core
    logits = lm_logits(params, cfg, tokens)              # tests
    state  = lm_prefill(params, cfg, batch, max_len)     # -> DecodeState
    logits, state = lm_decode_step(params, cfg, token, pos, state)

Batch keys: tokens/targets/mask (decoder-only) plus src_embeds for the
encoder-decoder (seamless -- the speech frontend is a stub providing frame
embeddings, per the brief).  Embedding tables are padded to a shardable
vocab multiple; padded logits are masked out of the loss.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import blocks
from repro.models.common import (
    dense_init,
    embed_init,
    init_rms_scale,
    pad_vocab,
    rms_norm,
    softmax_cross_entropy,
)

Params = Dict


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def init_lm(key, cfg: ArchConfig) -> Params:
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 12)
    vpad = pad_vocab(cfg.vocab_size)
    plan = blocks.build_stack_plan(cfg, "decoder")
    p: Params = {
        "embed": embed_init(ks[0], (vpad, cfg.d_model), dtype),
        "stack": tuple(
            blocks.init_group(ks[1 + i], g, cfg, dtype)
            for i, g in enumerate(plan)
        ),
        "final_norm": init_rms_scale(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[6], (cfg.d_model, vpad), dtype)
    if cfg.shared_attn_period:
        from repro.models import attention as attn_mod
        from repro.models import mlp as mlp_mod

        p["shared"] = {
            "attn": attn_mod.init_attn(ks[7], cfg, dtype),
            "mlp": mlp_mod.init_mlp(ks[8], cfg.d_model, cfg.d_ff, dtype),
        }
    if cfg.is_encoder_decoder:
        enc_plan = blocks.build_stack_plan(cfg, "encoder")
        p["encoder"] = {
            "stack": tuple(
                blocks.init_group(ks[9], g, cfg, dtype) for g in enc_plan
            ),
            "final_norm": init_rms_scale(cfg.d_model, dtype),
        }
    if cfg.mtp:
        spec = blocks.LayerSpec(mixer="attn")
        p["mtp"] = {
            "proj": dense_init(ks[10], (2 * cfg.d_model, cfg.d_model), dtype),
            "norm_h": init_rms_scale(cfg.d_model, dtype),
            "norm_e": init_rms_scale(cfg.d_model, dtype),
            "block": blocks.init_layer(ks[11], spec, cfg, dtype),
        }
    return p


def _positions(bsz: int, s: int) -> jnp.ndarray:
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (bsz, s))


def _embed(p: Params, cfg: ArchConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["embed"], tokens, axis=0)


def _head(p: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    h = rms_norm(x, p["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = h @ p["embed"].T
    else:
        logits = h @ p["lm_head"]
    return logits[..., : cfg.vocab_size]


def _encode(p: Params, cfg: ArchConfig, src_embeds: jnp.ndarray):
    enc_plan = blocks.build_stack_plan(cfg, "encoder")
    x = src_embeds.astype(_dtype(cfg))
    pos = _positions(x.shape[0], x.shape[1])
    for gp, gs in zip(p["encoder"]["stack"], enc_plan):
        x, _ = blocks.apply_group(gp, gs, cfg, x, pos)
    return rms_norm(x, p["encoder"]["final_norm"], cfg.norm_eps), pos


def _backbone(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    cross_x=None,
    cross_pos=None,
    remat: bool = False,
):
    plan = blocks.build_stack_plan(cfg, "decoder")
    aux = blocks._zero_aux()
    shared = p.get("shared")
    for gp, gs in zip(p["stack"], plan):
        x, a = blocks.apply_group(
            gp, gs, cfg, x, positions, shared,
            cross_x=cross_x, cross_pos=cross_pos, remat=remat,
        )
        aux = {k: aux[k] + a[k] for k in aux}
    return x, aux


def lm_logits(
    p: Params,
    cfg: ArchConfig,
    tokens: jnp.ndarray,
    *,
    src_embeds: Optional[jnp.ndarray] = None,
    remat: bool = False,
) -> jnp.ndarray:
    """Full-sequence logits (B, S, vocab)."""
    cross_x = cross_pos = None
    if cfg.is_encoder_decoder:
        assert src_embeds is not None, "enc-dec arch needs src_embeds"
        cross_x, cross_pos = _encode(p, cfg, src_embeds)
    x = _embed(p, cfg, tokens)
    pos = _positions(tokens.shape[0], tokens.shape[1])
    x, _ = _backbone(
        p, cfg, x, pos, cross_x=cross_x, cross_pos=cross_pos, remat=remat
    )
    return _head(p, cfg, x)


def lm_loss(
    p: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray], *, remat: bool = True
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    tokens, targets = batch["tokens"], batch["targets"]
    mask = batch.get("mask")
    cross_x = cross_pos = None
    if cfg.is_encoder_decoder:
        cross_x, cross_pos = _encode(p, cfg, batch["src_embeds"])
    x = _embed(p, cfg, tokens)
    pos = _positions(tokens.shape[0], tokens.shape[1])
    x, aux = _backbone(
        p, cfg, x, pos, cross_x=cross_x, cross_pos=cross_pos, remat=remat
    )
    logits = _head(p, cfg, x)
    nll = softmax_cross_entropy(logits, targets, mask)
    loss = nll + aux["moe_aux"] + aux["moe_z"]
    metrics = {"nll": nll, **aux}

    if cfg.mtp:  # DeepSeek-V3 multi-token prediction: predict t+2
        mp = p["mtp"]
        h_in = rms_norm(x[:, :-1], mp["norm_h"], cfg.norm_eps)
        e_in = rms_norm(
            _embed(p, cfg, targets[:, :-1]), mp["norm_e"], cfg.norm_eps
        )
        z = jnp.concatenate([h_in, e_in], axis=-1) @ mp["proj"]
        spec = blocks.LayerSpec(mixer="attn")
        z, _, _ = blocks.apply_layer(mp["block"], spec, cfg, z, pos[:, :-1])
        mtp_logits = _head(p, cfg, z)
        mtp_mask = None if mask is None else mask[:, 1:]
        mtp_nll = softmax_cross_entropy(mtp_logits, targets[:, 1:], mtp_mask)
        loss = loss + 0.3 * mtp_nll
        metrics["mtp_nll"] = mtp_nll

    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def init_decode_state(
    cfg: ArchConfig, batch: int, max_len: int, src_len: Optional[int] = None
) -> Params:
    """Empty decode caches (shape source for serving + the dry-run specs)."""
    dtype = _dtype(cfg)
    plan = blocks.build_stack_plan(cfg, "decoder")
    state: Params = {
        "groups": tuple(
            blocks.init_group_cache(g, cfg, batch, max_len, dtype) for g in plan
        )
    }
    if cfg.is_encoder_decoder:
        sl = src_len if src_len is not None else 1024
        state["cross_x"] = jnp.zeros((batch, sl, cfg.d_model), dtype)
        state["cross_pos"] = jnp.broadcast_to(
            jnp.arange(sl, dtype=jnp.int32), (batch, sl)
        )
    return state


def lm_prefill(
    p: Params,
    cfg: ArchConfig,
    tokens: jnp.ndarray,
    max_len: int,
    *,
    src_embeds: Optional[jnp.ndarray] = None,
):
    """Run the prompt, build caches.  Returns (last_logits, state)."""
    plan = blocks.build_stack_plan(cfg, "decoder")
    state: Params = {}
    cross_x = cross_pos = None
    if cfg.is_encoder_decoder:
        cross_x, cross_pos = _encode(p, cfg, src_embeds)
        state["cross_x"], state["cross_pos"] = cross_x, cross_pos
    x = _embed(p, cfg, tokens)
    pos = _positions(tokens.shape[0], tokens.shape[1])
    shared = p.get("shared")
    gcaches = []
    for gp, gs in zip(p["stack"], plan):
        x, _, caches = blocks.apply_group_prefill(
            gp, gs, cfg, x, pos, shared,
            max_len=max_len, cross_x=cross_x, cross_pos=cross_pos,
            cache_dtype=_dtype(cfg),
        )
        gcaches.append(caches)
    state["groups"] = tuple(gcaches)
    logits = _head(p, cfg, x[:, -1:])
    return logits[:, 0], state


def lm_decode_step(
    p: Params,
    cfg: ArchConfig,
    token: jnp.ndarray,  # (B,) int32
    pos,  # scalar int32: position of `token`
    state: Params,
):
    """One decode step.  Returns (logits (B, vocab), new state)."""
    plan = blocks.build_stack_plan(cfg, "decoder")
    x = _embed(p, cfg, token[:, None])
    shared = p.get("shared")
    cross_x = state.get("cross_x")
    cross_pos = state.get("cross_pos")
    new_groups = []
    for gp, gs, gc in zip(p["stack"], plan, state["groups"]):
        x, ngc = blocks.apply_group_decode(
            gp, gs, cfg, x, pos, gc, shared,
            cross_x=cross_x, cross_pos=cross_pos,
        )
        new_groups.append(ngc)
    new_state = dict(state)
    new_state["groups"] = tuple(new_groups)
    logits = _head(p, cfg, x)
    return logits[:, 0], new_state
