"""Global implementation knobs, so baseline vs optimized lowers from the
same model code (EXPERIMENTS.md SPerf before/after discipline).

Defaults are the OPTIMIZED configuration; `baseline()` restores the
paper-faithful/naive implementations the baselines were recorded with.
"""

from __future__ import annotations

import contextlib
import dataclasses


@dataclasses.dataclass
class Flags:
    attention_impl: str = "flash"  # "flash" | "chunked"
    flash_p_dtype: str = "bfloat16"  # P dtype between QK and PV matmuls
    flash_q_blk: int = 512
    flash_kv_blk: int = 512
    mla_absorb: bool = True  # latent-space decode scoring (no k/v expand)
    moe_shardmap: bool = False  # reserved: explicit a2a dispatch
    # SSD (mamba2): remat each chunk step (backward recomputes the dual-form
    # intermediates instead of storing them); 0 = use cfg.ssm.chunk
    ssm_chunk_remat: bool = True
    ssm_chunk_override: int = 0
    # context-parallel attention: shard the q sequence dim over this mesh
    # axis inside attention (prefill of archs whose head counts don't divide
    # the model axis -- EXPERIMENTS.md SPerf qwen cell)
    attention_cp_axis: str = ""
    # adaptive FSDP: replicate param trees smaller than this (bytes); large
    # trees shard over (pod, data).  Avoids per-layer all-gathers for models
    # that fit replicated (gemma3's collective bound).
    fsdp_min_tree_bytes: int = 3 << 30


FLAGS = Flags()


def set_baseline() -> None:
    FLAGS.attention_impl = "chunked"
    FLAGS.flash_p_dtype = "float32"
    FLAGS.mla_absorb = False
    FLAGS.ssm_chunk_remat = False
    FLAGS.ssm_chunk_override = 0
    FLAGS.attention_cp_axis = ""
    FLAGS.fsdp_min_tree_bytes = 0  # baseline: FSDP everything


def set_optimized() -> None:
    FLAGS.attention_impl = "flash"
    FLAGS.flash_p_dtype = "bfloat16"
    FLAGS.mla_absorb = True
    FLAGS.ssm_chunk_remat = True
    FLAGS.ssm_chunk_override = 128
    FLAGS.fsdp_min_tree_bytes = 3 << 30


@contextlib.contextmanager
def overrides(**kw):
    old = {k: getattr(FLAGS, k) for k in kw}
    try:
        for k, v in kw.items():
            setattr(FLAGS, k, v)
        yield
    finally:
        for k, v in old.items():
            setattr(FLAGS, k, v)
