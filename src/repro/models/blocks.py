"""Layer / super-block / stack assembly.

A model stack is a sequence of *groups*; each group is `lax.scan` over
`n_repeat` identical *super-blocks*; a super-block is a short static tuple of
`LayerSpec`s.  This one mechanism expresses every assigned architecture:

  dense / MoE LMs        one group, 1-layer super-block
  gemma3 (5 local : 1 global)   super-block of 6 attn layers with static
                                per-position windows + a tail group
  mamba2                 one group of mamba layers
  zamba2                 super-block = [shared-attn invocation, 6 x mamba];
                         the shared block's base weights live at model level,
                         per-invocation LoRA is scanned
  seamless (enc-dec)     an encoder stack + a decoder stack w/ cross-attn

Because the window / moe / mixer choices are static per super-block
*position*, one scanned program covers heterogeneous stacks with no traced
control flow.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models.common import init_rms_scale, rms_norm

Params = Dict


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str  # "attn" | "mla" | "mamba" | "shared_attn"
    window: int = 0  # 0 = global
    moe: bool = False
    has_mlp: bool = True  # mamba blocks carry no MLP
    cross_attn: bool = False  # decoder-side cross attention (enc-dec)
    causal: bool = True  # encoder layers are bidirectional


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    n_repeat: int
    layers: Tuple[LayerSpec, ...]


def build_stack_plan(cfg: ArchConfig, role: str = "decoder") -> Tuple[GroupSpec, ...]:
    if role == "encoder":
        spec = LayerSpec(mixer="attn", causal=False)
        return (GroupSpec(cfg.encoder_layers, (spec,)),)

    n = cfg.n_layers
    if cfg.family == "ssm":
        return (GroupSpec(n, (LayerSpec(mixer="mamba", has_mlp=False),)),)

    if cfg.shared_attn_period:  # zamba2-style hybrid
        p = cfg.shared_attn_period
        mamba = LayerSpec(mixer="mamba", has_mlp=False)
        shared = LayerSpec(mixer="shared_attn")
        full, rem = divmod(n, p)
        groups = []
        if full:
            groups.append(GroupSpec(full, (shared,) + (mamba,) * p))
        if rem:
            groups.append(GroupSpec(1, (mamba,) * rem))
        return tuple(groups)

    if cfg.local_global_period:  # gemma3-style 5:1 local:global
        p = cfg.local_global_period
        local = LayerSpec(mixer="attn", window=cfg.sliding_window, moe=bool(cfg.moe))
        glob = LayerSpec(mixer="attn", window=0, moe=bool(cfg.moe))
        full, rem = divmod(n, p)
        groups = []
        if full:
            groups.append(GroupSpec(full, (local,) * (p - 1) + (glob,)))
        if rem:
            groups.append(GroupSpec(1, (local,) * rem))
        return tuple(groups)

    mixer = "mla" if cfg.mla else "attn"
    spec = LayerSpec(
        mixer=mixer, moe=bool(cfg.moe), cross_attn=cfg.is_encoder_decoder
        and role == "decoder",
    )
    return (GroupSpec(n, (spec,)),)


def plan_layer_specs(plan: Tuple[GroupSpec, ...]) -> Tuple[LayerSpec, ...]:
    """Flattened per-layer specs (for inspection / tests)."""
    out = []
    for g in plan:
        for _ in range(g.n_repeat):
            out.extend(g.layers)
    return tuple(out)


# ---------------------------------------------------------------------------
# single-layer init / apply
# ---------------------------------------------------------------------------


def init_layer(key, spec: LayerSpec, cfg: ArchConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Params = {"ln1": init_rms_scale(d, dtype)}
    if spec.mixer == "attn":
        p["attn"] = attn_mod.init_attn(ks[0], cfg, dtype)
    elif spec.mixer == "mla":
        p["attn"] = attn_mod.init_mla(ks[0], cfg, dtype)
    elif spec.mixer == "mamba":
        p["mamba"] = mamba_mod.init_mamba(ks[0], cfg, dtype)
    elif spec.mixer == "shared_attn":
        # base weights are model-level; per-invocation LoRA + norms here
        hd = cfg.resolved_head_dim
        r = max(1, cfg.shared_attn_lora_rank)
        from repro.models.common import dense_init

        for nm, width in (
            ("q", cfg.n_heads * hd),
            ("k", cfg.n_kv_heads * hd),
            ("v", cfg.n_kv_heads * hd),
        ):
            p[f"lora_{nm}_a"] = dense_init(ks[1], (d, r), dtype)
            p[f"lora_{nm}_b"] = jnp.zeros((r, width), dtype)
        p["ln2"] = init_rms_scale(d, dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.cross_attn:
        p["ln_cross"] = init_rms_scale(d, dtype)
        p["cross"] = attn_mod.init_attn(ks[2], cfg, dtype)
    if spec.has_mlp and spec.mixer != "shared_attn":
        p["ln2"] = init_rms_scale(d, dtype)
        if spec.moe:
            p["moe"] = moe_mod.init_moe(ks[3], cfg, dtype)
        else:
            p["mlp"] = mlp_mod.init_mlp(ks[3], cfg.d_model, cfg.d_ff, dtype)
    return p


def _zero_aux():
    return {
        "moe_aux": jnp.zeros((), jnp.float32),
        "moe_z": jnp.zeros((), jnp.float32),
    }


def _merge_shared_attn(shared: Params, layer_p: Params) -> Params:
    merged = dict(shared["attn"])
    for k, v in layer_p.items():
        if k.startswith("lora_"):
            merged[k] = v
    return merged


def apply_layer(
    p: Params,
    spec: LayerSpec,
    cfg: ArchConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    shared: Optional[Params] = None,
    *,
    cross_x: Optional[jnp.ndarray] = None,
    cross_pos: Optional[jnp.ndarray] = None,
    build_cache_len: Optional[int] = None,
    dtype=None,
):
    """Full-sequence layer application (train / prefill / encoder).

    Returns (x, aux, cache_or_None).
    """
    aux = _zero_aux()
    cache = None
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    bsz = x.shape[0]

    if spec.mixer in ("attn", "shared_attn"):
        ap = _merge_shared_attn(shared, p) if spec.mixer == "shared_attn" else p["attn"]
        if build_cache_len is not None:
            y, (k, v) = attn_mod.attn_forward(
                ap, h, positions, cfg, window=spec.window, causal=spec.causal,
                return_kv=True,
            )
            cache = attn_mod.init_kv_cache(
                cfg, bsz, build_cache_len, spec.window, dtype or x.dtype
            )
            cache = attn_mod.fill_kv_cache(cache, k, v, positions)
        else:
            y = attn_mod.attn_forward(
                ap, h, positions, cfg, window=spec.window, causal=spec.causal
            )
        x = x + y
    elif spec.mixer == "mla":
        if build_cache_len is not None:
            y, (c_kv, k_rope) = attn_mod.mla_forward(
                p["attn"], h, positions, cfg, return_latent=True
            )
            cache = attn_mod.init_mla_cache(
                cfg, bsz, build_cache_len, dtype or x.dtype
            )
            cache = attn_mod.fill_mla_cache(cache, c_kv, k_rope, positions)
        else:
            y = attn_mod.mla_forward(p["attn"], h, positions, cfg)
        x = x + y
    elif spec.mixer == "mamba":
        if build_cache_len is not None:
            y, cache = mamba_mod.mamba_forward(
                p["mamba"], h, cfg, return_state=True
            )
        else:
            y = mamba_mod.mamba_forward(p["mamba"], h, cfg)
        x = x + y
    else:
        raise ValueError(spec.mixer)

    if spec.cross_attn:
        hc = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        x = x + attn_mod.attn_forward(
            p["cross"], hc, positions, cfg, cross_x=cross_x, cross_pos=cross_pos
        )

    if spec.has_mlp or spec.mixer == "shared_attn":
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.moe:
            y2, aux = moe_mod.moe_forward(p["moe"], h2, cfg)
        elif spec.mixer == "shared_attn":
            y2 = mlp_mod.mlp_forward(shared["mlp"], h2)
        else:
            y2 = mlp_mod.mlp_forward(p["mlp"], h2)
        x = x + y2

    return x, aux, cache


def init_layer_cache(
    spec: LayerSpec, cfg: ArchConfig, batch: int, max_len: int, dtype
) -> Params:
    if spec.mixer in ("attn", "shared_attn"):
        c = {
            "self": attn_mod.init_kv_cache(cfg, batch, max_len, spec.window, dtype)
        }
    elif spec.mixer == "mla":
        c = {"self": attn_mod.init_mla_cache(cfg, batch, max_len, dtype)}
    elif spec.mixer == "mamba":
        c = {"self": mamba_mod.init_mamba_cache(cfg, batch, dtype)}
    else:
        raise ValueError(spec.mixer)
    return c


def apply_layer_decode(
    p: Params,
    spec: LayerSpec,
    cfg: ArchConfig,
    x: jnp.ndarray,  # (B, 1, D)
    pos,  # scalar
    cache: Params,
    shared: Optional[Params] = None,
    *,
    cross_x: Optional[jnp.ndarray] = None,
    cross_pos: Optional[jnp.ndarray] = None,
):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if spec.mixer in ("attn", "shared_attn"):
        ap = _merge_shared_attn(shared, p) if spec.mixer == "shared_attn" else p["attn"]
        y, new_self = attn_mod.attn_decode(
            ap, h, pos, cache["self"], cfg, window=spec.window
        )
    elif spec.mixer == "mla":
        y, new_self = attn_mod.mla_decode(p["attn"], h, pos, cache["self"], cfg)
    elif spec.mixer == "mamba":
        y, new_self = mamba_mod.mamba_decode(p["mamba"], h, cache["self"], cfg)
    else:
        raise ValueError(spec.mixer)
    x = x + y
    if spec.cross_attn:
        hc = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        x = x + attn_mod.attn_forward(
            p["cross"], hc, jnp.full((x.shape[0], 1), pos, jnp.int32), cfg,
            cross_x=cross_x, cross_pos=cross_pos,
        )
    if spec.has_mlp or spec.mixer == "shared_attn":
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.moe:
            y2, _ = moe_mod.moe_forward(p["moe"], h2, cfg)
        elif spec.mixer == "shared_attn":
            y2 = mlp_mod.mlp_forward(shared["mlp"], h2)
        else:
            y2 = mlp_mod.mlp_forward(p["mlp"], h2)
        x = x + y2
    return x, {"self": new_self}


# ---------------------------------------------------------------------------
# group (scan over super-blocks)
# ---------------------------------------------------------------------------


def init_group(key, gspec: GroupSpec, cfg: ArchConfig, dtype) -> Params:
    """Per-layer params stacked along the repeat dimension."""
    def init_one(k):
        kl = jax.random.split(k, len(gspec.layers))
        return tuple(
            init_layer(kl[i], spec, cfg, dtype)
            for i, spec in enumerate(gspec.layers)
        )

    keys = jax.random.split(key, gspec.n_repeat)
    per_repeat = [init_one(k) for k in keys]
    return {
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *per_repeat)
    }


def apply_group(
    gp: Params,
    gspec: GroupSpec,
    cfg: ArchConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    shared: Optional[Params] = None,
    *,
    cross_x=None,
    cross_pos=None,
    remat: bool = False,
):
    """Train/encoder-mode scan.  Returns (x, aux_sums)."""

    def body(carry, layer_slice):
        x, aux = carry
        for i, spec in enumerate(gspec.layers):
            x, a, _ = apply_layer(
                layer_slice[i], spec, cfg, x, positions, shared,
                cross_x=cross_x, cross_pos=cross_pos,
            )
            aux = {k: aux[k] + a[k] for k in aux}
        return (x, aux), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, _zero_aux()), gp["layers"])
    return x, aux


def init_group_cache(
    gspec: GroupSpec, cfg: ArchConfig, batch: int, max_len: int, dtype
) -> Params:
    def one():
        return tuple(
            init_layer_cache(spec, cfg, batch, max_len, dtype)
            for spec in gspec.layers
        )

    per = [one() for _ in range(gspec.n_repeat)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per)


def apply_group_prefill(
    gp: Params,
    gspec: GroupSpec,
    cfg: ArchConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    shared: Optional[Params] = None,
    *,
    max_len: int,
    cross_x=None,
    cross_pos=None,
    cache_dtype=None,
):
    """Prefill: full forward that also builds the decode caches (scan ys)."""

    def body(carry, layer_slice):
        x, aux = carry
        caches = []
        for i, spec in enumerate(gspec.layers):
            x, a, cache = apply_layer(
                layer_slice[i], spec, cfg, x, positions, shared,
                cross_x=cross_x, cross_pos=cross_pos,
                build_cache_len=max_len, dtype=cache_dtype or x.dtype,
            )
            caches.append({"self": cache})
            aux = {k: aux[k] + a[k] for k in aux}
        return (x, aux), tuple(caches)

    (x, aux), caches = jax.lax.scan(body, (x, _zero_aux()), gp["layers"])
    return x, aux, caches


def apply_group_decode(
    gp: Params,
    gspec: GroupSpec,
    cfg: ArchConfig,
    x: jnp.ndarray,
    pos,
    gcache: Params,
    shared: Optional[Params] = None,
    *,
    cross_x=None,
    cross_pos=None,
):
    def body(x, slices):
        layer_slice, cache_slice = slices
        new_caches = []
        for i, spec in enumerate(gspec.layers):
            x, nc = apply_layer_decode(
                layer_slice[i], spec, cfg, x, pos, cache_slice[i], shared,
                cross_x=cross_x, cross_pos=cross_pos,
            )
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_gcache = jax.lax.scan(body, x, (gp["layers"], gcache))
    return x, new_gcache
