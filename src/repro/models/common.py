"""Shared model components: norms, rotary embeddings, initialisers."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def init_rms_scale(dim: int, dtype) -> jnp.ndarray:
    # stored as zeros, applied as (1 + scale) -- gemma-style, robust under
    # weight decay and friendly to zero-init checkatability
    return jnp.zeros((dim,), dtype)


def dense_init(key, shape, dtype, fan_in: Optional[int] = None) -> jnp.ndarray:
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype) -> jnp.ndarray:
    # std = 1/sqrt(d_model): keeps tied-head logits O(1) at init
    std = shape[-1] ** -0.5
    return (
        jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std
    ).astype(dtype)


def rotary_angles(positions: jnp.ndarray, dim: int, theta: float) -> jnp.ndarray:
    """positions (...,) int -> (..., dim//2) angles."""
    freqs = theta ** (-jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rotary(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x (B, S, H, hd), positions (B, S) -> rotated x (half-split convention)."""
    hd = x.shape[-1]
    ang = rotary_angles(positions, hd, theta)  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def softmax_cross_entropy(
    logits: jnp.ndarray, targets: jnp.ndarray, mask: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """Mean token NLL with f32 logits; targets (B, S) int32; mask optional."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def pad_vocab(vocab_size: int, multiple: int = 2048) -> int:
    """Pad embedding tables so the vocab axis shards evenly (DESIGN.md S5)."""
    return -(-vocab_size // multiple) * multiple
