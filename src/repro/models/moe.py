"""Mixture-of-Experts layer: top-k routing, capacity, shared experts.

Dispatch is sort-based (no (N, E, C) one-hot tensors): token-expert
assignments are argsorted by expert, positions-within-expert computed from
segment starts, tokens over capacity dropped (standard capacity discipline).
FLOPs scale with *active* experts -- important for the roofline's
MODEL_FLOPS / HLO_FLOPs ratio.

Sharding: expert tables are sharded over the `model` axis (EP); tokens over
`data`.  Under pjit, the scatter/gather between the two shardings lowers to
all-to-all-style collectives placed by GSPMD; the shard_map variant is a
perf iteration (EXPERIMENTS.md SPerf).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.common import dense_init

Params = Dict[str, jnp.ndarray]


def init_moe(key, cfg: ArchConfig, dtype) -> Params:
    m: MoEConfig = cfg.moe
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.n_experts), jnp.float32),
        "w1": dense_init(ks[1], (m.n_experts, d, f), dtype),
        "w3": dense_init(ks[2], (m.n_experts, d, f), dtype),
        "w2": dense_init(ks[3], (m.n_experts, f, d), dtype, fan_in=f),
    }
    if m.n_shared:
        fs = f * m.n_shared
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared_w1"] = dense_init(k1, (d, fs), dtype)
        p["shared_w3"] = dense_init(k2, (d, fs), dtype)
        p["shared_w2"] = dense_init(k3, (fs, d), dtype, fan_in=fs)
    return p


def capacity(n_tokens: int, m: MoEConfig) -> int:
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, -(-c // 8) * 8)


def moe_forward(
    p: Params, x: jnp.ndarray, cfg: ArchConfig
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x (B, S, D) -> (out, aux losses {moe_aux, moe_z})."""
    m: MoEConfig = cfg.moe
    b, s, d = x.shape
    n = b * s
    e, k = m.n_experts, m.top_k
    xt = x.reshape(n, d)

    logits = (xt.astype(jnp.float32)) @ p["router"]  # (N, E) f32 routing
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, ids = jax.lax.top_k(probs, k)  # (N, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # ---- aux losses (Switch-style load balance + router z-loss)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32), axis=0
    )
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs) * m.aux_loss_coef
    zloss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2) * (
        m.z_loss_coef
    )

    # ---- sort-based dispatch with capacity
    cap = capacity(n, m)
    flat_e = ids.reshape(-1)  # (N*k,)
    order = jnp.argsort(flat_e)  # stable
    sorted_e = flat_e[order]
    tok_of = order // k  # token index per sorted slot
    gate_of = gate_vals.reshape(-1)[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e))  # (E,)
    pos_in_e = jnp.arange(n * k) - starts[sorted_e]
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # overflow slot

    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(xt[tok_of] * keep[:, None].astype(x.dtype))
    h_in = buf[: e * cap].reshape(e, cap, d)

    # ---- expert FFN (batched over experts); bf16 operands, f32 accumulation
    # (MXU-native -- avoids materialising f32 copies of the expert tables)
    h1 = jnp.einsum("ecd,edf->ecf", h_in, p["w1"],
                    preferred_element_type=jnp.float32)
    h3 = jnp.einsum("ecd,edf->ecf", h_in, p["w3"],
                    preferred_element_type=jnp.float32)
    h = (jax.nn.silu(h1) * h3).astype(x.dtype)
    h_out = jnp.einsum(
        "ecf,efd->ecd", h, p["w2"], preferred_element_type=jnp.float32
    ).astype(x.dtype).reshape(e * cap, d)

    # ---- combine
    gathered = h_out[jnp.minimum(slot, e * cap - 1)]
    gathered = gathered * (keep & (slot < e * cap))[:, None].astype(x.dtype)
    out = jnp.zeros((n, d), x.dtype)
    out = out.at[tok_of].add(gathered * gate_of[:, None].astype(x.dtype))

    # ---- shared experts (always-on)
    if "shared_w1" in p:
        sh = jax.nn.silu(xt @ p["shared_w1"]) * (xt @ p["shared_w3"])
        out = out + sh @ p["shared_w2"]

    return out.reshape(b, s, d), {"moe_aux": aux, "moe_z": zloss}
