"""Mamba2 (SSD -- state-space duality) block: chunked train scan + decode step.

Train/prefill uses the SSD chunked algorithm: within a chunk of Q steps the
quadratic dual form (C B^T . decay) runs on the MXU; across chunks a
sequential `lax.scan` carries the (H, P, N) state.  Decode is the O(1)
recurrent update.  The short depthwise-causal conv is the paper-technique
touchpoint (DESIGN.md S5): `repro.kernels.conv1d_fused` provides the fused
taps-stationary Pallas kernel; the jnp path is the dry-run default.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.models.common import dense_init, rms_norm
from repro.core.conv import conv1d_depthwise_causal

Params = Dict[str, jnp.ndarray]


def _dims(cfg: ArchConfig):
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    d_xbc = d_inner + 2 * s.n_groups * s.d_state
    return s, d_inner, n_heads, d_xbc


def init_mamba(key, cfg: ArchConfig, dtype) -> Params:
    s, d_inner, h, d_xbc = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    d_in_proj = d_inner + d_xbc + h  # z, xBC, dt
    return {
        "in_proj": dense_init(ks[0], (d, d_in_proj), dtype),
        "conv_w": dense_init(ks[1], (s.d_conv, d_xbc), dtype, fan_in=s.d_conv),
        "conv_b": jnp.zeros((d_xbc,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),  # A = -exp(A_log) = -1 init
        "D": jnp.ones((h,), jnp.float32),
        "norm": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(ks[2], (d_inner, d), dtype, fan_in=d_inner),
    }


def _split(cfg: ArchConfig, zxbcdt: jnp.ndarray):
    s, d_inner, h, d_xbc = _dims(cfg)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : d_inner + d_xbc]
    dt = zxbcdt[..., d_inner + d_xbc :]
    return z, xbc, dt


def _split_xbc(cfg: ArchConfig, xbc: jnp.ndarray):
    s, d_inner, h, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    x = xbc[..., :d_inner]
    bmat = xbc[..., d_inner : d_inner + gn]
    cmat = xbc[..., d_inner + gn :]
    return x, bmat, cmat


def mamba_forward(
    p: Params,
    x_in: jnp.ndarray,
    cfg: ArchConfig,
    *,
    use_pallas_conv: bool = False,
    return_state: bool = False,
):
    """(B, S, D) -> (B, S, D); S must be a multiple of cfg.ssm.chunk (or is
    padded internally).  With return_state, also returns the decode cache
    {conv, ssm} at the end of the sequence."""
    from repro.models.runtime_flags import FLAGS

    s, d_inner, h, d_xbc = _dims(cfg)
    bsz, seq, _ = x_in.shape
    chunk = FLAGS.ssm_chunk_override or s.chunk
    q = min(chunk, seq)
    pad = (-seq) % q
    if pad:
        x_in = jnp.pad(x_in, ((0, 0), (0, pad), (0, 0)))
    seq_p = seq + pad
    nc = seq_p // q

    zxbcdt = x_in @ p["in_proj"]
    z, xbc, dt_raw = _split(cfg, zxbcdt)
    if use_pallas_conv:
        from repro.kernels.conv1d_fused import conv1d_fused

        xbc = conv1d_fused(xbc, p["conv_w"], p["conv_b"], activation="silu")
    else:
        xbc = jax.nn.silu(
            conv1d_depthwise_causal(xbc, p["conv_w"]) + p["conv_b"]
        )
    xs, bmat, cmat = _split_xbc(cfg, xbc)

    g, n, hd = s.n_groups, s.d_state, s.head_dim
    xs = xs.reshape(bsz, nc, q, h, hd)
    bmat = bmat.reshape(bsz, nc, q, g, n)
    cmat = cmat.reshape(bsz, nc, q, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    if pad:  # padded steps must not decay or feed the state (dt == 0)
        valid = (jnp.arange(seq_p) < seq).astype(jnp.float32)
        dt = dt * valid[None, :, None]
    dt = dt.reshape(bsz, nc, q, h)
    a = -jnp.exp(p["A_log"])  # (H,)
    la = jnp.cumsum(dt * a, axis=2)  # (B,nc,Q,H) log-decay within chunk
    rep = h // g

    def chunk_step(state, blk):
        xc, bc, cc, dtc, lac = blk  # (B,Q,...) for one chunk
        # broadcast groups over heads
        bh = jnp.repeat(bc, rep, axis=2)  # (B,Q,H,N)
        ch = jnp.repeat(cc, rep, axis=2)
        # intra-chunk dual (quadratic) form
        scores = jnp.einsum(
            "bthn,bshn->bhts", ch.astype(jnp.float32), bh.astype(jnp.float32)
        )  # (B,H,Q,Q)
        decay = jnp.exp(
            lac[:, :, None, :] - lac[:, None, :, :]
        ).transpose(0, 3, 1, 2)  # (B,H,Q,Q) exp(la[t]-la[s])
        tri = jnp.tril(jnp.ones((q, q), jnp.float32))
        w = scores * decay * tri * dtc.transpose(0, 2, 1)[:, :, None, :]
        xs_f = xc.astype(jnp.float32)
        y = jnp.einsum("bhts,bshp->bthp", w, xs_f)
        # inter-chunk contribution from carried state
        y = y + (
            jnp.einsum("bthn,bhpn->bthp", ch.astype(jnp.float32), state)
            * jnp.exp(lac)[..., None]
        )
        # new carried state
        last = lac[:, -1, :]  # (B,H)
        sc = jnp.einsum(
            "bshn,bsh,bshp->bhpn",
            bh.astype(jnp.float32),
            jnp.exp(last[:, None, :] - lac) * dtc,
            xs_f,
        )
        state = state * jnp.exp(last)[:, :, None, None] + sc
        return state, y

    state0 = jnp.zeros((bsz, h, hd, n), jnp.float32)
    blks = (
        xs.transpose(1, 0, 2, 3, 4),
        bmat.transpose(1, 0, 2, 3, 4),
        cmat.transpose(1, 0, 2, 3, 4),
        dt.transpose(1, 0, 2, 3),
        la.transpose(1, 0, 2, 3),
    )
    step_fn = (
        jax.checkpoint(chunk_step) if FLAGS.ssm_chunk_remat else chunk_step
    )  # remat: backward recomputes the (Q,Q) dual-form tensors per chunk
    state_f, ys = jax.lax.scan(step_fn, state0, blks)  # (nc,B,Q,H,P)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, seq_p, h, hd)
    y = y + xs.reshape(bsz, seq_p, h, hd).astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(bsz, seq_p, d_inner).astype(x_in.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, :seq]
    if not return_state:
        return out
    # decode cache: the raw (pre-conv) xBC tail + the final SSM state
    # (padded steps carry dt == 0, so the final state is exact).
    _, xbc_raw, _ = _split(cfg, zxbcdt)
    xbc_raw = xbc_raw[:, :seq]
    km1 = s.d_conv - 1
    conv_tail = xbc_raw[:, seq - km1 : seq] if seq >= km1 else jnp.pad(
        xbc_raw, ((0, 0), (km1 - seq, 0), (0, 0))
    )
    return out, {"conv": conv_tail, "ssm": state_f}


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype) -> Params:
    s, d_inner, h, d_xbc = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, d_xbc), dtype),
        "ssm": jnp.zeros((batch, h, s.head_dim, s.d_state), jnp.float32),
    }


def mamba_decode(
    p: Params, x_in: jnp.ndarray, cache: Params, cfg: ArchConfig
) -> Tuple[jnp.ndarray, Params]:
    """x_in (B, 1, D) single step; O(1) state update."""
    s, d_inner, h, d_xbc = _dims(cfg)
    bsz = x_in.shape[0]
    zxbcdt = x_in[:, 0] @ p["in_proj"]  # (B, *)
    z, xbc, dt_raw = _split(cfg, zxbcdt[:, None, :])
    z, xbc, dt_raw = z[:, 0], xbc[:, 0], dt_raw[:, 0]

    conv_win = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)
    acc = jnp.einsum("bkd,kd->bd", conv_win, p["conv_w"]) + p["conv_b"]
    xbc_c = jax.nn.silu(acc)
    new_conv = conv_win[:, 1:]

    xs, bmat, cmat = _split_xbc(cfg, xbc_c[:, None, :])
    g, n, hd = s.n_groups, s.d_state, s.head_dim
    xs = xs.reshape(bsz, h, hd).astype(jnp.float32)
    rep = h // g
    bh = jnp.repeat(bmat.reshape(bsz, g, n), rep, axis=1).astype(jnp.float32)
    ch = jnp.repeat(cmat.reshape(bsz, g, n), rep, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * a)  # (B,H)
    state = cache["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xs, bh
    )
    y = jnp.einsum("bhn,bhpn->bhp", ch, state)  # (B,H,P)
    y = y + xs * p["D"][:, None]
    y = y.reshape(bsz, d_inner).astype(x_in.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"conv": new_conv, "ssm": state}
