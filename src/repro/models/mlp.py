"""Dense SwiGLU MLP."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

Params = Dict[str, jnp.ndarray]


def init_mlp(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, (d_model, d_ff), dtype),
        "w3": dense_init(k2, (d_model, d_ff), dtype),
        "w2": dense_init(k3, (d_ff, d_model), dtype, fan_in=d_ff),
    }


def mlp_forward(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    return h @ p["w2"]
