"""Flash attention in pure JAX: tiled online-softmax with a custom VJP.

This is the paper's L3-fusion insight applied to attention (DESIGN.md S2):
the standard path materialises the probability matrix P between the QK and
PV matmuls and -- under scan autodiff -- *stores* every chunk's P for the
backward pass, an S^2-sized round-trip to slow memory per head per layer
(the dry-run baseline shows it dominating every training cell).  Here:

  * the (q-block x kv-block) tile loop only visits tiles that intersect
    the causal / sliding-window band (static pair list -- no FLOPs or
    traffic on masked-out tiles; 2x on causal, S/w on windowed layers);
  * the custom VJP recomputes P per tile in the backward pass instead of
    storing it (flash backward), so residuals are O(S * hd) not O(S^2);
  * P is cast to bf16 for the PV matmul (f32 softmax statistics).

The Pallas kernel (repro/kernels/flash_attention) is the TPU-native version
where P additionally never leaves VMEM; this module is the XLA-visible
form used by the dry-run and the CPU tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _pairs(nq: int, nk: int, q_blk: int, kv_blk: int, causal: bool,
           window: int, offset: int) -> np.ndarray:
    """Static list of (i, j) tiles intersecting the mask band.

    offset = kv_len_virtual_start difference; for self-attention with
    aligned positions it is 0.
    """
    out = []
    for i in range(nq):
        q_lo, q_hi = i * q_blk + offset, (i + 1) * q_blk - 1 + offset
        for j in range(nk):
            k_lo, k_hi = j * kv_blk, (j + 1) * kv_blk - 1
            if causal and k_lo > q_hi:
                continue  # tile entirely in the future
            if window > 0 and k_hi < q_lo - window + 1:
                continue  # tile entirely behind the window
            out.append((i, j))
    return np.asarray(out, np.int32).reshape(-1, 2)


def _tile_mask(q_pos, kv_pos, window: int, causal: bool):
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]
    ok = kp >= 0.0
    if causal:
        ok &= kp <= qp
    if window > 0:
        ok &= qp - kp < float(window)
    return ok


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9)
)
def _flash(q, k, v, q_pos, kv_pos, causal, window, q_blk, kv_blk, p_dtype):
    o, _, _ = _flash_fwd_impl(
        q, k, v, q_pos, kv_pos, causal, window, q_blk, kv_blk, p_dtype
    )
    return o


def _flash_fwd_impl(q, k, v, q_pos, kv_pos, causal, window, q_blk, kv_blk,
                    p_dtype):
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    vd = v.shape[3]  # may differ from hd (MLA)
    g = hq // hkv
    scale = hd ** -0.5
    nq, nk = sq // q_blk, sk // kv_blk
    pairs = _pairs(nq, nk, q_blk, kv_blk, causal, window, offset=sk - sq)

    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, g, hd)
    qf = qf.transpose(0, 2, 3, 1, 4)  # (B, Hkv, g, Sq, hd)
    kf = k.transpose(0, 2, 1, 3)  # (B, Hkv, Sk, hd)
    vf = v.transpose(0, 2, 1, 3)

    m0 = jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, vd), jnp.float32)

    def step(carry, ij):
        m, l, acc = carry
        i, j = ij[0], ij[1]
        qi = jax.lax.dynamic_slice_in_dim(qf, i * q_blk, q_blk, axis=3)
        kj = jax.lax.dynamic_slice_in_dim(kf, j * kv_blk, kv_blk, axis=2)
        vj = jax.lax.dynamic_slice_in_dim(vf, j * kv_blk, kv_blk, axis=2)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, i * q_blk, q_blk, axis=1)
        kp = jax.lax.dynamic_slice_in_dim(kv_pos, j * kv_blk, kv_blk, axis=1)
        s = jnp.einsum("bhgqd,bhcd->bhgqc", qi,
                       kj.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        msk = _tile_mask(qp, kp, window, causal)[:, None, None]
        s = jnp.where(msk, s, -jnp.inf)

        mi = jax.lax.dynamic_slice_in_dim(m, i * q_blk, q_blk, axis=3)
        li = jax.lax.dynamic_slice_in_dim(l, i * q_blk, q_blk, axis=3)
        ai = jax.lax.dynamic_slice_in_dim(acc, i * q_blk, q_blk, axis=3)

        m_new = jnp.maximum(mi, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(msk, p, 0.0)
        corr = jnp.where(
            jnp.isfinite(mi), jnp.exp(mi - m_safe), 0.0
        )
        l_new = li * corr + jnp.sum(p, axis=-1)
        a_new = ai * corr[..., None] + jnp.einsum(
            "bhgqc,bhcd->bhgqd", p.astype(p_dtype), vj.astype(p_dtype),
            preferred_element_type=jnp.float32,
        )
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, i * q_blk, axis=3)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_new, i * q_blk, axis=3)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, a_new, i * q_blk, axis=3)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.asarray(pairs))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, vd).astype(q.dtype)
    lse = jnp.where(l > 0, jnp.log(jnp.maximum(l, 1e-30)), 0.0) + jnp.where(
        jnp.isfinite(m), m, 0.0
    )  # (B, Hkv, g, Sq)
    return o, lse, (m, l)


def _flash_fwd(q, k, v, q_pos, kv_pos, causal, window, q_blk, kv_blk,
               p_dtype):
    o, lse, _ = _flash_fwd_impl(
        q, k, v, q_pos, kv_pos, causal, window, q_blk, kv_blk, p_dtype
    )
    return o, (q, k, v, o, lse, q_pos, kv_pos)


def _flash_bwd(causal, window, q_blk, kv_blk, p_dtype, res, do):
    q, k, v, o, lse, q_pos, kv_pos = res
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    vd = v.shape[3]
    g = hq // hkv
    scale = hd ** -0.5
    nq, nk = sq // q_blk, sk // kv_blk
    pairs = _pairs(nq, nk, q_blk, kv_blk, causal, window, offset=sk - sq)

    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, g, hd)
    qf = qf.transpose(0, 2, 3, 1, 4)  # (B,Hkv,g,Sq,hd)
    kf = k.transpose(0, 2, 1, 3).astype(jnp.float32)
    vf = v.transpose(0, 2, 1, 3).astype(jnp.float32)
    dof = do.astype(jnp.float32).reshape(b, sq, hkv, g, vd).transpose(
        0, 2, 3, 1, 4
    )
    of = o.astype(jnp.float32).reshape(b, sq, hkv, g, vd).transpose(
        0, 2, 3, 1, 4
    )
    delta = jnp.sum(dof * of, axis=-1)  # (B,Hkv,g,Sq)

    dq0 = jnp.zeros_like(qf)
    dk0 = jnp.zeros_like(kf)
    dv0 = jnp.zeros_like(vf)

    def step(carry, ij):
        dq, dk, dv = carry
        i, j = ij[0], ij[1]
        qi = jax.lax.dynamic_slice_in_dim(qf, i * q_blk, q_blk, axis=3)
        kj = jax.lax.dynamic_slice_in_dim(kf, j * kv_blk, kv_blk, axis=2)
        vj = jax.lax.dynamic_slice_in_dim(vf, j * kv_blk, kv_blk, axis=2)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, i * q_blk, q_blk, axis=1)
        kp = jax.lax.dynamic_slice_in_dim(kv_pos, j * kv_blk, kv_blk, axis=1)
        lse_i = jax.lax.dynamic_slice_in_dim(lse, i * q_blk, q_blk, axis=3)
        do_i = jax.lax.dynamic_slice_in_dim(dof, i * q_blk, q_blk, axis=3)
        dl_i = jax.lax.dynamic_slice_in_dim(delta, i * q_blk, q_blk, axis=3)

        s = jnp.einsum("bhgqd,bhcd->bhgqc", qi, kj,
                       preferred_element_type=jnp.float32)
        msk = _tile_mask(qp, kp, window, causal)[:, None, None]
        p = jnp.where(msk, jnp.exp(s - lse_i[..., None]), 0.0)

        pc = p.astype(p_dtype)
        dv_j = jnp.einsum("bhgqc,bhgqd->bhcd", pc.astype(jnp.float32), do_i,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhgqd,bhcd->bhgqc", do_i, vj,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - dl_i[..., None])  # (B,Hkv,g,q_blk,kv_blk)
        dq_i = jnp.einsum("bhgqc,bhcd->bhgqd", ds, kj,
                          preferred_element_type=jnp.float32)
        dk_j = jnp.einsum("bhgqc,bhgqd->bhcd", ds, qi,
                          preferred_element_type=jnp.float32)

        dq = jax.lax.dynamic_update_slice_in_dim(
            dq,
            jax.lax.dynamic_slice_in_dim(dq, i * q_blk, q_blk, axis=3) + dq_i,
            i * q_blk, axis=3,
        )
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk,
            jax.lax.dynamic_slice_in_dim(dk, j * kv_blk, kv_blk, axis=2) + dk_j,
            j * kv_blk, axis=2,
        )
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv,
            jax.lax.dynamic_slice_in_dim(dv, j * kv_blk, kv_blk, axis=2) + dv_j,
            j * kv_blk, axis=2,
        )
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(step, (dq0, dk0, dv0), jnp.asarray(pairs))
    dq = (dq * scale).transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, hd)
    # note: dq accumulated over s = (q*scale)K^T, so the scale factor applies
    dk = dk.transpose(0, 2, 1, 3)
    dv = dv.transpose(0, 2, 1, 3)
    return (
        dq.astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
        jnp.zeros_like(q_pos),
        jnp.zeros_like(kv_pos),
    )


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_pos: jnp.ndarray,
    kv_pos: jnp.ndarray,
    *,
    window: int = 0,
    causal: bool = True,
    q_blk: int = 512,
    kv_blk: int = 512,
    p_dtype=jnp.float32,
) -> jnp.ndarray:
    """Tiled attention, API-compatible with models.attention.chunked_attention.

    q (B,Sq,Hq,hd), k/v (B,Sk,Hkv,hd); positions (B,S*) int or float.
    Pads S to block multiples internally.
    """
    b, sq, hq, hd = q.shape
    sk = k.shape[1]
    q_blk = min(q_blk, max(sq, 1))
    kv_blk = min(kv_blk, max(sk, 1))
    pad_q = (-sq) % q_blk
    pad_k = (-sk) % kv_blk
    qp = q_pos.astype(jnp.float32)
    kp = kv_pos.astype(jnp.float32)
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        # padded q rows attend to nothing valid; give them huge positions so
        # causal keeps them harmless, then slice them away
        qp = jnp.pad(qp, ((0, 0), (0, pad_q)), constant_values=2e9)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kp = jnp.pad(kp, ((0, 0), (0, pad_k)), constant_values=-1.0)
    out = _flash(
        q, k, v, qp, kp, causal, int(window or 0), q_blk, kv_blk,
        jnp.dtype(p_dtype).name,
    )
    return out[:, :sq]
