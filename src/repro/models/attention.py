"""Attention: GQA (+bias, +qk-norm, +sliding window) and MLA, train + decode.

Prefill/train use a chunked online-softmax (flash-style) scan over KV blocks
so the lowered HLO never materialises (S x S) score tensors -- required for
the 32k-prefill dry-run cells to fit per-chip HBM.  Decode attends over the
cache in one masked pass (O(S) memory).

Sliding windows are traced scalars (`window <= 0` means global), so layers
with different windows share one scanned program (gemma3's 5:1 pattern).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MLAConfig
from repro.models.common import apply_rotary, dense_init, rms_norm
from repro.models.runtime_flags import FLAGS

KV_CHUNK = 512  # flash-scan KV block length

Params = Dict[str, jnp.ndarray]


def full_attention(q, k, v, q_pos, kv_pos, *, window=0, causal=True):
    """Dispatch full-sequence attention to the configured implementation."""
    if FLAGS.attention_cp_axis:
        # context parallelism: shard the q sequence over the model axis and
        # run the q-vectorised chunked path (each chip owns a q stripe; K/V
        # stay replicated -- the right shape when head counts don't divide
        # the model axis).  Prefill-only (no custom VJP on this path).
        from jax.sharding import PartitionSpec as P

        ax = FLAGS.attention_cp_axis
        spec = P("data", ax, None, None)  # batch x data, seq x model
        q = jax.lax.with_sharding_constraint(q, spec)
        out = chunked_attention(
            q, k, v, q_pos, kv_pos, window=window, causal=causal
        )
        return jax.lax.with_sharding_constraint(out, spec)
    if FLAGS.attention_impl == "flash":
        from repro.models.flash_attention import flash_attention

        return flash_attention(
            q, k, v, q_pos, kv_pos, window=int(window or 0), causal=causal,
            q_blk=FLAGS.flash_q_blk, kv_blk=FLAGS.flash_kv_blk,
            p_dtype=jnp.dtype(FLAGS.flash_p_dtype),
        )
    return chunked_attention(
        q, k, v, q_pos, kv_pos, window=window, causal=causal
    )


# ---------------------------------------------------------------------------
# chunked (flash-style) masked attention
# ---------------------------------------------------------------------------


def _mask(
    q_pos: jnp.ndarray, kv_pos: jnp.ndarray, window, causal: bool
) -> jnp.ndarray:
    """(..., Sq, Sk) boolean mask. kv_pos < 0 marks empty cache slots."""
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]
    ok = kp >= 0
    if causal:
        ok &= kp <= qp
    # window <= 0 => global
    win_ok = jnp.where(window > 0, qp - kp < window, True)
    return ok & win_ok


def chunked_attention(
    q: jnp.ndarray,  # (B, Sq, Hq, hd)
    k: jnp.ndarray,  # (B, Sk, Hkv, hd)
    v: jnp.ndarray,  # (B, Sk, Hkv, vd)
    q_pos: jnp.ndarray,  # (B, Sq)
    kv_pos: jnp.ndarray,  # (B, Sk)
    *,
    window=0,
    causal: bool = True,
    chunk: int = KV_CHUNK,
) -> jnp.ndarray:
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    vd = v.shape[3]
    g = hq // hkv
    scale = hd ** -0.5
    qf = (q * scale).reshape(b, sq, hkv, g, hd)

    if (sq == 1 or chunk >= sk) and FLAGS.attention_impl == "flash":
        # one-shot path (decode, optimized impl): no KV loop, so a
        # sequence-sharded cache contracts via psum partials (the
        # flash-decoding split-K pattern under GSPMD) instead of per-chunk
        # dynamic slices that force involuntary gathers
        s = jnp.einsum("bqhgd,bchd->bhgqc", qf, k,
                       preferred_element_type=jnp.float32)
        msk = _mask(q_pos, kv_pos, window, causal)[:, None, None]
        s = jnp.where(msk, s, -jnp.inf)
        m = jnp.max(s, axis=-1, keepdims=True)
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.where(msk, jnp.exp(s - m), 0.0)
        l = jnp.sum(p, axis=-1, keepdims=True)
        out = jnp.einsum("bhgqc,bchd->bhgqd", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        out = out / jnp.maximum(l, 1e-30)
        return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, vd).astype(
            q.dtype
        )

    if sk % chunk != 0:
        pad = (-sk) % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        sk += pad
    n_chunks = sk // chunk
    ks = k.reshape(b, n_chunks, chunk, hkv, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, n_chunks, chunk, hkv, vd).transpose(1, 0, 2, 3, 4)
    ps = kv_pos.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def step(carry, blk):
        m_prev, l_prev, acc = carry
        kc, vc, pc = blk  # (B, c, Hkv, hd), (B, c, Hkv, vd), (B, c)
        s = jnp.einsum(
            "bqhgd,bchd->bhgqc", qf, kc, preferred_element_type=jnp.float32
        )  # (B, Hkv, g, Sq, c)
        msk = _mask(q_pos, pc, window, causal)[:, None, None]  # (B,1,1,Sq,c)
        s = jnp.where(msk, s, -jnp.inf)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # guard fully-masked rows (m == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(msk, p, 0.0)
        corr = jnp.exp(
            jnp.where(jnp.isfinite(m_prev), m_prev - m_safe, -jnp.inf)
        )
        corr = jnp.where(jnp.isfinite(m_prev), corr, 0.0)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqc,bchd->bhgqd", p, vc.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, vd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (ks, vs, ps))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, vd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------


def init_attn(key, cfg: ArchConfig, dtype, lora_rank: int = 0) -> Params:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 8)
    p: Params = {
        "wq": dense_init(ks[0], (d, hq * hd), dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), dtype),
        "wo": dense_init(ks[3], (hq * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    if lora_rank:
        for nm, width in (("q", hq * hd), ("k", hkv * hd), ("v", hkv * hd)):
            p[f"lora_{nm}_a"] = dense_init(ks[4], (d, lora_rank), dtype)
            p[f"lora_{nm}_b"] = jnp.zeros((lora_rank, width), dtype)
    return p


def _project_qkv(p: Params, x, x_kv, cfg: ArchConfig):
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x_kv @ p["wk"]
    v = x_kv @ p["wv"]
    if "lora_q_a" in p:
        q = q + (x @ p["lora_q_a"]) @ p["lora_q_b"]
        k = k + (x_kv @ p["lora_k_a"]) @ p["lora_k_b"]
        v = v + (x_kv @ p["lora_v_a"]) @ p["lora_v_b"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    b, sq = x.shape[:2]
    sk = x_kv.shape[1]
    q = q.reshape(b, sq, hq, hd)
    k = k.reshape(b, sk, hkv, hd)
    v = v.reshape(b, sk, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def attn_forward(
    p: Params,
    x: jnp.ndarray,  # (B, S, D)
    positions: jnp.ndarray,  # (B, S)
    cfg: ArchConfig,
    *,
    window=0,
    causal: bool = True,
    cross_x: Optional[jnp.ndarray] = None,  # encoder states for cross-attn
    cross_pos: Optional[jnp.ndarray] = None,
    return_kv: bool = False,
):
    """Full-sequence attention (train / prefill / encoder)."""
    x_kv = cross_x if cross_x is not None else x
    kv_pos = cross_pos if cross_pos is not None else positions
    q, k, v = _project_qkv(p, x, x_kv, cfg)
    if cross_x is None:  # self-attention gets rotary
        q = apply_rotary(q, positions, cfg.rope_theta)
        k = apply_rotary(k, kv_pos, cfg.rope_theta)
    out = full_attention(
        q, k, v, positions, kv_pos, window=window,
        causal=causal and cross_x is None,
    )
    b, s = x.shape[:2]
    y = out.reshape(b, s, -1) @ p["wo"]
    if return_kv:
        return y, (k, v)
    return y


def fill_kv_cache(
    cache: Params, k: jnp.ndarray, v: jnp.ndarray, positions: jnp.ndarray
) -> Params:
    """Write prefill K/V (length S) into a cache (length >= S or ring)."""
    length = cache["k"].shape[1]
    s = k.shape[1]
    if s >= length:  # ring cache shorter than the prefix: keep the tail,
        # rotated so that position p sits at slot p % length (decode layout)
        tail = s - length
        shift = (s - length) % length
        k = jnp.roll(k[:, tail:], shift, axis=1)
        v = jnp.roll(v[:, tail:], shift, axis=1)
        positions = jnp.roll(positions[:, tail:], shift, axis=1)
        s = length
    return {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1),
        "pos": jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], positions.astype(jnp.int32), 0, 1
        ),
    }


def init_kv_cache(
    cfg: ArchConfig, batch: int, max_len: int, window: int, dtype
) -> Params:
    """window > 0 => ring buffer of that length; else dense max_len cache."""
    length = window if window and window > 0 else max_len
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, length, hkv, hd), dtype),
        "v": jnp.zeros((batch, length, hkv, hd), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
    }


def attn_decode(
    p: Params,
    x: jnp.ndarray,  # (B, 1, D)
    pos,  # scalar int32 current position
    cache: Params,
    cfg: ArchConfig,
    *,
    window=0,
) -> Tuple[jnp.ndarray, Params]:
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, x, cfg)
    q = apply_rotary(q, positions, cfg.rope_theta)
    k = apply_rotary(k, positions, cfg.rope_theta)
    length = cache["k"].shape[1]
    slot = jnp.mod(pos, length)  # ring for window caches; identity for dense
    cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, 1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, 1),
        "pos": jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], positions, slot, 1
        ),
    }
    out = chunked_attention(
        q, cache["k"], cache["v"], positions, cache["pos"],
        window=window, causal=True, chunk=min(KV_CHUNK, length),
    )
    return out.reshape(b, 1, -1) @ p["wo"], cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ArchConfig, dtype) -> Params:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 8)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_a_norm": jnp.zeros((m.q_lora_rank,), dtype),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, h * qd), dtype),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim), dtype),
        "kv_a_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "wk_b": dense_init(ks[3], (m.kv_lora_rank, h * m.qk_nope_dim), dtype),
        "wv_b": dense_init(ks[4], (m.kv_lora_rank, h * m.v_head_dim), dtype),
        "wo": dense_init(ks[5], (h * m.v_head_dim, d), dtype),
    }


def _mla_q(p: Params, x, positions, cfg: ArchConfig):
    m: MLAConfig = cfg.mla
    b, s = x.shape[:2]
    h = cfg.n_heads
    cq = rms_norm(x @ p["wq_a"], p["q_a_norm"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    q_rope = apply_rotary(q_rope, positions, cfg.rope_theta)
    return jnp.concatenate([q_nope, q_rope], axis=-1)


def _mla_kv_latent(p: Params, x, positions, cfg: ArchConfig):
    """x -> (c_kv normalised latent, k_rope rotated): the *cache contents*."""
    m: MLAConfig = cfg.mla
    ckv = x @ p["wkv_a"]
    c_kv = rms_norm(ckv[..., : m.kv_lora_rank], p["kv_a_norm"], cfg.norm_eps)
    k_rope = ckv[..., m.kv_lora_rank :][:, :, None, :]  # (B,S,1,rope)
    k_rope = apply_rotary(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope[:, :, 0, :]


def _mla_expand(p: Params, c_kv, k_rope, cfg: ArchConfig):
    """latents -> per-head k, v."""
    m: MLAConfig = cfg.mla
    b, s = c_kv.shape[:2]
    h = cfg.n_heads
    k_nope = (c_kv @ p["wk_b"]).reshape(b, s, h, m.qk_nope_dim)
    v = (c_kv @ p["wv_b"]).reshape(b, s, h, m.v_head_dim)
    k_rope_b = jnp.broadcast_to(
        k_rope[:, :, None, :], (b, s, h, m.qk_rope_dim)
    )
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return k, v


def mla_forward(
    p: Params, x, positions, cfg: ArchConfig, *, return_latent: bool = False
):
    b, s = x.shape[:2]
    q = _mla_q(p, x, positions, cfg)
    c_kv, k_rope = _mla_kv_latent(p, x, positions, cfg)
    k, v = _mla_expand(p, c_kv, k_rope, cfg)
    out = full_attention(q, k, v, positions, positions, causal=True)
    y = out.reshape(b, s, -1) @ p["wo"]
    if return_latent:
        return y, (c_kv, k_rope)
    return y


def fill_mla_cache(
    cache: Params, c_kv: jnp.ndarray, k_rope: jnp.ndarray, positions: jnp.ndarray
) -> Params:
    return {
        "c_kv": jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, 0, 1),
        "k_rope": jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope, 0, 1
        ),
        "pos": jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], positions.astype(jnp.int32), 0, 1
        ),
    }


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> Params:
    m: MLAConfig = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def mla_decode(
    p: Params, x, pos, cache: Params, cfg: ArchConfig
) -> Tuple[jnp.ndarray, Params]:
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = _mla_q(p, x, positions, cfg)
    c_kv, k_rope = _mla_kv_latent(p, x, positions, cfg)
    cache = {
        "c_kv": jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, pos, 1),
        "k_rope": jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope, pos, 1
        ),
        "pos": jax.lax.dynamic_update_slice_in_dim(cache["pos"], positions, pos, 1),
    }
    if FLAGS.mla_absorb:
        return mla_decode_absorbed(p, q, cache, cfg), cache
    # baseline: expand latents for the whole cache every step
    k, v = _mla_expand(p, cache["c_kv"], cache["k_rope"], cfg)
    out = chunked_attention(
        q, k, v, positions, cache["pos"], causal=True
    )
    return out.reshape(b, 1, -1) @ p["wo"], cache


def mla_decode_absorbed(p: Params, q, cache: Params, cfg: ArchConfig):
    """Weight-absorbed MLA decode (DeepSeek-V3 S2.1 inference form).

    Scores are computed in the 512-dim latent space:
        s = (q_nope W_uk) . c_kv + q_rope . k_rope
        o_latent = softmax(s) @ c_kv ;  o = (o_latent W_uv) per head
    Per-token cost drops from O(S * kv_rank * H * (nope+v)) (re-expanding
    k/v for the whole cache) to O(S * (kv_rank + rope)) per head -- the
    useful-FLOPs fix for the deepseek-v3 decode cell (EXPERIMENTS.md SPerf).
    """
    m: MLAConfig = cfg.mla
    b = q.shape[0]
    h = cfg.n_heads
    cdtype = cache["c_kv"].dtype  # keep the cache in its native dtype:
    # bf16 x bf16 -> f32-accum is MXU-native; upcasting the 32k latent cache
    # to f32 per decoded token was the memory-term offender (SPerf iter 3)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]
    wk = p["wk_b"].reshape(m.kv_lora_rank, h, m.qk_nope_dim)
    q_lat = jnp.einsum(
        "bqhn,rhn->bqhr", q_nope.astype(cdtype), wk.astype(cdtype),
        preferred_element_type=jnp.float32,
    )
    ckv = cache["c_kv"]  # (B, S, r)
    kr = cache["k_rope"]  # (B, S, rope)
    s = jnp.einsum(
        "bqhr,bsr->bhqs", q_lat.astype(cdtype), ckv,
        preferred_element_type=jnp.float32,
    ) + jnp.einsum(
        "bqhn,bsn->bhqs", q_rope.astype(cdtype), kr,
        preferred_element_type=jnp.float32,
    )
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    s = s * scale
    valid = (cache["pos"] >= 0)[:, None, None, :]
    s = jnp.where(valid, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(valid, w, 0.0)
    o_lat = jnp.einsum(
        "bhqs,bsr->bqhr", w.astype(cdtype), ckv,
        preferred_element_type=jnp.float32,
    )  # (B,1,H,r)
    wv = p["wv_b"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    o = jnp.einsum(
        "bqhr,rhv->bqhv", o_lat.astype(cdtype), wv.astype(cdtype),
        preferred_element_type=jnp.float32,
    )
    o = o.reshape(b, 1, h * m.v_head_dim).astype(q.dtype)
    return o @ p["wo"]
