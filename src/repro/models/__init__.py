from repro.models.lm import (
    init_decode_state,
    init_lm,
    lm_decode_step,
    lm_logits,
    lm_loss,
    lm_prefill,
)

__all__ = [
    "init_lm", "lm_loss", "lm_logits",
    "lm_prefill", "lm_decode_step", "init_decode_state",
]
