"""The training loop: steps + checkpoints + fault handling + watchdog.

This is the single-process core; `launch/train.py` wraps it with mesh
construction and host-sharded data.  All fault-tolerance behaviour
(restore-on-failure, SIGTERM save, straggler alarms) is exercised by
tests/test_fault.py with injected failures.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.runtime.fault import FailureInjector, StragglerWatchdog

Pytree = Any


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep: int = 3
    log_every: int = 10
    max_restarts: int = 5


def train_loop(
    *,
    state: Pytree,
    train_step: Callable,
    next_batch: Callable[[int], Dict[str, np.ndarray]],
    cfg: LoopConfig,
    injector: Optional[FailureInjector] = None,
    log: Callable[[str], None] = print,
) -> Pytree:
    """Run to cfg.total_steps with restore-on-failure semantics."""
    ckpt = (
        ckpt_io.AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
        if cfg.ckpt_dir
        else None
    )
    watchdog = StragglerWatchdog()

    # resume if a checkpoint exists
    step = 0
    if cfg.ckpt_dir:
        last = ckpt_io.latest_step(cfg.ckpt_dir)
        if last is not None:
            state, step = ckpt_io.restore(cfg.ckpt_dir, last, state)
            step += 1
            log(f"[resume] restored step {step - 1}, continuing at {step}")

    # SIGTERM (preemption) -> synchronous save + clean exit
    interrupted = {"flag": False}

    def _on_term(signum, frame):
        interrupted["flag"] = True

    old_handler = signal.signal(signal.SIGTERM, _on_term)

    restarts = 0
    try:
        while step < cfg.total_steps:
            try:
                batch = next_batch(step)
                if injector is not None:
                    injector.check(step)
                t0 = time.monotonic()
                state, metrics = train_step(state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.monotonic() - t0
                alarm = watchdog.observe(step, dt)
                if alarm:
                    log(f"[straggler] step {step}: {dt:.3f}s vs p50 "
                        f"{alarm['p50']:.3f}s -- flagging for reassignment")
                if step % cfg.log_every == 0:
                    log(
                        f"step {step:6d} loss {float(metrics['loss']):.4f} "
                        f"gnorm {float(metrics.get('grad_norm', 0)):.3f} "
                        f"({dt:.3f}s)"
                    )
                if ckpt and step > 0 and step % cfg.ckpt_every == 0:
                    ckpt.save(step, state)
                if interrupted["flag"]:
                    log(f"[preempt] SIGTERM at step {step}: saving + exiting")
                    if ckpt:
                        ckpt.wait()
                        ckpt_io.save(cfg.ckpt_dir, step, state, keep=cfg.keep)
                    return state
                step += 1
            except Exception as e:
                if ckpt is None or restarts >= cfg.max_restarts:
                    raise
                restarts += 1
                log(f"[fault] step {step}: {type(e).__name__}: {e} -- "
                    f"restoring from last checkpoint (restart {restarts})")
                ckpt.wait()
                last = ckpt_io.latest_step(cfg.ckpt_dir)
                if last is None:
                    raise
                state, restored = ckpt_io.restore(cfg.ckpt_dir, last, state)
                step = restored + 1
        if ckpt:
            ckpt.wait()
            ckpt_io.save(cfg.ckpt_dir, cfg.total_steps - 1, state, keep=cfg.keep)
    finally:
        signal.signal(signal.SIGTERM, old_handler)
    return state
