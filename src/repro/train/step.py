"""Train-step factory: loss -> grads -> clip -> AdamW, remat + microbatching.

The returned function is pjit-able: state and batch are plain pytrees whose
shardings are provided at jit time by the launcher / dry-run.

Distributed-optimization options:
  * gradient accumulation over microbatches with DEFERRED reduction -- the
    psum over microbatches happens once per step (jax.lax.scan over
    microbatches accumulates local grads; GSPMD reduces the accumulated
    tree when the optimizer consumes it), not once per microbatch.
  * int8-compressed gradient all-reduce with error feedback lives in
    repro/distributed/collectives.py (shard_map path, opt-in).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm as lm_mod
from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    warmup_cosine,
)

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1  # grad accumulation steps per global step
    remat: bool = True
    warmup_steps: int = 100
    total_steps: int = 10000


def init_train_state(key, cfg: ArchConfig, tcfg: TrainConfig) -> Dict[str, Pytree]:
    params = lm_mod.init_lm(key, cfg)
    return {
        "params": params,
        "opt": adamw_init(params, tcfg.optimizer),
        "step": jnp.zeros((), jnp.int32),
    }


def _split_microbatches(batch: Dict[str, jnp.ndarray], n: int):
    def sp(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(sp, batch)


def make_train_step(
    cfg: ArchConfig, tcfg: TrainConfig
) -> Callable[[Dict[str, Pytree], Dict[str, jnp.ndarray]], Tuple[Pytree, Dict]]:
    def loss_fn(params, mb):
        return lm_mod.lm_loss(params, cfg, mb, remat=tcfg.remat)

    def train_step(state, batch):
        params = state["params"]
        if tcfg.microbatches > 1:
            mbs = _split_microbatches(batch, tcfg.microbatches)

            def acc_body(carry, mb):
                gsum, lsum = carry
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                return (gsum, lsum + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(acc_body, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
            loss = lsum / tcfg.microbatches
            metrics: Dict[str, jnp.ndarray] = {"loss": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, batch)

        lr_scale = warmup_cosine(
            state["step"], warmup=tcfg.warmup_steps, total=tcfg.total_steps
        )
        params, opt, opt_metrics = adamw_update(
            params, grads, state["opt"], tcfg.optimizer, lr_scale
        )
        new_state = {
            "params": params,
            "opt": opt,
            "step": state["step"] + 1,
        }
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_state, metrics

    return train_step
