"""IR verifier: abstract interpretation over an `ExecProgram`.

`verify_program(spec, plan)` re-derives, statically, every invariant the
planner relied on when it emitted the plan and the executor will rely on
when it runs the lowered program — so a machine-generated (or
hand-edited, or stale) plan is rejected *before* it binds weights or
reaches a replica:

  * structural legality — `program.lower` itself (coverage, geometry,
    group adjacency, pool placement); its `ProgramError`s are folded
    into the report under their own codes (CVK101..CVK110),
  * shape/dtype propagation — walk the stage chain from the plan's
    reference `input_hw`, checking every unit's declared ConvSpec
    geometry against the running shape, the channel chain across units,
    pool divisibility under stride (`downsample_factor` consistency),
    and the final shape against `NetSpec.infer_shapes`
    (CVK105/106/113/116),
  * fusion-group legality — the working-set terms the planner charged:
    joint right-hand matrices within `MATRIX_RESIDENCY_FRAC` of the
    shared level (CVK112), the resident slab (`tile_rows` + halo) within
    the slab budget (CVK111), members chainable under one transform
    family (CVK115),
  * halo recursion — expand the receptive-field recursion
    (`Algorithm.execute_staged`'s `want` ranges) over every super-tile
    and check no member is asked for rows outside its padded true
    extent, i.e. no phantom rows (CVK116),
  * kernel-cache key injectivity — two units with distinct weights must
    never share a static `KernelCache.key`, and a unit whose params
    dropped a declared weight param is under-keyed (CVK114).

The verifier never executes anything: it needs the spec, the plan, and a
hardware model (for the residency budgets), nothing else.
"""

from __future__ import annotations

from typing import Optional

from repro.core import analysis, registry
from repro.core import tune as tune_mod
from repro.convserve.check.diagnostics import (
    CheckReport,
    Diagnostic,
    ProgramError,
)
from repro.convserve.graph import NetSpec
from repro.convserve.plan import NetPlan
from repro.convserve.program import ExecProgram, Stage, lower

# the planner's residency fractions — verified against the SAME constants
# the decision used, so verifier and planner cannot drift apart silently
from repro.convserve.planner import _SLAB_FRAC  # noqa: F401  (re-exported)

_MATRIX_FRAC = analysis.MATRIX_RESIDENCY_FRAC


def _err(report: CheckReport, code: str, msg: str, loc: str) -> None:
    report.add(Diagnostic(code=code, message=msg, loc=loc))


# ----------------------------------------------------------- shape chain


def _walk_shapes(
    report: CheckReport,
    spec: NetSpec,
    plan: NetPlan,
    program: ExecProgram,
) -> None:
    """Propagate (h, w, c) through every stage and unit, checking each
    unit's declared ConvSpec against the running shape and the epilogue
    pools against divisibility.  Mirrors `NetSpec.infer_shapes`, but
    against the PLAN's declared geometry, not the spec's — that is the
    whole point: the spec is trusted, the plan is the artifact under
    verification."""
    h, w = plan.input_hw
    c0 = spec.conv_layers()[0][1].c_in
    try:
        want_final = spec.out_shape(h, w, c0)
    except ValueError as e:
        _err(report, "CVK113", f"input_hw {plan.input_hw} does not survive "
             f"the net's downsampling chain: {e}", plan.net)
        return
    c = c0
    for op in program.prologue:
        if op.kind == "maxpool":
            if h % op.window or w % op.window:
                _err(
                    report, "CVK113",
                    f"prologue layer {op.layer}: pool window {op.window} "
                    f"does not divide ({h}, {w})", plan.net,
                )
                return
            h, w = h // op.window, w // op.window
    for stage in program.stages:
        for u in stage.units:
            s = u.plan.spec
            loc = f"{plan.net}/{stage.label}/layer{u.layer}"
            if (s.h, s.w) != (h, w):
                _err(
                    report, "CVK116",
                    f"layer {u.layer} planned at {s.h}x{s.w}, shape "
                    f"propagation reaches it at {h}x{w}", loc,
                )
            if s.c_in != c:
                _err(
                    report, "CVK106",
                    f"layer {u.layer} expects c_in={s.c_in}, channel chain "
                    f"carries {c}", loc,
                )
            if s.dtype != plan.dtype:
                _err(
                    report, "CVK105",
                    f"layer {u.layer} planned for dtype {s.dtype!r}, plan "
                    f"dtype is {plan.dtype!r}", loc,
                )
            try:
                h, w = s.out_hw
            except ValueError as e:
                _err(report, "CVK113", f"layer {u.layer}: {e}", loc)
                return
            c = s.c_out
            for op in u.epilogue:
                if op.kind == "maxpool":
                    if h % op.window or w % op.window:
                        _err(
                            report, "CVK113",
                            f"layer {op.layer}: pool window {op.window} "
                            f"does not divide ({h}, {w})", loc,
                        )
                        return
                    h, w = h // op.window, w // op.window
    got_final = (h, w, c)
    if got_final != want_final:
        _err(
            report, "CVK116",
            f"stage chain produces {got_final}, NetSpec.infer_shapes "
            f"expects {want_final}", plan.net,
        )


# -------------------------------------------------------- fusion groups


def _check_group(
    report: CheckReport,
    plan: NetPlan,
    stage: Stage,
    hw: analysis.HardwareModel,
) -> None:
    """Fusion-group legality: the working-set budgets `_group_decision`
    charged, re-derived from the lowered stage."""
    loc = f"{plan.net}/{stage.label}"
    members = [u.plan for u in stage.units]
    # dtype must agree across the seam: the intermediate is handed from
    # one member's inverse transform straight to the next member's
    # forward transform, with no cast in between
    dtypes = {p.spec.dtype for p in members}
    if len(dtypes) > 1:
        _err(
            report, "CVK105",
            f"fusion group mixes dtypes {sorted(dtypes)} across a seam",
            loc,
        )
    # chainability + joint matrix residency via each member's TileAlgebra
    matrix_bytes = 0
    for prev, nxt in zip(members, members[1:]):
        try:
            chains = registry.get(prev.algo).can_chain(
                prev.algo_plan(), nxt.algo_plan()
            )
        except Exception as e:
            chains = False
            _err(
                report, "CVK115",
                f"layers {prev.layer}->{nxt.layer}: chain probe failed "
                f"({e})", loc,
            )
        if not chains:
            _err(
                report, "CVK115",
                f"layers {prev.layer}->{nxt.layer} cannot chain "
                f"({prev.algo} -> {nxt.algo})", loc,
            )
            return
    for p in members:
        try:
            ta = registry.get(p.algo).tile_algebra(p.algo_plan())
        except Exception as e:
            _err(
                report, "CVK115",
                f"layer {p.layer} ({p.algo}): transform params are "
                f"unusable ({e})", loc,
            )
            return
        if ta is None:
            _err(
                report, "CVK115",
                f"layer {p.layer} ({p.algo}) has no transform family: "
                "cannot join a fusion group", loc,
            )
            return
        matrix_bytes += ta.kernel_matrix_bytes(p.c_in, p.c_out, p.groups)
    if matrix_bytes > _MATRIX_FRAC * hw.fast_shared_bytes:
        _err(
            report, "CVK112",
            f"joint right-hand matrices {matrix_bytes}B exceed "
            f"{_MATRIX_FRAC:.0%} of the shared level "
            f"({int(_MATRIX_FRAC * hw.fast_shared_bytes)}B)", loc,
        )
    # resident slab: the super-tile of the largest intermediate plus the
    # last conv's (K-1)-row halo must fit the planner's slab budget
    inter = [(p.spec.h, p.spec.w, p.spec.c_in) for p in members[1:]]
    slab_row_bytes = max(w_ * c_ * 4 for _, w_, c_ in inter)
    h_final, _ = members[-1].spec.out_hw
    k_last = members[-1].k
    eff_rows = stage.tile_rows if stage.tile_rows > 0 else h_final
    budget = _SLAB_FRAC * hw.fast_shared_bytes
    need = (eff_rows + k_last - 1) * slab_row_bytes
    if need > budget:
        _err(
            report, "CVK111",
            f"tile_rows={stage.tile_rows} needs a {need}B resident slab, "
            f"budget is {int(budget)}B ({_SLAB_FRAC:.0%} of the shared "
            "level)", loc,
        )
    _check_halo(report, plan, stage, loc)


def _check_halo(
    report: CheckReport, plan: NetPlan, stage: Stage, loc: str
) -> None:
    """Expand `execute_staged`'s receptive-field recursion over every
    super-tile: each member's wanted row range, before clamping, must
    stay within its padded input extent — a range reaching further would
    read phantom rows the clamp silently fabricates as zeros."""
    members = [u.plan for u in stage.units]
    h_final = members[-1].spec.h + 2 * members[-1].pad - members[-1].k + 1
    rows = stage.tile_rows if stage.tile_rows > 0 else h_final
    if rows <= 0 or h_final <= 0:
        _err(
            report, "CVK111",
            f"non-positive effective tile_rows/extent ({rows}, {h_final}) "
            "in fused stage", loc,
        )
        return
    a = 0
    while a < h_final:
        b = min(a + rows, h_final)  # output rows [a, b) of the stage
        lo, hi = a, b
        for p in reversed(members):
            s = p.spec
            # half-open input row range this member needs for output rows
            # [lo, hi) -- the same recursion execute_staged runs
            want_lo, want_hi = lo - s.pad, hi - s.pad + s.k - 1
            if want_lo < -s.pad or want_hi > s.h + s.pad:
                _err(
                    report, "CVK116",
                    f"halo recursion for output rows [{a}, {b}) asks "
                    f"layer {p.layer} for input rows "
                    f"[{want_lo}, {want_hi}) outside its padded extent "
                    f"[{-s.pad}, {s.h + s.pad}) (phantom rows)", loc,
                )
                return
            # clamp to the true extent, exactly as execute_staged does,
            # before recursing into the producer
            lo, hi = max(want_lo, 0), min(want_hi, s.h)
        a = b


# ----------------------------------------------------- cache-key checks


def _check_cache_keys(
    report: CheckReport, plan: NetPlan, program: ExecProgram
) -> None:
    """`KernelCache.key` injectivity over this program's units.

    Two distinct units sharing a static key would serve each other's
    transforms; a unit whose params dropped one of its algorithm's
    declared weight params is under-keyed — the key no longer separates
    two plans of the same layer with different transform settings, so a
    shared cache can hand back a transform prepared for the wrong tile
    size."""
    seen = {}
    for stage in program.stages:
        for u in stage.units:
            p = u.plan
            alg = registry.get(p.algo)
            if not alg.consumes_wt:
                continue
            loc = f"{plan.net}/{stage.label}/layer{u.layer}"
            missing = [
                name for name in alg.weight_params if name not in p.params
            ]
            if missing:
                _err(
                    report, "CVK114",
                    f"layer {u.layer} ({p.algo}) is missing declared "
                    f"weight params {missing}: prepare_key degenerates "
                    "and distinct transforms collide", loc,
                )
            s = p.spec
            try:
                pkey = alg.prepare_key(p.params)
            except Exception:
                pkey = None  # missing params already flagged above
            key = (
                plan.net, p.layer, p.algo, s.k, s.c_in, s.c_out, s.groups,
                pkey,
            )
            if key in seen:
                _err(
                    report, "CVK114",
                    f"units {seen[key]} and {loc} share one kernel-cache "
                    "key: distinct weights would collide", loc,
                )
            else:
                seen[key] = loc


# --------------------------------------------------- hand-built programs


def _check_structure(
    report: CheckReport, plan: NetPlan, program: ExecProgram
) -> None:
    """Re-assert the invariants `Stage.__post_init__` enforces, for
    programs built outside `lower()` (the dataclass checks can be
    bypassed with object.__setattr__; the verifier cannot)."""
    for stage in program.stages:
        loc = f"{plan.net}/{stage.label}"
        if not stage.units:
            _err(report, "CVK104", "stage with no units", loc)
            continue
        for u in stage.units[:-1]:
            if u.has_pool:
                _err(
                    report, "CVK110",
                    f"maxpool inside fusion group (layer {u.layer}): pool "
                    "must end a group — it would run inside the task loop",
                    loc,
                )
        if stage.fused and stage.tile_rows < 0:
            _err(
                report, "CVK111",
                f"negative tile_rows {stage.tile_rows}", loc,
            )


# ------------------------------------------------------------ entrypoint


def verify_program(
    spec: NetSpec,
    plan: NetPlan,
    *,
    program: Optional[ExecProgram] = None,
    hw: Optional[analysis.HardwareModel] = None,
) -> CheckReport:
    """Statically verify `plan` (or an already-lowered `program`) against
    `spec` on hardware model `hw`.  Never raises for plan defects — every
    finding lands in the returned `CheckReport`; `report.ok` is the
    verdict."""
    hw = hw or tune_mod.default_hw()
    report = CheckReport(analyzer="ir")
    if program is None:
        try:
            program = lower(spec, plan)
        except ProgramError as e:
            report.add(e.diagnostic)
            return report
        except ValueError as e:  # non-coded lowering failure
            report.add(
                Diagnostic(code="CVK104", message=str(e), loc=plan.net)
            )
            return report
    _check_structure(report, plan, program)
    _walk_shapes(report, spec, plan, program)
    _check_cache_keys(report, plan, program)
    for stage in program.stages:
        if stage.fused:
            _check_group(report, plan, stage, hw)
    return report


def verify_compiled(net, hw=None) -> CheckReport:
    """Convenience: verify a `CompiledNet`-shaped object (anything with
    `.spec`, `.plan`, `.program`)."""
    return verify_program(
        net.spec, net.plan, program=net.program,
        hw=hw or getattr(net, "hw", None),
    )
