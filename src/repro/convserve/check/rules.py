"""Clock/convention linter: pluggable AST rules over the source tree.

Rules are small objects with a stable code, run by `analyze_rules` over
every ``.py`` file under the given roots.  Adding a rule is: subclass
`Rule`, implement `check`, append an instance to `DEFAULT_RULES` (the
README documents this as the extension point).

The built-in rules encode two conventions the runtime depends on:

  *clock discipline* — the whole serving stack is testable because
  every time read routes through the injectable `Clock`
  (runtime/clock.py).  One stray ``time.perf_counter()`` makes a
  SimClock run nondeterministic (and its latency pairs incomparable
  with clocked ones), so direct reads are banned outside the allowlist:
  `runtime/clock.py` (the clock IS the time source) and `core/tune.py`
  (offline autotuning measures real kernels by design; its wisdom
  timestamps are wall-time on purpose).  `time.time()` is CVK301 —
  non-monotonic, wrong for durations everywhere; `time.perf_counter()`
  is CVK302; inside `convserve/` even `time.monotonic()`/`time.sleep()`
  are CVK303 (must go through a Clock so simulation reaches them).

  *kernel discipline* — `pl.pallas_call` is the raw kernel-launch
  primitive; every launch must live under ``kernels/`` (CVK320), where
  the parametric tile engine owns grids, BlockSpecs and interpret-mode
  fallbacks.  A `pallas_call` in core/ or convserve/ bypasses the
  engine's backend resolution and block autotuning — it would run
  uninterpreted on CPU CI and untuned everywhere.

  *registry discipline* — an `Algorithm` subclass must declare its
  `supports` predicate before (lexically above) its `execute` body
  (CVK310: the capability contract is read top-down, and a class that
  executes without any reachable `supports` in its base chain silently
  accepts every spec), and call sites must not pass ``wt=`` to an
  algorithm that does not consume pre-transformed weights (CVK311: the
  argument would be silently meaningless — the registry raises at
  runtime, the rule catches it statically when ``algo=`` is a literal).

  *telemetry discipline* — counters, gauges and spans mutate only
  through the `Telemetry`/`Tracer` API (CVK330).  A direct dict poke at
  the stores outside `runtime/telemetry.py` and `obs/` skips the lock
  AND the freshness stamp that the autoscaler's and adapt controller's
  stale-snapshot guards depend on.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.convserve.check.diagnostics import CheckReport, Diagnostic

# files where direct time reads are the point, not a leak
CLOCK_ALLOWLIST = ("runtime/clock.py", "core/tune.py")

_BANNED_EVERYWHERE = {"time": "CVK301", "perf_counter": "CVK302"}
_BANNED_CONVSERVE = {"monotonic": "CVK303", "sleep": "CVK303"}


def _is_allowlisted(path: str) -> bool:
    posix = Path(path).as_posix()
    return any(posix.endswith(suffix) for suffix in CLOCK_ALLOWLIST)


@dataclasses.dataclass
class FileContext:
    """One parsed file plus the cross-file class table (for rules that
    need whole-program knowledge, like supports/execute resolution)."""

    path: str
    lines: List[str]
    tree: ast.Module
    classes: Dict[str, "ClassDecl"]  # global, keyed by class name


@dataclasses.dataclass
class ClassDecl:
    name: str
    path: str
    bases: Tuple[str, ...]
    methods: Dict[str, int]  # name -> lineno


class Rule:
    """One convention: a stable code and a per-file check."""

    code = "CVK000"
    name = "rule"

    def check(self, ctx: FileContext, report: CheckReport) -> None:
        raise NotImplementedError


# ------------------------------------------------------------- clock rules


class DirectTimeRule(Rule):
    """CVK301/302/303: direct `time.*` reads outside the allowlist."""

    code = "CVK301"
    name = "direct-time"

    def check(self, ctx: FileContext, report: CheckReport) -> None:
        if _is_allowlisted(ctx.path):
            return
        in_convserve = "/convserve/" in Path(ctx.path).as_posix()
        # names imported straight off the time module:
        #   from time import perf_counter [as pc]
        direct: Dict[str, str] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom) and node.module == "time":
                for alias in node.names:
                    direct[alias.asname or alias.name] = alias.name
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            member = None
            if (
                isinstance(func, ast.Attribute)
                and isinstance(func.value, ast.Name)
                and func.value.id == "time"
            ):
                member = func.attr
            elif isinstance(func, ast.Name) and func.id in direct:
                member = direct[func.id]
            if member is None:
                continue
            code = _BANNED_EVERYWHERE.get(member)
            if code is None and in_convserve:
                code = _BANNED_CONVSERVE.get(member)
            if code is None:
                continue
            report.add(
                Diagnostic(
                    code=code,
                    message=f"direct time.{member}() call: route through "
                    "the injected Clock"
                    + (" (non-monotonic, wrong for durations)"
                       if member == "time" else ""),
                    loc=f"{ctx.path}:{node.lineno}",
                )
            )


# ---------------------------------------------------------- registry rules

_ROOT_ALGO_CLASSES = {"Algorithm", "TransformedAlgorithm"}


class PallasCallOutsideKernelsRule(Rule):
    """CVK320: a direct ``pl.pallas_call`` (or a name imported from
    ``jax.experimental.pallas``) outside ``kernels/``.  Kernel launches
    belong to the kernel packages; everything else goes through the
    parametric tile engine's dispatchers."""

    code = "CVK320"
    name = "pallas-call-outside-kernels"

    def check(self, ctx: FileContext, report: CheckReport) -> None:
        posix = Path(ctx.path).as_posix()
        if "/kernels/" in posix:
            return
        # names imported straight off the pallas module:
        #   from jax.experimental.pallas import pallas_call [as pc]
        direct: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.ImportFrom)
                    and node.module
                    and node.module.endswith("pallas")):
                for alias in node.names:
                    if alias.name == "pallas_call":
                        direct.add(alias.asname or alias.name)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            hit = (
                isinstance(func, ast.Attribute)
                and func.attr == "pallas_call"
                or isinstance(func, ast.Name) and func.id in direct
            )
            if hit:
                report.add(
                    Diagnostic(
                        code=self.code,
                        message="pallas_call outside kernels/: launch "
                        "through the parametric tile engine "
                        "(repro.kernels.fused_tile) instead",
                        loc=f"{ctx.path}:{node.lineno}",
                    )
                )


class SupportsBeforeExecuteRule(Rule):
    """CVK310: an Algorithm subclass declares `supports` before
    `execute` — lexically within one body, and reachably across the
    base chain (a class that executes with no `supports` anywhere up to
    the root accepts every spec)."""

    code = "CVK310"
    name = "supports-before-execute"

    def _is_algorithm(self, decl: ClassDecl, classes: Dict[str, ClassDecl],
                      seen: Set[str]) -> bool:
        for b in decl.bases:
            if b in _ROOT_ALGO_CLASSES:
                return True
            if b in classes and b not in seen:
                seen.add(b)
                if self._is_algorithm(classes[b], classes, seen):
                    return True
        return False

    def _chain_declares_supports(
        self, decl: ClassDecl, classes: Dict[str, ClassDecl], seen: Set[str]
    ) -> bool:
        if "supports" in decl.methods:
            return True
        for b in decl.bases:
            if b in _ROOT_ALGO_CLASSES:
                # the registry root's default predicate counts only if
                # it is the REAL root (scanned); an unscanned base named
                # Algorithm is given the benefit of the doubt too --
                # fixture trees can define their own bare root
                root = classes.get(b)
                if root is None or "supports" in root.methods:
                    return True
                if self._chain_declares_supports(root, classes, seen):
                    return True
                continue
            if b in classes and b not in seen:
                seen.add(b)
                if self._chain_declares_supports(classes[b], classes, seen):
                    return True
        return False

    def check(self, ctx: FileContext, report: CheckReport) -> None:
        for decl in ctx.classes.values():
            if decl.path != ctx.path:
                continue
            if decl.name in _ROOT_ALGO_CLASSES:
                continue
            if not self._is_algorithm(decl, ctx.classes, set()):
                continue
            exec_line = decl.methods.get("execute")
            if exec_line is None:
                continue
            sup_line = decl.methods.get("supports")
            if sup_line is not None:
                if sup_line > exec_line:
                    report.add(
                        Diagnostic(
                            code=self.code,
                            message=f"{decl.name}.supports (line "
                            f"{sup_line}) is declared after execute "
                            f"(line {exec_line})",
                            loc=f"{ctx.path}:{sup_line}",
                        )
                    )
            elif not self._chain_declares_supports(
                decl, ctx.classes, {decl.name}
            ):
                report.add(
                    Diagnostic(
                        code=self.code,
                        message=f"{decl.name} defines execute but no "
                        "supports is reachable in its base chain: it "
                        "would accept every ConvSpec",
                        loc=f"{ctx.path}:{exec_line}",
                    )
                )


class WtToNonConsumerRule(Rule):
    """CVK311: `wt=` handed to an algorithm that does not consume
    pre-transformed weights (checked statically where `algo=` is a
    string literal; the registry raises the same complaint at call
    time)."""

    code = "CVK311"
    name = "wt-non-consumer"

    def _consumes(self, algo: str) -> Optional[bool]:
        try:  # live registry: single source of truth for capabilities
            from repro.core import registry

            return registry.get(algo).consumes_wt
        except Exception:
            return None  # unknown algo: not this rule's complaint

    def check(self, ctx: FileContext, report: CheckReport) -> None:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fname = (
                node.func.attr if isinstance(node.func, ast.Attribute)
                else node.func.id if isinstance(node.func, ast.Name)
                else ""
            )
            if fname != "conv2d":
                continue
            kw = {k.arg: k.value for k in node.keywords if k.arg}
            wt = kw.get("wt")
            algo = kw.get("algo")
            if wt is None or isinstance(wt, ast.Constant) and wt.value is None:
                continue
            if not (isinstance(algo, ast.Constant)
                    and isinstance(algo.value, str)):
                continue
            if algo.value == "auto":
                continue
            if self._consumes(algo.value) is False:
                report.add(
                    Diagnostic(
                        code=self.code,
                        message=f"wt= passed to algo={algo.value!r}, "
                        "which does not consume pre-transformed weights",
                        loc=f"{ctx.path}:{node.lineno}",
                    )
                )


class TelemetryDisciplineRule(Rule):
    """CVK330: counters, gauges and spans mutate only through the
    `Telemetry`/`Tracer` API.  An ad-hoc poke at the metric stores
    (``telemetry._counters[...] = ...``, ``tracer._events.append(...)``,
    a ``telemetry.counters`` dict write) outside ``runtime/telemetry.py``
    and ``obs/`` bypasses both the lock and the freshness stamp -- the
    mutation is invisible to the stale-snapshot guards downstream, so
    the autoscaler/adapt controller would act on data that looks stale
    (or, worse, looks fresh) for the wrong reason."""

    code = "CVK330"
    name = "telemetry-discipline"

    # attrs that ARE the stores (Telemetry internals)
    STORES = ("_counters", "_gauges", "_hists")
    # attrs that are only suspicious when the owner expression names the
    # registry ("telemetry"/"tracer"): `pool._events` is a legit event
    # heap, `tracer._events` is the span ring buffer
    LOOSE = ("counters", "gauges", "_events")
    MUTATORS = ("setdefault", "update", "pop", "popitem", "clear",
                "append", "appendleft", "extend")

    # files that own the stores: mutation is the point there
    ALLOW_SUFFIXES = ("runtime/telemetry.py",)
    ALLOW_PARTS = ("/obs/",)

    def _allowlisted(self, path: str) -> bool:
        posix = Path(path).as_posix()
        return (
            any(posix.endswith(s) for s in self.ALLOW_SUFFIXES)
            or any(p in posix for p in self.ALLOW_PARTS)
        )

    def _store_attr(self, node) -> Optional[str]:
        """The store name if `node` is an Attribute reading one."""
        if not isinstance(node, ast.Attribute):
            return None
        if node.attr in self.STORES:
            return node.attr
        if node.attr in self.LOOSE:
            try:
                owner = ast.unparse(node.value).lower()
            except Exception:  # pragma: no cover - unparse is total on ast
                return None
            if "telemetry" in owner or "tracer" in owner:
                return node.attr
        return None

    def _flag(self, report: CheckReport, ctx: FileContext, lineno: int,
              store: str, what: str) -> None:
        report.add(
            Diagnostic(
                code=self.code,
                message=f"{what} of telemetry store {store!r}: mutate "
                "through the Telemetry/Tracer API (inc/set_gauge/"
                "observe, begin/end/instant) so the lock and the "
                "freshness stamp see it",
                loc=f"{ctx.path}:{lineno}",
            )
        )

    def check(self, ctx: FileContext, report: CheckReport) -> None:
        if self._allowlisted(ctx.path):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (
                    node.targets if isinstance(node, ast.Assign)
                    else [node.target]
                )
                for t in targets:
                    if isinstance(t, ast.Subscript):
                        store = self._store_attr(t.value)
                        if store:
                            self._flag(report, ctx, node.lineno,
                                       store, "item write")
                    elif isinstance(t, ast.Attribute):
                        store = self._store_attr(t)
                        if store:
                            self._flag(report, ctx, node.lineno,
                                       store, "rebind")
            elif isinstance(node, ast.Delete):
                for t in node.targets:
                    if (isinstance(t, ast.Subscript)
                            and self._store_attr(t.value)):
                        self._flag(report, ctx, node.lineno,
                                   self._store_attr(t.value), "item delete")
            elif isinstance(node, ast.Call):
                func = node.func
                if (isinstance(func, ast.Attribute)
                        and func.attr in self.MUTATORS):
                    store = self._store_attr(func.value)
                    if store:
                        self._flag(report, ctx, node.lineno, store,
                                   f"{func.attr}() call")


DEFAULT_RULES: List[Rule] = [
    DirectTimeRule(),
    PallasCallOutsideKernelsRule(),
    SupportsBeforeExecuteRule(),
    WtToNonConsumerRule(),
    TelemetryDisciplineRule(),
]


# --------------------------------------------------------------- driver


def _collect_files(paths) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    return files


def analyze_rules(paths, rules: Optional[List[Rule]] = None) -> CheckReport:
    """Run every rule over every ``.py`` file under `paths`."""
    rules = DEFAULT_RULES if rules is None else rules
    report = CheckReport(analyzer="rules")
    parsed: List[Tuple[str, List[str], ast.Module]] = []
    classes: Dict[str, ClassDecl] = {}
    for f in _collect_files(paths):
        try:
            src = f.read_text()
            tree = ast.parse(src, filename=str(f))
        except (OSError, SyntaxError) as e:
            report.add(
                Diagnostic(
                    code="CVK304", message=f"unparseable: {e}",
                    severity="warning", loc=str(f),
                )
            )
            continue
        parsed.append((str(f), src.splitlines(), tree))
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                classes[node.name] = ClassDecl(
                    name=node.name,
                    path=str(f),
                    bases=tuple(
                        b.attr if isinstance(b, ast.Attribute)
                        else b.id if isinstance(b, ast.Name) else ""
                        for b in node.bases
                    ),
                    methods={
                        item.name: item.lineno
                        for item in node.body
                        if isinstance(item, (ast.FunctionDef,
                                             ast.AsyncFunctionDef))
                    },
                )
    for path, lines, tree in parsed:
        ctx = FileContext(path=path, lines=lines, tree=tree, classes=classes)
        for rule in rules:
            rule.check(ctx, report)
    return report
