"""``python -m repro.convserve.check``: run all three analyzers.

Default scope mirrors the CI job: the IR verifier over every benched
config's fresh plan, the lock analyzer over the runtime's shared-state
modules, and the rule linter over all of ``src/repro``.  Exit status is
1 if any analyzer reports errors (``--strict`` also fails on warnings);
``--baseline PATH`` writes the merged report as JSON for artifact
upload either way.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.convserve.check.diagnostics import CheckReport
from repro.convserve.check.ir import verify_program
from repro.convserve.check.locks import analyze_locks
from repro.convserve.check.rules import analyze_rules

# the committed configs the bench suite serves — what "the tree's plans
# verify clean" means concretely
BENCHED_CONFIGS = (
    "vgg_mixed_channel",
    "tiny_testnet",
    "resnet_downsample",
    "resnext_grouped",
    "fft_fewchannel",
)


def _src_root() -> Path:
    # .../src/repro/convserve/check/__main__.py -> .../src
    return Path(__file__).resolve().parents[3]


def run_ir() -> CheckReport:
    from repro.configs import convnets
    from repro.convserve.planner import plan_net
    from repro.core import tune

    hw = tune.default_hw()
    merged = CheckReport(analyzer="ir")
    for name in BENCHED_CONFIGS:
        spec = getattr(convnets, name)()
        plan = plan_net(spec, 64, 64, hw=hw)
        merged.extend(verify_program(spec, plan, hw=hw))
    return merged


def run_locks(src: Path) -> CheckReport:
    convserve = src / "repro" / "convserve"
    return analyze_locks([
        convserve / "runtime",
        convserve / "adapt",
        convserve / "fleet",
        convserve / "obs",
        convserve / "cache.py",
        # the fleet's fault schedule lives outside convserve but is
        # consulted from replica completion paths: same discipline
        src / "repro" / "runtime" / "fault.py",
    ])


def run_rules(src: Path) -> CheckReport:
    return analyze_rules([src / "repro"])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.convserve.check",
        description="convcheck: IR verifier + lock discipline + "
        "clock/convention rules",
    )
    ap.add_argument(
        "--strict", action="store_true",
        help="fail (exit 1) on warnings too, not just errors",
    )
    ap.add_argument(
        "--baseline", metavar="PATH", default=None,
        help="write the merged JSON report here (written even on failure)",
    )
    ap.add_argument(
        "--only", choices=("ir", "locks", "rules"), default=None,
        help="run a single analyzer instead of all three",
    )
    args = ap.parse_args(argv)

    src = _src_root()
    reports = []
    if args.only in (None, "ir"):
        reports.append(run_ir())
    if args.only in (None, "locks"):
        reports.append(run_locks(src))
    if args.only in (None, "rules"):
        reports.append(run_rules(src))

    errors = sum(len(r.errors) for r in reports)
    warnings = sum(len(r.warnings) for r in reports)
    for r in reports:
        print(r.format())
    print(
        f"convcheck: {errors} error(s), {warnings} warning(s) across "
        f"{len(reports)} analyzer(s)"
    )

    if args.baseline:
        doc = {
            "errors": errors,
            "warnings": warnings,
            "reports": [r.to_dict() for r in reports],
        }
        Path(args.baseline).write_text(json.dumps(doc, indent=1, sort_keys=True))
        print(f"baseline written to {args.baseline}")

    failed = errors > 0 or (args.strict and warnings > 0)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
