"""convcheck: static verification for the serving stack.

Three analyzers behind one diagnostic vocabulary (``CVK###`` codes,
see `diagnostics.HINTS`):

  * `check.ir.verify_program` — ExecProgram legality (shapes, fusion
    budgets, halo recursion, cache-key injectivity),
  * `check.locks.analyze_locks` — guarded-field discipline and the
    lock-order graph,
  * `check.rules.analyze_rules` — clock discipline and registry
    conventions (pluggable rules).

Run all three from the command line::

    python -m repro.convserve.check [--strict] [--baseline out.json]

Only the diagnostics core is imported eagerly: `program.py` raises
through `ProgramError`, so this package must be importable from inside
`repro.convserve.program`'s own import — the analyzer submodules (which
import `program` back) load on first attribute access.
"""

from repro.convserve.check.diagnostics import (  # noqa: F401
    CheckReport,
    Diagnostic,
    ProgramError,
    VerificationError,
    program_error,
)

_SUBMODULES = ("ir", "locks", "rules", "diagnostics")

__all__ = [
    "CheckReport",
    "Diagnostic",
    "ProgramError",
    "VerificationError",
    "program_error",
    *_SUBMODULES,
]


def __getattr__(name):  # PEP 562: lazy analyzer imports
    if name in _SUBMODULES:
        import importlib

        return importlib.import_module(f"{__name__}.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
