"""Coded diagnostics: the one vocabulary every convcheck analyzer —
and `program.lower`'s own runtime validation — speaks.

A `Diagnostic` is one finding: a stable ``CVK###`` code, a severity, a
location (file:line for AST findings, net/stage coordinates for IR
findings), a one-line message, and a one-line fix hint.  `CheckReport`
collects them per analyzer run; `ProgramError` / `VerificationError`
carry them across the raise boundary so a runtime lowering failure and
a static verifier finding print identically and are matched by tests
the same way (both subclass ValueError, and str() keeps the plain
message the pre-convcheck ValueErrors carried).

Code space (documented in README "Static verification"):

  CVK1xx  IR verifier (`check.ir`) — ExecProgram legality
  CVK2xx  lock discipline (`check.locks`)
  CVK3xx  clock + kernel + registry conventions (`check.rules`)
"""

from __future__ import annotations

import dataclasses
import json
from typing import List, Tuple

ERROR = "error"
WARNING = "warning"

# one-line fix hints, keyed by code — a diagnostic may override, but the
# table is the documented default (and the README's source of truth)
HINTS = {
    "CVK101": "re-plan the net, or load the plan file for this net",
    "CVK102": "re-plan: every conv layer needs a LayerPlan",
    "CVK103": "stale plan file: re-plan against the current NetSpec",
    "CVK104": "unknown kind/algo: check spelling against the registry",
    "CVK105": "keep one dtype across a fusion group (and the plan dtype)",
    "CVK106": "channel chain broken: layer c_in must equal producer c_out",
    "CVK107": "fusion groups may only name conv layers",
    "CVK108": "fusion groups must cover adjacent conv units",
    "CVK109": "remove the layer from one of the overlapping groups",
    "CVK110": "maxpool must terminate its fusion group (move or split)",
    "CVK111": "tile_rows oversizes the resident slab: re-derive via "
              "planner.plan_fusion_groups",
    "CVK112": "joint kernel matrices overflow the shared level: split "
              "the group",
    "CVK113": "shape chain breaks under stride/pool: pick a bucket that "
              "survives NetSpec.downsample_factor",
    "CVK114": "kernel-cache key is not injective here: restore the "
              "algorithm's declared weight params / deduplicate units",
    "CVK115": "members cannot chain: same transform family with "
              "compatible tiles required",
    "CVK116": "stage geometry disagrees with shape propagation: re-plan "
              "at the plan's input_hw",
    "CVK201": "mutate guarded fields inside `with self.<lock>:` (or mark "
              "the helper `# holds-lock: <lock>` / suffix it `_locked`)",
    "CVK202": "lock-order cycle: acquire locks in one global order",
    "CVK203": "annotate shared fields with `# guarded-by: <lock>`",
    "CVK301": "read time through the injected Clock (runtime/clock.py)",
    "CVK302": "measure through the injected Clock (runtime/clock.py)",
    "CVK303": "convserve code must route time/sleep through a Clock",
    "CVK304": "fix the syntax error so the linter can parse the file",
    "CVK310": "declare supports() before execute() on the Algorithm",
    "CVK311": "this algorithm does not consume wt=: drop the argument",
    "CVK320": "move the pallas_call into a kernels/ package (or call "
              "the tile engine, repro.kernels.fused_tile)",
    "CVK330": "mutate metrics through the Telemetry/Tracer API "
              "(inc/set_gauge/observe, begin/end/instant) -- direct "
              "store pokes skip the lock and the freshness stamp",
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One coded finding."""

    code: str
    message: str
    severity: str = ERROR
    loc: str = ""  # "path:line" or "net/stage" coordinates
    hint: str = ""

    def __post_init__(self):
        if self.severity not in (ERROR, WARNING):
            raise ValueError(f"unknown severity {self.severity!r}")
        if not self.hint:
            object.__setattr__(self, "hint", HINTS.get(self.code, ""))

    def format(self) -> str:
        loc = f"{self.loc}: " if self.loc else ""
        tail = f"  [fix: {self.hint}]" if self.hint else ""
        return f"{loc}{self.code} {self.severity}: {self.message}{tail}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class CheckReport:
    """All findings of one analyzer run (or several merged runs)."""

    analyzer: str = ""
    diagnostics: List[Diagnostic] = dataclasses.field(default_factory=list)

    def add(self, diag: Diagnostic) -> None:
        self.diagnostics.append(diag)

    def extend(self, other: "CheckReport") -> None:
        self.diagnostics.extend(other.diagnostics)

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == ERROR]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == WARNING]

    @property
    def ok(self) -> bool:
        return not self.errors

    def codes(self) -> Tuple[str, ...]:
        return tuple(sorted({d.code for d in self.diagnostics}))

    def has(self, code: str) -> bool:
        return any(d.code == code for d in self.diagnostics)

    def format(self) -> str:
        if not self.diagnostics:
            return f"{self.analyzer or 'check'}: clean"
        return "\n".join(d.format() for d in self.diagnostics)

    def to_dict(self) -> dict:
        return {
            "analyzer": self.analyzer,
            "errors": len(self.errors),
            "warnings": len(self.warnings),
            "diagnostics": [d.to_dict() for d in self.diagnostics],
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1, sort_keys=True)


class ProgramError(ValueError):
    """A lowering/IR-structure failure carrying its diagnostic.

    Subclasses ValueError so callers (and tests) that matched the old
    inline ``raise ValueError(...)`` messages keep working; str() is the
    plain message, the code rides on `.diagnostic`.
    """

    def __init__(self, diagnostic: Diagnostic):
        super().__init__(diagnostic.message)
        self.diagnostic = diagnostic

    @property
    def code(self) -> str:
        return self.diagnostic.code


class VerificationError(ValueError):
    """A verifier rejection carrying the whole report (one or many
    diagnostics).  str() lists every error message, so substring matching
    against any individual finding still works."""

    def __init__(self, report: CheckReport):
        msgs = "; ".join(d.message for d in report.errors) or "verification failed"
        codes = ",".join(sorted({d.code for d in report.errors}))
        super().__init__(f"[{codes}] {msgs}" if codes else msgs)
        self.report = report

    @property
    def codes(self) -> Tuple[str, ...]:
        return tuple(sorted({d.code for d in self.report.errors}))


def program_error(code: str, message: str, *, loc: str = "") -> ProgramError:
    """Shorthand used by `program.lower` and the IR verifier."""
    return ProgramError(Diagnostic(code=code, message=message, loc=loc))
