"""Lock-discipline analyzer: guarded fields and the lock-order graph.

The runtime's concurrency contract is conventions: every shared
structure is mutated under its owner's lock, and locks nest in one
global order (`hot_swap` drains in-flight work under the pool lock
while touching the shared kernel cache — a second path acquiring those
two locks in the other order would deadlock).  This pass makes the
conventions machine-checked, driven by two comment registries in the
code itself:

  ``self._store = {}  # guarded-by: _lock``
      registers `_store` as guarded by `self._lock`; any mutation of a
      guarded field (assignment, augmented assignment, subscript/attr
      store, or a mutating method call like `.append`/`.pop`) outside a
      ``with self._lock:`` block is CVK201.

  ``# holds-lock: _lock``
      on a method's ``def`` line (or first body line) declares a
      caller-holds-lock helper — the analyzer treats the lock as held
      for the whole body.  Methods named ``*_locked`` and ``__init__``
      (construction precedes sharing) get the same waiver implicitly.

``threading.Condition(self._lock)`` aliases are resolved: holding the
condition IS holding the lock.  A class that owns a lock but annotates
no fields at all gets CVK203 (warning) — the registry must be complete
for CVK201 to mean anything.

The lock graph takes an edge held->acquired for every syntactic nesting
(``with self.a:`` inside ``with self.b:``) and, across objects, for
every call made under a lock to a method of a known lock-owning class
that itself acquires its lock (receivers resolved by attribute name
through ``self.x = OwnerClass(...)`` assignments).  Any cycle is CVK202.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.convserve.check.diagnostics import (
    WARNING,
    CheckReport,
    Diagnostic,
)

_GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_]\w*)")
_HOLDS_RE = re.compile(r"#\s*holds-lock:\s*([A-Za-z_]\w*)")
_LOCK_CTORS = {"Lock", "RLock"}

# method calls that mutate their receiver in place
_MUTATORS = {
    "append", "appendleft", "add", "update", "pop", "popleft", "popitem",
    "clear", "extend", "extendleft", "remove", "discard", "insert",
    "setdefault", "move_to_end", "sort", "reverse",
}


@dataclasses.dataclass
class ClassInfo:
    """Everything the analyzer knows about one class."""

    name: str
    path: str
    node: ast.ClassDef
    locks: Set[str] = dataclasses.field(default_factory=set)
    guarded: Dict[str, str] = dataclasses.field(default_factory=dict)
    cond_alias: Dict[str, str] = dataclasses.field(default_factory=dict)
    attr_types: Dict[str, str] = dataclasses.field(default_factory=dict)
    # methods that (syntactically) acquire one of the class's own locks
    acquires: Dict[str, Set[str]] = dataclasses.field(default_factory=dict)

    @property
    def owns_locks(self) -> bool:
        return bool(self.locks)

    def lock_of(self, attr: str) -> Optional[str]:
        """Resolve an attribute used in ``with self.<attr>:`` to the lock
        it holds (identity, or through a Condition alias)."""
        if attr in self.locks:
            return attr
        return self.cond_alias.get(attr)


def _call_name(node: ast.AST) -> str:
    """Dotted tail of a call target: `threading.RLock` -> 'RLock'."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _self_attr(node: ast.AST) -> Optional[str]:
    """`self.X` -> 'X' (else None)."""
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return node.attr
    return None


def _mutated_self_attr(target: ast.AST) -> Optional[str]:
    """The self-attribute a store-target mutates.

    `self.X = ..` and `self.X[k] = ..` and `self.X.attr = ..` all mutate
    (the object bound to) `self.X`; deeper chains resolve to the first
    self-attribute on the chain.
    """
    node = target
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        attr = _self_attr(node)
        if attr is not None:
            return attr
        node = node.value
    return None


def _scan_class(path: str, lines: List[str], node: ast.ClassDef) -> ClassInfo:
    info = ClassInfo(name=node.name, path=path, node=node)
    for stmt in ast.walk(node):
        if not isinstance(stmt, (ast.Assign, ast.AnnAssign)):
            continue
        targets = stmt.targets if isinstance(stmt, ast.Assign) else [stmt.target]
        value = stmt.value
        for tgt in targets:
            attr = _self_attr(tgt)
            if attr is None:
                continue
            if isinstance(value, ast.Call):
                ctor = _call_name(value.func)
                if ctor in _LOCK_CTORS:
                    info.locks.add(attr)
                elif ctor == "Condition":
                    # threading.Condition(self._lock): holding the
                    # condition is holding the lock
                    if value.args:
                        base = _self_attr(value.args[0])
                        if base is not None:
                            info.cond_alias[attr] = base
                    else:
                        info.locks.add(attr)  # owns its own lock
                elif ctor and ctor[0].isupper():
                    info.attr_types[attr] = ctor
            m = _GUARDED_RE.search(lines[stmt.lineno - 1])
            if m:
                info.guarded[attr] = m.group(1)
    # which methods acquire which of the class's own locks (any depth)
    for item in node.body:
        if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            held: Set[str] = set()
            for sub in ast.walk(item):
                if isinstance(sub, ast.With):
                    for w in sub.items:
                        attr = _self_attr(w.context_expr)
                        lock = info.lock_of(attr) if attr else None
                        if lock:
                            held.add(lock)
            if held:
                info.acquires[item.name] = held
    return info


def _holds_waiver(lines: List[str], fn: ast.FunctionDef) -> Optional[str]:
    """`# holds-lock: X` anywhere between the ``def`` line and the first
    body statement (so it can sit above or below a docstring header)."""
    last = fn.body[0].lineno if fn.body else fn.lineno
    for ln in range(fn.lineno - 1, min(last, len(lines))):
        m = _HOLDS_RE.search(lines[ln])
        if m:
            return m.group(1)
    return None


class _MethodChecker(ast.NodeVisitor):
    """Walks one method body tracking the set of held locks."""

    def __init__(
        self,
        report: CheckReport,
        info: ClassInfo,
        path: str,
        fn: ast.FunctionDef,
        classes: Dict[str, "ClassInfo"],
        attr_types: Dict[str, str],
        edges: Set[Tuple[str, str]],
        initial: Set[str],
    ):
        self.report = report
        self.info = info
        self.path = path
        self.fn = fn
        self.classes = classes
        self.attr_types = attr_types
        self.edges = edges
        self.held: Set[str] = set(initial)

    def _diag(self, code: str, msg: str, line: int, severity: str = "error"):
        self.report.add(
            Diagnostic(
                code=code, message=msg, severity=severity,
                loc=f"{self.path}:{line}",
            )
        )

    # -- lock acquisition -------------------------------------------------

    def visit_With(self, node: ast.With):
        acquired: List[str] = []
        for w in node.items:
            attr = _self_attr(w.context_expr)
            lock = self.info.lock_of(attr) if attr else None
            if lock:
                for h in self.held:
                    if h != lock:
                        self.edges.add(
                            (f"{self.info.name}.{h}",
                             f"{self.info.name}.{lock}")
                        )
                acquired.append(lock)
        self.held.update(acquired)
        for stmt in node.body:
            self.visit(stmt)
        self.held.difference_update(acquired)

    # -- mutations --------------------------------------------------------

    def _check_mutation(self, attr: str, line: int, what: str):
        lock = self.info.guarded.get(attr)
        if lock is None:
            return
        if lock not in self.held:
            self._diag(
                "CVK201",
                f"{self.info.name}.{attr} ({what}) is guarded by "
                f"{lock!r} but mutated outside `with self.{lock}:` "
                f"in {self.fn.name}()",
                line,
            )

    def visit_Assign(self, node: ast.Assign):
        for tgt in node.targets:
            attr = _mutated_self_attr(tgt)
            if attr is not None:
                self._check_mutation(attr, node.lineno, "assignment")
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        attr = _mutated_self_attr(node.target)
        if attr is not None:
            self._check_mutation(attr, node.lineno, "augmented assignment")
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        if node.value is not None:
            attr = _mutated_self_attr(node.target)
            if attr is not None:
                self._check_mutation(attr, node.lineno, "assignment")
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete):
        for tgt in node.targets:
            attr = _mutated_self_attr(tgt)
            if attr is not None:
                self._check_mutation(attr, node.lineno, "del")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        func = node.func
        if isinstance(func, ast.Attribute):
            # self.X.append(...) and self.X[k].append(...) mutate self.X
            if func.attr in _MUTATORS:
                attr = _mutated_self_attr(func.value)
                if attr is not None:
                    self._check_mutation(
                        attr, node.lineno, f".{func.attr}()"
                    )
            # cross-object acquisition: calling, under a held lock, a
            # method of a known lock-owning class that takes its lock
            if self.held:
                self._cross_edge(func)
        self.generic_visit(node)

    def _cross_edge(self, func: ast.Attribute):
        recv = func.value
        recv_attr = None
        if isinstance(recv, ast.Attribute):
            recv_attr = recv.attr
        elif isinstance(recv, ast.Name) and recv.id != "self":
            recv_attr = recv.id
        if recv_attr is None:
            return
        target_cls = self.attr_types.get(recv_attr)
        if target_cls is None:
            return
        target = self.classes.get(target_cls)
        if target is None or not target.owns_locks:
            return
        for lock in target.acquires.get(func.attr, ()):
            for h in self.held:
                self.edges.add(
                    (f"{self.info.name}.{h}", f"{target.name}.{lock}")
                )


def _find_cycles(edges: Set[Tuple[str, str]]) -> List[List[str]]:
    graph: Dict[str, List[str]] = {}
    for a, b in sorted(edges):
        graph.setdefault(a, []).append(b)
    cycles: List[List[str]] = []
    seen_cycles: Set[Tuple[str, ...]] = set()

    def dfs(node: str, stack: List[str], on_stack: Set[str]):
        for nxt in graph.get(node, ()):
            if nxt in on_stack:
                cyc = stack[stack.index(nxt):] + [nxt]
                key = tuple(sorted(set(cyc)))
                if key not in seen_cycles:
                    seen_cycles.add(key)
                    cycles.append(cyc)
            elif nxt not in visited:
                visited.add(nxt)
                stack.append(nxt)
                on_stack.add(nxt)
                dfs(nxt, stack, on_stack)
                on_stack.discard(nxt)
                stack.pop()

    visited: Set[str] = set()
    for start in sorted(graph):
        if start not in visited:
            visited.add(start)
            dfs(start, [start], {start})
    return cycles


def analyze_locks(paths) -> CheckReport:
    """Run the lock-discipline pass over every ``.py`` file under the
    given files/directories and return one merged report."""
    report = CheckReport(analyzer="locks")
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    classes: Dict[str, ClassInfo] = {}
    attr_types: Dict[str, str] = {}
    parsed: List[Tuple[str, List[str], ast.Module]] = []
    for f in files:
        try:
            src = f.read_text()
            tree = ast.parse(src, filename=str(f))
        except (OSError, SyntaxError) as e:
            report.add(
                Diagnostic(
                    code="CVK203", message=f"unparseable: {e}",
                    severity=WARNING, loc=str(f),
                )
            )
            continue
        lines = src.splitlines()
        parsed.append((str(f), lines, tree))
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                info = _scan_class(str(f), lines, node)
                classes[info.name] = info
                attr_types.update(info.attr_types)
    edges: Set[Tuple[str, str]] = set()
    for path, lines, _tree in parsed:
        for info in classes.values():
            if info.path != path:
                continue
            if info.owns_locks and not info.guarded:
                report.add(
                    Diagnostic(
                        code="CVK203",
                        message=f"class {info.name} owns lock(s) "
                        f"{sorted(info.locks)} but annotates no fields "
                        "with `# guarded-by:`",
                        severity=WARNING,
                        loc=f"{path}:{info.node.lineno}",
                    )
                )
            for item in info.node.body:
                if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if item.name == "__init__" or item.name.endswith("_locked"):
                    continue
                initial: Set[str] = set()
                waiver = _holds_waiver(lines, item)
                if waiver:
                    initial.add(info.lock_of(waiver) or waiver)
                checker = _MethodChecker(
                    report, info, path, item, classes, attr_types,
                    edges, initial,
                )
                for stmt in item.body:
                    checker.visit(stmt)
    for cyc in _find_cycles(edges):
        report.add(
            Diagnostic(
                code="CVK202",
                message="lock-order cycle: " + " -> ".join(cyc),
                loc=cyc[0],
            )
        )
    return report
