"""Planned-net executor: a thin driver over the `ExecProgram` IR.

The net -- every stage in its planned algorithm plus the epilogue glue
lowered into it -- runs as ONE XLA program per concrete input shape, so
serving a bucket is a single dispatch.  The executor interprets nothing
per layer: `program.lower` already resolved the net into stages, each
stage's elementwise glue is folded into the owning algorithm's task loop
(`Algorithm.fuse_epilogue`), and fusion-group stages run whole chains of
convs through `Algorithm.execute_staged` without materializing the full
intermediate activation.  Pre-transformed kernels come from the
`KernelCache` and enter the program as arguments (not constants): a new
bucket shape recompiles the program but reuses the cached transforms,
and the cache counters are visible per-request because the fetch happens
outside the jit boundary.

Ragged batches: images smaller than their bucket ride in zero-padded.
Zero padding alone is NOT enough for correctness -- the first conv writes
nonzero values into the padded margin (its taps reach real pixels), and
later same-padded convs bleed those back across the true-image edge.  So
when per-sample extents are supplied, every stage re-zeroes everything
beyond each sample's true extent before handing to the next (`sizes` is
data, not shape: masking costs one compare+multiply and never
recompiles).  Inside a fusion group the intermediate masks are applied
tile-position-aware (the epilogue callables carry the super-tile's row
offset), so fused serving stays exact.  With true dims divisible by the
pool windows, pooling windows never straddle the mask edge, which makes
the padded run exactly equal to running each image unpadded.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import registry
from repro.convserve.cache import KernelCache, weights_fingerprint
from repro.convserve.graph import NetSpec
from repro.convserve.obs.trace import (
    CAT_PROFILE,
    CAT_STAGE,
    NULL_TRACER,
    capture_tile_phases,
)
from repro.convserve.runtime.clock import Clock, RealClock
from repro.convserve.plan import NetPlan
from repro.convserve.program import EpilogueOp, ExecProgram, Stage, lower


def _mask_to_extent(
    x: jnp.ndarray, hs: jnp.ndarray, ws: jnp.ndarray, row0: int = 0
) -> jnp.ndarray:
    """Zero rows >= hs[b] and cols >= ws[b] of an NHWC batch.  `row0` is
    the global row offset of `x` when it is a super-tile of a larger
    tensor (fusion-group interiors)."""
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 2)
    keep = (rows < hs[:, None, None, None]) & (cols < ws[:, None, None, None])
    return jnp.where(keep, x, jnp.zeros((), x.dtype))


def _split_epilogue(
    ops: Tuple[EpilogueOp, ...]
) -> Tuple[Tuple[EpilogueOp, ...], Tuple[EpilogueOp, ...]]:
    """(elementwise prefix, rest): the prefix folds into the algorithm's
    task loop; pools (and anything after them) run on assembled output."""
    for i, op in enumerate(ops):
        if not op.elementwise:
            return ops[:i], ops[i:]
    return ops, ()


class _Extent:
    """Traced per-sample true extents (ragged batches), or inert when the
    batch is dense.  Geometry updates mirror the ops applied."""

    def __init__(self, hs, ws):
        self.hs, self.ws = hs, ws

    @property
    def live(self) -> bool:
        return self.hs is not None

    def after_conv(self, spec) -> "_Extent":
        if not self.live:
            return self
        return _Extent(
            (self.hs + 2 * spec.pad - spec.k) // spec.stride + 1,
            (self.ws + 2 * spec.pad - spec.k) // spec.stride + 1,
        )

    def after_pool(self, window: int) -> "_Extent":
        if not self.live:
            return self
        return _Extent(self.hs // window, self.ws // window)

    def mask(self, x, row0: int = 0):
        return _mask_to_extent(x, self.hs, self.ws, row0) if self.live else x


def _maxpool(x: jnp.ndarray, window: int) -> jnp.ndarray:
    b, h, w, c = x.shape
    v = window
    return x.reshape(b, h // v, v, w // v, v, c).max(axis=(2, 4))


class NetExecutor:
    """Runs a `NetSpec` lowered to an `ExecProgram` with cached kernel
    transforms."""

    def __init__(
        self,
        spec: NetSpec,
        weights: Dict[int, jnp.ndarray],
        plan: NetPlan,
        *,
        cache: Optional[KernelCache] = None,
        dtype=jnp.float32,
        clock: Optional[Clock] = None,
        tracer=None,
    ):
        missing = [i for i, _ in spec.param_layers() if i not in weights]
        if missing:
            raise ValueError(f"weights missing for parameter layers {missing}")
        # lower() validates plan-vs-spec coverage, geometry, and the
        # fusion groups' structural legality
        self.program: ExecProgram = lower(spec, plan)
        self.spec = spec
        self.plan = plan
        self.dtype = jnp.dtype(dtype)
        self.cache = cache if cache is not None else KernelCache()
        self.clock = clock or RealClock()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.weights = {i: jnp.asarray(w, dtype) for i, w in weights.items()}
        # hash once here, not per request: the fingerprint keys the cache
        # to these parameter values (shared caches stay collision-free)
        self._weights_fp = {
            i: weights_fingerprint(w) for i, w in self.weights.items()
        }
        self._plans = {p.layer: p for p in plan.layers}
        self._compiled: Dict[tuple, object] = {}
        self.calls = 0  # batches served through __call__
        self.images = 0  # batch rows served (padding rows included)

    @property
    def compile_count(self) -> int:
        """How many programs have been lowered (bounded by bucketing)."""
        return len(self._compiled)

    def compiles_by_bucket(self) -> Dict[int, int]:
        """Compiled-program count per spatial bucket (input H)."""
        out: Dict[int, int] = {}
        for shape, _ in self._compiled:
            out[shape[1]] = out.get(shape[1], 0) + 1
        return out

    def cache_keys(self) -> list:
        """Every `KernelCache` key this executor's plan can touch (one
        per transform-consuming layer).  The hot-swap path diffs the
        outgoing and incoming executors' key sets to invalidate only
        what the new program no longer needs."""
        return [
            KernelCache.key(
                self.plan.net, p, self.dtype, self._weights_fp[i]
            )
            for i, p in self._plans.items()
            if registry.get(p.algo).consumes_wt
        ]

    def stats(self) -> dict:
        """Compile counts + kernel-cache counters, one dict -- the single
        source the engine and serving front-ends extend."""
        return {
            "compiled_programs": self.compile_count,
            "compiles_per_bucket": self.compiles_by_bucket(),
            "calls": self.calls,
            "images": self.images,
            "cache": self.cache.stats(),
        }

    # ------------------------------------------------------ stage driver

    def _elementwise_fn(self, ops: Tuple[EpilogueOp, ...], ws):
        """Fold bias/relu ops into a structured `registry.ElementwiseOps`
        (None when empty): still a plain ``y -> y`` callable, but fused
        algorithms can read its static op list and fold the glue into
        their kernel's scatter phase instead of a separate pass."""
        if not ops:
            return None
        return registry.ElementwiseOps(
            [
                ("bias", ws[op.layer]) if op.kind == "bias" else ("relu",)
                for op in ops
            ]
        )

    def _apply_tail(
        self, x, ops: Tuple[EpilogueOp, ...], ext: _Extent, ws
    ) -> Tuple[jnp.ndarray, _Extent]:
        """Pools and any post-pool elementwise ops, on assembled output.
        True dims divide the pool windows (validated at admission), so no
        window straddles the mask edge; masked stays masked garbage-free
        after the end-of-stage re-mask."""
        for op in ops:
            if op.kind == "maxpool":
                x = _maxpool(x, op.window)
                ext = ext.after_pool(op.window)
            elif op.kind == "bias":
                x = x + ws[op.layer]
            else:
                x = jax.nn.relu(x)
        return x, ext

    def _run_single(self, stage: Stage, x, ws, wts, ext: _Extent):
        u = stage.units[0]
        aplan = u.plan.algo_plan()
        alg = registry.get(aplan.algo)
        pre, tail = _split_epilogue(u.epilogue)
        runner = alg.fuse_epilogue(aplan, self._elementwise_fn(pre, ws))
        x = runner(x, ws[u.layer], wts.get(u.layer))
        ext = ext.after_conv(aplan.spec)
        x, ext = self._apply_tail(x, tail, ext, ws)
        return ext.mask(x), ext

    def _run_fused(self, stage: Stage, x, ws, wts, ext: _Extent):
        chain: List[registry.ChainLink] = []
        cur = ext
        tail_ops: Tuple[EpilogueOp, ...] = ()
        for j, u in enumerate(stage.units):
            aplan = u.plan.algo_plan()
            nxt = cur.after_conv(aplan.spec)
            last = j == len(stage.units) - 1
            pre, tail = _split_epilogue(u.epilogue)
            if last:
                tail_ops = tail
            # elementwise glue (bias/relu) folds into the owning
            # algorithm's task loop inside the chain, exactly as in a
            # single stage; only the position-dependent extent re-mask
            # (ragged batches) runs on the assembled intermediate --
            # tile-position-aware so the next conv of the chain never
            # taps across a true-image edge
            epi = (
                (lambda y, row0, _e=nxt: _e.mask(y, row0))
                if nxt.live and not last
                else None
            )
            chain.append(
                registry.ChainLink(
                    w=ws[u.layer], wt=wts.get(u.layer), plan=aplan,
                    epilogue=epi,
                    elementwise=self._elementwise_fn(pre, ws),
                )
            )
            cur = nxt
        alg = registry.get(stage.units[0].plan.algo)
        x = alg.execute_staged(x, chain, tile_rows=stage.tile_rows)
        x, cur = self._apply_tail(x, tail_ops, cur, ws)
        return cur.mask(x), cur

    def _forward(self, x, ws, wts, sizes):
        ext = _Extent(
            sizes[:, 0] if sizes is not None else None,
            sizes[:, 1] if sizes is not None else None,
        )
        x = ext.mask(x)
        if self.program.prologue:
            x, ext = self._apply_tail(x, self.program.prologue, ext, ws)
            x = ext.mask(x)
        for stage in self.program.stages:
            run = self._run_fused if stage.fused else self._run_single
            x, ext = run(stage, x, ws, wts, ext)
        return x

    # -------------------------------------------------------- public API

    def _fetch_transforms(self) -> Dict[int, jnp.ndarray]:
        """Per-request cache fetch: first request per layer transforms and
        stores; later requests (any bucket) count as hits.  The cache
        itself knows (via the registry) which algorithms have nothing to
        prepare and returns None for those."""
        wts = {}
        for i, _ in self.spec.conv_layers():
            wt = self.cache.get(
                self.plan.net, self._plans[i], self.weights[i], self.dtype,
                w_fp=self._weights_fp[i],
            )
            if wt is not None:
                wts[i] = wt
        return wts

    def _validate_call(self, x, sizes):
        if x.ndim != 4:
            raise ValueError(f"expected NHWC input, got shape {x.shape}")
        self.spec.infer_shapes(x.shape[1], x.shape[2], x.shape[3])  # validate
        if sizes is not None:
            sizes = jnp.asarray(sizes, jnp.int32)
            if sizes.shape != (x.shape[0], 2):
                raise ValueError(
                    f"sizes shape {sizes.shape} != ({x.shape[0]}, 2)"
                )
        return sizes

    def __call__(
        self, x: jnp.ndarray, sizes: Optional[jnp.ndarray] = None
    ) -> jnp.ndarray:
        """Run one batch.

        x: (B, H, W, C); defines the bucket.  sizes: optional (B, 2) int32
        true (h, w) per sample for ragged batches -- samples are zeroed
        beyond their true extent stage by stage so padded serving is
        exact (see module docstring).
        """
        x = jnp.asarray(x, self.dtype)
        sizes = self._validate_call(x, sizes)
        wts = self._fetch_transforms()
        key = (tuple(x.shape), sizes is not None)
        fn = self._compiled.get(key)
        if fn is None:
            fn = jax.jit(self._forward)
            self._compiled[key] = fn
        self.calls += 1
        self.images += int(x.shape[0])
        return fn(x, self.weights, wts, sizes)

    def profile_stages(
        self, x: jnp.ndarray, sizes: Optional[jnp.ndarray] = None
    ) -> List[Tuple[str, float]]:
        """Per-stage wall times (seconds), each stage jitted and timed
        separately -- the benchmark surface; serving always runs the
        whole net as one program."""
        x = jnp.asarray(x, self.dtype)
        sizes = self._validate_call(x, sizes)
        wts = self._fetch_transforms()
        b_h, b_w, b_c = int(x.shape[1]), int(x.shape[2]), int(x.shape[3])
        ext0 = _Extent(
            sizes[:, 0] if sizes is not None else None,
            sizes[:, 1] if sizes is not None else None,
        )
        x = ext0.mask(x)
        if self.program.prologue:  # mirror _forward: pre-conv glue first
            x, ext0 = self._apply_tail(
                x, self.program.prologue, ext0, self.weights
            )
            x = ext0.mask(x)
        x = jax.block_until_ready(x)
        rows: List[Tuple[str, float]] = []
        tr = self.tracer
        with tr.span(
            "profile_stages", CAT_PROFILE,
            net=self.plan.net, bucket=b_h, batch=int(x.shape[0]),
        ):
            for stage in self.program.stages:
                run = self._run_fused if stage.fused else self._run_single

                def step(x, ws, wts, hs, ws_cols, _run=run, _stage=stage):
                    y, ext = _run(_stage, x, ws, wts, _Extent(hs, ws_cols))
                    return y, ext.hs, ext.ws

                fn = jax.jit(step)
                args = (x, self.weights, wts, ext0.hs, ext0.ws)
                with tr.span(
                    f"stage:{stage.label}", CAT_STAGE,
                    stage=stage.label, fused=stage.fused,
                ):
                    # the phase hook fires while jit traces the stage --
                    # the warm-up compile below announces gather/GEMM/mix
                    # phases as instants nested under this stage span
                    with capture_tile_phases(tr, stage=stage.label):
                        jax.block_until_ready(fn(*args))  # compile untimed
                    t0 = self.clock.now()
                    y, hs, ws_cols = fn(*args)
                    x = jax.block_until_ready(y)
                    dt = self.clock.now() - t0
                    rows.append((stage.label, dt))
                ext0 = _Extent(hs, ws_cols)
        want = self.spec.out_shape(b_h, b_w, b_c)
        if tuple(x.shape[1:]) != want:
            raise AssertionError(
                f"profiled stage chain produced {tuple(x.shape[1:])}, net "
                f"expects {want} -- stage driver out of sync with _forward"
            )
        return rows
