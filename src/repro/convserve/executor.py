"""Planned-net executor: one jitted program per input bucket.

The whole net -- every conv in its planned algorithm plus the pointwise
glue -- lowers as ONE XLA program per concrete input shape, so serving a
bucket is a single dispatch.  Pre-transformed kernels come from the
`KernelCache` and enter the program as arguments (not constants): a new
bucket shape recompiles the program but reuses the cached transforms,
and the cache counters are visible per-request because the fetch happens
outside the jit boundary.  The executor never names an algorithm: which
layers have cacheable transforms, and how each conv runs, is decided by
the registry through the layer's plan.

Ragged batches: images smaller than their bucket ride in zero-padded.
Zero padding alone is NOT enough for correctness -- the first conv writes
nonzero values into the padded margin (its taps reach real pixels), and
later same-padded convs bleed those back across the true-image edge.  So
when per-sample extents are supplied, the executor re-zeroes everything
beyond each sample's true extent after every conv (`sizes` is data, not
shape: masking costs one compare+multiply and never recompiles).  With
true dims divisible by the pool windows, pooling windows never straddle
the mask edge, which makes the padded run exactly equal to running each
image unpadded.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.conv import conv2d
from repro.convserve.cache import KernelCache, weights_fingerprint
from repro.convserve.graph import NetSpec
from repro.convserve.plan import NetPlan


def _mask_to_extent(x: jnp.ndarray, hs: jnp.ndarray, ws: jnp.ndarray):
    """Zero rows >= hs[b] and cols >= ws[b] of an NHWC batch."""
    rows = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 2)
    keep = (rows < hs[:, None, None, None]) & (cols < ws[:, None, None, None])
    return jnp.where(keep, x, jnp.zeros((), x.dtype))


class NetExecutor:
    """Runs a `NetSpec` under a `NetPlan` with cached kernel transforms."""

    def __init__(
        self,
        spec: NetSpec,
        weights: Dict[int, jnp.ndarray],
        plan: NetPlan,
        *,
        cache: Optional[KernelCache] = None,
        dtype=jnp.float32,
    ):
        missing = [i for i, _ in spec.conv_layers() if i not in weights]
        if missing:
            raise ValueError(f"weights missing for conv layers {missing}")
        if plan.net != spec.name:
            raise ValueError(
                f"plan is for net {plan.net!r}, spec is {spec.name!r}"
            )
        plans = {p.layer: p for p in plan.layers}
        for i, layer in spec.conv_layers():
            p = plans.get(i)
            if p is None:
                raise ValueError(f"plan missing conv layer {i}")
            s = p.spec
            got = (s.c_in, s.c_out, s.k, s.pad, s.stride, s.groups)
            want = (
                layer.c_in, layer.c_out, layer.k, layer.pad,
                layer.stride, layer.groups,
            )
            if got != want:
                raise ValueError(
                    f"plan layer {i} geometry {got} != spec {want} "
                    "(stale plan file?)"
                )
        self.spec = spec
        self.plan = plan
        self.dtype = jnp.dtype(dtype)
        self.cache = cache if cache is not None else KernelCache()
        self.weights = {i: jnp.asarray(w, dtype) for i, w in weights.items()}
        # hash once here, not per request: the fingerprint keys the cache
        # to these parameter values (shared caches stay collision-free)
        self._weights_fp = {
            i: weights_fingerprint(w) for i, w in self.weights.items()
        }
        self._plans = plans
        self._compiled: Dict[tuple, object] = {}

    @property
    def compile_count(self) -> int:
        """How many programs have been lowered (bounded by bucketing)."""
        return len(self._compiled)

    def _forward(self, x, ws, wts, sizes):
        if sizes is not None:
            hs, wcols = sizes[:, 0], sizes[:, 1]
            x = _mask_to_extent(x, hs, wcols)
        for i, layer in enumerate(self.spec.layers):
            if layer.kind == "conv":
                x = conv2d(x, ws[i], plan=self._plans[i], wt=wts.get(i))
                if sizes is not None:
                    hs = (hs + 2 * layer.pad - layer.k) // layer.stride + 1
                    wcols = (
                        wcols + 2 * layer.pad - layer.k
                    ) // layer.stride + 1
                    x = _mask_to_extent(x, hs, wcols)
            elif layer.kind == "relu":
                x = jax.nn.relu(x)  # relu(0) == 0: the mask survives
            elif layer.kind == "maxpool":
                b, h, w, c = x.shape
                v = layer.window
                x = x.reshape(b, h // v, v, w // v, v, c).max(axis=(2, 4))
                if sizes is not None:
                    # true dims divide v (validated at admission), so no
                    # window straddles the mask edge; masked stays masked
                    hs, wcols = hs // v, wcols // v
            else:
                raise AssertionError(layer.kind)
        return x

    def _fetch_transforms(self) -> Dict[int, jnp.ndarray]:
        """Per-request cache fetch: first request per layer transforms and
        stores; later requests (any bucket) count as hits.  The cache
        itself knows (via the registry) which algorithms have nothing to
        prepare and returns None for those."""
        wts = {}
        for i, _ in self.spec.conv_layers():
            wt = self.cache.get(
                self.plan.net, self._plans[i], self.weights[i], self.dtype,
                w_fp=self._weights_fp[i],
            )
            if wt is not None:
                wts[i] = wt
        return wts

    def __call__(
        self, x: jnp.ndarray, sizes: Optional[jnp.ndarray] = None
    ) -> jnp.ndarray:
        """Run one batch.

        x: (B, H, W, C); defines the bucket.  sizes: optional (B, 2) int32
        true (h, w) per sample for ragged batches -- samples are zeroed
        beyond their true extent after every conv so padded serving is
        exact (see module docstring).
        """
        if x.ndim != 4:
            raise ValueError(f"expected NHWC input, got shape {x.shape}")
        x = jnp.asarray(x, self.dtype)
        self.spec.infer_shapes(x.shape[1], x.shape[2], x.shape[3])  # validate
        if sizes is not None:
            sizes = jnp.asarray(sizes, jnp.int32)
            if sizes.shape != (x.shape[0], 2):
                raise ValueError(
                    f"sizes shape {sizes.shape} != ({x.shape[0]}, 2)"
                )
        wts = self._fetch_transforms()
        key = (tuple(x.shape), sizes is not None)
        fn = self._compiled.get(key)
        if fn is None:
            fn = jax.jit(self._forward)
            self._compiled[key] = fn
        return fn(x, self.weights, wts, sizes)
