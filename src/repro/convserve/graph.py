"""ConvNet layer-graph description for the serving engine.

A net is a sequential tuple of `LayerSpec`s -- convolutions interleaved
with the pointwise/pooling glue of the VGG/ResNet-stem family.  The spec
is pure geometry: weights live beside it (`init_weights`) so the same
spec can be planned once and served with any parameter set.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.conv import conv2d_direct
from repro.core.registry import ConvSpec


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer.  kind: "conv" | "bias" | "relu" | "maxpool"."""

    kind: str
    c_in: int = 0
    c_out: int = 0
    k: int = 3
    pad: int = 1
    stride: int = 1  # conv only
    groups: int = 1  # conv only (grouped / ResNeXt-style)
    window: int = 2  # maxpool only

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "LayerSpec":
        return LayerSpec(**d)


def conv(
    c_in: int, c_out: int, k: int = 3, pad: int = -1,
    stride: int = 1, groups: int = 1,
) -> LayerSpec:
    """3x3-style conv layer; pad defaults to "same" (k // 2)."""
    return LayerSpec(
        kind="conv", c_in=c_in, c_out=c_out, k=k,
        pad=(k // 2 if pad < 0 else pad), stride=stride, groups=groups,
    )


def bias(c: int) -> LayerSpec:
    """Per-channel bias add; owns a (C,) weight vector like convs own
    kernels (the classic conv+bias+relu epilogue of inference graphs)."""
    return LayerSpec(kind="bias", c_in=c, c_out=c)


def relu() -> LayerSpec:
    return LayerSpec(kind="relu")


def maxpool(window: int = 2) -> LayerSpec:
    return LayerSpec(kind="maxpool", window=window)


@dataclasses.dataclass(frozen=True)
class NetSpec:
    """A sequential ConvNet: name + layer tuple."""

    name: str
    layers: Tuple[LayerSpec, ...]

    def conv_layers(self) -> List[Tuple[int, LayerSpec]]:
        return [(i, l) for i, l in enumerate(self.layers) if l.kind == "conv"]

    def param_layers(self) -> List[Tuple[int, LayerSpec]]:
        """Layers that own weights: convs (HWIO kernels) + biases ((C,))."""
        return [
            (i, l)
            for i, l in enumerate(self.layers)
            if l.kind in ("conv", "bias")
        ]

    @property
    def pool_factor(self) -> int:
        """Product of pooling windows: input dims must divide this for the
        reshape-based pooling in the executor."""
        f = 1
        for l in self.layers:
            if l.kind == "maxpool":
                f *= l.window
        return f

    @property
    def downsample_factor(self) -> int:
        """The net's total spatial downsampling: pooling windows AND conv
        strides.  Serving buckets must survive this whole chain -- a
        stride-2 net halves extents before its pools ever see them, so
        validating against `pool_factor` alone admits buckets that break
        at runtime."""
        f = 1
        for l in self.layers:
            if l.kind == "maxpool":
                f *= l.window
            elif l.kind == "conv":
                f *= l.stride
        return f

    def infer_shapes(self, h: int, w: int, c: int) -> List[Tuple[int, int, int]]:
        """(H, W, C) after each layer; validates channel wiring."""
        shapes = []
        for i, l in enumerate(self.layers):
            if l.kind == "conv":
                if l.c_in != c:
                    raise ValueError(
                        f"layer {i}: conv expects C={l.c_in}, got {c}"
                    )
                try:
                    # ConvSpec owns conv geometry: output dims, groups
                    # divisibility, kernel-vs-padded-input validation
                    h, w = ConvSpec(
                        h=h, w=w, c_in=l.c_in, c_out=l.c_out, k=l.k,
                        pad=l.pad, stride=l.stride, groups=l.groups,
                    ).out_hw
                except ValueError as e:
                    raise ValueError(f"layer {i}: {e}") from None
                c = l.c_out
            elif l.kind == "maxpool":
                if h % l.window or w % l.window:
                    raise ValueError(
                        f"layer {i}: pool window {l.window} does not divide "
                        f"({h}, {w})"
                    )
                h, w = h // l.window, w // l.window
            elif l.kind == "bias":
                if l.c_in != c:
                    raise ValueError(
                        f"layer {i}: bias expects C={l.c_in}, got {c}"
                    )
            elif l.kind != "relu":
                raise ValueError(f"layer {i}: unknown kind {l.kind!r}")
            shapes.append((h, w, c))
        return shapes

    def out_shape(self, h: int, w: int, c: int) -> Tuple[int, int, int]:
        return self.infer_shapes(h, w, c)[-1]

    def to_dict(self) -> dict:
        return {"name": self.name, "layers": [l.to_dict() for l in self.layers]}

    @staticmethod
    def from_dict(d: dict) -> "NetSpec":
        return NetSpec(
            name=d["name"],
            layers=tuple(LayerSpec.from_dict(l) for l in d["layers"]),
        )


def init_weights(
    spec: NetSpec, seed: int = 0, dtype=jnp.float32, scale: float = 0.05
) -> Dict[int, jnp.ndarray]:
    """Weights for every parameter layer, keyed by layer index: HWIO
    kernels for convs, (C,) vectors for biases."""
    rng = np.random.default_rng(seed)
    ws: Dict[int, jnp.ndarray] = {}
    for i, l in spec.param_layers():
        if l.kind == "bias":
            ws[i] = jnp.asarray(rng.standard_normal((l.c_in,)) * scale, dtype)
        else:
            # HWIO with grouping: the kernel sees C/groups input channels
            ws[i] = jnp.asarray(
                rng.standard_normal((l.k, l.k, l.c_in // l.groups, l.c_out))
                * scale,
                dtype,
            )
    return ws


def run_direct(
    spec: NetSpec, weights: Dict[int, jnp.ndarray], x: jnp.ndarray
) -> jnp.ndarray:
    """Reference execution with XLA's direct convolution everywhere.

    The single source of the net's semantics outside the planned executor:
    the oracle that examples, benchmarks, and tests compare against.
    """
    for i, layer in enumerate(spec.layers):
        if layer.kind == "conv":
            x = conv2d_direct(
                x, weights[i],
                pad=layer.pad, stride=layer.stride, groups=layer.groups,
            )
        elif layer.kind == "bias":
            x = x + weights[i]
        elif layer.kind == "relu":
            x = jax.nn.relu(x)
        elif layer.kind == "maxpool":
            b, h, w, c = x.shape
            v = layer.window
            x = x.reshape(b, h // v, v, w // v, v, c).max(axis=(2, 4))
        else:
            raise ValueError(f"layer {i}: unknown kind {layer.kind!r}")
    return x
