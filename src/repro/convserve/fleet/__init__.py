"""Elastic fleet serving: sharded waves, autoscaling, fault tolerance.

The fleet subsystem turns the single-pool serving runtime into a
distributed one:

  * `sharding` -- split one wave's rows across a `jax` mesh and decide,
    per layer, whether pre-transformed kernels replicate or shard;
  * `pool` -- an elastic replica pool with lifecycle states, a
    discrete-event simulation core, injectable faults, and health
    probes that detect (and repair) shared-cache corruption;
  * `autoscaler` -- the telemetry-driven controller growing and
    shrinking the fleet with hysteresis, cooldown, and an admission cap
    while newcomers warm;
  * `service` -- `FleetRuntime`, the `ServeRuntime` subclass that runs
    the whole thing on a simulated or real clock.
"""

from repro.convserve.fleet.autoscaler import (  # noqa: F401
    Autoscaler,
    AutoscalerConfig,
)
from repro.convserve.fleet.pool import (  # noqa: F401
    DRAINING,
    ElasticPool,
    FAILED,
    FixedServiceModel,
    LOSS_NO_HEALTHY_REPLICA,
    LOSS_REASONS,
    LOSS_RETRIES_EXHAUSTED,
    QUARANTINED,
    READY,
    RETIRED,
    Replica,
    STARTING,
    WaveLoss,
)
from repro.convserve.fleet.service import FleetRuntime  # noqa: F401
from repro.convserve.fleet.sharding import (  # noqa: F401
    REPLICATE,
    SHARD,
    ShardedWaveExecutor,
    apply_placement,
    plan_weight_placement,
    probe_image,
    shard_bounds,
)
