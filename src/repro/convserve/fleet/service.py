"""The fleet serving runtime: `ServeRuntime` over an `ElasticPool`.

`FleetRuntime` is a subclass, not a fork: admission, wave formation,
telemetry, and the results contract are inherited.  What changes:

  * **the loop is a discrete-event simulation** under a `SimClock`:
    instead of sleeping on condition variables (which never fire when
    time is simulated), `run_until`/`drain` step the clock exactly onto
    the next scheduled instant -- a wave completion, a replica becoming
    ready, an injected fault, a health probe, an autoscaler tick, or a
    bucket's deadline flush -- and let the pool resolve it.  A simulated
    million-user day runs in seconds of wall time with exact latency
    stamps.  Under a `RealClock` everything delegates to the parent
    (the elastic pool executes inline).
  * **admission knows about elasticity**: while a scale-up's newcomers
    warm, requests above what the READY replicas can drain are rejected
    with the reason-coded ``scaling`` rejection instead of queueing for
    replicas that do not exist yet.
  * **loss is a first-class outcome**: a wave the pool could not serve
    (crashed replicas, retries exhausted) resolves to `WaveLoss`; the
    runtime records every rider's rid under `losses[rid] = reason` and
    counts ``lost``/``lost.<reason>`` telemetry, so the accounting
    invariant *admitted == served + lost* holds under any fault
    schedule -- no request ever vanishes.
  * **scale events bracket the adapt loop**: the autoscaler's
    start/end hooks pause and resume shadow replanning traffic, so
    measured evidence never straddles a fleet reshape.
"""

from __future__ import annotations

import math
from typing import Dict, Optional

import numpy as np

from repro.convserve.fleet.autoscaler import Autoscaler, AutoscalerConfig
from repro.convserve.fleet.pool import ElasticPool, WaveLoss
from repro.convserve.obs.trace import CAT_WAVE, attach as attach_tracer
from repro.convserve.runtime.clock import Clock
from repro.convserve.runtime.queueing import (
    REJECT_SCALING,
    Rejection,
    STANDARD,
)
from repro.convserve.runtime.replicas import WaveResult
from repro.convserve.runtime.scheduler import RuntimeConfig
from repro.convserve.runtime.service import ServeRuntime
from repro.convserve.runtime.telemetry import Telemetry


class FleetRuntime(ServeRuntime):
    """Elastic, fault-tolerant serving over a replica fleet."""

    def __init__(
        self,
        pool: ElasticPool,
        cfg: RuntimeConfig,
        *,
        clock: Optional[Clock] = None,
        telemetry: Optional[Telemetry] = None,
        autoscaler: Optional[AutoscalerConfig] = None,
        adapt=None,
        tracer=None,
        recorder=None,
    ):
        super().__init__(
            pool, cfg, clock=clock, telemetry=telemetry,
            tracer=tracer, recorder=recorder,
        )
        self.pool: ElasticPool = pool
        if self.tracer.active and not pool.tracer.active:
            # the pool emits the lifecycle/fault/loss instants; share the
            # runtime's ring unless the pool was given its own tracer
            pool.tracer = self.tracer
            for ex in pool.executors:
                attach_tracer(ex, self.tracer)
        self.adapt = adapt  # a replanner exposing pause()/resume()
        self.losses: Dict[int, str] = {}  # rid -> reason; guarded-by: _lock
        self.autoscaler = (
            Autoscaler(
                pool,
                autoscaler,
                clock=self.clock,
                queue_depth_fn=self.scheduler.depth,
                on_scale_start=self._on_scale_start,
                on_scale_end=self._on_scale_end,
                telemetry=self.telemetry,
                tracer=self.tracer,
            )
            if autoscaler is not None
            else None
        )

    # -------------------------------------------------- scale events

    def _on_scale_start(self, action: str) -> None:
        self.telemetry.inc("scale_events")
        self.telemetry.inc(f"scale_events.{action}")
        if self.adapt is not None:
            self.adapt.pause(reason=f"scale_event:{action}")

    def _on_scale_end(self) -> None:
        self.telemetry.inc("scale_events.settled")
        if self.adapt is not None:
            self.adapt.resume()

    # ------------------------------------------------------ admission

    def submit(
        self,
        image: np.ndarray,
        *,
        rid: Optional[int] = None,
        priority: int = STANDARD,
        deadline_s: Optional[float] = None,
    ) -> Optional[Rejection]:
        auto = self.autoscaler
        if (
            auto is not None
            and auto.scaling(self.clock.now())
            and self.scheduler.depth() >= auto.admission_cap()
        ):
            with self._lock:
                if rid is None:
                    rid = self._next_rid
                self._next_rid = max(self._next_rid, rid) + 1
            rej = Rejection(
                rid=rid,
                reason=REJECT_SCALING,
                detail=(
                    "scale-up in progress: queue at the READY replicas' "
                    f"admission cap ({auto.admission_cap():.0f})"
                ),
            )
            self.telemetry.inc("rejected")
            self.telemetry.inc(f"rejected.{REJECT_SCALING}")
            with self._lock:
                self.rejections[rid] = rej
            return rej
        return super().submit(
            image, rid=rid, priority=priority, deadline_s=deadline_s
        )

    # ------------------------------------------------------- dispatch

    def poll(self) -> int:
        """Resolve due pool events and run the autoscaler before
        dispatching -- completions free replicas and scale decisions
        change capacity, and both must be visible to the capacity gate."""
        now = self.clock.now()
        self.pool.advance(now)
        if self.autoscaler is not None:
            self.autoscaler.tick(now)
        return super().poll()

    def _on_done(self, fut) -> None:
        exc = fut.exception()
        if isinstance(exc, WaveLoss):
            wave = exc.wave
            self.telemetry.inc("lost_waves")
            self.telemetry.inc(f"lost.{exc.reason}")
            self.telemetry.inc("lost_images", len(wave.requests))
            self._close_wave_span(fut, wave, lost=True, reason=exc.reason)
            self.tracer.instant(
                "wave.lost", CAT_WAVE, reason=exc.reason,
                n=len(wave.requests),
            )
            with self._done_cv:
                for r in wave.requests:
                    self.losses[r.rid] = exc.reason
                self._outstanding -= 1
                self._done_cv.notify_all()
            # close the riders' request spans: the loss IS their outcome
            for r in wave.requests:
                with self._lock:
                    rsid = self._req_spans.pop(r.rid, 0)
                self.tracer.end(rsid, lost=True, reason=exc.reason)
            if self.recorder is not None:
                self.recorder.trip(
                    "wave_loss", loss=exc.reason, n=len(wave.requests)
                )
            return
        super()._on_done(fut)
        if exc is None and self.autoscaler is not None:
            res: WaveResult = fut.result()
            done = self.clock.now()
            slack = min(
                (r.deadline - done for r in res.wave.requests
                 if not math.isinf(r.deadline)),
                default=None,
            )
            if slack is not None:
                self.autoscaler.note_slack(slack)

    # ------------------------------------------------------- the loop

    def _next_wake(self, now: float, t_target: float) -> float:
        """Earliest strictly-future scheduled instant: pool event
        (completion / replica-ready / fault / probe), autoscaler tick,
        or bucket deadline flush -- bounded by the target."""
        cands = [self.scheduler.next_event(now), self.pool.next_event()]
        if self.autoscaler is not None:
            cands.append(self.autoscaler.next_tick())
        future = [t for t in cands if t > now and not math.isinf(t)]
        return min(future, default=t_target) if t_target >= now else now

    def run_until(self, t_target: float) -> None:
        if self.clock.realtime:
            return super().run_until(t_target)
        while True:
            self.poll()
            now = self.clock.now()
            if now >= t_target:
                return
            wake = min(self._next_wake(now, t_target), t_target)
            if wake > now:
                self.clock.sleep(wake - now)
            # wake == now: an instant just crossed; loop and poll again

    def drain(self) -> None:
        if self.clock.realtime:
            return super().drain()
        while True:
            self.poll()
            now = self.clock.now()
            if self.pool.has_capacity() and self.scheduler.depth():
                wave = self.scheduler.drain_wave(now)
                if wave is not None:
                    self._dispatch(wave)
                    continue
            with self._done_cv:
                outstanding = self._outstanding
            if not outstanding and not self.scheduler.depth():
                return
            nxt = self.pool.next_event()
            if self.autoscaler is not None:
                nxt = min(nxt, self.autoscaler.next_tick())
            if math.isinf(nxt):
                # nothing scheduled can ever free capacity: the queued
                # waves are doomed -- dispatch them so they resolve to
                # reason-coded losses instead of hanging the drain
                if self.scheduler.depth():
                    wave = self.scheduler.drain_wave(now)
                    if wave is not None:
                        self._dispatch(wave)
                        continue
                self.pool.advance(float("inf"))
                continue
            if nxt > now:
                self.clock.sleep(nxt - now)

    # ---------------------------------------------------------- stats

    def stats(self, profile_bucket: Optional[int] = None) -> dict:
        doc = super().stats(profile_bucket)
        if self.autoscaler is not None:
            doc["autoscaler"] = self.autoscaler.stats()
        with self._lock:
            by_reason: Dict[str, int] = {}
            for reason in self.losses.values():
                by_reason[reason] = by_reason.get(reason, 0) + 1
            # always present (even all-zero) so the document schema is
            # stable across scale events and fault drills
            doc["losses"] = {
                "requests": len(self.losses),
                "by_reason": by_reason,
            }
        return doc
