"""Elastic replica pool: a discrete-event fleet with faults and probes.

`ReplicaPool` (runtime/replicas.py) is a fixed set of executors on a
thread pool; an elastic fleet needs three things it cannot express:

  * **replica lifecycle** -- replicas are born (STARTING, compile +
    warm for `startup_s` of clock time before taking traffic), serve
    (READY), leave gracefully (DRAINING: no new waves, in-flight wave
    finishes, then RETIRED), or leave badly (FAILED on an injected
    crash, QUARANTINED when health probes catch a slow or corrupted
    replica);
  * **simulated occupancy** -- under a `SimClock`, wave outputs are
    computed by the real executors (instant in simulated time) while a
    deterministic `service model` charges the replica `service_s` of
    *simulated* busy time.  Completions are heap events; `advance(now)`
    resolves every event at or before `now`, and `next_event()` lets
    the fleet runtime step the clock exactly onto the next completion,
    replica-ready instant, fault, or probe -- so a million-user day
    runs in seconds of wall time with exact latency stamps.  Under a
    `RealClock` the pool degrades to inline execution (the thin
    threaded mode; the DES machinery books `free_at` from measured wall
    time).
  * **fault-tolerant dispatch** -- a `runtime.fault.FaultPlan` injects
    crashes, slowdowns, and shared-cache corruption on the same clock.
    A crash orphans the victim's in-flight wave; the pool re-dispatches
    it to a healthy replica with bounded retries, and when retries run
    out the wave's future resolves to a `WaveLoss` carrying a
    machine-readable reason -- every admitted request is either served
    or reason-coded lost, never silently dropped.

The pool duck-types `ReplicaPool` where `ServeRuntime` cares (`spec`,
`cache`, `clock`, `submit`, `has_capacity`, `warmup`, `profile_stages`,
`stats`, `shutdown`), so the fleet runtime is a subclass of the serving
runtime, not a fork of it.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
import threading
from concurrent.futures import Future
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.convserve.fleet.sharding import ShardedWaveExecutor, probe_image
from repro.convserve.obs.trace import (
    CAT_FLEET,
    NULL_TRACER,
    attach as attach_tracer,
)
from repro.convserve.runtime.clock import Clock, RealClock
from repro.convserve.runtime.replicas import WaveResult
from repro.convserve.runtime.scheduler import Wave
from repro.runtime.fault import (
    FAULT_CACHE_CORRUPT,
    FAULT_CRASH,
    FAULT_SLOW,
    FaultPlan,
)

# replica lifecycle states
STARTING = "starting"
READY = "ready"
DRAINING = "draining"
RETIRED = "retired"
FAILED = "failed"
QUARANTINED = "quarantined"
LIVE_STATES = (STARTING, READY, DRAINING)

# wave-loss reasons (the dispatch analogue of the admission-reject
# vocabulary: accounting counts by it, tests assert on it)
LOSS_RETRIES_EXHAUSTED = "retries_exhausted"
LOSS_NO_HEALTHY_REPLICA = "no_healthy_replica"
LOSS_REASONS = (LOSS_RETRIES_EXHAUSTED, LOSS_NO_HEALTHY_REPLICA)


class WaveLoss(RuntimeError):
    """A wave the fleet could not serve: carries the wave and a reason
    code so the runtime can account for every admitted request."""

    def __init__(self, wave: Wave, reason: str):
        super().__init__(f"wave of {len(wave.requests)} lost: {reason}")
        self.wave = wave
        self.reason = reason


@dataclasses.dataclass(frozen=True)
class FixedServiceModel:
    """Deterministic simulated service time for one wave.

    ``base_s + per_image_s * rows`` for the unsharded wave; sharding
    divides the row term across shards and charges a per-extra-shard
    overhead (scatter/gather), so the model rewards sharding big waves
    and penalizes sharding tiny ones -- the shape a real mesh shows.
    A slow replica multiplies the whole thing by its fault factor."""

    base_s: float = 0.004
    per_image_s: float = 0.002
    shard_overhead_s: float = 0.0005

    def service_s(self, wave: Wave, *, shards: int = 1,
                  slow_factor: float = 1.0) -> float:
        shards = max(1, min(shards, len(wave.requests)))
        rows = self.per_image_s * len(wave.requests) / shards
        over = self.shard_overhead_s * (shards - 1)
        return (self.base_s + rows + over) * slow_factor


@dataclasses.dataclass
class Replica:
    """One fleet member: an executor plus its lifecycle bookkeeping.
    All mutable fields are guarded by the owning pool's `_lock`."""

    idx: int
    executor: ShardedWaveExecutor
    state: str = STARTING
    ready_at: float = 0.0
    free_at: float = 0.0  # sim time its current wave completes
    slow_factor: float = 1.0
    dispatched: int = 0
    probes: int = 0
    probe_failures: int = 0
    retired_at: Optional[float] = None

    @property
    def live(self) -> bool:
        return self.state in LIVE_STATES


class _Completion:
    """One in-flight wave's completion record (heap events point here;
    re-dispatch after a crash swaps `replica`/`t_done` and leaves stale
    heap entries to lazy-invalidate against `epoch`)."""

    __slots__ = ("seq", "wave", "future", "replica", "t_done", "t_submit",
                 "retries", "epoch", "resolved")

    def __init__(self, seq: int, wave: Wave, future: Future,
                 replica: int, t_done: float, t_submit: float):
        self.seq = seq
        self.wave = wave
        self.future = future
        self.replica = replica
        self.t_done = t_done
        self.t_submit = t_submit
        self.retries = 0
        self.epoch = 0  # bumped on re-dispatch; heap entries carry a copy
        self.resolved = False


class ElasticPool:
    """A growable/shrinkable fleet of replicas of one compiled net,
    sharing one `KernelCache` and one plan, with injectable faults."""

    def __init__(
        self,
        replicas: Sequence[ShardedWaveExecutor],
        *,
        clock: Optional[Clock] = None,
        make_replica: Optional[Callable[[], ShardedWaveExecutor]] = None,
        service_model: Optional[FixedServiceModel] = None,
        fault_plan: Optional[FaultPlan] = None,
        max_retries: int = 2,
        startup_s: float = 5.0,
        probe_interval_s: Optional[float] = None,
        slow_quarantine_factor: float = 2.5,
        max_replicas: int = 64,
        tracer=None,
    ):
        if not replicas:
            raise ValueError("elastic pool needs at least one replica")
        cache = replicas[0].cache
        spec = replicas[0].spec
        for ex in replicas[1:]:
            if ex.cache is not cache:
                raise ValueError(
                    "fleet replicas must share one KernelCache"
                )
            if ex.spec is not spec and ex.spec != spec:
                raise ValueError("fleet replicas must serve the same NetSpec")
        self.spec = spec
        self.cache = cache
        self.clock = clock or RealClock()
        self.service_model = service_model or FixedServiceModel()
        self.fault_plan = fault_plan
        self.max_retries = max_retries
        self.startup_s = startup_s
        self.probe_interval_s = probe_interval_s
        self.slow_quarantine_factor = slow_quarantine_factor
        self.max_replicas = max_replicas
        self._make_replica = make_replica
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if self.tracer.active:
            for ex in replicas:
                attach_tracer(ex, self.tracer)

        now = self.clock.now()
        self._lock = threading.RLock()
        self.replicas: List[Replica] = [  # guarded-by: _lock
            Replica(idx=i, executor=ex, state=READY,
                    ready_at=now, free_at=now)
            for i, ex in enumerate(replicas)
        ]
        self._events: List[tuple] = []  # guarded-by: _lock (heap)
        self._eseq = 0  # guarded-by: _lock (heap tiebreak)
        self._inflight: Dict[int, _Completion] = {}  # guarded-by: _lock
        self._wseq = 0  # guarded-by: _lock (wave seq)
        self._warm_shapes: List[tuple] = []  # guarded-by: _lock
        self._golden: Dict[int, np.ndarray] = {}  # guarded-by: _lock
        self._next_probe_t = (  # guarded-by: _lock
            now + probe_interval_s if probe_interval_s else float("inf")
        )
        # counters -- all guarded-by: _lock
        self.dispatches = 0
        self.retries = 0
        self.orphaned = 0
        self.losses: Dict[str, int] = {}
        self.grown = 0
        self.retired = 0
        self.failures = 0
        self.quarantines = 0
        self.cache_repairs = 0
        self.probe_mismatches = 0

    # ----------------------------------------------------------- build

    @classmethod
    def build(cls, engine, spec, weights, n: int, *,
              shards: int = 1, mesh=None,
              clock: Optional[Clock] = None,
              fuse: bool = True,
              **kwargs):
        """Compile `n` sharded replicas of one net on one engine (hence
        one shared cache), planning ONCE, and keep the factory so
        `grow()` can mint identical replicas later.  Extra engine
        compile knobs (e.g. ``input_hw``) ride through `compile_kwargs`.
        """
        compile_kwargs = {
            k: kwargs.pop(k)
            for k in ("input_hw", "verify") if k in kwargs
        }
        first = engine.compile(spec, weights, fuse=fuse, **compile_kwargs)

        def make():
            net = engine.compile(
                spec, weights, plan=first.plan, fuse=fuse, **compile_kwargs
            )
            return ShardedWaveExecutor(net, shards=shards, mesh=mesh)

        execs = [ShardedWaveExecutor(first, shards=shards, mesh=mesh)]
        execs += [make() for _ in range(n - 1)]
        return cls(execs, clock=clock, make_replica=make, **kwargs)

    # ------------------------------------------------------- lifecycle

    def grow(self, n: int = 1, *, now: Optional[float] = None) -> List[int]:
        """Add `n` STARTING replicas (compiled + warmed immediately in
        wall time; taking traffic only after `startup_s` of clock time,
        which models image pull + process boot on a real fleet).
        Returns the new replica indices."""
        if self._make_replica is None:
            raise ValueError("pool built without a replica factory")
        t = self.clock.now() if now is None else now
        born: List[int] = []
        for _ in range(n):
            with self._lock:
                if sum(r.live for r in self.replicas) >= self.max_replicas:
                    break
            ex = self._make_replica()  # compile outside the lock
            self._warm_executor(ex)
            if self.tracer.active:
                attach_tracer(ex, self.tracer)
            with self._lock:
                idx = len(self.replicas)
                ready = t + self.startup_s
                self.replicas.append(Replica(
                    idx=idx, executor=ex, state=STARTING,
                    ready_at=ready, free_at=ready,
                ))
                heapq.heappush(
                    self._events, (ready, self._eseq, "ready", idx)
                )
                self._eseq += 1
                self.grown += 1
                born.append(idx)
            self.tracer.instant(
                "fleet.grow", CAT_FLEET, pid=idx, replica=idx, ready_at=ready
            )
        return born

    def retire(self, n: int = 1, *, now: Optional[float] = None) -> List[int]:
        """Mark `n` replicas DRAINING (newest READY first; STARTING ones
        are cancelled outright).  A draining replica takes no new waves;
        its in-flight wave completes, then it is RETIRED -- `advance`
        performs the hand-off.  Never drains the last live replica."""
        t = self.clock.now() if now is None else now
        out: List[int] = []
        with self._lock:
            for _ in range(n):
                live = [r for r in self.replicas if r.live]
                if len(live) <= 1:
                    break
                victims = [r for r in live if r.state == STARTING]
                if not victims:
                    victims = [r for r in live if r.state == READY]
                if not victims:
                    break
                r = victims[-1]  # newest first: LIFO keeps the fleet warm
                if r.state == STARTING:
                    r.state = RETIRED
                    r.retired_at = t
                else:
                    r.state = DRAINING
                    if r.free_at <= t:  # idle: retires immediately
                        r.state = RETIRED
                        r.retired_at = t
                    else:
                        heapq.heappush(
                            self._events,
                            (r.free_at, self._eseq, "drain", r.idx),
                        )
                        self._eseq += 1
                self.retired += 1
                out.append(r.idx)
        for idx in out:
            self.tracer.instant(
                "fleet.retire", CAT_FLEET, pid=idx, replica=idx
            )
        return out

    def counts(self) -> Dict[str, int]:
        with self._lock:
            out: Dict[str, int] = {}
            for r in self.replicas:
                out[r.state] = out.get(r.state, 0) + 1
            return out

    def ready_count(self) -> int:
        with self._lock:
            return sum(r.state == READY for r in self.replicas)

    def live_count(self) -> int:
        with self._lock:
            return sum(r.live for r in self.replicas)

    @property
    def executors(self) -> List[ShardedWaveExecutor]:
        with self._lock:
            return [r.executor for r in self.replicas if r.live]

    # -------------------------------------------------------- dispatch

    def has_capacity(self) -> bool:
        """A wave dispatched now starts now: some READY replica is idle
        at the current clock reading."""
        now = self.clock.now()
        with self._lock:
            return any(
                r.state == READY and r.free_at <= now for r in self.replicas
            )

    def _pick_locked(self, now: float) -> Optional[Replica]:
        # holds-lock: _lock
        ready = [r for r in self.replicas if r.state == READY]
        if not ready:
            return None
        return min(ready, key=lambda r: (max(r.free_at, now),
                                         r.dispatched, r.idx))

    def submit(self, wave: Wave) -> "Future[WaveResult]":
        """Schedule the wave on the best READY replica.  Under a
        SimClock the future resolves when `advance` reaches the
        completion instant; under a RealClock it resolves inline."""
        now = self.clock.now()
        fut: Future = Future()
        with self._lock:
            r = self._pick_locked(now)
            if r is None:
                self.losses[LOSS_NO_HEALTHY_REPLICA] = (
                    self.losses.get(LOSS_NO_HEALTHY_REPLICA, 0) + 1
                )
                self.tracer.instant(
                    "fleet.wave_lost", CAT_FLEET,
                    reason=LOSS_NO_HEALTHY_REPLICA, n=len(wave.requests),
                )
                fut.set_exception(WaveLoss(wave, LOSS_NO_HEALTHY_REPLICA))
                return fut
            service = self.service_model.service_s(
                wave, shards=r.executor.shards, slow_factor=r.slow_factor
            )
            t_start = max(r.free_at, now)
            t_done = t_start + service
            r.free_at = t_done
            r.dispatched += 1
            self.dispatches += 1
            seq = self._wseq
            self._wseq += 1
            rec = _Completion(seq, wave, fut, r.idx, t_done, now)
            self._inflight[seq] = rec
            heapq.heappush(
                self._events, (t_done, self._eseq, "complete", (seq, 0))
            )
            self._eseq += 1
        if self.clock.realtime:
            # thin threaded mode: compute inline on the caller's thread
            # (the fleet's determinism story lives on the SimClock path)
            self.advance(float("inf"))
        return fut

    def _execute(self, rec: _Completion, replica: Replica) -> WaveResult:
        """Run the wave's actual computation (at completion time, so a
        crash beforehand orphans un-computed work cleanly)."""
        ex = replica.executor
        batch, sizes = rec.wave.assemble()
        before = ex.compile_count
        t0 = self.clock.now()
        y = np.asarray(jax.block_until_ready(ex(batch, sizes)))
        wall = self.clock.now() - t0
        compute = wall if self.clock.realtime else rec.t_done - rec.t_submit
        return WaveResult(
            wave=rec.wave, outputs=rec.wave.crop(self.spec, y),
            replica=replica.idx, compute_s=compute,
            compiled=ex.compile_count > before,
        )

    # ------------------------------------------------------ simulation

    def next_event(self) -> float:
        """Clock time of the next pool event: a completion, a replica
        becoming ready / finishing its drain, a scheduled fault, or a
        health probe.  inf when the pool is quiescent."""
        with self._lock:
            t = self._events[0][0] if self._events else float("inf")
            t = min(t, self._next_probe_t)
        if self.fault_plan is not None:
            t = min(t, self.fault_plan.next_t())
        return t

    def advance(self, now: float) -> int:
        """Resolve every event at or before `now` in TIME order --
        completions, replica transitions, faults, and probes interleave
        on one timeline, so a crash at t=5 can never orphan a wave that
        completed at t=3 just because both fell inside one step.
        Returns the number of completions resolved.  This is the DES
        heart: the fleet runtime calls it each loop iteration after
        stepping the clock.  (``advance(inf)`` -- shutdown / the inline
        realtime path -- flushes events and faults but not the periodic
        probes, which would never terminate.)"""
        done = 0
        inf = float("inf")
        while True:
            with self._lock:
                t_ev = self._events[0][0] if self._events else inf
                # periodic probes only tick toward a finite horizon
                t_pr = self._next_probe_t if math.isfinite(now) else inf
            t_fl = self.fault_plan.next_t() if self.fault_plan else inf
            t = min(t_ev, t_pr, t_fl)
            if t > now or t == inf:
                return done
            if t_ev == t:
                # heap events at this instant resolve before a fault at
                # the same instant: the wave made it
                ripe: List[tuple] = []
                with self._lock:
                    while self._events and self._events[0][0] <= t:
                        ripe.append(heapq.heappop(self._events))
                for tt, _, kind, payload in ripe:
                    if kind == "ready":
                        self._on_ready(payload)
                    elif kind == "drain":
                        self._on_drain(payload, tt)
                    elif kind == "complete":
                        done += self._on_complete(payload)
                continue
            if t_fl == t:
                for fault in self.fault_plan.due(t):
                    self._apply_fault(fault, t)
                continue
            with self._lock:
                self._next_probe_t += self.probe_interval_s
            self.probe(t)

    def _on_ready(self, idx: int) -> None:
        with self._lock:
            r = self.replicas[idx]
            became_ready = r.state == STARTING
            if became_ready:
                r.state = READY
        if became_ready:
            self.tracer.instant(
                "fleet.ready", CAT_FLEET, pid=idx, replica=idx
            )

    def _on_drain(self, idx: int, t: float) -> None:
        with self._lock:
            r = self.replicas[idx]
            if r.state == DRAINING and r.free_at <= t:
                r.state = RETIRED
                r.retired_at = t

    def _on_complete(self, payload) -> int:
        seq, epoch = payload
        with self._lock:
            rec = self._inflight.get(seq)
            if rec is None or rec.resolved or rec.epoch != epoch:
                return 0  # stale heap entry (re-dispatched or lost)
            replica = self.replicas[rec.replica]
            rec.resolved = True
            del self._inflight[seq]
        # the actual compute happens OUTSIDE the lock: it is the
        # expensive part, and it only touches the executor + the
        # internally-locked shared cache
        try:
            res = self._execute(rec, replica)
            rec.future.set_result(res)
        except BaseException as e:
            rec.future.set_exception(e)
        return 1

    # ---------------------------------------------------------- faults

    def _apply_fault(self, fault, now: float) -> None:
        self.tracer.instant(
            "fleet.fault", CAT_FLEET, pid=getattr(fault, "replica", 0) or 0,
            kind=fault.kind, replica=getattr(fault, "replica", None),
        )
        if fault.kind == FAULT_CACHE_CORRUPT:
            self.cache.corrupt_entry()
            return
        with self._lock:
            if fault.replica >= len(self.replicas):
                return
            r = self.replicas[fault.replica]
            if fault.kind == FAULT_SLOW:
                if r.live:
                    r.slow_factor = fault.factor
                return
            # FAULT_CRASH: the replica dies NOW; any in-flight wave on
            # it is orphaned and re-dispatched with bounded retries
            if fault.kind != FAULT_CRASH or not r.live:
                return
            r.state = FAILED
            r.retired_at = now
            self.failures += 1
            orphans = [
                rec for rec in self._inflight.values()
                if rec.replica == r.idx and not rec.resolved
            ]
            for rec in orphans:
                self.orphaned += 1
                self._redispatch_locked(rec, now)

    def _redispatch_locked(self, rec: _Completion, now: float) -> None:
        # holds-lock: _lock
        rec.retries += 1
        rec.epoch += 1
        if rec.retries > self.max_retries:
            self._lose_locked(rec, LOSS_RETRIES_EXHAUSTED)
            return
        r = self._pick_locked(now)
        if r is None:
            self._lose_locked(rec, LOSS_NO_HEALTHY_REPLICA)
            return
        self.retries += 1
        self.tracer.instant(
            "fleet.redispatch", CAT_FLEET, pid=r.idx,
            replica=r.idx, retries=rec.retries,
            n=len(rec.wave.requests),
        )
        service = self.service_model.service_s(
            rec.wave, shards=r.executor.shards, slow_factor=r.slow_factor
        )
        rec.replica = r.idx
        rec.t_done = max(r.free_at, now) + service
        r.free_at = rec.t_done
        r.dispatched += 1
        heapq.heappush(
            self._events,
            (rec.t_done, self._eseq, "complete", (rec.seq, rec.epoch)),
        )
        self._eseq += 1

    def _lose_locked(self, rec: _Completion, reason: str) -> None:
        # holds-lock: _lock
        rec.resolved = True
        self._inflight.pop(rec.seq, None)
        self.losses[reason] = self.losses.get(reason, 0) + 1
        self.tracer.instant(
            "fleet.wave_lost", CAT_FLEET, reason=reason,
            n=len(rec.wave.requests),
        )
        rec.future.set_exception(WaveLoss(rec.wave, reason))

    # ---------------------------------------------------------- health

    def _warm_executor(self, ex) -> None:
        with self._lock:
            shapes = list(self._warm_shapes)
        for b, s, c0 in shapes:
            x = np.zeros((s, b, b, c0), np.float32)
            jax.block_until_ready(ex(x, np.zeros((s, 2), np.int32)))

    def warmup(self, buckets: Sequence[int],
               batch_sizes: Sequence[int]) -> None:
        """Compile every (bucket, batch) program on every live replica,
        remember the shapes (grow() warms newcomers to the same set),
        and record the golden probe outputs the health probes compare
        against."""
        c0 = self.spec.conv_layers()[0][1].c_in
        with self._lock:
            for b in buckets:
                for s in batch_sizes:
                    shape = (int(b), int(s), c0)
                    if shape not in self._warm_shapes:
                        self._warm_shapes.append(shape)
            live = [r.executor for r in self.replicas if r.live]
        for ex in live:
            self._warm_executor(ex)
        self._record_golden()

    def _probe_batch(self, side: int) -> tuple:
        with self._lock:
            sizes = sorted(s for b, s, _ in self._warm_shapes if b == side)
        n = sizes[0] if sizes else 1
        c0 = self.spec.conv_layers()[0][1].c_in
        img = probe_image(self.spec, side)
        x = np.zeros((n, side, side, c0), np.float32)
        x[0] = img
        ext = np.zeros((n, 2), np.int32)
        ext[0] = (side, side)
        return x, ext

    def _record_golden(self) -> None:
        """Golden probe outputs, one per warmed bucket, from replica 0
        right after warmup -- the fleet's known-good reference."""
        with self._lock:
            buckets = sorted({b for b, _, _ in self._warm_shapes})
            ex = next(
                (r.executor for r in self.replicas if r.live), None
            )
        if ex is None:
            return
        for b in buckets:
            x, ext = self._probe_batch(b)
            y = np.asarray(jax.block_until_ready(ex(x, ext)))
            with self._lock:
                self._golden[b] = y[0].copy()

    def probe(self, now: Optional[float] = None) -> dict:
        """Health-probe every READY replica: run the fixed probe input
        and compare against the golden output; check the slow-factor
        against the quarantine threshold.

          * one replica mismatches -> quarantine it (bad local state);
          * EVERY probed replica mismatches -> the shared kernel cache
            is corrupted (they share nothing else): invalidate it (next
            fetch re-transforms from pristine weights) and count a
            repair -- the probe-visible recovery path for the
            ``cache_corrupt`` fault;
          * slow_factor >= threshold -> quarantine (the straggler that
            would otherwise stretch every wave it touches).
        """
        t = self.clock.now() if now is None else now
        with self._lock:
            targets = [r for r in self.replicas if r.state == READY]
            golden = dict(self._golden)
        if not targets or not golden:
            return {"probed": 0}
        side = sorted(golden)[0]
        x, ext = self._probe_batch(side)
        mismatched: List[Replica] = []
        for r in targets:
            y = np.asarray(jax.block_until_ready(r.executor(x, ext)))
            ok = np.array_equal(y[0], golden[side])
            with self._lock:
                r.probes += 1
                if not ok:
                    r.probe_failures += 1
                    self.probe_mismatches += 1
            if not ok:
                mismatched.append(r)
        repaired = False
        if mismatched and len(mismatched) == len(targets):
            # unanimous corruption: the only shared state is the cache
            self.cache.invalidate()
            with self._lock:
                self.cache_repairs += 1
            self.tracer.instant(
                "fleet.cache_repair", CAT_FLEET, probed=len(targets)
            )
            repaired = True
            mismatched = []
        with self._lock:
            for r in mismatched:
                if r.state == READY:
                    r.state = QUARANTINED
                    r.retired_at = t
                    self.quarantines += 1
                    self.tracer.instant(
                        "fleet.quarantine", CAT_FLEET, pid=r.idx,
                        replica=r.idx, why="probe_mismatch",
                    )
            slow = [
                r for r in targets
                if r.state == READY
                and r.slow_factor >= self.slow_quarantine_factor
            ]
            for r in slow:
                r.state = QUARANTINED
                r.retired_at = t
                self.quarantines += 1
                self.tracer.instant(
                    "fleet.quarantine", CAT_FLEET, pid=r.idx,
                    replica=r.idx, why="slow",
                )
            # quarantined replicas orphan their in-flight waves too
            quarantined = {r.idx for r in slow} | {
                r.idx for r in mismatched
            }
            for rec in list(self._inflight.values()):
                if rec.replica in quarantined and not rec.resolved:
                    self.orphaned += 1
                    self._redispatch_locked(rec, t)
        return {
            "probed": len(targets),
            "quarantined": len(mismatched) + len(slow),
            "cache_repaired": repaired,
        }

    # ----------------------------------------------------------- stats

    def profile_stages(self, side: int, batch: int = 1) -> List[tuple]:
        c0 = self.spec.conv_layers()[0][1].c_in
        x = np.zeros((batch, side, side, c0), np.float32)
        with self._lock:
            ex = next(r.executor for r in self.replicas if r.live)
        return ex.profile_stages(x)

    def stats(self) -> dict:
        with self._lock:
            states = {}
            for r in self.replicas:
                states[r.state] = states.get(r.state, 0) + 1
            per_replica = [
                {
                    "idx": r.idx, "state": r.state,
                    "dispatched": r.dispatched,
                    "slow_factor": r.slow_factor,
                    "probes": r.probes,
                    "probe_failures": r.probe_failures,
                }
                for r in self.replicas
            ]
            doc = {
                "replicas": len(self.replicas),
                "states": states,
                "dispatches": self.dispatches,
                "retries": self.retries,
                "orphaned": self.orphaned,
                "losses": dict(self.losses),
                "grown": self.grown,
                "retired": self.retired,
                "failures": self.failures,
                "quarantines": self.quarantines,
                "cache_repairs": self.cache_repairs,
                "probe_mismatches": self.probe_mismatches,
                "in_flight": len(self._inflight),
                "per_replica": per_replica,
                "compiled_programs": sum(
                    r.executor.compile_count for r in self.replicas
                ),
                "cache": self.cache.stats(),
            }
        if self.fault_plan is not None:
            doc["faults"] = self.fault_plan.stats()
        return doc

    def shutdown(self) -> None:
        """Resolve everything still in flight (the DES pool owns no
        threads, so shutdown is bookkeeping, not joining)."""
        self.advance(float("inf"))
