"""Telemetry-driven elasticity: grow/shrink the fleet from live signals.

The controller reads two signals every `tick_interval_s` of clock time:

  * **queue pressure** -- the scheduler's queue depth per READY replica,
  * **deadline slack** -- seconds to spare at completion (negative =
    missed), fed per wave by the fleet runtime,

both EWMA-smoothed so a single burst wave cannot flap the fleet.
Decisions are hysteretic and rate-limited: scale-up needs pressure
above `queue_high` (or slack below `slack_min_s`), scale-down needs
pressure below the *separate, lower* `queue_low` AND comfortable slack,
and any scale decision starts a `cooldown_s` window in which only
failure replacement may act.  Replacement is the exception on purpose:
a crashed replica is re-added toward `min_replicas` immediately --
waiting out a cooldown during an outage would be the controller
amplifying the fault.

While new replicas warm (`startup_s`), the controller exposes an
**admission cap**: the fleet runtime sheds load above what the READY
replicas can plausibly drain (reason-coded ``scaling`` rejections)
instead of building a queue the newcomers will answer too late.  Scale
events also bracket the adapt loop's shadow traffic (pause on first
action, resume when the fleet is steady again) so replanning evidence
is never collected while the fleet is reshaping.
"""

from __future__ import annotations

import dataclasses
import math
import threading
from typing import Callable, List, Optional

from repro.convserve.fleet.pool import ElasticPool
from repro.convserve.obs.trace import CAT_SCALE, NULL_TRACER
from repro.convserve.runtime.clock import Clock


@dataclasses.dataclass
class AutoscalerConfig:
    """Elasticity knobs.  `queue_high`/`queue_low` are per-READY-replica
    EWMA queue depths (hysteresis band); `slack_min_s` is the smoothed
    deadline slack below which the fleet is about to miss SLOs."""

    min_replicas: int = 1
    max_replicas: int = 8
    tick_interval_s: float = 5.0
    queue_high: float = 12.0
    queue_low: float = 1.0
    slack_min_s: float = 0.0
    slack_comfort_s: float = 0.05  # scale-down needs at least this
    ewma: float = 0.3
    cooldown_s: float = 30.0
    step: int = 1  # replicas per scale decision
    admission_queue_per_replica: float = 32.0  # cap during scale-up
    # stale-telemetry guard: a scale decision whose telemetry stamp has
    # not advanced since the previous decision (or whose last mutation
    # is older than `stale_after_s`) is counted + audited, and -- with
    # `require_fresh_telemetry` -- blocked.  Replacement is exempt:
    # re-adding a crashed replica on stale data beats not re-adding it.
    require_fresh_telemetry: bool = False
    stale_after_s: Optional[float] = None

    def __post_init__(self):
        if self.min_replicas < 1:
            raise ValueError("min_replicas must be >= 1")
        if self.max_replicas < self.min_replicas:
            raise ValueError("max_replicas must be >= min_replicas")
        if self.queue_low >= self.queue_high:
            raise ValueError(
                "hysteresis needs queue_low < queue_high "
                f"(got {self.queue_low} >= {self.queue_high})"
            )


class Autoscaler:
    """The fleet's elastic pool controller (pure logic over an injected
    clock reading -- the fleet runtime calls `tick` from its loop)."""

    def __init__(
        self,
        pool: ElasticPool,
        cfg: AutoscalerConfig,
        *,
        clock: Optional[Clock] = None,
        queue_depth_fn: Callable[[], int] = lambda: 0,
        on_scale_start: Optional[Callable[[str], None]] = None,
        on_scale_end: Optional[Callable[[], None]] = None,
        telemetry=None,
        tracer=None,
    ):
        self.pool = pool
        self.cfg = cfg
        self.clock = clock or pool.clock
        self.queue_depth_fn = queue_depth_fn
        self.on_scale_start = on_scale_start
        self.on_scale_end = on_scale_end
        self.telemetry = telemetry  # freshness-stamp source (optional)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        now = self.clock.now()
        self._lock = threading.Lock()
        self.q_ewma = 0.0  # guarded-by: _lock
        self.slack_ewma: Optional[float] = None  # guarded-by: _lock
        self._next_tick_t = now + cfg.tick_interval_s  # guarded-by: _lock
        self._last_scale_t = -math.inf  # guarded-by: _lock
        self._scaling_until = -math.inf  # guarded-by: _lock
        self._scale_active = False  # guarded-by: _lock
        self.ticks = 0  # guarded-by: _lock
        self.scale_ups = 0  # guarded-by: _lock
        self.scale_downs = 0  # guarded-by: _lock
        self.replacements = 0  # guarded-by: _lock
        self.stale_decisions = 0  # guarded-by: _lock
        self._last_decision_seq = -1  # guarded-by: _lock
        self.events: List[dict] = []  # guarded-by: _lock (audit trail)

    # -------------------------------------------------------- signals

    def note_slack(self, slack_s: float) -> None:
        """Feed one wave's worst-case deadline slack (completion time
        margin; negative = the wave missed) into the smoothed signal."""
        a = self.cfg.ewma
        with self._lock:
            if self.slack_ewma is None:
                self.slack_ewma = slack_s
            else:
                self.slack_ewma = (1 - a) * self.slack_ewma + a * slack_s

    # ----------------------------------------------------- admission

    def scaling(self, now: float) -> bool:
        """True while a scale-up's newcomers are still warming -- the
        window in which the fleet runtime applies the admission cap."""
        with self._lock:
            return now < self._scaling_until

    def admission_cap(self) -> float:
        """Max total queue depth to admit into during a scale-up: what
        the currently READY replicas can plausibly drain."""
        return (
            max(1, self.pool.ready_count())
            * self.cfg.admission_queue_per_replica
        )

    # ----------------------------------------------------------- tick

    def next_tick(self) -> float:
        with self._lock:
            return self._next_tick_t

    def tick(self, now: float) -> Optional[str]:
        """Run the control loop if a tick is due.  Returns the action
        taken ("up"/"down"/"replace"/None)."""
        cfg = self.cfg
        with self._lock:
            if now < self._next_tick_t:
                return None
            while self._next_tick_t <= now:
                self._next_tick_t += cfg.tick_interval_s
            self.ticks += 1
            ready = self.pool.ready_count()
            q = self.queue_depth_fn() / max(1, ready)
            self.q_ewma = (1 - cfg.ewma) * self.q_ewma + cfg.ewma * q
            q_ewma = self.q_ewma
            slack = self.slack_ewma
            cooled = now - self._last_scale_t >= cfg.cooldown_s
        live = self.pool.live_count()
        stamp = self.telemetry.stamp() if self.telemetry is not None else None

        action = None
        if live < cfg.min_replicas:
            # failure replacement: exempt from cooldown by design
            n = cfg.min_replicas - live
            born = self.pool.grow(n, now=now)
            if born:
                action = "replace"
                with self._lock:
                    self.replacements += len(born)
                    self._scaling_until = now + self.pool.startup_s
                self._record(now, action, len(born), "below min_replicas",
                             q_ewma, slack)
        elif cooled and live < cfg.max_replicas and (
            q_ewma > cfg.queue_high
            or (slack is not None and slack < cfg.slack_min_s)
        ) and not self._stale_guard(now, "up", stamp, q_ewma, slack):
            n = min(cfg.step, cfg.max_replicas - live)
            born = self.pool.grow(n, now=now)
            if born:
                action = "up"
                why = (
                    f"queue ewma {q_ewma:.1f} > {cfg.queue_high}"
                    if q_ewma > cfg.queue_high
                    else f"slack ewma {slack:.3f}s < {cfg.slack_min_s}s"
                )
                with self._lock:
                    self.scale_ups += 1
                    self._last_scale_t = now
                    self._scaling_until = now + self.pool.startup_s
                    if stamp is not None:
                        self._last_decision_seq = stamp["seq"]
                self._record(now, action, len(born), why, q_ewma, slack)
        elif (
            cooled
            and live > cfg.min_replicas
            and q_ewma < cfg.queue_low
            and (slack is None or slack > cfg.slack_comfort_s)
            and not self._stale_guard(now, "down", stamp, q_ewma, slack)
        ):
            gone = self.pool.retire(cfg.step, now=now)
            if gone:
                action = "down"
                with self._lock:
                    self.scale_downs += 1
                    self._last_scale_t = now
                    if stamp is not None:
                        self._last_decision_seq = stamp["seq"]
                self._record(
                    now, action, len(gone),
                    f"queue ewma {q_ewma:.1f} < {cfg.queue_low}",
                    q_ewma, slack,
                )

        self._bracket_scale_window(now, action)
        return action

    def _stale_guard(self, now, action, stamp, q_ewma, slack) -> bool:
        """True when a would-be `action` must be blocked because the
        telemetry snapshot is stale.  Stale = the stamp's seq has not
        advanced since the previous scale decision, or its last mutation
        is older than `stale_after_s`.  Every stale decision is counted
        and audited; only `require_fresh_telemetry` turns the audit into
        a veto (replacement never routes through here)."""
        if stamp is None:
            return False
        cfg = self.cfg
        with self._lock:
            seq_stale = stamp["seq"] == self._last_decision_seq
        age = (
            now - stamp["t"]
            if stamp["t"] is not None and cfg.stale_after_s is not None
            else None
        )
        age_stale = age is not None and age > cfg.stale_after_s
        if not seq_stale and not age_stale:
            return False
        why = (
            f"telemetry seq {stamp['seq']} unchanged since last decision"
            if seq_stale else f"telemetry age {age:.3f}s > "
            f"{cfg.stale_after_s}s"
        )
        with self._lock:
            self.stale_decisions += 1
        if self.telemetry is not None:
            self.telemetry.inc("autoscaler.stale_snapshot")
        self.tracer.instant(
            "scale.stale_snapshot", CAT_SCALE, action=action,
            seq=stamp["seq"],
            blocked=cfg.require_fresh_telemetry,
        )
        self._record(now, f"stale:{action}", 0, why, q_ewma, slack)
        return cfg.require_fresh_telemetry

    def _record(self, now, action, n, why, q_ewma, slack) -> None:
        with self._lock:
            self.events.append({
                "t": now, "action": action, "n": n, "why": why,
                "queue_ewma": round(q_ewma, 3),
                "slack_ewma": None if slack is None else round(slack, 4),
            })
        self.tracer.instant(
            f"scale.{action}", CAT_SCALE, n=n, why=why,
        )

    def _bracket_scale_window(self, now: float, action) -> None:
        """Pause/resume hooks around the reshaping window: first action
        fires `on_scale_start`; `on_scale_end` fires on the first steady
        tick after every newcomer is READY and every drain finished."""
        counts = self.pool.counts()
        reshaping = (
            counts.get("starting", 0) > 0
            or counts.get("draining", 0) > 0
            or action is not None
        )
        with self._lock:
            was = self._scale_active
            if reshaping:
                self._scale_active = True
            elif was and now >= self._scaling_until:
                self._scale_active = False
            fire_start = reshaping and not was
            fire_end = was and not self._scale_active
        if fire_start and self.on_scale_start is not None:
            self.on_scale_start(action or "reshape")
        if fire_end and self.on_scale_end is not None:
            self.on_scale_end()

    # ---------------------------------------------------------- stats

    def stats(self) -> dict:
        with self._lock:
            return {
                "ticks": self.ticks,
                "scale_ups": self.scale_ups,
                "scale_downs": self.scale_downs,
                "replacements": self.replacements,
                "stale_decisions": self.stale_decisions,
                "queue_ewma": round(self.q_ewma, 3),
                "slack_ewma": (
                    None if self.slack_ewma is None
                    else round(self.slack_ewma, 4)
                ),
                "scale_active": self._scale_active,
                "events": self.events[-50:],
                "config": dataclasses.asdict(self.cfg),
            }
