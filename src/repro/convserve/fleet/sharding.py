"""Sharded wave execution over a `jax` mesh.

A wave is a batch of like-bucketed images; its rows are independent, so
the fleet splits them across the mesh's data axis and reassembles the
outputs in request order -- including ragged waves, whose per-sample
extent rows travel with their image rows, so the executor's masking
keeps every shard exact and the reassembled wave is bitwise the
unsharded one.

Two execution paths, picked per wave:

  * **mesh path** -- when the mesh really has >1 device on its data axis
    and the batch divides it, the batch (and extents) are `device_put`
    with the `distributed.sharding.batch_spec` PartitionSpec and the
    replica's ONE compiled program runs GSPMD-partitioned (exercised in
    the multi-device subprocess test; the main test process is pinned to
    one device).
  * **logical path** -- otherwise the rows are split into `shards`
    contiguous groups run back to back through the same program.  On
    one device this buys nothing in wall time, but the fleet's
    discrete-event simulation charges a sharded wave `~service/shards`
    of *simulated* time, which is what the scale-out curve measures.

Weight-cache **replication vs. sharding** is a planner decision, not a
default (`plan_weight_placement`): a small pre-transformed kernel is
cheapest replicated on every device; a large transformed kernel stack
(the paper's 4 C C' T^2 matrices at high channel counts) is sharded
over the mesh so the fleet's resident-transform footprint stays flat as
devices grow.  `apply_placement` carries the decision out with
`jax.device_put` on the resident cache entries (value-identical moves,
enforced by `KernelCache.place`).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import registry
from repro.distributed.sharding import batch_spec

REPLICATE = "replicate"
SHARD = "shard"

# below this, a transformed kernel stack is cheaper replicated than the
# all-gather it would cost sharded (the mesh analogue of the planner's
# shared-level residency gate)
DEFAULT_SHARD_THRESHOLD_BYTES = 1 << 20


def shard_bounds(n: int, shards: int) -> List[Tuple[int, int]]:
    """Contiguous, balanced row ranges: `n` rows into at most `shards`
    non-empty ``(lo, hi)`` slices, earlier shards taking the remainder
    (the same split a data axis of size `shards` would produce)."""
    if n <= 0 or shards <= 0:
        return []
    shards = min(shards, n)
    base, rem = divmod(n, shards)
    bounds = []
    lo = 0
    for i in range(shards):
        hi = lo + base + (1 if i < rem else 0)
        bounds.append((lo, hi))
        lo = hi
    return bounds


def _data_axis_size(mesh) -> int:
    if mesh is None:
        return 1
    return int(mesh.shape.get("data", 1))


def plan_weight_placement(
    net,
    *,
    mesh=None,
    threshold_bytes: int = DEFAULT_SHARD_THRESHOLD_BYTES,
) -> Dict[int, dict]:
    """Per-conv-layer placement decision: ``{layer: {placement, bytes,
    why}}``.

    Prefers the ACTUAL resident transform bytes (post-warmup cache
    entries); falls back to the closed-form t^2 C C' estimate per
    transform family when a layer has not been prepared yet.  Layers
    whose algorithm consumes no pre-transform (direct, Pallas) have
    nothing to place and replicate trivially."""
    resident = {k[1]: k for k in net.cache_keys()}
    out: Dict[int, dict] = {}
    for p in net.plan.layers:
        alg = registry.get(p.algo)
        if not alg.consumes_wt:
            out[p.layer] = {
                "placement": REPLICATE, "bytes": 0,
                "why": "no pre-transformed kernels",
            }
            continue
        key = resident.get(p.layer)
        nb = net.cache.entry_nbytes(key) if key is not None else None
        why = "resident transform bytes"
        if nb is None:
            s = p.spec
            t = p.params.get("t") or (p.params.get("r", 2) + s.k - 1)
            elem = 8 if getattr(alg, "chain_family", "") == "fft" else 4
            nb = t * t * s.c_in * s.c_out * elem // max(s.groups, 1)
            why = "estimated (not yet prepared)"
        out[p.layer] = {
            "placement": SHARD if nb >= threshold_bytes else REPLICATE,
            "bytes": int(nb),
            "why": why,
        }
    return out


def apply_placement(net, mesh, placement: Dict[int, dict]) -> dict:
    """Carry a `plan_weight_placement` decision out on the resident
    cache entries: SHARD layers are `device_put` partitioned over the
    mesh's data axis (last weight dim divisible by it; the divisibility
    fallback replicates, mirroring `distributed.sharding`), REPLICATE
    layers are explicitly replicated.  A no-op on degenerate (single-
    device) meshes.  Returns ``{sharded, replicated, skipped}`` counts.
    """
    counts = {"sharded": 0, "replicated": 0, "skipped": 0}
    ndata = _data_axis_size(mesh)
    if mesh is None or ndata <= 1:
        counts["skipped"] = len(placement)
        return counts
    resident = {k[1]: k for k in net.cache_keys()}
    for layer, decision in placement.items():
        key = resident.get(layer)
        if key is None:
            counts["skipped"] += 1
            continue

        def put(wt, want_shard=(decision["placement"] == SHARD)):
            spec = [None] * wt.ndim
            if want_shard:
                # partition the last dim divisible by the data axis --
                # transform families lay kernels out differently, but
                # all of them keep channel-like dims trailing
                for d in range(wt.ndim - 1, -1, -1):
                    if wt.shape[d] % ndata == 0 and wt.shape[d] >= ndata:
                        spec[d] = "data"
                        break
            return jax.device_put(wt, NamedSharding(mesh, P(*spec)))

        if net.cache.place(key, put):
            sharded = decision["placement"] == SHARD
            counts["sharded" if sharded else "replicated"] += 1
        else:
            counts["skipped"] += 1
    return counts


class ShardedWaveExecutor:
    """One replica's executor, wave-sharded over a mesh's data axis.

    Duck-types `CompiledNet` everywhere the pool and the hot-swap path
    care (`spec`/`cache`/`plan`/`program`/`hw`/`compile_count`/
    `profile_stages`/`cache_keys`), so an elastic pool of sharded
    replicas composes with everything built for plain ones."""

    def __init__(
        self,
        net,
        *,
        shards: int = 1,
        mesh=None,
        placement: Optional[Dict[int, dict]] = None,
    ):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.net = net
        self.shards = shards
        self.mesh = mesh
        self.placement = placement

    # --------------------------------------------------- passthroughs

    @property
    def spec(self):
        return self.net.spec

    @property
    def cache(self):
        return self.net.cache

    @property
    def plan(self):
        return self.net.plan

    @property
    def program(self):
        return self.net.program

    @property
    def hw(self):
        return self.net.hw

    @property
    def compile_count(self) -> int:
        return self.net.compile_count

    def profile_stages(self, x, sizes=None):
        return self.net.profile_stages(x, sizes)

    def cache_keys(self) -> list:
        return self.net.cache_keys()

    def stats(self) -> dict:
        return self.net.stats()

    # ------------------------------------------------------ execution

    def __call__(self, x, sizes=None):
        n = int(x.shape[0])
        if self.shards <= 1 or n <= 1:
            return self.net(x, sizes)
        ndata = _data_axis_size(self.mesh)
        if ndata > 1 and n % ndata == 0:
            # real mesh path: one program, GSPMD-partitioned input
            xs = jax.device_put(
                x, NamedSharding(
                    self.mesh, batch_spec("wave", x.shape, self.mesh)
                )
            )
            ss = sizes
            if sizes is not None:
                ss = jax.device_put(
                    sizes,
                    NamedSharding(
                        self.mesh,
                        batch_spec("extents", sizes.shape, self.mesh),
                    ),
                )
            return self.net(xs, ss)
        # logical path: contiguous row groups through the same program,
        # reassembled in order -- bitwise the unsharded wave, because
        # rows are computed independently and extents ride their rows
        ys = []
        for lo, hi in shard_bounds(n, self.shards):
            ss = None if sizes is None else sizes[lo:hi]
            ys.append(jnp.asarray(self.net(x[lo:hi], ss)))
        return jnp.concatenate(ys, axis=0)


def probe_image(spec, side: int, *, seed: int = 20240) -> np.ndarray:
    """The fleet's fixed health-probe input: one seeded image at the
    given bucket geometry (deterministic across replicas and runs)."""
    c0 = spec.conv_layers()[0][1].c_in
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((side, side, c0)) * 0.1).astype(np.float32)
