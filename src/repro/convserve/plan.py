"""Serializable per-layer algorithm plans (the net-level "wisdom file").

A `NetPlan` records, for every conv layer of a `NetSpec`, the problem it
was planned for (a `ConvSpec`), which algorithm the roofline planner
picked, and that algorithm's own params dict -- JSON on disk next to the
per-op wisdom file, so a planned net can be shipped to serving hosts
without re-planning (or re-measuring).

A `LayerPlan` is exactly `ConvSpec + algorithm name + algorithm-owned
params`: nothing in this module (or the cache/executor that consume it)
interprets the params -- only the owning registry algorithm does.

Plan format v3 adds `FusionGroup`s: the planner's cross-layer decisions
(which adjacent convs execute as one resident stage, and the super-tile
row count bounding the live intermediate).  v2 files still load --
their groups are empty, and `planner.upgrade_plan` re-derives them from
the same roofline model (see `convserve.program` for the staged IR the
executor lowers a NetPlan into).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any, Dict, Optional, Tuple

from repro.core import registry
from repro.core.registry import AlgoPlan, ConvSpec

PLAN_VERSION = 3
_READABLE_VERSIONS = (2, 3)  # v2: per-layer only, no fusion groups


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """The planner's decision for one conv layer.

    `spec` records what the layer was planned *for*: the executor applies
    algo + params to whatever shape bucket arrives, and the kernel cache
    keys transforms on the spec geometry plus the algorithm's declared
    weight params.  Convenience properties expose the common fields.
    """

    layer: int  # index into NetSpec.layers
    algo: str
    spec: ConvSpec
    params: Dict[str, Any]
    predicted_util: float = 0.0
    tuned: bool = False  # R came from measurement, not the model

    def __post_init__(self):
        if self.algo not in registry.names():
            raise ValueError(
                f"unknown algo {self.algo!r}, expected one of "
                f"{registry.names()}"
            )

    # ----- convenience views (geometry lives in spec, knobs in params)

    @property
    def pad(self) -> int:
        return self.spec.pad

    @property
    def stride(self) -> int:
        return self.spec.stride

    @property
    def groups(self) -> int:
        return self.spec.groups

    @property
    def c_in(self) -> int:
        return self.spec.c_in

    @property
    def c_out(self) -> int:
        return self.spec.c_out

    @property
    def k(self) -> int:
        return self.spec.k

    @property
    def h(self) -> int:
        return self.spec.h

    @property
    def w(self) -> int:
        return self.spec.w

    @property
    def r_tiles(self) -> int:
        return int(self.params.get("r_tiles", 0))

    @property
    def m(self) -> Optional[int]:
        return self.params.get("m")

    @property
    def t_fft(self) -> Optional[int]:
        return self.params.get("t_fft")

    @property
    def t(self) -> Optional[int]:
        """Transform tile size T, whichever family is planned."""
        if "t_fft" in self.params:
            return self.params["t_fft"]
        if "m" in self.params:
            return self.params["m"] + self.spec.k - 1
        return None

    def algo_plan(self) -> AlgoPlan:
        """The registry-level view: what execute()/prepare_weights() take."""
        return AlgoPlan(
            algo=self.algo, spec=self.spec, params=dict(self.params),
            predicted_util=self.predicted_util, tuned=self.tuned,
        )

    @staticmethod
    def from_algo_plan(layer: int, ap: AlgoPlan) -> "LayerPlan":
        return LayerPlan(
            layer=layer, algo=ap.algo, spec=ap.spec, params=dict(ap.params),
            predicted_util=ap.predicted_util, tuned=ap.tuned,
        )

    def to_dict(self) -> dict:
        return {
            "layer": self.layer,
            "algo": self.algo,
            "spec": self.spec.to_dict(),
            "params": dict(self.params),
            "predicted_util": self.predicted_util,
            "tuned": self.tuned,
        }

    @staticmethod
    def from_dict(d: dict) -> "LayerPlan":
        return LayerPlan(
            layer=d["layer"],
            algo=d["algo"],
            spec=ConvSpec.from_dict(d["spec"]),
            params=dict(d["params"]),
            predicted_util=d.get("predicted_util", 0.0),
            tuned=d.get("tuned", False),
        )


@dataclasses.dataclass(frozen=True)
class FusionGroup:
    """One cross-layer fusion decision: the conv layers (NetSpec indices,
    adjacent in conv order) that execute as a single resident stage, and
    the super-tile row count that bounds the live intermediate (0 means
    untiled -- the whole extent fits the fast shared level)."""

    layers: Tuple[int, ...]
    tile_rows: int = 0

    def __post_init__(self):
        if len(self.layers) < 2:
            raise ValueError(
                f"fusion group needs >= 2 conv layers, got {self.layers}"
            )
        if self.tile_rows < 0:
            raise ValueError(f"negative tile_rows in {self}")

    def to_dict(self) -> dict:
        return {"layers": list(self.layers), "tile_rows": self.tile_rows}

    @staticmethod
    def from_dict(d: dict) -> "FusionGroup":
        return FusionGroup(
            layers=tuple(d["layers"]), tile_rows=d.get("tile_rows", 0)
        )


@dataclasses.dataclass(frozen=True)
class NetPlan:
    """All layer plans (and fusion groups) for one net on one hardware
    model."""

    net: str  # NetSpec.name
    hw: str  # HardwareModel.name the plan was derived for
    dtype: str
    input_hw: Tuple[int, int]  # reference (H, W) the plan was derived at
    layers: Tuple[LayerPlan, ...]
    groups: Tuple[FusionGroup, ...] = ()

    def layer_plan(self, idx: int) -> Optional[LayerPlan]:
        for p in self.layers:
            if p.layer == idx:
                return p
        return None

    def algos(self) -> Tuple[str, ...]:
        return tuple(p.algo for p in self.layers)

    def group_of(self, idx: int) -> Optional[FusionGroup]:
        for g in self.groups:
            if idx in g.layers:
                return g
        return None

    def to_json(self) -> str:
        return json.dumps(
            {
                "version": PLAN_VERSION,
                "net": self.net,
                "hw": self.hw,
                "dtype": self.dtype,
                "input_hw": list(self.input_hw),
                "layers": [p.to_dict() for p in self.layers],
                "groups": [g.to_dict() for g in self.groups],
            },
            indent=1,
            sort_keys=True,
        )

    @staticmethod
    def from_json(text: str) -> "NetPlan":
        d = json.loads(text)
        version = d.get("version")
        if version not in _READABLE_VERSIONS:
            raise ValueError(
                f"plan version {version} not in {_READABLE_VERSIONS}"
            )
        # v2 carries no fusion decisions: load with empty groups; callers
        # that want them re-derive via planner.upgrade_plan (same roofline
        # model, so a v2 plan replans identically)
        groups = tuple(
            FusionGroup.from_dict(g) for g in d.get("groups", ())
        )
        return NetPlan(
            net=d["net"],
            hw=d["hw"],
            dtype=d["dtype"],
            input_hw=tuple(d["input_hw"]),
            layers=tuple(LayerPlan.from_dict(l) for l in d["layers"]),
            groups=groups,
        )

    def save(self, path) -> None:
        from repro.core.ioutil import atomic_write_text

        atomic_write_text(pathlib.Path(path), self.to_json())

    @staticmethod
    def load(path) -> "NetPlan":
        return NetPlan.from_json(pathlib.Path(path).read_text())
