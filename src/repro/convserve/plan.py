"""Serializable per-layer algorithm plans (the net-level "wisdom file").

A `NetPlan` records, for every conv layer of a `NetSpec`, which algorithm
the roofline planner picked, at what tile size and R, and the predicted
utilisation -- JSON on disk next to the per-op wisdom file, so a planned
net can be shipped to serving hosts without re-planning (or re-measuring).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Optional, Tuple

PLAN_ALGOS = ("direct", "three_stage", "l3_fused", "fft_fused", "l3_fused_pallas")
PLAN_VERSION = 1


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """The planner's decision for one conv layer.

    Geometry fields (h, w, c_in, c_out, k, pad) record what the layer was
    planned *for*: the executor applies algo/m/t_fft/r_tiles to whatever
    shapes arrive, and the kernel cache keys transforms on the geometry.
    """

    layer: int  # index into NetSpec.layers
    algo: str
    pad: int
    r_tiles: int
    c_in: int
    c_out: int
    k: int
    h: int  # planned input spatial dims (reference bucket)
    w: int
    m: Optional[int] = None  # Winograd output-tile size (wino family)
    t_fft: Optional[int] = None  # FFT tile size (fft family)
    predicted_util: float = 0.0
    tuned: bool = False  # R came from measurement, not the model

    def __post_init__(self):
        if self.algo not in PLAN_ALGOS:
            raise ValueError(f"unknown algo {self.algo!r}")

    @property
    def t(self) -> Optional[int]:
        """Transform tile size T, whichever family is planned."""
        if self.algo == "fft_fused":
            return self.t_fft
        if self.m is not None:
            return self.m + self.k - 1
        return None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "LayerPlan":
        return LayerPlan(**d)


@dataclasses.dataclass(frozen=True)
class NetPlan:
    """All layer plans for one net on one hardware model."""

    net: str  # NetSpec.name
    hw: str  # HardwareModel.name the plan was derived for
    dtype: str
    input_hw: Tuple[int, int]  # reference (H, W) the plan was derived at
    layers: Tuple[LayerPlan, ...]

    def layer_plan(self, idx: int) -> Optional[LayerPlan]:
        for p in self.layers:
            if p.layer == idx:
                return p
        return None

    def algos(self) -> Tuple[str, ...]:
        return tuple(p.algo for p in self.layers)

    def to_json(self) -> str:
        return json.dumps(
            {
                "version": PLAN_VERSION,
                "net": self.net,
                "hw": self.hw,
                "dtype": self.dtype,
                "input_hw": list(self.input_hw),
                "layers": [p.to_dict() for p in self.layers],
            },
            indent=1,
            sort_keys=True,
        )

    @staticmethod
    def from_json(text: str) -> "NetPlan":
        d = json.loads(text)
        if d.get("version") != PLAN_VERSION:
            raise ValueError(f"plan version {d.get('version')} != {PLAN_VERSION}")
        return NetPlan(
            net=d["net"],
            hw=d["hw"],
            dtype=d["dtype"],
            input_hw=tuple(d["input_hw"]),
            layers=tuple(LayerPlan.from_dict(l) for l in d["layers"]),
        )

    def save(self, path) -> None:
        from repro.core.ioutil import atomic_write_text

        atomic_write_text(pathlib.Path(path), self.to_json())

    @staticmethod
    def load(path) -> "NetPlan":
        return NetPlan.from_json(pathlib.Path(path).read_text())
