"""Serializable per-layer algorithm plans (the net-level "wisdom file").

A `NetPlan` records, for every conv layer of a `NetSpec`, the problem it
was planned for (a `ConvSpec`), which algorithm the roofline planner
picked, and that algorithm's own params dict -- JSON on disk next to the
per-op wisdom file, so a planned net can be shipped to serving hosts
without re-planning (or re-measuring).

A `LayerPlan` is exactly `ConvSpec + algorithm name + algorithm-owned
params`: nothing in this module (or the cache/executor that consume it)
interprets the params -- only the owning registry algorithm does.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any, Dict, Optional, Tuple

from repro.core import registry
from repro.core.registry import AlgoPlan, ConvSpec

PLAN_VERSION = 2


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """The planner's decision for one conv layer.

    `spec` records what the layer was planned *for*: the executor applies
    algo + params to whatever shape bucket arrives, and the kernel cache
    keys transforms on the spec geometry plus the algorithm's declared
    weight params.  Convenience properties expose the common fields.
    """

    layer: int  # index into NetSpec.layers
    algo: str
    spec: ConvSpec
    params: Dict[str, Any]
    predicted_util: float = 0.0
    tuned: bool = False  # R came from measurement, not the model

    def __post_init__(self):
        if self.algo not in registry.names():
            raise ValueError(
                f"unknown algo {self.algo!r}, expected one of "
                f"{registry.names()}"
            )

    # ----- convenience views (geometry lives in spec, knobs in params)

    @property
    def pad(self) -> int:
        return self.spec.pad

    @property
    def stride(self) -> int:
        return self.spec.stride

    @property
    def groups(self) -> int:
        return self.spec.groups

    @property
    def c_in(self) -> int:
        return self.spec.c_in

    @property
    def c_out(self) -> int:
        return self.spec.c_out

    @property
    def k(self) -> int:
        return self.spec.k

    @property
    def h(self) -> int:
        return self.spec.h

    @property
    def w(self) -> int:
        return self.spec.w

    @property
    def r_tiles(self) -> int:
        return int(self.params.get("r_tiles", 0))

    @property
    def m(self) -> Optional[int]:
        return self.params.get("m")

    @property
    def t_fft(self) -> Optional[int]:
        return self.params.get("t_fft")

    @property
    def t(self) -> Optional[int]:
        """Transform tile size T, whichever family is planned."""
        if "t_fft" in self.params:
            return self.params["t_fft"]
        if "m" in self.params:
            return self.params["m"] + self.spec.k - 1
        return None

    def algo_plan(self) -> AlgoPlan:
        """The registry-level view: what execute()/prepare_weights() take."""
        return AlgoPlan(
            algo=self.algo, spec=self.spec, params=dict(self.params),
            predicted_util=self.predicted_util, tuned=self.tuned,
        )

    @staticmethod
    def from_algo_plan(layer: int, ap: AlgoPlan) -> "LayerPlan":
        return LayerPlan(
            layer=layer, algo=ap.algo, spec=ap.spec, params=dict(ap.params),
            predicted_util=ap.predicted_util, tuned=ap.tuned,
        )

    def to_dict(self) -> dict:
        return {
            "layer": self.layer,
            "algo": self.algo,
            "spec": self.spec.to_dict(),
            "params": dict(self.params),
            "predicted_util": self.predicted_util,
            "tuned": self.tuned,
        }

    @staticmethod
    def from_dict(d: dict) -> "LayerPlan":
        return LayerPlan(
            layer=d["layer"],
            algo=d["algo"],
            spec=ConvSpec.from_dict(d["spec"]),
            params=dict(d["params"]),
            predicted_util=d.get("predicted_util", 0.0),
            tuned=d.get("tuned", False),
        )


@dataclasses.dataclass(frozen=True)
class NetPlan:
    """All layer plans for one net on one hardware model."""

    net: str  # NetSpec.name
    hw: str  # HardwareModel.name the plan was derived for
    dtype: str
    input_hw: Tuple[int, int]  # reference (H, W) the plan was derived at
    layers: Tuple[LayerPlan, ...]

    def layer_plan(self, idx: int) -> Optional[LayerPlan]:
        for p in self.layers:
            if p.layer == idx:
                return p
        return None

    def algos(self) -> Tuple[str, ...]:
        return tuple(p.algo for p in self.layers)

    def to_json(self) -> str:
        return json.dumps(
            {
                "version": PLAN_VERSION,
                "net": self.net,
                "hw": self.hw,
                "dtype": self.dtype,
                "input_hw": list(self.input_hw),
                "layers": [p.to_dict() for p in self.layers],
            },
            indent=1,
            sort_keys=True,
        )

    @staticmethod
    def from_json(text: str) -> "NetPlan":
        d = json.loads(text)
        if d.get("version") != PLAN_VERSION:
            raise ValueError(f"plan version {d.get('version')} != {PLAN_VERSION}")
        return NetPlan(
            net=d["net"],
            hw=d["hw"],
            dtype=d["dtype"],
            input_hw=tuple(d["input_hw"]),
            layers=tuple(LayerPlan.from_dict(l) for l in d["layers"]),
        )

    def save(self, path) -> None:
        from repro.core.ioutil import atomic_write_text

        atomic_write_text(pathlib.Path(path), self.to_json())

    @staticmethod
    def load(path) -> "NetPlan":
        return NetPlan.from_json(pathlib.Path(path).read_text())
