"""Online serving runtime for the convserve engine.

Request lifecycle:  submit -> admit (bounded per-bucket queues,
priority classes, reject-with-reason) -> deadline-aware wave formation
(`WaveScheduler`) -> replica pool sharing one pre-transformed kernel
cache (`ReplicaPool`) -> telemetry (latency histograms, queue depth,
wave/reject counters, cache + stage rollups in one JSON document).

Everything is driven through an injectable `Clock`: `RealClock` for
traffic, `SimClock` for deterministic scheduling tests.  The offline
`ConvServer` front-end reuses the same scheduler (admit everything,
drain), so wave formation has exactly one implementation.
"""

from repro.convserve.runtime.clock import Clock, RealClock, SimClock
from repro.convserve.runtime.loadgen import (
    Arrival,
    burst_trace,
    diurnal_rate,
    diurnal_trace,
    make_images,
    merge_traces,
    poisson_trace,
)
from repro.convserve.runtime.queueing import (
    BATCH,
    INTERACTIVE,
    REJECT_BAD_SHAPE,
    REJECT_QUEUE_FULL,
    REJECT_REASONS,
    REJECT_SCALING,
    REJECT_TOO_LARGE,
    STANDARD,
    BucketQueue,
    Rejection,
    Request,
)
from repro.convserve.runtime.replicas import ReplicaPool, WaveResult
from repro.convserve.runtime.scheduler import (
    FLUSH_DEADLINE,
    FLUSH_DRAIN,
    FLUSH_FULL,
    RuntimeConfig,
    Wave,
    WaveScheduler,
)
from repro.convserve.runtime.service import ServeRuntime
from repro.convserve.runtime.telemetry import (
    Histogram,
    Telemetry,
    stage_rollup,
)

__all__ = [
    "Clock",
    "RealClock",
    "SimClock",
    "Request",
    "Rejection",
    "BucketQueue",
    "INTERACTIVE",
    "STANDARD",
    "BATCH",
    "REJECT_REASONS",
    "REJECT_QUEUE_FULL",
    "REJECT_TOO_LARGE",
    "REJECT_BAD_SHAPE",
    "REJECT_SCALING",
    "RuntimeConfig",
    "Wave",
    "WaveScheduler",
    "FLUSH_FULL",
    "FLUSH_DEADLINE",
    "FLUSH_DRAIN",
    "ReplicaPool",
    "WaveResult",
    "ServeRuntime",
    "Telemetry",
    "Histogram",
    "stage_rollup",
    "Arrival",
    "poisson_trace",
    "burst_trace",
    "diurnal_rate",
    "diurnal_trace",
    "merge_traces",
    "make_images",
]
