"""Deadline/SLO-aware wave formation over bucketed request queues.

The paper's fused path wins by amortizing pre-transformed kernels and
compiled programs across batches, so the scheduler's job is to form the
*largest wave it can afford to wait for*:

  * a bucket whose queue reaches `max_batch` dispatches a full wave
    immediately;
  * otherwise the wave waits -- but only until the oldest queued
    request's slack runs out.  Slack is measured against the request's
    completion deadline minus the bucket's (EWMA-estimated) service
    time, so a partial wave leaves the moment waiting any longer would
    break the SLO, not when a timer guesses;
  * partial waves are padded with batch-size *hysteresis*: a wave of n
    rides the smallest already-dispatched power-of-two batch >= n when
    one exists, so deadline flushes reuse already-compiled programs
    instead of minting new batch shapes under load;
  * buckets take turns: among ready buckets the scheduler rotates
    round-robin from the last bucket served, so continuous traffic in
    one bucket cannot starve another (and any queued bucket becomes
    ready once its slack expires).

The scheduler is pure logic over an injected notion of "now" -- no
threads, no sleeping -- which is what makes its behaviour provable under
a `SimClock` and shareable between the online runtime (`service.py`)
and the offline `ConvServer` front-end (which admits everything up
front and drains).
"""

from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, List, Mapping, Optional, Sequence, Set, Union

import numpy as np

from repro.convserve.graph import NetSpec
from repro.convserve.runtime.queueing import (
    BucketQueue,
    REJECT_BAD_SHAPE,
    REJECT_QUEUE_FULL,
    REJECT_TOO_LARGE,
    Rejection,
    Request,
)

# wave-dispatch reasons (telemetry vocabulary)
FLUSH_FULL = "full"
FLUSH_DEADLINE = "deadline"
FLUSH_DRAIN = "drain"


@dataclasses.dataclass
class RuntimeConfig:
    """Knobs for the serving runtime (the online superset of the offline
    `ConvServeConfig`).

    slo_s: default completion SLO per priority class (or one scalar for
    all classes); a request with no explicit deadline gets
    ``t_admit + slo``.  None means no implicit deadlines -- only full
    waves and explicit drains dispatch.
    service_est_s: initial per-wave compute estimate used for deadline
    slack before any wave has been measured (the runtime feeds measured
    wave times back via `observe_service`).
    """

    max_batch: int = 8
    buckets: Sequence[int] = (32, 64, 128, 224)
    pad_batch: bool = True  # power-of-two padding + hysteresis
    queue_depth: int = 64  # per-bucket admission bound
    slo_s: Union[None, float, Mapping[int, float]] = None
    service_est_s: float = 0.0
    service_ewma: float = 0.3  # weight of the newest wave measurement

    def slo_for(self, priority: int) -> float:
        if self.slo_s is None:
            return math.inf
        if isinstance(self.slo_s, Mapping):
            return self.slo_s.get(priority, math.inf)
        return float(self.slo_s)


@dataclasses.dataclass
class Wave:
    """One dispatchable batch: like-bucketed requests plus the padded
    batch size the executor will see."""

    bucket: int
    requests: List[Request]
    batch_size: int
    reason: str  # FLUSH_FULL | FLUSH_DEADLINE | FLUSH_DRAIN
    formed_at: float

    @property
    def partial(self) -> bool:
        return self.reason != FLUSH_FULL

    def assemble(self) -> tuple:
        """(batch, sizes): requests zero-padded into the bucket square
        and stacked; padding rows (ragged margins AND batch-fill rows)
        carry extent 0 so the executor's masking keeps serving exact."""
        c = self.requests[0].image.shape[2]
        batch = np.zeros(
            (self.batch_size, self.bucket, self.bucket, c),
            self.requests[0].image.dtype,
        )
        sizes = np.zeros((self.batch_size, 2), np.int32)
        for i, r in enumerate(self.requests):
            h, w, rc = r.image.shape
            if rc != c:
                raise ValueError(
                    f"request {r.rid}: channel mismatch {rc} != {c}"
                )
            batch[i, :h, :w, :] = r.image
            sizes[i] = (h, w)
        return batch, sizes

    def crop(self, spec: NetSpec, y: np.ndarray) -> Dict[int, np.ndarray]:
        """Per-request true-extent crops of the wave output.  Copies,
        not views: a view would pin the wave's whole padded batch buffer
        alive for as long as any single request's result is retained."""
        out: Dict[int, np.ndarray] = {}
        for i, r in enumerate(self.requests):
            h, w, c = r.image.shape
            oh, ow, _ = spec.out_shape(h, w, c)
            out[r.rid] = np.ascontiguousarray(y[i, :oh, :ow, :])
        return out


class WaveScheduler:
    """Admission + wave formation for one net's bucketed traffic."""

    def __init__(self, spec: NetSpec, cfg: RuntimeConfig):
        convs = spec.conv_layers()
        if not convs:
            raise ValueError(f"net {spec.name!r} has no conv layers")
        self._c0 = convs[0][1].c_in
        # every bucket must survive the net's whole downsampling chain;
        # simulate the exact shape pipeline (stride-2 convs halve extents
        # before pools ever see them, so a pool-factor modulo check is
        # not enough)
        for b in cfg.buckets:
            try:
                spec.infer_shapes(b, b, self._c0)
            except ValueError as e:
                raise ValueError(
                    f"bucket {b} does not survive net {spec.name!r}'s "
                    f"downsampling chain (total factor "
                    f"{spec.downsample_factor}): {e}"
                ) from None
        self.spec = spec
        self.cfg = cfg
        # one lock over queues + counters: submits arrive from client
        # threads, waves form on the runtime loop, and service-time
        # observations land on replica completion threads.  Guarding
        # admission keeps the "reject, never throw" contract under
        # concurrency (an unguarded depth check would race into
        # BucketQueue's OverflowError).
        self._lock = threading.RLock()
        self._queues: Dict[int, BucketQueue] = {  # guarded-by: _lock
            b: BucketQueue(b, cfg.queue_depth) for b in sorted(cfg.buckets)
        }
        self._order = sorted(cfg.buckets)
        self._rr = 0  # guarded-by: _lock (index of last bucket served)
        self._sizes: Dict[int, Set[int]] = {  # guarded-by: _lock
            b: set() for b in self._order
        }
        self.service_est: Dict[int, float] = {  # guarded-by: _lock
            b: cfg.service_est_s for b in self._order
        }
        self.admitted = 0  # guarded-by: _lock
        self.rejected: Dict[str, int] = {}  # guarded-by: _lock
        self.cleared = 0  # guarded-by: _lock
        self.waves = 0  # guarded-by: _lock
        self.partial_waves = 0  # guarded-by: _lock
        self.waves_by_reason: Dict[str, int] = {}  # guarded-by: _lock

    # ------------------------------------------------------- admission

    def bucket_for(self, h: int, w: int) -> Optional[int]:
        for b in self._order:
            if h <= b and w <= b:
                return b
        return None

    def admit(self, req: Request, now: float) -> Optional[Rejection]:
        """Validate + enqueue; returns a `Rejection` (never raises) when
        the request cannot be taken, so overload shows up as an explicit
        per-reason counter instead of an exception mid-wave."""
        if req.image.ndim != 3:
            return self._reject(
                req, REJECT_BAD_SHAPE, f"expected HWC, got {req.image.shape}"
            )
        h, w, c = req.image.shape
        try:
            # a bad request must fail here, not at crop time after its
            # wave-mates have already been computed
            self.spec.infer_shapes(h, w, c)
        except ValueError as e:
            return self._reject(req, REJECT_BAD_SHAPE, str(e))
        bucket = self.bucket_for(h, w)
        if bucket is None:
            return self._reject(
                req,
                REJECT_TOO_LARGE,
                f"image ({h}, {w}) exceeds largest bucket {self._order[-1]}",
            )
        with self._lock:
            q = self._queues[bucket]
            if q.full:
                return self._reject(
                    req,
                    REJECT_QUEUE_FULL,
                    f"bucket {bucket} queue at depth bound {q.depth}",
                )
            req.bucket = bucket
            req.t_admit = now
            if math.isinf(req.deadline):
                req.deadline = now + self.cfg.slo_for(req.priority)
            q.push(req)
            self.admitted += 1
        return None

    def _reject(self, req: Request, reason: str, detail: str) -> Rejection:
        with self._lock:
            self.rejected[reason] = self.rejected.get(reason, 0) + 1
        return Rejection(rid=req.rid, reason=reason, detail=detail)

    # -------------------------------------------------- wave formation

    def depth(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    def depth_by_bucket(self) -> Dict[int, int]:
        with self._lock:
            return {b: len(q) for b, q in self._queues.items() if len(q)}

    def _flush_at(self, bucket: int) -> float:
        """Absolute time the bucket's oldest deadline forces a dispatch:
        completion deadline minus the estimated wave service time."""
        return self._queues[bucket].oldest_deadline() - self.service_est[
            bucket
        ]

    def _ready_reason(self, bucket: int, now: float) -> Optional[str]:
        q = self._queues[bucket]
        if not len(q):
            return None
        if len(q) >= self.cfg.max_batch:
            return FLUSH_FULL
        if now >= self._flush_at(bucket):
            return FLUSH_DEADLINE
        return None

    def next_wave(self, now: float) -> Optional[Wave]:
        """The next dispatchable wave, or None if every bucket should
        keep waiting.  Among ready buckets, rotates round-robin from the
        last bucket served -- continuous full-wave traffic in one bucket
        cannot starve another that became ready."""
        n = len(self._order)
        with self._lock:
            for step in range(1, n + 1):
                i = (self._rr + step) % n
                reason = self._ready_reason(self._order[i], now)
                if reason is not None:
                    self._rr = i
                    return self._form(self._order[i], reason, now)
        return None

    def drain_wave(self, now: float = 0.0) -> Optional[Wave]:
        """Force-form a wave from any non-empty bucket (round-robin) --
        the offline path and end-of-trace flush."""
        n = len(self._order)
        with self._lock:
            for step in range(1, n + 1):
                i = (self._rr + step) % n
                b = self._order[i]
                if len(self._queues[b]):
                    self._rr = i
                    reason = (
                        FLUSH_FULL
                        if len(self._queues[b]) >= self.cfg.max_batch
                        else FLUSH_DRAIN
                    )
                    return self._form(b, reason, now)
        return None

    def next_event(self, now: float) -> float:
        """Earliest future instant a queued bucket becomes deadline-ready
        (absolute clock time; inf when nothing is waiting on a deadline).
        The runtime sleeps until min(next arrival, this)."""
        t = math.inf
        with self._lock:
            for b in self._order:
                if len(self._queues[b]):
                    t = min(t, self._flush_at(b))
        return max(t, now)

    def _wave_size(self, bucket: int, n: int) -> int:
        if not self.cfg.pad_batch:
            return n
        p = 1
        while p < n:
            p *= 2
        p = min(p, self.cfg.max_batch)
        # hysteresis: prefer the smallest batch shape this bucket has
        # already dispatched (hence compiled) that still fits, so a
        # deadline-flushed partial wave never mints a new program when a
        # warm one can serve it
        compiled = self._sizes[bucket]
        if p not in compiled:
            bigger = [s for s in compiled if n <= s <= self.cfg.max_batch]
            if bigger:
                p = min(bigger)
        return p

    def _form(self, bucket: int, reason: str, now: float) -> Wave:
        # holds-lock: _lock (only called from poll()'s locked section)
        reqs = self._queues[bucket].pop(self.cfg.max_batch)
        size = self._wave_size(bucket, len(reqs))
        self._sizes[bucket].add(size)
        self.waves += 1
        self.waves_by_reason[reason] = self.waves_by_reason.get(reason, 0) + 1
        if reason != FLUSH_FULL:
            self.partial_waves += 1
        return Wave(
            bucket=bucket,
            requests=reqs,
            batch_size=size,
            reason=reason,
            formed_at=now,
        )

    def clear(self) -> int:
        """Drop every queued request (counted in `cleared`) -- the
        abort path: an offline batch that failed admission must not
        leak its already-admitted mates into the next run."""
        with self._lock:
            n = sum(len(q) for q in self._queues.values())
            for b in self._order:
                self._queues[b] = BucketQueue(b, self.cfg.queue_depth)
            self.cleared += n
            return n

    def note_compiled(self, bucket: int, size: int) -> None:
        """Register an externally warmed batch shape (`ReplicaPool.
        warmup`) so hysteresis pads partial waves onto it from the
        first dispatch."""
        with self._lock:
            if bucket in self._sizes:
                self._sizes[bucket].add(size)

    def compiled_sizes(self) -> Dict[int, list]:
        """Snapshot of every batch shape each bucket has dispatched (or
        had warmed): ``{bucket: sorted sizes}``.  The hot-swap path warms
        a candidate program at exactly these shapes, so the swapped-in
        replicas never cold-compile under live traffic."""
        with self._lock:
            return {b: sorted(s) for b, s in self._sizes.items()}

    def observe_service(self, bucket: int, seconds: float) -> None:
        """Feed a measured wave compute time back into the slack model."""
        a = self.cfg.service_ewma
        with self._lock:
            prev = self.service_est[bucket]
            self.service_est[bucket] = (
                seconds if prev == 0.0 else (1 - a) * prev + a * seconds
            )

    def stats(self) -> dict:
        with self._lock:
            return self._stats_locked()

    def _stats_locked(self) -> dict:
        return {
            "admitted": self.admitted,
            "rejected": dict(self.rejected),
            "cleared": self.cleared,
            "waves": self.waves,
            "partial_waves": self.partial_waves,
            "waves_by_reason": dict(self.waves_by_reason),
            "queue_depth": sum(len(q) for q in self._queues.values()),
            "queue_depth_by_bucket": {
                b: len(q) for b, q in self._queues.items() if len(q)
            },
            "service_est_s": dict(self.service_est),
        }
