"""Metrics registry for the serving runtime.

One thread-safe `Telemetry` object per runtime: monotonic counters
(waves, rejects, deadline misses), gauges (queue depth, in-flight),
and log-bucketed latency histograms (queue wait / compute / end-to-end)
with p50/p95/p99 estimation.  `snapshot()` rolls everything -- plus the
caller-supplied sections like kernel-cache counters and per-stage
profiles -- into ONE plain-JSON document, the single artifact the
benchmarks write and dashboards would scrape.

Histograms are fixed log-spaced buckets, not reservoirs: recording is
O(1) and allocation-free under load, and the percentile error is
bounded by the bucket ratio (~12% with the default 2**(1/4) spacing),
tight enough for tail-latency tracking.
"""

from __future__ import annotations

import json
import math
import threading
from typing import Dict, List, Optional


class Histogram:
    """Log-spaced latency histogram over (lo_s, hi_s)."""

    def __init__(
        self, lo_s: float = 1e-6, hi_s: float = 1e3, ratio: float = 2 ** 0.25
    ):
        self._lo = lo_s
        self._ratio = ratio
        self._log_ratio = math.log(ratio)
        n = int(math.ceil(math.log(hi_s / lo_s) / self._log_ratio)) + 1
        self._counts = [0] * (n + 2)  # +underflow, +overflow
        self.count = 0
        self.total = 0.0
        self.max = 0.0

    def _bucket(self, v: float) -> int:
        if v < self._lo:
            return 0
        i = int(math.log(v / self._lo) / self._log_ratio) + 1
        return min(i, len(self._counts) - 1)

    def record(self, seconds: float) -> None:
        self._counts[self._bucket(seconds)] += 1
        self.count += 1
        self.total += seconds
        self.max = max(self.max, seconds)

    def percentile(self, p: float) -> float:
        """Upper edge of the bucket holding the p-quantile (0 < p <= 1),
        clamped to the observed max."""
        if self.count == 0:
            return 0.0
        target = p * self.count
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= target:
                if i == 0:
                    return min(self._lo, self.max)
                return min(self._lo * self._ratio ** i, self.max)
        return self.max

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "mean_s": self.mean,
            "p50_s": self.percentile(0.50),
            "p95_s": self.percentile(0.95),
            "p99_s": self.percentile(0.99),
            "max_s": self.max,
        }


class Telemetry:
    """Counters + gauges + named histograms behind one lock (histogram
    recording happens on replica completion threads).

    Every mutation bumps a monotonic sequence number, and `snapshot()`
    stamps the document with it (plus the injected clock's time) under
    a ``meta`` section.  Consumers that make decisions from snapshots --
    the autoscaler, the adapt controller -- compare the stamp against
    the live `stamp()` to detect that they are acting on stale data.
    """

    def __init__(self, *, clock=None):
        self._lock = threading.Lock()
        self._clock = clock  # None = unstamped times (seq still works)
        self._seq = 0  # guarded-by: _lock (bumps on every mutation)
        self._mut_t: Optional[float] = None  # guarded-by: _lock
        self._counters: Dict[str, int] = {}  # guarded-by: _lock
        self._gauges: Dict[str, float] = {}  # guarded-by: _lock
        self._hists: Dict[str, Histogram] = {}  # guarded-by: _lock

    def _touch_locked(self) -> None:
        # holds-lock: _lock
        self._seq += 1
        if self._clock is not None:
            self._mut_t = self._clock.now()

    def inc(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._touch_locked()
            self._counters[name] = self._counters.get(name, 0) + n

    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._touch_locked()
            self._gauges[name] = value

    def observe(self, name: str, seconds: float) -> None:
        with self._lock:
            self._touch_locked()
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram()
            h.record(seconds)

    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def histogram(self, name: str) -> Optional[Histogram]:
        with self._lock:
            return self._hists.get(name)

    def stamp(self) -> dict:
        """The live freshness stamp: ``{"seq", "t"}``.  `seq` increments
        on every mutation; `t` is the clock time of the LAST mutation
        (None without an injected clock, or before any mutation) -- so
        ``now - t`` is the snapshot's data age."""
        with self._lock:
            return self._stamp_locked()

    def _stamp_locked(self) -> dict:
        # holds-lock: _lock
        return {"seq": self._seq, "t": self._mut_t}

    def snapshot(self, **sections) -> dict:
        """The one JSON document: counters, gauges, latency percentiles,
        plus any extra sections (scheduler/pool/cache/stage rollups)
        merged in by name.  Always JSON-serializable.  The ``meta``
        section carries the freshness stamp taken atomically with the
        counter/gauge/latency read."""
        with self._lock:
            doc = {
                "meta": self._stamp_locked(),
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "latency": {k: h.snapshot() for k, h in self._hists.items()},
            }
        for name, section in sections.items():
            if section is not None:
                doc[name] = section
        json.dumps(doc)  # refuse to return a non-serializable document
        return doc

    def to_json(self, **sections) -> str:
        return json.dumps(self.snapshot(**sections), indent=1, sort_keys=True)


def stage_rollup(profile: List[tuple]) -> List[dict]:
    """`NetExecutor.profile_stages` rows -> JSON-able per-stage rollup."""
    return [{"label": label, "us": secs * 1e6} for label, secs in profile]
