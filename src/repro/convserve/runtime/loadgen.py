"""Seeded open-loop traffic generators for the serving runtime.

Open-loop means arrival times are drawn up front and never react to the
server (the standard methodology for tail-latency measurement --
closed-loop clients hide queueing delay by slowing down with the
server, the "coordinated omission" trap).  Every generator takes a seed
and returns a plain list of `Arrival`s, so a trace replays identically
against the real clock, the simulated clock, and across the fused /
unfused A-B runs of the benchmark.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.convserve.runtime.queueing import STANDARD


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request's schedule: when it arrives and what it looks like."""

    t: float  # seconds from trace start
    rid: int
    h: int
    w: int
    priority: int = STANDARD
    deadline_s: Optional[float] = None  # relative completion deadline


def _draw(
    rng: np.random.Generator,
    times: Sequence[float],
    sizes: Sequence[int],
    priorities: Sequence[int],
    deadline_s: Optional[float],
) -> List[Arrival]:
    out = []
    for rid, t in enumerate(times):
        side = int(rng.choice(np.asarray(sizes)))
        out.append(
            Arrival(
                t=float(t), rid=rid, h=side, w=side,
                priority=int(rng.choice(np.asarray(priorities))),
                deadline_s=deadline_s,
            )
        )
    return out


def poisson_trace(
    rate_hz: float,
    n: int,
    *,
    seed: int,
    sizes: Sequence[int] = (64,),
    priorities: Sequence[int] = (STANDARD,),
    deadline_s: Optional[float] = None,
) -> List[Arrival]:
    """`n` arrivals with exponential inter-arrival times at `rate_hz`."""
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    return _draw(rng, times, sizes, priorities, deadline_s)


def burst_trace(
    n: int,
    *,
    burst: int,
    period_s: float,
    seed: int,
    sizes: Sequence[int] = (64,),
    priorities: Sequence[int] = (STANDARD,),
    deadline_s: Optional[float] = None,
) -> List[Arrival]:
    """`burst` simultaneous arrivals every `period_s` (flash-crowd
    traffic: exercises admission control and partial-wave flushes)."""
    rng = np.random.default_rng(seed)
    times = [(i // burst) * period_s for i in range(n)]
    return _draw(rng, times, sizes, priorities, deadline_s)


def diurnal_rate(
    mean_rate_hz: float,
    *,
    depth: float = 0.8,
    period_s: float = 86400.0,
    phase_s: float = 0.0,
) -> Callable[[float], float]:
    """Sinusoidal rate profile: the trough sits at ``t = phase_s`` (the
    simulated day starts at night) and the peak half a period later.
    ``depth`` in [0, 1) scales the swing around `mean_rate_hz`."""
    if not 0.0 <= depth < 1.0:
        raise ValueError(f"depth must be in [0, 1), got {depth}")

    def rate(t: float) -> float:
        return mean_rate_hz * (
            1.0 - depth * math.cos(2.0 * math.pi * (t - phase_s) / period_s)
        )

    return rate


def diurnal_trace(
    mean_rate_hz: float,
    n: int,
    *,
    seed: int,
    depth: float = 0.8,
    period_s: float = 86400.0,
    phase_s: float = 0.0,
    sizes: Sequence[int] = (64,),
    priorities: Sequence[int] = (STANDARD,),
    deadline_s: Optional[float] = None,
) -> List[Arrival]:
    """`n` arrivals from a non-homogeneous Poisson process whose rate
    follows `diurnal_rate` -- the "million-user day" shape: quiet night,
    busy noon.  Drawn by Lewis-Shedlock thinning against the peak rate,
    so the arrivals are exactly Poisson at every instant and the whole
    trace is reproducible from the seed.  Compose with `burst_trace`
    (flash crowd on top of the daily curve) via `merge_traces`."""
    rng = np.random.default_rng(seed)
    rate = diurnal_rate(
        mean_rate_hz, depth=depth, period_s=period_s, phase_s=phase_s
    )
    peak = mean_rate_hz * (1.0 + depth)
    times: List[float] = []
    t = 0.0
    while len(times) < n:
        t += rng.exponential(1.0 / peak)
        if rng.uniform() * peak <= rate(t):
            times.append(t)
    return _draw(rng, times, sizes, priorities, deadline_s)


def merge_traces(*traces: Sequence[Arrival]) -> List[Arrival]:
    """Superimpose traces (diurnal baseline + flash-crowd bursts + ...)
    into one arrival-ordered trace with dense, collision-free rids.
    Priorities, sizes, and deadlines ride through unchanged; only the
    rids are re-assigned (in arrival order), so `make_images` on the
    merged trace keys every request correctly."""
    merged = sorted(
        (a for trace in traces for a in trace), key=lambda a: (a.t, a.rid)
    )
    return [
        dataclasses.replace(a, rid=i) for i, a in enumerate(merged)
    ]


def make_images(
    trace: Sequence[Arrival], c: int, *, seed: int, scale: float = 0.1
) -> Dict[int, np.ndarray]:
    """Seeded HWC images matching a trace, keyed by rid."""
    rng = np.random.default_rng(seed)
    return {
        a.rid: (rng.standard_normal((a.h, a.w, c)) * scale).astype(
            np.float32
        )
        for a in trace
    }
