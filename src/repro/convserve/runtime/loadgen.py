"""Seeded open-loop traffic generators for the serving runtime.

Open-loop means arrival times are drawn up front and never react to the
server (the standard methodology for tail-latency measurement --
closed-loop clients hide queueing delay by slowing down with the
server, the "coordinated omission" trap).  Every generator takes a seed
and returns a plain list of `Arrival`s, so a trace replays identically
against the real clock, the simulated clock, and across the fused /
unfused A-B runs of the benchmark.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.convserve.runtime.queueing import STANDARD


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request's schedule: when it arrives and what it looks like."""

    t: float  # seconds from trace start
    rid: int
    h: int
    w: int
    priority: int = STANDARD
    deadline_s: Optional[float] = None  # relative completion deadline


def _draw(
    rng: np.random.Generator,
    times: Sequence[float],
    sizes: Sequence[int],
    priorities: Sequence[int],
    deadline_s: Optional[float],
) -> List[Arrival]:
    out = []
    for rid, t in enumerate(times):
        side = int(rng.choice(np.asarray(sizes)))
        out.append(
            Arrival(
                t=float(t), rid=rid, h=side, w=side,
                priority=int(rng.choice(np.asarray(priorities))),
                deadline_s=deadline_s,
            )
        )
    return out


def poisson_trace(
    rate_hz: float,
    n: int,
    *,
    seed: int,
    sizes: Sequence[int] = (64,),
    priorities: Sequence[int] = (STANDARD,),
    deadline_s: Optional[float] = None,
) -> List[Arrival]:
    """`n` arrivals with exponential inter-arrival times at `rate_hz`."""
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    return _draw(rng, times, sizes, priorities, deadline_s)


def burst_trace(
    n: int,
    *,
    burst: int,
    period_s: float,
    seed: int,
    sizes: Sequence[int] = (64,),
    priorities: Sequence[int] = (STANDARD,),
    deadline_s: Optional[float] = None,
) -> List[Arrival]:
    """`burst` simultaneous arrivals every `period_s` (flash-crowd
    traffic: exercises admission control and partial-wave flushes)."""
    rng = np.random.default_rng(seed)
    times = [(i // burst) * period_s for i in range(n)]
    return _draw(rng, times, sizes, priorities, deadline_s)


def make_images(
    trace: Sequence[Arrival], c: int, *, seed: int, scale: float = 0.1
) -> Dict[int, np.ndarray]:
    """Seeded HWC images matching a trace, keyed by rid."""
    rng = np.random.default_rng(seed)
    return {
        a.rid: (rng.standard_normal((a.h, a.w, c)) * scale).astype(
            np.float32
        )
        for a in trace
    }
