"""Injectable time source for the serving runtime.

Every runtime component that reasons about time -- admission stamps,
deadline slack, wave flushes, latency histograms -- reads it through a
`Clock` so the whole scheduler can run against a `SimClock` in tests:
deterministic, instant, and able to prove deadline behaviour (a partial
wave flushed at an exact simulated instant) without ever sleeping.
"""

from __future__ import annotations

import time


class Clock:
    """Monotonic seconds + sleep.  The interface both impls satisfy.

    `realtime` tells the runtime whether wall-clock measurements (wave
    compute times) are commensurable with this clock's timeline: under
    a `SimClock` they are not, and feeding them into the scheduler's
    slack model would make "deterministic" simulated scheduling depend
    on host speed.
    """

    realtime = True

    def now(self) -> float:
        raise NotImplementedError

    def sleep(self, seconds: float) -> None:
        raise NotImplementedError


class RealClock(Clock):
    """Wall time (`time.monotonic`): the production clock."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


class SimClock(Clock):
    """Simulated time: `sleep` (and `advance`) move `now` forward
    instantly.  Starts at 0.0 so test timestamps read as offsets."""

    realtime = False

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def sleep(self, seconds: float) -> None:
        self.advance(seconds)

    def advance(self, seconds: float) -> None:
        if seconds < 0:
            raise ValueError(f"cannot move time backwards ({seconds})")
        self._t += seconds
