"""Replica pool: N executors of one net sharing one `KernelCache`.

The paper's pre-transformed kernels are the expensive shared state --
the whole point of the cache is that transforms are prepared ONCE and
served everywhere, so replicas must share it (the cache is internally
locked).  Each replica owns its jit-compiled program table; waves are
dispatched to the least-loaded replica on a thread pool, with
per-replica in-flight and dispatch accounting.  `workers=0` runs waves
inline on the caller's thread -- the deterministic mode the simulated-
clock tests use (no thread interleaving, same results, same counters).
"""

from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence

import dataclasses

import jax
import numpy as np

from repro.convserve.runtime.clock import Clock, RealClock
from repro.convserve.runtime.scheduler import Wave


@dataclasses.dataclass
class WaveResult:
    """One executed wave: per-request outputs plus where/how long.
    `compiled` marks a cold wave (the replica jitted a new program for
    this shape): its wall time is compile + compute, so the runtime
    keeps it out of the deadline-slack service estimate."""

    wave: Wave
    outputs: Dict[int, np.ndarray]  # rid -> (H', W', C')
    replica: int
    compute_s: float
    compiled: bool = False


class ReplicaPool:
    """Dispatches waves across replicas of one compiled net.

    `executors` are callables ``ex(batch, sizes)`` exposing ``spec`` and
    ``cache`` (both `NetExecutor` and `engine.CompiledNet` qualify) that
    were built against the SAME `KernelCache` -- asserted here, because
    separate caches would silently re-transform every kernel per
    replica.
    """

    def __init__(self, executors: Sequence, *, workers: Optional[int] = None,
                 clock: Optional[Clock] = None):
        if not executors:
            raise ValueError("replica pool needs at least one executor")
        cache = executors[0].cache
        spec = executors[0].spec
        for ex in executors[1:]:
            if ex.cache is not cache:
                raise ValueError(
                    "replicas must share one KernelCache (pass the same "
                    "cache/Engine when compiling each replica)"
                )
            if ex.spec is not spec and ex.spec != spec:
                raise ValueError("replicas must serve the same NetSpec")
        self.spec = spec
        self.cache = cache
        self.clock = clock or RealClock()
        self.workers = len(executors) if workers is None else workers
        self._pool = (
            ThreadPoolExecutor(
                max_workers=self.workers, thread_name_prefix="replica"
            )
            if self.workers > 0
            else None
        )
        self._lock = threading.Lock()
        self.executors = list(executors)  # guarded-by: _lock
        self.in_flight = [0] * len(executors)  # guarded-by: _lock
        self.dispatched = [0] * len(executors)  # guarded-by: _lock

    @classmethod
    def build(cls, engine, spec, weights, n: int, *,
              workers: Optional[int] = None,
              clock: Optional[Clock] = None, **compile_kwargs):
        """Compile `n` replicas of one net on one engine (hence one
        shared cache) and pool them.  The net is PLANNED once; replicas
        2..n bind the first replica's plan -- planning n times would be
        redundant roofline work, and with measurement-backed knobs
        (``tune_r=True``) could even hand different replicas different
        programs, breaking the pool's shared-shape assumption."""
        first = engine.compile(spec, weights, **compile_kwargs)
        fuse = compile_kwargs.get("fuse", True)
        nets = [first] + [
            engine.compile(spec, weights, plan=first.plan, fuse=fuse)
            for _ in range(n - 1)
        ]
        return cls(nets, workers=workers, clock=clock)

    # ------------------------------------------------------- dispatch

    def _pick(self):
        """Least-loaded replica; dispatch count breaks ties so the
        synchronous mode still spreads waves across replicas.  Returns
        ``(index, executor)`` -- the executor is read under the same
        lock, so a concurrent `swap` cannot slip between pick and run."""
        with self._lock:
            i = min(
                range(len(self.executors)),
                key=lambda j: (self.in_flight[j], self.dispatched[j], j),
            )
            self.in_flight[i] += 1
            self.dispatched[i] += 1
            return i, self.executors[i]

    def _run(self, i: int, ex, wave: Wave) -> WaveResult:
        try:
            batch, sizes = wave.assemble()
            before = ex.compile_count
            t0 = self.clock.now()
            y = ex(batch, sizes)
            y = np.asarray(jax.block_until_ready(y))
            dt = self.clock.now() - t0
            return WaveResult(
                wave=wave, outputs=wave.crop(self.spec, y),
                replica=i, compute_s=dt,
                compiled=ex.compile_count > before,
            )
        finally:
            with self._lock:
                self.in_flight[i] -= 1

    def submit(self, wave: Wave) -> "Future[WaveResult]":
        """Run the wave on the least-loaded replica.  Returns a Future;
        with ``workers=0`` it is already completed (inline execution)."""
        i, ex = self._pick()
        if self._pool is None:
            fut: Future = Future()
            try:
                fut.set_result(self._run(i, ex, wave))
            except BaseException as e:  # mirror executor.submit semantics
                fut.set_exception(e)
            return fut
        return self._pool.submit(self._run, i, ex, wave)

    def run(self, wave: Wave) -> WaveResult:
        """Synchronous convenience wrapper."""
        return self.submit(wave).result()

    def swap(self, executors: Sequence, *, timeout_s: float = 5.0) -> list:
        """Atomically replace every replica's executor with `executors`
        (the hot-swap path).  Waits for all in-flight waves to drain on
        the OLD program first -- the drain check and the flip happen
        under the dispatch lock, so no wave can be picked between them.
        Returns the outgoing executors (the caller diffs their cache
        keys against the new ones to invalidate stale transforms).
        """
        new = list(executors)
        if len(new) != len(self.executors):
            raise ValueError(
                f"swap needs {len(self.executors)} executors, got {len(new)}"
            )
        for ex in new:
            if ex.cache is not self.cache:
                raise ValueError(
                    "swapped-in replicas must share the pool's KernelCache"
                )
            if ex.spec is not self.spec and ex.spec != self.spec:
                raise ValueError("swapped-in replicas must serve the same NetSpec")
        deadline = self.clock.now() + timeout_s
        while True:
            with self._lock:
                if sum(self.in_flight) == 0:
                    old = self.executors
                    self.executors = new
                    return old
            if self.clock.now() > deadline:
                raise TimeoutError(
                    f"in-flight waves did not drain within {timeout_s}s"
                )
            self.clock.sleep(0.001)

    def has_capacity(self) -> bool:
        """Whether a dispatched wave would start immediately.  The
        runtime gates wave formation on this: dispatching into a
        saturated pool would just move the queue somewhere batching
        can no longer reach it."""
        if self._pool is None:
            return True
        with self._lock:
            return sum(self.in_flight) < self.workers

    def warmup(self, buckets: Sequence[int],
               batch_sizes: Sequence[int]) -> None:
        """Compile every (bucket, batch size) program on EVERY replica
        and prepare the shared transforms, using all-padding waves
        (batch rows of extent 0 are fully masked, so warmup computes
        zeros and cannot affect any served output)."""
        c0 = self.spec.conv_layers()[0][1].c_in
        for ex in self.executors:
            for b in buckets:
                for s in batch_sizes:
                    x = np.zeros((s, b, b, c0), np.float32)
                    jax.block_until_ready(ex(x, np.zeros((s, 2), np.int32)))

    # ---------------------------------------------------------- stats

    def profile_stages(self, side: int, batch: int = 1) -> List[tuple]:
        """Per-stage wall times on replica 0 at a bucket geometry (the
        telemetry snapshot's stage rollup)."""
        c0 = self.spec.conv_layers()[0][1].c_in
        x = np.zeros((batch, side, side, c0), np.float32)
        return self.executors[0].profile_stages(x)

    def stats(self) -> dict:
        with self._lock:
            per_replica = {
                "dispatched": list(self.dispatched),
                "in_flight": list(self.in_flight),
            }
            executors = list(self.executors)
        return {
            "replicas": len(executors),
            "workers": self.workers,
            **per_replica,
            "compiled_programs": sum(
                ex.compile_count for ex in executors
            ),
            "cache": self.cache.stats(),
        }

    def shutdown(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
