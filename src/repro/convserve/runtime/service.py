"""The online serving runtime: admit -> queue -> wave -> replica ->
telemetry.

`ServeRuntime` glues the deadline-aware `WaveScheduler` to a
`ReplicaPool` behind one submit/poll/drain surface:

    pool = ReplicaPool.build(engine, spec, weights, n=2)
    rt = ServeRuntime(pool, RuntimeConfig(buckets=(32, 64), slo_s=0.05))
    rt.submit(image, rid=0)      # None, or a Rejection (reason-coded)
    rt.poll()                    # dispatch every wave that is ready NOW
    rt.drain()                   # flush + wait for in-flight waves
    rt.results[0]                # (H', W', C')
    rt.stats()                   # the one telemetry JSON document

The runtime never owns a scheduling thread: `poll()` dispatches every
wave the scheduler considers ready at the injected clock's "now", and
`play()` replays an open-loop arrival trace, sleeping only until the
next arrival or the next deadline flush -- the same loop drives real
traffic (RealClock + threaded replicas) and deterministic tests
(SimClock + inline replicas) with identical scheduling decisions.
Request completions land on replica threads; results, counters, and
histograms are lock-protected.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.convserve.obs.trace import (
    CAT_REQUEST,
    CAT_WAVE,
    NULL_TRACER,
    attach as attach_tracer,
)
from repro.convserve.runtime.clock import Clock, RealClock
from repro.convserve.runtime.loadgen import Arrival
from repro.convserve.runtime.queueing import Rejection, Request, STANDARD
from repro.convserve.runtime.replicas import ReplicaPool, WaveResult
from repro.convserve.runtime.scheduler import (
    RuntimeConfig,
    Wave,
    WaveScheduler,
)
from repro.convserve.runtime.telemetry import Telemetry, stage_rollup


class ServeRuntime:
    """One net's online serving loop over a replica pool."""

    def __init__(
        self,
        pool: ReplicaPool,
        cfg: RuntimeConfig,
        *,
        clock: Optional[Clock] = None,
        telemetry: Optional[Telemetry] = None,
        tracer=None,
        recorder=None,
    ):
        self.pool = pool
        self.cfg = cfg
        self.clock = clock or RealClock()
        self.telemetry = telemetry or Telemetry(clock=self.clock)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.recorder = recorder  # obs.FlightRecorder (optional)
        self.scheduler = WaveScheduler(pool.spec, cfg)
        self._lock = threading.Lock()
        self._done_cv = threading.Condition(self._lock)
        self._wake = threading.Event()  # set by submit(): interrupts idle
        self._outstanding = 0  # guarded-by: _lock
        self._next_rid = 0  # guarded-by: _lock
        self.results: Dict[int, np.ndarray] = {}  # guarded-by: _lock
        self.rejections: Dict[int, Rejection] = {}  # guarded-by: _lock
        self.errors: List[BaseException] = []  # guarded-by: _lock
        self._wave_observers: List = []
        # open request spans, closed when the result lands / is lost
        self._req_spans: Dict[int, int] = {}  # guarded-by: _lock
        # latest wave flow id per bucket: links wave -> stage profiling
        self._wave_flows: Dict[int, str] = {}  # guarded-by: _lock
        # in-flight wave spans, keyed by the pool future's identity
        self._wave_ctx: Dict[int, int] = {}  # guarded-by: _lock
        if self.tracer.active:
            for ex in getattr(self.pool, "executors", ()):
                attach_tracer(ex, self.tracer)

    def _first_executor(self):
        exs = getattr(self.pool, "executors", None)
        return exs[0] if exs else None

    def add_wave_observer(self, fn) -> None:
        """Register ``fn(result: WaveResult)`` to run after each wave's
        client-side bookkeeping completes.  This is the adapt loop's tap
        point: shadow duplication happens here, strictly AFTER the live
        wave's results and latency histograms are recorded, so whatever
        the observer does can never count toward client latency SLOs.
        Observer exceptions are counted (`wave_observer_errors`), never
        propagated into the serving path."""
        self._wave_observers.append(fn)

    # ------------------------------------------------------ admission

    def submit(
        self,
        image: np.ndarray,
        *,
        rid: Optional[int] = None,
        priority: int = STANDARD,
        deadline_s: Optional[float] = None,
    ) -> Optional[Rejection]:
        """Admit one request.  Returns None on success, else the
        `Rejection` (also kept in `self.rejections`) -- the runtime
        never throws at callers for overload."""
        now = self.clock.now()
        with self._lock:
            if rid is None:
                rid = self._next_rid
            self._next_rid = max(self._next_rid, rid) + 1
        req = Request(
            rid=rid,
            image=np.asarray(image),
            priority=priority,
            deadline=(now + deadline_s) if deadline_s is not None
            else float("inf"),
        )
        rej = self.scheduler.admit(req, now)
        if rej is not None:
            self.telemetry.inc("rejected")
            self.telemetry.inc(f"rejected.{rej.reason}")
            self.tracer.instant(
                "request.rejected", CAT_REQUEST, rid=rid, reason=rej.reason
            )
            with self._lock:
                self.rejections[rid] = rej
            return rej
        self.telemetry.inc("admitted")
        sid = self.tracer.begin(
            f"request:{rid}", CAT_REQUEST,
            flow_out=(f"r{rid}",), rid=rid, priority=priority,
        )
        if sid:
            with self._lock:
                self._req_spans[rid] = sid
        # a serving loop asleep until the next deadline/arrival must
        # reconsider now that this request's own deadline is in play
        self._wake.set()
        return None

    # ------------------------------------------------------- dispatch

    def warmup(self, batch_sizes: Optional[Sequence[int]] = None) -> None:
        """Compile every (bucket, batch size) program on every replica
        and prepare the shared kernel transforms before traffic.  Also
        seeds the scheduler's hysteresis, so the first deadline-flushed
        partial wave already rides a warm program.  Defaults to the one
        shape steady traffic uses: the full `max_batch` wave."""
        sizes = list(batch_sizes) if batch_sizes else [self.cfg.max_batch]
        self.pool.warmup(self.cfg.buckets, sizes)
        for b in self.cfg.buckets:
            for s in sizes:
                self.scheduler.note_compiled(b, s)

    def poll(self) -> int:
        """Dispatch ready waves (full queues first come first via
        round-robin, then expired slack) while the pool has a free
        replica slot.  Returns the number of waves dispatched.

        The capacity gate is what preserves batching under overload:
        with every replica busy, ready requests stay IN the scheduler's
        queues -- where late arrivals can still join their wave -- and
        the backlog drains as full waves instead of a convoy of
        singles queued behind a saturated pool."""
        n = 0
        while self.pool.has_capacity():
            wave = self.scheduler.next_wave(self.clock.now())
            if wave is None:
                return n
            self._dispatch(wave)
            n += 1
        return n

    def _dispatch(self, wave: Wave) -> None:
        now = self.clock.now()
        for r in wave.requests:
            r.t_dispatch = now
        with self._lock:
            self._outstanding += 1
        self.telemetry.inc("waves")
        self.telemetry.inc(f"waves.{wave.reason}")
        if wave.partial:
            self.telemetry.inc("partial_waves")
        # the wave span opens on the dispatch thread and closes on a
        # replica completion thread: explicit begin/end, id carried in
        # _wave_ctx keyed by the pool future (registered BEFORE the
        # callback so inline/already-done futures still find it)
        sid = self.tracer.begin(
            f"wave:b{wave.bucket}", CAT_WAVE,
            flow_in=tuple(f"r{r.rid}" for r in wave.requests),
            bucket=wave.bucket, n=len(wave.requests),
            reason=wave.reason, partial=wave.partial,
        )
        fut = self.pool.submit(wave)
        if sid:
            with self._lock:
                self._wave_ctx[id(fut)] = sid
        fut.add_done_callback(self._on_done)

    def _close_wave_span(self, fut, wave: Optional[Wave], **args) -> None:
        """Close the wave span opened at dispatch (and the request spans
        it carried, when the wave's outcome is known here)."""
        with self._lock:
            sid = self._wave_ctx.pop(id(fut), 0)
        if not sid:
            return
        flow = f"w{sid}"
        self.tracer.end(sid, flow_out=(flow,), **args)
        if wave is not None:
            with self._lock:
                self._wave_flows[wave.bucket] = flow

    def _on_done(self, fut) -> None:
        try:
            res: WaveResult = fut.result()
        except BaseException as e:  # keep serving; surface in stats
            self.telemetry.inc("wave_errors")
            self._close_wave_span(fut, None, error=type(e).__name__)
            self.tracer.instant("wave.error", CAT_WAVE, error=str(e)[:200])
            self._trip_on_error(e)
            with self._done_cv:
                self.errors.append(e)
                self._outstanding -= 1
                self._done_cv.notify_all()
            return
        done = self.clock.now()
        wave = res.wave
        if res.compiled:
            # cold wave: wall time is jit compile + compute; feeding it
            # into the slack EWMA would zero every queue's slack and
            # degenerate scheduling into per-request waves until the
            # estimate decays.  Count it, histogram it separately.
            self.telemetry.inc("cold_waves")
            self.telemetry.observe("compute_cold", res.compute_s)
        else:
            if self.clock.realtime:
                # under a SimClock, wall-clock compute is not on the
                # simulated timeline: feeding it into the slack model
                # would make "deterministic" scheduling host-dependent,
                # so the estimate stays at cfg.service_est_s (tests set
                # it explicitly / via observe_service)
                self.scheduler.observe_service(wave.bucket, res.compute_s)
            self.telemetry.observe("compute", res.compute_s)
        self.telemetry.inc("images", len(wave.requests))
        self._close_wave_span(
            fut, wave, replica=res.replica, compute_s=res.compute_s,
            compiled=res.compiled, pid=res.replica,
        )
        misses = 0
        for r in wave.requests:
            r.t_done = done
            self.telemetry.observe("queue_wait", r.t_dispatch - r.t_admit)
            self.telemetry.observe("e2e", done - r.t_admit)
            miss = done > r.deadline
            if miss:
                self.telemetry.inc("deadline_miss")
                misses += 1
            with self._lock:
                rsid = self._req_spans.pop(r.rid, 0)
            self.tracer.end(rsid, deadline_miss=miss)
        if misses and self.recorder is not None:
            self.recorder.trip(
                "slo_breach", bucket=wave.bucket, misses=misses
            )
        with self._done_cv:
            self.results.update(res.outputs)
            self._outstanding -= 1
            self._done_cv.notify_all()
        for fn in self._wave_observers:
            try:
                fn(res)
            except Exception:
                self.telemetry.inc("wave_observer_errors")

    def _trip_on_error(self, e: BaseException) -> None:
        """Route a wave-path exception to the flight recorder when it is
        one of the dump-worthy kinds."""
        if self.recorder is None:
            return
        from repro.convserve.check.diagnostics import VerificationError

        if isinstance(e, VerificationError):
            self.recorder.trip("verification_error", error=str(e)[:200])

    # ------------------------------------------------------ the loop

    def run_until(self, t_target: float) -> None:
        """Serve until the clock reaches `t_target`: dispatch ready
        waves, otherwise sleep to the next deadline flush (or the
        target).  With a SimClock this advances simulated time."""
        while True:
            self.poll()
            now = self.clock.now()
            if now >= t_target:
                return
            wake = min(self.scheduler.next_event(now), t_target)
            with self._done_cv:
                busy = self._outstanding > 0
            if busy:
                # waves in flight (threaded pool): wait on the completion
                # signal, bounded by the next scheduled instant, so a
                # freed replica dispatches the next ready wave the moment
                # it exists instead of idling until wake/t_target
                self._await_completion(
                    min(wake - now, 0.05) if wake > now else 0.005
                )
            elif wake > now:
                self._sleep_interruptible(wake - now)
            # wake == now and idle: a bucket crossed its flush instant
            # this iteration; loop and poll again

    def _sleep_interruptible(self, seconds: float) -> None:
        """Idle until `seconds` pass OR a client thread submits (which
        may move the next deadline earlier than the wake time this loop
        computed).  SimClock sleeps advance simulated time directly --
        sim tests drive submit and poll from one thread."""
        if self.clock.realtime:
            self._wake.wait(timeout=seconds)
            self._wake.clear()
        else:
            self.clock.sleep(seconds)

    def _await_completion(self, timeout: float) -> None:
        with self._done_cv:
            if self._outstanding:
                self._done_cv.wait(timeout=timeout)

    def drain(self) -> None:
        """Flush every queue (ready waves first, then forced partial
        drains, all capacity-gated) and wait for every in-flight wave
        to complete."""
        while True:
            self.poll()
            if self.pool.has_capacity() and self.scheduler.depth():
                wave = self.scheduler.drain_wave(self.clock.now())
                if wave is not None:
                    self._dispatch(wave)
                    continue
            with self._done_cv:
                if not self._outstanding and not self.scheduler.depth():
                    return
                if self._outstanding:
                    self._done_cv.wait(timeout=0.05)

    def play(
        self,
        trace: Sequence[Arrival],
        images: Dict[int, np.ndarray],
    ) -> Dict[int, np.ndarray]:
        """Replay an open-loop arrival trace (loadgen.*_trace) against
        this runtime, drain, and return the results map."""
        t0 = self.clock.now()
        for a in sorted(trace, key=lambda a: a.t):
            self.run_until(t0 + a.t)
            self.submit(
                images[a.rid], rid=a.rid,
                priority=a.priority, deadline_s=a.deadline_s,
            )
        self.drain()
        return dict(self.results)

    def pop_result(self, rid: int, default=None):
        """Consume one result (and its memory).  Long-running services
        should pop (or periodically clear `results`) -- the dict itself
        never evicts, which is fine for bounded traces but grows without
        bound under continuous traffic."""
        with self._lock:
            return self.results.pop(rid, default)

    # ---------------------------------------------------------- stats

    def stats(self, profile_bucket: Optional[int] = None) -> dict:
        """The runtime's single JSON document: latency histograms plus
        scheduler / pool / shared-cache sections (and, on request, the
        per-stage profile rollup at one bucket geometry)."""
        self.telemetry.set_gauge("queue_depth", self.scheduler.depth())
        stages = None
        roofline = None
        if profile_bucket is not None:
            with self._lock:
                fid = self._wave_flows.get(profile_bucket)
            # the flow hint links the latest wave at this bucket to the
            # stage spans the profile sweep opens
            with self.tracer.flow(fid):
                profile = self.pool.profile_stages(profile_bucket)
            stages = stage_rollup(profile)
            roofline = self._roofline_section(profile)
        trace = self.tracer.stats() if self.tracer.active else None
        return self.telemetry.snapshot(
            scheduler=self.scheduler.stats(),
            pool=self.pool.stats(),
            cache=self.pool.cache.stats(),
            stages=stages,
            roofline=roofline,
            trace=trace,
        )

    def _roofline_section(self, profile) -> Optional[dict]:
        """Join the stage profile with TileAlgebra + HardwareModel into
        the live roofline attribution (None when the pool's executors do
        not expose a program/hw pair, e.g. bare NetExecutors)."""
        ex = self._first_executor()
        program = getattr(ex, "program", None)
        hw = getattr(ex, "hw", None)
        if program is None or hw is None:
            return None
        from repro.convserve.obs import roofline as roofline_mod

        return roofline_mod.roofline_section(
            program, profile, hw, batch=1, tracer=self.tracer
        )

    def shutdown(self) -> None:
        self.drain()
        self.pool.shutdown()
