"""Bounded per-bucket request queues with priority classes and
admission control.

A `Request` is one image plus its scheduling metadata (priority class,
absolute completion deadline).  Admission either stamps it into exactly
one spatial bucket's `BucketQueue` or returns a `Rejection` carrying a
machine-readable reason -- overload is an explicit, observable outcome,
never an unbounded queue.  Within a bucket, requests pop in (priority
class, FIFO) order; fairness *across* buckets is the scheduler's job
(round-robin in `scheduler.WaveScheduler`).
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, List

import numpy as np

# priority classes: lower is more urgent
INTERACTIVE = 0
STANDARD = 1
BATCH = 2

# admission-reject reasons (the closed vocabulary telemetry counts by);
# "scaling" is the fleet runtime's scale-up admission gate: while new
# replicas warm, the queue is capped at what the READY ones can drain
REJECT_QUEUE_FULL = "queue_full"
REJECT_TOO_LARGE = "too_large"
REJECT_BAD_SHAPE = "bad_shape"
REJECT_SCALING = "scaling"
REJECT_REASONS = (
    REJECT_QUEUE_FULL, REJECT_TOO_LARGE, REJECT_BAD_SHAPE, REJECT_SCALING,
)


@dataclasses.dataclass
class Request:
    """One in-flight image request.  `deadline` is the absolute clock
    time the response should be *completed* by (inf = no deadline; the
    scheduler assigns one from the priority class's SLO when unset).
    Admission fills `bucket`/`t_admit`; dispatch and completion stamp
    the remaining times for the latency histograms."""

    rid: int
    image: np.ndarray  # (H, W, C)
    priority: int = STANDARD
    deadline: float = math.inf
    # stamped by the runtime:
    bucket: int = -1
    t_admit: float = math.nan
    t_dispatch: float = math.nan
    t_done: float = math.nan


@dataclasses.dataclass(frozen=True)
class Rejection:
    """Why a request was not admitted."""

    rid: int
    reason: str  # one of REJECT_REASONS
    detail: str = ""


class BucketQueue:
    """One spatial bucket's pending requests: a bounded deque per
    priority class, popped urgent-first and FIFO within a class."""

    def __init__(self, bucket: int, depth: int):
        self.bucket = bucket
        self.depth = depth
        self._q: Dict[int, Deque[Request]] = {}

    def __len__(self) -> int:
        return sum(len(q) for q in self._q.values())

    @property
    def full(self) -> bool:
        return len(self) >= self.depth

    def push(self, req: Request) -> None:
        if self.full:
            raise OverflowError(
                f"bucket {self.bucket} queue at depth bound {self.depth}"
            )
        self._q.setdefault(req.priority, deque()).append(req)

    def pop(self, n: int) -> List[Request]:
        """Up to `n` requests, most-urgent class first, FIFO within."""
        out: List[Request] = []
        for pri in sorted(self._q):
            q = self._q[pri]
            while q and len(out) < n:
                out.append(q.popleft())
            if len(out) == n:
                break
        return out

    def oldest_deadline(self) -> float:
        """Earliest completion deadline among queued requests (inf when
        empty or none carry a deadline) -- the scheduler's flush driver."""
        return min(
            (r.deadline for q in self._q.values() for r in q),
            default=math.inf,
        )
