"""Trace exporters: Chrome/Perfetto JSON, Prometheus text, FlightRecorder.

The Chrome trace event format is the interchange surface: a JSON array
of events (`ph: "X"` complete spans, `"i"` instants, `"s"`/`"f"` flow
pairs) that loads directly in Perfetto / chrome://tracing.  `pid` is
the replica, `tid` the shard, and flow arrows link a request span to
the wave that served it and the wave to the stage executions it timed.

`FlightRecorder` is the black box: it watches for the three "something
went visibly wrong" signals -- an SLO breach, a `WaveLoss`, a
`VerificationError` -- and dumps the tracer's ring buffer (plus the
telemetry snapshot, when given one) to a `.trace.json` the moment one
fires, throttled to `max_dumps` per incident class so a loss storm
cannot fill the disk.
"""

from __future__ import annotations

import json
import threading
from typing import List, Optional

from repro.convserve.obs.trace import InstantEvent, Span

_US = 1e6  # Clock seconds -> trace microseconds


def chrome_trace_events(events, *, process_names=None) -> List[dict]:
    """Render a ring snapshot as a Chrome trace event array.

    Flow links: a span carrying ``flow_out`` ids emits a flow *start*
    (``"s"``) at its close; a span carrying ``flow_in`` ids emits the
    matching flow *finish* (``"f"``, ``bp: "e"``) at its open.  Chrome
    draws one arrow per id from every start to every finish, which is
    exactly request -> wave -> stage.  Only flows with BOTH ends in the
    ring are emitted: every wave advertises its flow id at close, but
    only the profiled wave gains a stage-side consumer, and a dangling
    half-arrow is exporter noise, not information.
    """
    out: List[dict] = []
    flow_ids = {}  # flow string -> stable small int

    def fid(flow: str) -> int:
        return flow_ids.setdefault(flow, len(flow_ids) + 1)

    starts = {f for e in events if isinstance(e, Span) for f in e.flow_out}
    ends = {f for e in events if isinstance(e, Span) for f in e.flow_in}
    live_flows = starts & ends

    for e in events:
        if isinstance(e, Span):
            out.append({
                "ph": "X",
                "name": e.name,
                "cat": e.cat,
                "ts": e.t0 * _US,
                "dur": max(0.0, e.dur) * _US,
                "pid": e.pid,
                "tid": e.tid,
                "args": dict(e.args),
            })
            for flow in e.flow_in:
                if flow in live_flows:
                    out.append({
                        "ph": "f", "bp": "e", "name": flow, "cat": e.cat,
                        "id": fid(flow), "ts": e.t0 * _US,
                        "pid": e.pid, "tid": e.tid,
                    })
            for flow in e.flow_out:
                if flow in live_flows:
                    out.append({
                        "ph": "s", "name": flow, "cat": e.cat,
                        "id": fid(flow), "ts": e.t1 * _US,
                        "pid": e.pid, "tid": e.tid,
                    })
        elif isinstance(e, InstantEvent):
            out.append({
                "ph": "i",
                "name": e.name,
                "cat": e.cat,
                "ts": e.t * _US,
                "pid": e.pid,
                "tid": e.tid,
                "s": "p",  # process-scoped instant
                "args": dict(e.args),
            })
    if process_names:
        for pid, name in process_names.items():
            out.append({
                "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                "args": {"name": name},
            })
    return out


def validate_chrome_trace(data) -> List[str]:
    """Structural validation of an exported trace; returns problems
    (empty list == valid).  Checks the acceptance-criteria invariants:
    loads as an event array, every duration event is well-formed and
    non-negative, and every flow id has both a start and a finish."""
    problems: List[str] = []
    if not isinstance(data, list):
        return [f"trace is {type(data).__name__}, expected a JSON array"]
    starts, finishes = set(), set()
    for i, e in enumerate(data):
        if not isinstance(e, dict) or "ph" not in e:
            problems.append(f"event {i}: not an event object")
            continue
        ph = e["ph"]
        if ph in ("X", "i", "s", "f") and "name" not in e:
            problems.append(f"event {i}: ph={ph} missing name")
        if ph == "X":
            if "dur" not in e or "ts" not in e:
                problems.append(f"event {i}: complete event missing ts/dur")
            elif e["dur"] < 0:
                problems.append(f"event {i}: negative duration {e['dur']}")
        elif ph == "s":
            starts.add(e.get("id"))
        elif ph == "f":
            finishes.add(e.get("id"))
    for fid in sorted(starts - finishes, key=str):
        problems.append(f"flow id {fid}: start without finish")
    for fid in sorted(finishes - starts, key=str):
        problems.append(f"flow id {fid}: finish without start")
    return problems


def write_trace(tracer, path, *, process_names=None, extra_events=()) -> int:
    """Dump the tracer's ring as Chrome-trace JSON; returns the event
    count written."""
    events = chrome_trace_events(tracer.events(), process_names=process_names)
    events.extend(extra_events)
    with open(path, "w") as f:
        json.dump(events, f)
    return len(events)


def _prom_name(name: str) -> str:
    out = [c if c.isalnum() or c == "_" else "_" for c in name]
    if out and out[0].isdigit():
        out.insert(0, "_")
    return "".join(out)


def prometheus_text(snapshot: dict, *, prefix: str = "convserve") -> str:
    """Render a `Telemetry.snapshot()` document in the Prometheus text
    exposition format (counters, gauges, and latency quantiles)."""
    lines: List[str] = []
    for name, val in sorted(snapshot.get("counters", {}).items()):
        m = f"{prefix}_{_prom_name(name)}_total"
        lines.append(f"# TYPE {m} counter")
        lines.append(f"{m} {val}")
    for name, val in sorted(snapshot.get("gauges", {}).items()):
        m = f"{prefix}_{_prom_name(name)}"
        lines.append(f"# TYPE {m} gauge")
        lines.append(f"{m} {val}")
    for name, h in sorted(snapshot.get("latency", {}).items()):
        m = f"{prefix}_{_prom_name(name)}_seconds"
        lines.append(f"# TYPE {m} summary")
        for q, key in (("0.5", "p50_s"), ("0.95", "p95_s"), ("0.99", "p99_s")):
            if key in h:
                lines.append(f'{m}{{quantile="{q}"}} {h[key]}')
        if "count" in h:
            lines.append(f"{m}_count {h['count']}")
        if "count" in h and "mean_s" in h:
            lines.append(f"{m}_sum {h['count'] * h['mean_s']}")
    return "\n".join(lines) + "\n"


# the signals a flight recorder dumps on
TRIP_SLO_BREACH = "slo_breach"
TRIP_WAVE_LOSS = "wave_loss"
TRIP_VERIFICATION = "verification_error"


class FlightRecorder:
    """Dump the ring buffer when the serving stack visibly misbehaves.

    `trip(reason)` is called by the runtime on an SLO breach (deadline
    miss), a `WaveLoss`, or a `VerificationError`; each distinct reason
    gets at most `max_dumps` dumps, written as
    ``{path_prefix}.{reason}.{n}.trace.json``.  A disabled recorder
    (``path_prefix=None``) only counts trips -- useful in tests and in
    benches that want the counters without the files.
    """

    def __init__(
        self,
        tracer,
        *,
        telemetry=None,
        path_prefix: Optional[str] = None,
        max_dumps: int = 3,
    ):
        self.tracer = tracer
        self.telemetry = telemetry
        self.path_prefix = path_prefix
        self.max_dumps = int(max_dumps)
        self._lock = threading.Lock()
        self._trips = {}  # guarded-by: _lock (reason -> trip count)
        self._dumps: List[str] = []  # guarded-by: _lock (paths written)

    def trip(self, reason: str, **detail) -> Optional[str]:
        """Record an incident; dump the ring if this reason still has
        dump budget.  Returns the path written, or None."""
        with self._lock:
            n = self._trips.get(reason, 0) + 1
            self._trips[reason] = n
            want_dump = self.path_prefix is not None and n <= self.max_dumps
            path = (
                f"{self.path_prefix}.{reason}.{n}.trace.json"
                if want_dump else None
            )
        self.tracer.instant(
            "flight.trip", "fleet", reason=reason, dumped=bool(path), **detail
        )
        if self.telemetry is not None:
            self.telemetry.inc(f"flight.trip.{reason}")
        if path is not None:
            extra = ()
            if self.telemetry is not None:
                extra = ({
                    "ph": "M", "name": "telemetry", "pid": 0, "tid": 0,
                    "args": json.loads(self.telemetry.to_json()),
                },)
            write_trace(self.tracer, path, extra_events=extra)
            with self._lock:
                self._dumps.append(path)
        return path

    def guard(self, reason: str = TRIP_VERIFICATION):
        """Context manager: trip on `VerificationError` (re-raised)."""
        return _RecorderGuard(self, reason)

    def stats(self) -> dict:
        with self._lock:
            return {
                "trips": dict(self._trips),
                "dumps": list(self._dumps),
                "max_dumps": self.max_dumps,
            }


class _RecorderGuard:
    def __init__(self, recorder: FlightRecorder, reason: str):
        self.recorder = recorder
        self.reason = reason

    def __enter__(self):
        return self.recorder

    def __exit__(self, exc_type, exc, tb):
        if exc is not None:
            from repro.convserve.check.diagnostics import VerificationError

            if isinstance(exc, VerificationError):
                self.recorder.trip(self.reason, error=str(exc)[:200])
        return False


def roofline_table(rows, *, hw_name: str = "") -> str:
    """Human-readable measured-vs-ceiling table from roofline rows (the
    dicts of `obs.roofline.attribute_program` / a BENCH ``roofline``
    section / ``roofline.stage`` trace instants)."""
    head = (
        f"{'stage':<14} {'level':<12} {'meas us':>9} {'pred us':>9} "
        f"{'GFLOP/s':>9} {'roof':>9} {'frac':>6}  verdict"
    )
    lines = [f"roofline attribution{' on ' + hw_name if hw_name else ''}",
             head, "-" * len(head)]
    for r in rows:
        pred = r.get("predicted_us")
        lines.append(
            f"{r['stage']:<14} {r['binding_level']:<12} "
            f"{r['measured_us']:>9.1f} "
            f"{(f'{pred:.1f}' if pred is not None else '-'):>9} "
            f"{r['achieved_gflops']:>9.2f} {r['roof_gflops']:>9.2f} "
            f"{r['frac_of_roof']:>6.3f}  {r['verdict']}"
        )
        for ph in r.get("phases") or ():
            lines.append(
                f"  · {ph['phase']:<11} {'':<12} "
                f"{ph['attributed_us']:>9.1f} {'':>9} {'':>9} {'':>9} "
                f"{ph['macs_frac']:>6.3f}"
            )
    return "\n".join(lines)
