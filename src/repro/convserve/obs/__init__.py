"""convserve.obs: flight-recorder tracing + live roofline attribution.

`trace` is the span recorder (Clock-routed, ring-buffered, sampled);
`export` turns a ring into Chrome/Perfetto JSON, Prometheus text, or a
FlightRecorder crash dump.  `roofline` (imported explicitly -- it pulls
in the planner) joins measured stage seconds with TileAlgebra terms and
HardwareModel ceilings.
"""

from repro.convserve.obs.export import (
    FlightRecorder,
    TRIP_SLO_BREACH,
    TRIP_VERIFICATION,
    TRIP_WAVE_LOSS,
    chrome_trace_events,
    prometheus_text,
    roofline_table,
    validate_chrome_trace,
    write_trace,
)
from repro.convserve.obs.trace import (
    CAT_ADAPT,
    CAT_FLEET,
    CAT_PHASE,
    CAT_PROFILE,
    CAT_REQUEST,
    CAT_ROOFLINE,
    CAT_SCALE,
    CAT_STAGE,
    CAT_WAVE,
    InstantEvent,
    NULL_TRACER,
    NullTracer,
    Span,
    Tracer,
    attach,
    capture_tile_phases,
    span_index,
    span_tree_signature,
)

__all__ = [
    "CAT_ADAPT", "CAT_FLEET", "CAT_PHASE", "CAT_PROFILE", "CAT_REQUEST",
    "CAT_ROOFLINE", "CAT_SCALE", "CAT_STAGE", "CAT_WAVE",
    "FlightRecorder", "InstantEvent", "NULL_TRACER", "NullTracer", "Span",
    "TRIP_SLO_BREACH", "TRIP_VERIFICATION", "TRIP_WAVE_LOSS", "Tracer",
    "attach", "capture_tile_phases", "chrome_trace_events",
    "prometheus_text", "roofline_table", "span_index",
    "span_tree_signature", "validate_chrome_trace", "write_trace",
]
