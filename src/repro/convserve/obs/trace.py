"""Flight-recorder spans: a low-overhead, Clock-routed trace ring.

One `Tracer` per serving stack records the full causal chain -- admit
-> queue -> wave formation -> replica dispatch -> per-stage execute ->
tile-engine phases -- as `Span`s (durations) and `InstantEvent`s
(points: faults, scale decisions, adapt verdicts).  Three properties
make it serving-grade:

  * **Clock-routed**: every timestamp comes from the injected `Clock`.
    Under a `SimClock` the whole trace is deterministic -- the same
    seeded run produces the identical span tree, so traces are
    golden-testable, and a simulated fault drill can be replayed span
    by span in Perfetto.
  * **Ring-buffered**: completed events land in a bounded deque; under
    sustained load the recorder holds the most recent `capacity` events
    and counts what it dropped -- it never grows without bound and
    never blocks the serving path on export.
  * **Sampled deterministically**: the `sample_rate` knob keeps every
    Nth *root* span (the counter rule ``int(n*rate) > int((n-1)*rate)``
    -- no RNG, so SimClock determinism survives sampling).  Children
    begun under a dropped root are dropped with it, keeping every
    recorded tree complete.

Cross-thread spans (a wave begins on the dispatch thread and ends on a
replica completion thread) use the explicit `begin()`/`end()` API with
the span id carried by the caller; same-thread nesting uses the
`span()` context manager, which maintains the parent stack in a
thread-local.  Components default to the no-op `NULL_TRACER`, so an
uninstrumented runtime pays one attribute load per site and nothing
else.
"""

from __future__ import annotations

import collections
import contextlib
import threading
from typing import Dict, Iterable, List, Optional, Tuple

# event categories (the span taxonomy; see README "Observability")
CAT_REQUEST = "request"  # admit -> result, one span per rid
CAT_WAVE = "wave"  # dispatch -> completion, one span per wave
CAT_STAGE = "stage"  # one ExecProgram stage's timed execution
CAT_PHASE = "phase"  # tile-engine phase instants (gather/GEMM/...)
CAT_PROFILE = "profile"  # a profile_stages sweep
CAT_FLEET = "fleet"  # replica lifecycle / fault instants
CAT_SCALE = "scale"  # autoscaler decisions
CAT_ADAPT = "adapt"  # replan / shadow / promote / rollback
CAT_ROOFLINE = "roofline"  # per-stage attribution rows as instants

_DROPPED = -1  # stack sentinel: children of a sampled-out root


class Span:
    """One closed duration event.  `flow_in`/`flow_out` carry the flow
    ids the Chrome exporter turns into request->wave->stage arrows."""

    __slots__ = ("sid", "parent", "name", "cat", "t0", "t1", "pid", "tid",
                 "flow_in", "flow_out", "args")

    def __init__(self, sid, parent, name, cat, t0, pid, tid,
                 flow_in, flow_out, args):
        self.sid = sid
        self.parent = parent
        self.name = name
        self.cat = cat
        self.t0 = t0
        self.t1 = t0
        self.pid = pid
        self.tid = tid
        self.flow_in = tuple(flow_in)
        self.flow_out = tuple(flow_out)
        self.args = args

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class InstantEvent:
    """One point event."""

    __slots__ = ("name", "cat", "t", "pid", "tid", "args")

    def __init__(self, name, cat, t, pid, tid, args):
        self.name = name
        self.cat = cat
        self.t = t
        self.pid = pid
        self.tid = tid
        self.args = args


class Tracer:
    """The span recorder: a bounded ring of closed events behind one
    lock, timestamps from the injected clock."""

    active = True  # NullTracer overrides: lets callers skip sections

    def __init__(
        self,
        *,
        clock=None,
        capacity: int = 65536,
        sample_rate: float = 1.0,
        enabled: bool = True,
    ):
        if not 0.0 <= sample_rate <= 1.0:
            raise ValueError(f"sample_rate must be in [0, 1], got {sample_rate}")
        if clock is None:
            # deferred: runtime/__init__ imports modules that import us
            from repro.convserve.runtime.clock import RealClock

            clock = RealClock()
        self.clock = clock
        self.capacity = int(capacity)
        self.sample_rate = float(sample_rate)
        self.enabled = enabled
        self._lock = threading.Lock()
        # the ring: closed spans + instants, oldest evicted first
        self._events = collections.deque(  # guarded-by: _lock
            maxlen=self.capacity
        )
        self._open: Dict[int, Span] = {}  # guarded-by: _lock
        self._next_sid = 1  # guarded-by: _lock
        self._roots_seen = 0  # guarded-by: _lock (sampling counter)
        self._recorded = 0  # guarded-by: _lock
        self._sampled_out = 0  # guarded-by: _lock
        self._tls = threading.local()  # per-thread parent stack + flow hint

    # ------------------------------------------------------ internals

    def _stack(self) -> List[int]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _keep_root_locked(self) -> bool:
        # holds-lock: _lock
        self._roots_seen += 1
        n, rate = self._roots_seen, self.sample_rate
        return int(n * rate) > int((n - 1) * rate)

    # ------------------------------------------------------ span API

    def begin(
        self,
        name: str,
        cat: str = CAT_REQUEST,
        *,
        parent: Optional[int] = None,
        pid: int = 0,
        tid: int = 0,
        flow_in: Iterable[str] = (),
        flow_out: Iterable[str] = (),
        **args,
    ) -> int:
        """Open a span; returns its id (0 when disabled or sampled out).
        The id is plain data -- `end()` may run on another thread."""
        if not self.enabled:
            return 0
        stack = self._stack()
        if parent is None and stack:
            parent = stack[-1]
        if parent == _DROPPED:
            return 0  # child of a sampled-out root: drop the whole tree
        t0 = self.clock.now()
        hint = getattr(self._tls, "flow_hint", None)
        if hint and parent is None:
            flow_in = tuple(flow_in) + (hint,)
        with self._lock:
            if parent is None and not self._keep_root_locked():
                self._sampled_out += 1
                return 0
            sid = self._next_sid
            self._next_sid += 1
            self._open[sid] = Span(
                sid, parent, name, cat, t0, pid, tid, flow_in, flow_out, args
            )
        return sid

    def end(
        self,
        sid: int,
        *,
        pid: Optional[int] = None,
        tid: Optional[int] = None,
        flow_out: Iterable[str] = (),
        **args,
    ) -> None:
        """Close a span by id (no-op for id 0).  Late-binding fields --
        the replica a wave landed on is known only at completion -- may
        be supplied here."""
        if sid <= 0 or not self.enabled:
            return
        t1 = self.clock.now()
        with self._lock:
            span = self._open.pop(sid, None)
            if span is None:
                return
            span.t1 = t1
            if pid is not None:
                span.pid = pid
            if tid is not None:
                span.tid = tid
            if flow_out:
                span.flow_out = span.flow_out + tuple(flow_out)
            if args:
                span.args.update(args)
            self._events.append(span)
            self._recorded += 1

    @contextlib.contextmanager
    def span(self, name: str, cat: str = CAT_REQUEST, **kw):
        """Same-thread nested span: children begun inside parent under
        this tracer on this thread."""
        sid = self.begin(name, cat, **kw)
        stack = self._stack()
        stack.append(sid if sid else _DROPPED)
        try:
            yield sid
        finally:
            stack.pop()
            self.end(sid)

    def instant(
        self, name: str, cat: str = CAT_FLEET, *, pid: int = 0, tid: int = 0,
        **args,
    ) -> None:
        """Record one point event (fault, scale decision, adapt verdict,
        tile phase)."""
        if not self.enabled:
            return
        stack = self._stack()
        if stack and stack[-1] == _DROPPED:
            return
        t = self.clock.now()
        with self._lock:
            self._events.append(InstantEvent(name, cat, t, pid, tid, args))
            self._recorded += 1

    @contextlib.contextmanager
    def flow(self, flow_id: Optional[str]):
        """Attach `flow_id` as a flow-in on every root span begun inside
        (this thread): the runtime brackets a stage profile with the
        latest wave's flow id so traces link wave -> stage."""
        if not flow_id:
            yield
            return
        prev = getattr(self._tls, "flow_hint", None)
        self._tls.flow_hint = flow_id
        try:
            yield
        finally:
            self._tls.flow_hint = prev

    # ------------------------------------------------------- reading

    def events(self) -> List[object]:
        """Snapshot of the ring (closed spans + instants, record order)."""
        with self._lock:
            return list(self._events)

    def open_count(self) -> int:
        with self._lock:
            return len(self._open)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._open.clear()

    def stats(self) -> dict:
        """The `trace` telemetry section: recorder health counters."""
        with self._lock:
            dropped = max(0, self._recorded - len(self._events))
            return {
                "enabled": self.enabled,
                "sample_rate": self.sample_rate,
                "capacity": self.capacity,
                "recorded": self._recorded,
                "buffered": len(self._events),
                "dropped": dropped,
                "sampled_out": self._sampled_out,
                "open_spans": len(self._open),
            }


class NullTracer:
    """The no-op default: instrumented code pays one method call."""

    active = False
    enabled = False
    sample_rate = 0.0

    def begin(self, *a, **kw) -> int:
        return 0

    def end(self, *a, **kw) -> None:
        return None

    @contextlib.contextmanager
    def span(self, *a, **kw):
        yield 0

    def instant(self, *a, **kw) -> None:
        return None

    @contextlib.contextmanager
    def flow(self, flow_id=None):
        yield

    def events(self) -> list:
        return []

    def open_count(self) -> int:
        return 0

    def clear(self) -> None:
        return None

    def stats(self) -> dict:
        return {"enabled": False}


NULL_TRACER = NullTracer()


@contextlib.contextmanager
def capture_tile_phases(tracer, **extra):
    """Route the tile engine's phase hook into `tracer` for the duration:
    every `conv2d_fused_tile` dispatch inside emits one instant per
    logical phase (gather -> forward GEMM -> mix -> inverse GEMM ->
    scatter) carrying the kernel geometry.  Phases of one fused kernel
    are not separately timeable (they live inside a single compiled
    program), so these fire at dispatch/trace time; the roofline pass
    splits a stage's measured seconds across them by per-phase FLOPs."""
    if tracer is None or not getattr(tracer, "enabled", False):
        yield
        return
    from repro.kernels.fused_tile import ops as tile_ops

    def hook(phase: str, info: dict) -> None:
        tracer.instant(f"phase:{phase}", CAT_PHASE, **info, **extra)

    prev = tile_ops.set_phase_hook(hook)
    try:
        yield
    finally:
        tile_ops.set_phase_hook(prev)


def attach(obj, tracer) -> None:
    """Best-effort: point a pool executor's inner `NetExecutor` at
    `tracer`.  Unwraps the serving onion (`ShardedWaveExecutor.net` ->
    `CompiledNet.executor`); unknown objects are left alone."""
    inner = getattr(obj, "net", obj)  # ShardedWaveExecutor
    inner = getattr(inner, "executor", inner)  # CompiledNet
    if getattr(inner, "tracer", None) is NULL_TRACER:
        inner.tracer = tracer


def span_index(events) -> Dict[int, Span]:
    """sid -> Span over a snapshot (helper for tree assertions)."""
    return {e.sid: e for e in events if isinstance(e, Span)}


def span_tree_signature(events) -> List[Tuple]:
    """A stable, id-free signature of the span forest: (name, cat,
    parent-name-path, t0, t1, pid, tid) per span, sorted.  Two runs of
    the same seeded SimClock workload must produce equal signatures."""
    index = span_index(events)

    def path(span: Span) -> Tuple[str, ...]:
        names: List[str] = []
        cur = span
        seen = set()
        while cur.parent and cur.parent in index and cur.parent not in seen:
            seen.add(cur.parent)
            cur = index[cur.parent]
            names.append(cur.name)
        return tuple(reversed(names))

    return sorted(
        (s.name, s.cat, path(s), round(s.t0, 9), round(s.t1, 9),
         s.pid, s.tid)
        for s in index.values()
    )
