"""Live hierarchical-roofline attribution: measured seconds vs ceilings.

The paper's argument is a roofline argument -- a transformed conv wins
when its arithmetic intensity against each memory level clears that
level's compute-to-memory ratio (S5).  This module closes the loop at
serve time: join a stage's *measured* seconds (`profile_stages`) with
its `TileAlgebra` FLOP/byte terms and the calibrated `HardwareModel`
ceilings, and report per stage

  * achieved GFLOP/s and arithmetic intensity (DRAM and fast-level),
  * the **binding roofline level** -- which ceiling (DRAM bandwidth,
    shared-L3 bandwidth at AI_fast = R/2, or the fast-private compute
    peak) is lowest for this stage's intensities,
  * a predicted-vs-achieved verdict keyed ``backend:family:geometry``,

the paper's Figure 2/3 as queryable telemetry (`Telemetry.snapshot()`'s
``roofline`` section) and as `roofline.stage` trace instants.

For fused/transformed stages the stage's measured time is additionally
split across the tile engine's logical phases (forward GEMM / mix /
inverse GEMM) proportionally to each phase's MAC count -- the phases
execute inside one compiled kernel and cannot be timed separately, so
proportional-FLOPs attribution is the honest estimate.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from repro.core import analysis, registry

SCHEMA_VERSION = 2  # BENCH JSON / snapshot schema (v1 = unversioned)

# binding roofline levels, lowest-ceiling-wins
LEVEL_DRAM = "dram"
LEVEL_SHARED = "shared_l3"
LEVEL_PRIVATE = "fast_private"

# achieved/roof verdict bands: wide on purpose -- the verdict flags
# order-of-magnitude stories (a stage running at 3% of its roof), not
# calibration jitter
VERDICT_ABOVE = "above_model"  # achieved > roof: the model under-prices
VERDICT_AT = "at_roof"
VERDICT_BELOW = "below_roof"
VERDICT_FAR_BELOW = "far_below_roof"


def _backend() -> str:
    from repro.kernels.fused_tile.ops import resolve_backend

    return resolve_backend()


def _unit_terms(u, batch: int) -> dict:
    """FLOPs / DRAM bytes / intensities for one stage unit (one conv)."""
    p = u.plan
    s = p.spec
    oh, ow = s.out_hw
    ta = registry.get(p.algo).tile_algebra(p.algo_plan())
    if ta is not None:
        # stride-1 tile grid, decimation after -- mirror the planner's
        # charge so predicted and achieved price the same work
        oh1 = s.h + 2 * s.pad - s.k + 1
        ow1 = s.w + 2 * s.pad - s.k + 1
        flops = ta.engine_flops(oh1, ow1, s.c_in, s.c_out, s.groups, batch)
        w_bytes = ta.kernel_matrix_bytes(s.c_in, s.c_out, s.groups)
        macs = ta.engine_macs_per_tile(s.c_in, s.c_out, s.groups)
        pl, dp = ta.planes, ta.domain_points
        fwd = pl * dp * ta.t * ta.t * s.c_in
        mix = dp * (pl * s.c_in) * (pl * s.c_out) // s.groups
        inv = ta.t_out * ta.t_out * pl * dp * s.c_out
        phase_macs = {"forward_gemm": fwd, "mix": mix, "inverse_gemm": inv}
        assert fwd + mix + inv == macs
        family = ta.family
    else:
        flops = 2 * batch * oh * ow * s.c_in * s.c_out * s.k * s.k // s.groups
        w_bytes = 4 * s.k * s.k * (s.c_in // s.groups) * s.c_out
        phase_macs = None
        family = p.algo
    act_bytes = 4 * batch * (s.h * s.w * s.c_in + oh * ow * s.c_out)
    r = p.params.get("r_tiles")
    return {
        "family": family,
        "algo": p.algo,
        "flops": int(flops),
        "dram_bytes": int(act_bytes + w_bytes),
        "ai_fast": analysis.ai_fast_level(int(r)) if r else None,
        "phase_macs": phase_macs,
        "geometry": (
            f"{s.h}x{s.w}x{s.c_in}->{s.c_out}:k{s.k}:s{s.stride}"
            f":g{s.groups}"
        ),
    }


def _binding(hw, ai_dram: float, ai_fast: Optional[float]) -> Tuple[str, float]:
    """(level, roof GFLOP-ceiling in FLOP/s): the lowest of the DRAM
    bandwidth roof, the shared-fast-level roof at AI_fast, and the
    compute peak (the fast-private level: working sets resident in
    private memory leave only the peak to bind)."""
    roofs = [(LEVEL_PRIVATE, hw.peak_flops),
             (LEVEL_DRAM, ai_dram * hw.dram_bw)]
    if ai_fast is not None:
        roofs.append((LEVEL_SHARED, ai_fast * hw.fast_shared_bw))
    level, roof = min(roofs, key=lambda kv: kv[1])
    return level, roof


def _verdict(frac_of_roof: float) -> str:
    if frac_of_roof > 1.1:
        return VERDICT_ABOVE
    if frac_of_roof >= 0.5:
        return VERDICT_AT
    if frac_of_roof >= 0.1:
        return VERDICT_BELOW
    return VERDICT_FAR_BELOW


def attribute_stage(
    stage,
    measured_s: float,
    hw: analysis.HardwareModel,
    *,
    batch: int = 1,
    predicted_s: Optional[float] = None,
    backend: Optional[str] = None,
) -> dict:
    """One stage's roofline row: achieved GFLOP/s, intensities, the
    binding level, the verdict, and per-phase attributed time."""
    units = [_unit_terms(u, batch) for u in stage.units]
    flops = sum(u["flops"] for u in units)
    dram_bytes = sum(u["dram_bytes"] for u in units)
    ai_dram = flops / dram_bytes if dram_bytes else 0.0
    fasts = [u["ai_fast"] for u in units if u["ai_fast"] is not None]
    ai_fast = min(fasts) if fasts else None  # the tightest unit binds
    level, roof = _binding(hw, ai_dram, ai_fast)
    achieved = flops / measured_s if measured_s > 0 else 0.0
    frac = achieved / roof if roof > 0 else 0.0
    be = backend or _backend()
    families = "+".join(dict.fromkeys(u["family"] for u in units))
    key = f"{be}:{families}:{units[0]['geometry']}"

    phases = None
    phase_units = [u for u in units if u["phase_macs"] is not None]
    if phase_units:
        totals = {"forward_gemm": 0, "mix": 0, "inverse_gemm": 0}
        for u in phase_units:
            for ph, m in u["phase_macs"].items():
                totals[ph] += m
        macs = sum(totals.values())
        phases = [
            {
                "phase": ph,
                "macs_frac": totals[ph] / macs if macs else 0.0,
                "attributed_us": (
                    measured_s * 1e6 * totals[ph] / macs if macs else 0.0
                ),
            }
            for ph in ("forward_gemm", "mix", "inverse_gemm")
        ]

    row = {
        "stage": stage.label,
        "key": key,
        "fused": bool(stage.fused),
        "measured_us": measured_s * 1e6,
        "flops": flops,
        "dram_bytes": dram_bytes,
        "achieved_gflops": achieved / 1e9,
        "ai_dram": ai_dram,
        "ai_fast": ai_fast,
        "binding_level": level,
        "roof_gflops": roof / 1e9,
        "frac_of_roof": frac,
        "verdict": _verdict(frac),
        "phases": phases,
    }
    if predicted_s is not None:
        row["predicted_us"] = predicted_s * 1e6
        row["measured_over_predicted"] = (
            measured_s / predicted_s if predicted_s > 0 else None
        )
    return row


def attribute_program(
    program,
    profile: Sequence[Tuple[str, float]],
    hw: analysis.HardwareModel,
    *,
    batch: int = 1,
) -> List[dict]:
    """Roofline rows for every profiled stage of an `ExecProgram`.  The
    planner's predictions ride along so the verdict can say both
    "how far under the roof" and "how far off the model"."""
    from repro.convserve import planner  # deferred: planner is heavy

    predicted = dict(planner.predict_stage_times(program, hw))
    backend = _backend()
    rows = []
    by_label = {stage.label: stage for stage in program.stages}
    for label, seconds in profile:
        stage = by_label.get(label)
        if stage is None:
            continue
        rows.append(
            attribute_stage(
                stage, seconds, hw, batch=batch,
                predicted_s=(
                    predicted.get(label, 0.0) * batch
                    if predicted.get(label) is not None else None
                ),
                backend=backend,
            )
        )
    return rows


def roofline_section(
    program,
    profile: Sequence[Tuple[str, float]],
    hw: analysis.HardwareModel,
    *,
    batch: int = 1,
    tracer=None,
) -> dict:
    """The schema-stable ``roofline`` telemetry section.  With a tracer,
    each row is also recorded as a ``roofline.stage`` instant so traces
    carry their own attribution (benchmarks/roofline_report.py reads
    either form)."""
    rows = attribute_program(program, profile, hw, batch=batch)
    if tracer is not None and getattr(tracer, "enabled", False):
        for row in rows:
            args = {k: v for k, v in row.items() if k != "phases"}
            tracer.instant("roofline.stage", "roofline", **args)
    return {
        "schema_version": SCHEMA_VERSION,
        "hw": {
            "name": hw.name,
            "peak_gflops": hw.peak_flops / 1e9,
            "dram_gbs": hw.dram_bw / 1e9,
            "fast_shared_gbs": hw.fast_shared_bw / 1e9,
            "cmr_dram": hw.cmr_dram,
            "cmr_fast": hw.cmr_fast,
        },
        "batch": batch,
        "stages": rows,
    }
