"""Execution-program IR: the staged form a `NetPlan` lowers into.

The interpreter the engine used to be -- walk `NetSpec.layers`, switch on
layer kind, re-materialize every full activation between convs -- is
replaced by an explicit two-level IR:

    NetSpec + NetPlan --lower()--> ExecProgram = [Stage, Stage, ...]

Each `Stage` owns one conv *unit* (a `StageUnit`: the conv's `LayerPlan`
plus its fused epilogue -- the bias/relu/pool glue that used to be
interpreter cases) or, when the planner emitted a `FusionGroup`, several
transform-compatible adjacent units that execute as ONE resident stage:
conv -> epilogue -> conv over row super-tiles with halo recompute
(`Algorithm.execute_staged`), never materializing the full activation at
the layer boundary.  This is the paper's L3-residency argument lifted
from a single conv's three stages to the net level: exactly the
small-channel layers whose transform steps dominate are the ones whose
intermediates fit -- and stay -- in the fast shared level.

The IR is pure data (derivable from `NetSpec` + `NetPlan` v3, so plan
JSON round-trips reproduce identical stages); `executor.NetExecutor` is
a thin driver over it and `engine.Engine` the public front-end.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.convserve.check.diagnostics import program_error
from repro.convserve.graph import LayerSpec, NetSpec
from repro.convserve.plan import NetPlan

EPILOGUE_KINDS = ("bias", "relu", "maxpool")


@dataclasses.dataclass(frozen=True)
class EpilogueOp:
    """One pointwise/pooling glue op folded into a stage.

    `layer` is the op's NetSpec layer index -- provenance, and the
    weights key for bias vectors.  Elementwise ops (bias, relu) may run
    inside the owning algorithm's task loop; maxpool changes geometry
    and always ends a unit's in-tile region.
    """

    kind: str
    layer: int
    window: int = 1  # maxpool only

    def __post_init__(self):
        if self.kind not in EPILOGUE_KINDS:
            raise program_error(
                "CVK104", f"unknown epilogue kind {self.kind!r}"
            )

    @property
    def elementwise(self) -> bool:
        return self.kind != "maxpool"

    @staticmethod
    def from_layer(idx: int, layer: LayerSpec) -> "EpilogueOp":
        return EpilogueOp(kind=layer.kind, layer=idx, window=layer.window)


@dataclasses.dataclass(frozen=True)
class StageUnit:
    """One conv plus its fused epilogue (everything up to the next conv)."""

    plan: "LayerPlan"  # noqa: F821 -- repro.convserve.plan.LayerPlan
    epilogue: Tuple[EpilogueOp, ...] = ()

    @property
    def layer(self) -> int:
        return self.plan.layer

    @property
    def has_pool(self) -> bool:
        return any(op.kind == "maxpool" for op in self.epilogue)


@dataclasses.dataclass(frozen=True)
class Stage:
    """One execution stage: a single unit, or a fusion group of >= 2
    units that run conv -> epilogue -> conv without re-materializing the
    intermediate activation (`tile_rows` bounds the resident slab)."""

    units: Tuple[StageUnit, ...]
    tile_rows: int = 0

    def __post_init__(self):
        if not self.units:
            raise program_error("CVK104", "stage with no units")
        # pool inside a fusion group would change the coordinate system
        # mid-chain; lowering only ever places it in the final unit
        for u in self.units[:-1]:
            if u.has_pool:
                raise program_error(
                    "CVK110",
                    f"maxpool inside fusion group (layer {u.layer}): pool "
                    "must end a group",
                )

    @property
    def fused(self) -> bool:
        return len(self.units) > 1

    @property
    def conv_layers(self) -> Tuple[int, ...]:
        return tuple(u.layer for u in self.units)

    @property
    def label(self) -> str:
        if self.fused:
            return "fuse[" + "+".join(str(i) for i in self.conv_layers) + "]"
        return f"conv{self.units[0].layer}"


@dataclasses.dataclass(frozen=True)
class ExecProgram:
    """The staged execution program for one net under one NetPlan."""

    net: str
    prologue: Tuple[EpilogueOp, ...]  # glue before the first conv (rare)
    stages: Tuple[Stage, ...]

    @property
    def n_fused(self) -> int:
        return sum(1 for s in self.stages if s.fused)

    def describe(self) -> str:
        """One line per stage -- what the bench/report surfaces."""
        lines = []
        for s in self.stages:
            algos = ";".join(u.plan.algo for u in s.units)
            tail = f" tile_rows={s.tile_rows}" if s.fused else ""
            lines.append(f"{s.label:12s} {algos}{tail}")
        return "\n".join(lines)


def split_units(
    spec: NetSpec,
) -> Tuple[Tuple[EpilogueOp, ...], List[Tuple[int, Tuple[EpilogueOp, ...]]]]:
    """Partition a net's layers into per-conv units.

    Returns (prologue, units) where `prologue` is any glue before the
    first conv and each unit is ``(conv_layer_index, epilogue_ops)`` --
    the epilogue being every non-conv layer up to the next conv.
    """
    prologue: List[EpilogueOp] = []
    units: List[Tuple[int, Tuple[EpilogueOp, ...]]] = []
    current: Optional[int] = None
    ops: List[EpilogueOp] = []
    for i, layer in enumerate(spec.layers):
        if layer.kind == "conv":
            if current is not None:
                units.append((current, tuple(ops)))
            current, ops = i, []
        elif layer.kind in EPILOGUE_KINDS:
            (ops if current is not None else prologue).append(
                EpilogueOp.from_layer(i, layer)
            )
        else:
            raise program_error(
                "CVK104", f"layer {i}: unknown kind {layer.kind!r}"
            )
    if current is not None:
        units.append((current, tuple(ops)))
    return tuple(prologue), units


def lower(spec: NetSpec, plan: NetPlan) -> ExecProgram:
    """NetSpec + NetPlan -> ExecProgram.

    Validates the plan against the spec (coverage, geometry, net name)
    and the fusion groups against the unit structure (adjacency, no
    mid-group pooling) so a stale or hand-edited plan file fails here,
    not at request time.
    """
    if plan.net != spec.name:
        raise program_error(
            "CVK101",
            f"plan is for net {plan.net!r}, spec is {spec.name!r}",
        )
    plans = {p.layer: p for p in plan.layers}
    for i, layer in spec.conv_layers():
        p = plans.get(i)
        if p is None:
            raise program_error("CVK102", f"plan missing conv layer {i}")
        s = p.spec
        got = (s.c_in, s.c_out, s.k, s.pad, s.stride, s.groups)
        want = (
            layer.c_in, layer.c_out, layer.k, layer.pad,
            layer.stride, layer.groups,
        )
        if got != want:
            raise program_error(
                "CVK103",
                f"plan layer {i} geometry {got} != spec {want} "
                "(stale plan file?)",
            )
    prologue, units = split_units(spec)
    unit_pos = {conv_idx: pos for pos, (conv_idx, _) in enumerate(units)}
    grouped = {}
    for g in plan.groups:
        positions = []
        for conv_idx in g.layers:
            if conv_idx not in unit_pos:
                raise program_error(
                    "CVK107",
                    f"fusion group {g.layers} names layer {conv_idx}, which "
                    "is not a conv layer of the net",
                )
            positions.append(unit_pos[conv_idx])
        if positions != list(range(positions[0], positions[0] + len(positions))):
            raise program_error(
                "CVK108",
                f"fusion group {g.layers} is not a run of adjacent convs",
            )
        for conv_idx in g.layers:
            if conv_idx in grouped:
                raise program_error(
                    "CVK109",
                    f"layer {conv_idx} appears in two fusion groups",
                )
            grouped[conv_idx] = g
    stages: List[Stage] = []
    pos = 0
    while pos < len(units):
        conv_idx, ops = units[pos]
        g = grouped.get(conv_idx)
        if g is not None and g.layers[0] == conv_idx:
            members = []
            for member_idx in g.layers:
                midx, mops = units[unit_pos[member_idx]]
                members.append(StageUnit(plan=plans[midx], epilogue=mops))
            stages.append(Stage(units=tuple(members), tile_rows=g.tile_rows))
            pos += len(g.layers)
        else:
            stages.append(
                Stage(units=(StageUnit(plan=plans[conv_idx], epilogue=ops),))
            )
            pos += 1
    return ExecProgram(net=spec.name, prologue=prologue, stages=tuple(stages))
