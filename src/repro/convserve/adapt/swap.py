"""Zero-downtime plan hot swap.

Promotion is three ordered moves, each safe on its own:

1. **Warm** the candidate executors at every (bucket, batch-size) shape
   the scheduler has ever dispatched (`WaveScheduler.compiled_sizes`),
   using all-padding waves -- after this, no live request can hit a jit
   compile on the new program.
2. **Flip** dispatch: `ReplicaPool.swap` waits for in-flight waves to
   drain on the old program and switches the executor list under the
   dispatch lock, so every wave runs wholly on one program or the other
   -- never a mix, never a drop.
3. **Invalidate** surgically: the old program's `KernelCache` keys MINUS
   the keys the new program still uses are evicted.  A promotion that
   keeps some layers' algorithms keeps their transforms resident.
"""

from __future__ import annotations

from typing import Sequence

import jax
import numpy as np


def warm_executors(
    executors: Sequence,
    sizes_by_bucket: dict,
) -> int:
    """Compile every (bucket, batch size) program on every candidate
    executor with all-padding waves (extent-0 rows are fully masked, so
    warming computes zeros and cannot affect any served output).
    Returns the number of programs warmed."""
    n = 0
    for ex in executors:
        c0 = ex.spec.conv_layers()[0][1].c_in
        for bucket, sizes in sizes_by_bucket.items():
            for s in sizes:
                x = np.zeros((s, bucket, bucket, c0), np.float32)
                jax.block_until_ready(ex(x, np.zeros((s, 2), np.int32)))
                n += 1
    return n


def hot_swap(
    pool,
    candidates: Sequence,
    *,
    scheduler=None,
    timeout_s: float = 5.0,
    invalidate: bool = True,
    verify: bool = True,
) -> list:
    """Promote `candidates` into `pool` with zero downtime.

    With ``verify`` (default), any candidate exposing a spec + plan is
    first run through the static IR verifier against the hardware model
    it was compiled for (`CompiledNet.hw`) — a failing candidate raises
    `VerificationError` BEFORE any warmup or drain, so a corrupted plan
    can never flip into live dispatch.  (The adapt loop verifies again
    earlier, at candidate-planning time; this is the last line of
    defense for hand-rolled swaps.)

    Warms at the scheduler's compiled shapes (skipped when no scheduler
    is passed), drains + flips dispatch atomically, then drops the old
    program's now-orphaned cache entries.  Returns the outgoing
    executors (the rollback path keeps them warm by simply swapping
    them back)."""
    if verify:
        from repro.convserve.check.diagnostics import VerificationError
        from repro.convserve.check.ir import verify_program

        for ex in candidates:
            spec = getattr(ex, "spec", None)
            plan = getattr(ex, "plan", None)
            if spec is None or plan is None:
                continue
            report = verify_program(
                spec, plan,
                program=getattr(ex, "program", None),
                hw=getattr(ex, "hw", None),
            )
            if report.errors:
                raise VerificationError(report)
    if scheduler is not None:
        warm_executors(candidates, scheduler.compiled_sizes())
    old = pool.swap(candidates, timeout_s=timeout_s)
    if invalidate:
        old_keys = set()
        new_keys = set()
        for ex in old:
            old_keys.update(ex.cache_keys())
        for ex in pool.executors:
            new_keys.update(ex.cache_keys())
        stale = old_keys - new_keys
        if stale:
            pool.cache.invalidate_keys(stale)
    return old
