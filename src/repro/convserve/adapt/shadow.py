"""Shadow A/B verifier: the gate between a candidate plan and traffic.

A candidate `ExecProgram` is never promoted on the replanner's say-so:
a trickle of live waves is duplicated onto it (on a spare replica,
after the live wave's results are already recorded -- shadow work can
never show up in a client latency histogram) and this verifier
accumulates two things per shadow wave:

* exactness -- every duplicated request's candidate output against the
  live output.  ``bitwise`` mode demands equality to the bit (the right
  bar when the candidate keeps the live per-layer algorithms and only
  changes fusion structure: the untiled fused path IS the unfused
  computation); ``rtol`` allows the documented cross-family tolerance
  (fused-FFT vs direct agree to ~1e-3 relative).  One mismatch is
  disqualifying -- exactness is not a statistic.

* latency -- live vs candidate compute seconds, cold samples excluded
  on both sides (either side jitting mid-shadow is a one-time cost, not
  a property of the plan).

`verdict()` stays None until `min_waves` clean comparisons have
accumulated, then answers "promote" iff the candidate's mean compute is
within `promote_margin` of live (and strictly "rollback" on any
mismatch, immediately, regardless of sample count).
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


class ShadowVerifier:
    """Accumulates exactness + latency evidence for one candidate."""

    def __init__(
        self,
        *,
        mode: str = "bitwise",
        rtol: float = 1e-3,
        atol: float = 1e-5,
        min_waves: int = 3,
        promote_margin: float = 0.0,
    ):
        if mode not in ("bitwise", "rtol"):
            raise ValueError(f"unknown exactness mode {mode!r}")
        self.mode = mode
        self.rtol = rtol
        self.atol = atol
        self.min_waves = min_waves
        self.promote_margin = promote_margin
        self.waves = 0
        self.requests = 0
        self.mismatches = 0
        self.live_s: List[float] = []
        self.cand_s: List[float] = []
        self.cold_skipped = 0

    def record(
        self,
        live_outputs: Dict[int, np.ndarray],
        cand_outputs: Dict[int, np.ndarray],
        *,
        live_compute_s: Optional[float] = None,
        cand_compute_s: Optional[float] = None,
        cold: bool = False,
    ) -> bool:
        """Fold one shadow wave in; returns whether it was exact."""
        self.waves += 1
        exact = True
        for rid, live in live_outputs.items():
            cand = cand_outputs.get(rid)
            self.requests += 1
            if cand is None:
                exact = False
            elif self.mode == "bitwise":
                exact &= bool(np.array_equal(live, cand))
            else:
                exact &= bool(
                    np.allclose(live, cand, rtol=self.rtol, atol=self.atol)
                )
        if not exact:
            self.mismatches += 1
        if cold:
            self.cold_skipped += 1
        elif live_compute_s is not None and cand_compute_s is not None:
            self.live_s.append(live_compute_s)
            self.cand_s.append(cand_compute_s)
        return exact

    @property
    def live_mean_s(self) -> Optional[float]:
        return sum(self.live_s) / len(self.live_s) if self.live_s else None

    @property
    def cand_mean_s(self) -> Optional[float]:
        return sum(self.cand_s) / len(self.cand_s) if self.cand_s else None

    def verdict(self) -> Optional[str]:
        """"promote" / "rollback" once the evidence is in, else None.
        Any mismatch rolls back immediately; latency needs `min_waves`
        clean (warm, paired) samples before it may promote."""
        if self.mismatches:
            return "rollback"
        if len(self.cand_s) < self.min_waves:
            return None
        live, cand = self.live_mean_s, self.cand_mean_s
        if cand <= live * (1.0 + self.promote_margin):
            return "promote"
        return "rollback"

    def stats(self) -> dict:
        return {
            "mode": self.mode,
            "waves": self.waves,
            "requests": self.requests,
            "mismatches": self.mismatches,
            "cold_skipped": self.cold_skipped,
            "paired_samples": len(self.cand_s),
            "live_mean_s": self.live_mean_s,
            "cand_mean_s": self.cand_mean_s,
        }
