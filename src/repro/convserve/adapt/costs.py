"""Measured-cost wisdom store: the adapt loop's memory.

Entries are keyed the same way `tune.py` wisdom is keyed --
``backend:family:geometry`` -- so a measurement taken for one executor
transfers to any plan that poses the same (algorithm, ConvSpec)
question, and an FFT measurement can never shadow a Winograd one:

    cpu:fft_fused:48x48x4->8:k3:s1:g1              (single stage)
    cpu:group[fft_fused+fft_fused]:48x48x4->8:...  (fused group stage)

Values are EWMA-smoothed measured seconds together with the roofline
prediction for the same stage, stamped with a monotonic generation and
a clock timestamp (the same staleness discipline `tune.py` entries
carry, so online and offline wisdom can expire each other).  Cold
(compile-inclusive) observations are excluded from the EWMA -- they are
counted, because a store that silently drops data is a store you cannot
debug.

`MeasuredCostStore` is also the `costs=` view the planner consumes
(`plan_net(..., costs=store)`): `algo_time_s` answers the per-layer
override and `group_time_s` the fusion verdict, both None when the
geometry has never been measured (the planner then falls back to the
analytic model -- measurement only ever *narrows* the model, never
invents numbers).
"""

from __future__ import annotations

import dataclasses
import json
import statistics
import threading
from typing import Dict, Optional, Sequence

import jax

from repro.core import registry


def layer_key(algo: str, spec: registry.ConvSpec, backend=None) -> str:
    """Measured-cost key for one (algorithm, geometry) -- mirrors
    `tune._key`'s backend:family:geometry shape."""
    backend = backend or jax.default_backend()
    return (
        f"{backend}:{algo}:{spec.h}x{spec.w}x{spec.c_in}->{spec.c_out}"
        f":k{spec.k}:s{spec.stride}:g{spec.groups}"
    )


def group_key(members: Sequence, backend=None) -> str:
    """Measured-cost key for a fused group stage: the member algorithms
    plus the group's input geometry and the per-member channel chain
    (enough to distinguish any two groups a planner can form)."""
    backend = backend or jax.default_backend()
    algos = "+".join(p.algo for p in members)
    first = members[0].spec
    chain = "->".join(
        [str(first.c_in)] + [str(p.spec.c_out) for p in members]
    )
    return (
        f"{backend}:group[{algos}]:{first.h}x{first.w}x{chain}"
        f":k{'+'.join(str(p.spec.k) for p in members)}"
    )


def stage_key(stage, backend=None) -> str:
    """Key for an ExecProgram stage: group key when fused, else the
    single unit's layer key."""
    plans = [u.plan for u in stage.units]
    if stage.fused:
        return group_key(plans, backend=backend)
    return layer_key(plans[0].algo, plans[0].spec, backend=backend)


@dataclasses.dataclass
class CostEntry:
    """One measured geometry: EWMA seconds + the roofline's prediction
    for the same stage, generation/timestamp stamped."""

    measured_s: float
    predicted_s: Optional[float]
    n: int
    gen: int
    ts: float

    @property
    def ratio(self) -> Optional[float]:
        """measured / predicted -- the divergence currency."""
        if not self.predicted_s or self.predicted_s <= 0:
            return None
        return self.measured_s / self.predicted_s


class MeasuredCostStore:
    """EWMA store of measured stage times, usable as the planner's
    `costs=` view.  Thread-safe: telemetry taps observe from replica
    threads while the replanner reads."""

    def __init__(self, *, ewma: float = 0.3, clock=None):
        if not 0 < ewma <= 1:
            raise ValueError(f"ewma must be in (0, 1], got {ewma}")
        self.ewma = ewma
        self._clock = clock
        self._lock = threading.Lock()
        self._entries: Dict[str, CostEntry] = {}  # guarded-by: _lock
        self._gen = 0  # guarded-by: _lock
        self.cold_skipped = 0  # guarded-by: _lock

    def _now(self) -> float:
        return self._clock.now() if self._clock is not None else 0.0

    # ------------------------------------------------------- writes

    def observe(
        self,
        key: str,
        measured_s: float,
        *,
        predicted_s: Optional[float] = None,
        cold: bool = False,
        now: Optional[float] = None,
    ) -> None:
        """Fold one measurement into the EWMA for `key`.  Cold (compile-
        inclusive) samples are excluded -- they would poison the EWMA
        with one-time jit cost -- but counted in `cold_skipped`."""
        if cold:
            with self._lock:
                self.cold_skipped += 1
            return
        now = self._now() if now is None else now
        with self._lock:
            self._gen += 1
            prev = self._entries.get(key)
            if prev is None:
                self._entries[key] = CostEntry(
                    measured_s=float(measured_s),
                    predicted_s=predicted_s,
                    n=1, gen=self._gen, ts=now,
                )
            else:
                a = self.ewma
                self._entries[key] = CostEntry(
                    measured_s=(1 - a) * prev.measured_s + a * float(measured_s),
                    predicted_s=(
                        predicted_s if predicted_s is not None
                        else prev.predicted_s
                    ),
                    n=prev.n + 1, gen=self._gen, ts=now,
                )

    # -------------------------------------------------------- reads

    def entry(
        self,
        key: str,
        *,
        max_age_s: Optional[float] = None,
        min_gen: int = 0,
        now: Optional[float] = None,
    ) -> Optional[CostEntry]:
        with self._lock:
            e = self._entries.get(key)
        if e is None or e.gen < min_gen:
            return None
        if max_age_s is not None:
            now = self._now() if now is None else now
            if e.ts < now - max_age_s:
                return None
        return e

    def lookup(self, key: str, **kw) -> Optional[float]:
        e = self.entry(key, **kw)
        return e.measured_s if e is not None else None

    def ratio_scale(self) -> float:
        """Median measured/predicted ratio across every entry that has a
        prediction.  The divergence monitor judges each stage's ratio
        RELATIVE to this scale, so a uniformly mis-calibrated peak-FLOPs
        constant (every stage 5x slower than modeled) reads as zero
        divergence while one pathological stage stands out."""
        with self._lock:
            ratios = [
                e.ratio for e in self._entries.values()
                if e.ratio is not None
            ]
        return statistics.median(ratios) if ratios else 1.0

    @property
    def generation(self) -> int:
        with self._lock:
            return self._gen

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    # ------------------------------------- the planner's `costs=` view

    def algo_time_s(
        self, algo: str, spec: registry.ConvSpec
    ) -> Optional[float]:
        """Measured single-stage seconds for (algo, geometry), else None."""
        return self.lookup(layer_key(algo, spec))

    def group_time_s(self, members: Sequence) -> Optional[float]:
        """Measured fused-group seconds for these member plans, else None."""
        return self.lookup(group_key(members))

    # ------------------------------------------------------- persist

    def to_json(self) -> dict:
        with self._lock:
            return {
                k: dataclasses.asdict(e) for k, e in self._entries.items()
            }

    def save(self, path) -> None:
        from repro.core.ioutil import atomic_write_text

        atomic_write_text(path, json.dumps(self.to_json(), indent=1,
                                           sort_keys=True))

    @classmethod
    def load(cls, path, **kw) -> "MeasuredCostStore":
        store = cls(**kw)
        with open(path) as f:
            raw = json.load(f)
        with store._lock:
            for k, v in raw.items():
                store._entries[k] = CostEntry(**v)
                store._gen = max(store._gen, int(v.get("gen", 0)))
        return store
