"""Online adaptive replanning: measured costs replace the roofline.

The S5 analytical model plans well in the common case but mispredicts in
exactly the regime the paper targets (`fft-fewchannel`: the model picks
fused FFT, measurement says direct is ~2x faster).  This package closes
the loop against a LIVE serving runtime:

  measure -> diverge -> replan -> shadow -> promote / rollback

* `costs`     -- measured-cost wisdom store (EWMA, cold-compile
                 excluded), keyed like `tune.py` wisdom.
* `replanner` -- divergence monitor + background replanner: when
                 measured stage times drift past a threshold relative
                 to the roofline predictions, `plan_net` re-runs with
                 measured costs overriding the `HardwareModel`.
* `shadow`    -- A/B verifier: a trickle of live waves is duplicated
                 onto the candidate program, exactness asserted,
                 latency compared.
* `swap`      -- zero-downtime hot swap: warm the candidate at every
                 compiled shape, drain in-flight waves, atomically
                 switch dispatch, invalidate the old program's cache
                 entries.
"""

from repro.convserve.adapt.costs import CostEntry, MeasuredCostStore
from repro.convserve.adapt.replanner import AdaptConfig, AdaptController
from repro.convserve.adapt.shadow import ShadowVerifier
from repro.convserve.adapt.swap import hot_swap

__all__ = [
    "AdaptConfig",
    "AdaptController",
    "CostEntry",
    "MeasuredCostStore",
    "ShadowVerifier",
    "hot_swap",
]
