"""Divergence monitor + background replanner: the adapt control loop.

`AdaptController` sits beside a live `ServeRuntime` and closes the
measure -> diverge -> replan -> shadow -> promote/rollback loop:

* **measure** -- `measure()` profiles the live program's stages (the
  executor's `profile_stages`, which compiles outside the timed region,
  so measurements are warm by construction) and folds them into the
  `MeasuredCostStore` next to the roofline's `predict_stage_times`
  prediction for the same stage.  `probe_alternatives()` does the same
  for the plans the replanner might switch TO (the unfused variant of
  the live plan, the direct baseline), because a measured override can
  only choose between measured options.

* **diverge** -- `check()` compares each live stage's measured/predicted
  ratio against the store-wide median ratio (`ratio_scale`).  A
  uniformly mis-calibrated hardware constant cancels out; one stage
  whose ratio stands `divergence_ratio`x above the rest is a real
  misprediction, and triggers a replan.

* **replan** -- `plan_net(..., costs=store)`: measured seconds override
  the tier-ranked roofline choice per layer and the saved-vs-extra
  model per fusion group.  A candidate identical to the live plan is a
  no-op (audited; cooldown applies).

* **shadow** -- the runtime's wave observer duplicates a
  `shadow_fraction` trickle of live waves onto the candidate, strictly
  after live results and latency histograms are recorded (shadow work
  can never count toward client SLOs).  Exactness mode is picked
  automatically: bitwise when the candidate keeps the live per-layer
  algorithms (fusion-structure-only change -- the untiled fused path IS
  the unfused computation), the documented ~1e-3 cross-family tolerance
  otherwise.

* **promote / rollback** -- on a clean latency win the candidate is
  `hot_swap`ped in (warm, atomic, surgically cache-invalidated); on any
  mismatch or a measured loss the candidate is discarded and the old
  program keeps serving.  Every transition lands in a reason-coded
  audit log and the `adapt.*` telemetry counters.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import registry
from repro.convserve import planner
from repro.convserve.adapt.costs import MeasuredCostStore, stage_key
from repro.convserve.adapt.shadow import ShadowVerifier
from repro.convserve.adapt.swap import hot_swap
from repro.convserve.obs.trace import CAT_ADAPT
from repro.convserve.check.ir import verify_program

IDLE = "idle"
SHADOW = "shadow"


@dataclasses.dataclass
class AdaptConfig:
    """Knobs of the control loop (see README "Adaptive replanning")."""

    divergence_ratio: float = 2.0  # stage ratio vs store median that triggers
    min_samples: int = 1           # stage observations before it is judged
    shadow_fraction: float = 0.25  # fraction of live waves duplicated
    shadow_min_waves: int = 3      # clean paired samples before a verdict
    promote_margin: float = 0.0    # candidate may be this much slower and win
    exactness: str = "auto"        # "auto" | "bitwise" | "rtol"
    rtol: float = 1e-3             # cross-family tolerance (fused vs direct)
    cooldown_s: float = 1.0        # after rollback/no-op, before re-checking
    probe_batch: int = 1
    probe_bucket: Optional[int] = None  # default: smallest runtime bucket
    probe_reps: int = 1
    consider_fft: bool = True
    swap_timeout_s: float = 5.0
    # stale-telemetry guard: a replan trigger whose telemetry stamp has
    # not advanced since the previous trigger (or whose last mutation is
    # older than `stale_after_s`) is counted + audited; with
    # `require_fresh_telemetry` it is also suppressed until fresh
    # evidence arrives.
    require_fresh_telemetry: bool = False
    stale_after_s: Optional[float] = None


class AdaptController:
    """One net's adaptive replanning loop over a live `ServeRuntime`.

    `probe` injects the stage-timing function (``probe(net, bucket,
    batch) -> [(label, seconds)]``; defaults to the executor's real
    `profile_stages`) and `shadow_timer` the latency pairing
    (``shadow_timer(result, cand_s) -> (live_s, cand_s)``; defaults to
    wall times) -- both exist so SimClock tests are deterministic.
    """

    def __init__(
        self,
        runtime,
        engine,
        spec,
        weights: Dict[int, np.ndarray],
        cfg: Optional[AdaptConfig] = None,
        *,
        store: Optional[MeasuredCostStore] = None,
        probe=None,
        shadow_timer=None,
    ):
        self.runtime = runtime
        self.engine = engine
        self.spec = spec
        self.weights = weights
        self.cfg = cfg or AdaptConfig()
        self.store = store or MeasuredCostStore(clock=runtime.clock)
        self._probe = probe
        self._shadow_timer = shadow_timer
        self.state = IDLE
        self.paused = False  # scale events gate shadow traffic off
        self._pause_reason: Optional[str] = None
        self.candidate: Optional[List] = None  # per-replica CompiledNets
        self.candidate_plan = None
        self.verifier: Optional[ShadowVerifier] = None
        self.last_verifier: Optional[ShadowVerifier] = None
        self.replans_triggered = 0
        self.shadows_run = 0
        self.promotions = 0
        self.rollbacks = 0
        self.audit: List[dict] = []
        self.stale_checks = 0
        self._last_check_seq = -1
        self._waves_seen = 0
        self._cooldown_until = -float("inf")
        runtime.add_wave_observer(self.on_wave)

    # ------------------------------------------------------- helpers

    @property
    def live(self):
        """Replica 0's CompiledNet -- the program traffic runs on."""
        return self.runtime.pool.executors[0]

    def _now(self) -> float:
        return self.runtime.clock.now()

    def _audit(self, event: str, reason: str, **detail) -> None:
        self.audit.append(
            {"t": self._now(), "event": event, "reason": reason, **detail}
        )
        tracer = getattr(self.runtime, "tracer", None)
        if tracer is not None:
            # mirror the audit trail into the trace, so a dumped ring
            # explains replans/verdicts/swaps on the same timeline as
            # the waves they affected
            tracer.instant(
                f"adapt.{event}", CAT_ADAPT, reason=reason, **detail
            )

    def _inc(self, name: str) -> None:
        self.runtime.telemetry.inc(f"adapt.{name}")

    def _bucket_batch(self) -> Tuple[int, int]:
        bucket = self.cfg.probe_bucket or min(self.runtime.cfg.buckets)
        return bucket, self.cfg.probe_batch

    def _profile(self, net) -> List[Tuple[str, float]]:
        """Warm per-stage seconds for `net` at the probe geometry."""
        bucket, batch = self._bucket_batch()
        if self._probe is not None:
            return self._probe(net, bucket, batch)
        c0 = net.spec.conv_layers()[0][1].c_in
        x = np.zeros((batch, bucket, bucket, c0), np.float32)
        rows = net.profile_stages(x)
        for _ in range(self.cfg.probe_reps - 1):
            rows = [
                (lab, min(t, t2))
                for (lab, t), (_, t2) in zip(rows, net.profile_stages(x))
            ]
        return rows

    def _record_program(self, net) -> None:
        """Probe `net` and fold each stage's (measured, predicted) pair
        into the store, keyed by stage structure -- measurements for a
        probe-only program transfer to any plan posing the same stage."""
        hw = self.engine.hw
        measured = self._profile(net)
        predicted = planner.predict_stage_times(net.program, hw)
        for stage, (label, t_meas), (_, t_pred) in zip(
            net.program.stages, measured, predicted
        ):
            self.store.observe(
                stage_key(stage), t_meas, predicted_s=t_pred
            )

    # ------------------------------------------------------- pausing

    def pause(self, reason: str = "scale_event") -> None:
        """Suspend the control loop: no new replans open and -- the part
        scale events care about -- `on_wave` duplicates NOTHING while
        paused, so shadow compute never competes with replicas that are
        warming up or draining.  An open shadow keeps its candidate and
        evidence; `resume` picks up exactly where it stopped."""
        if self.paused:
            return
        self.paused = True
        self._pause_reason = reason
        self._inc("paused")
        self._audit("pause", reason)

    def resume(self) -> None:
        if not self.paused:
            return
        self.paused = False
        self._audit("resume", f"was paused for {self._pause_reason}")
        self._pause_reason = None

    # ------------------------------------------------------- measure

    def measure(self) -> None:
        """Profile the LIVE program's stages into the cost store."""
        self._record_program(self.live)

    def probe_alternatives(
        self, include: Sequence[str] = ("unfused", "direct")
    ) -> List[str]:
        """Measure the plans the replanner may switch to.  Probe
        programs share the engine's kernel cache (an unfused probe of a
        fused plan reuses the live transforms) and are discarded after
        timing; only their measurements persist."""
        probed = []
        live_plan = self.live.plan
        if "unfused" in include and live_plan.groups:
            plan = dataclasses.replace(live_plan, groups=())
            net = self.engine.compile(
                self.spec, self.weights, plan=plan, fuse=None
            )
            self._record_program(net)
            probed.append("unfused")
        if "direct" in include:
            h, w = live_plan.input_hw
            net = self.engine.compile(
                self.spec, self.weights, input_hw=(h, w),
                allowed=("direct",), fuse=False,
            )
            if net.plan.algos() != live_plan.algos():
                self._record_program(net)
                probed.append("direct")
        return probed

    # ------------------------------------------------------ diverge

    def _best_alternative_s(self, stage) -> Optional[float]:
        """Measured seconds of the fastest MEASURED alternative
        realization of this stage's layers: the unfused member sum for a
        fused stage, and the per-layer best measured algorithm either
        way.  None until `probe_alternatives` has populated the store."""
        plans = [u.plan for u in stage.units]
        alts = []
        if stage.fused:
            singles = [
                self.store.algo_time_s(p.algo, p.spec) for p in plans
            ]
            if all(t is not None for t in singles):
                alts.append(sum(singles))
        totals = []
        for p in plans:
            best = None
            for name in registry.names():
                alg = registry.get(name)
                if not (alg.auto_candidate and alg.supports(p.spec)):
                    continue
                t = self.store.algo_time_s(name, p.spec)
                if t is not None and (best is None or t < best):
                    best = t
            totals.append(best)
        if totals and all(t is not None for t in totals):
            alts.append(sum(totals))
        return min(alts) if alts else None

    def divergence(self) -> List[dict]:
        """Per-live-stage divergence rows, two currencies:

        * ``divergence`` -- measured/predicted ratio relative to the
          store-wide median ratio.  Scale-free: a uniformly
          mis-calibrated peak-FLOPs constant reads as 1.0 everywhere,
          while one stage whose misprediction stands out reads high.
        * ``regret`` -- measured live seconds over the measured-best
          alternative realization of the same layers.  Catches the
          uniform-calibration case the ratio signal cannot: the model
          predicted fused fastest, measurement says otherwise.
        """
        scale = self.store.ratio_scale()
        rows = []
        for stage in self.live.program.stages:
            e = self.store.entry(stage_key(stage))
            if e is None or e.n < self.cfg.min_samples or e.ratio is None:
                continue
            alt = self._best_alternative_s(stage)
            rows.append(
                {
                    "stage": stage.label,
                    "measured_s": e.measured_s,
                    "predicted_s": e.predicted_s,
                    "ratio": e.ratio,
                    "divergence": e.ratio / scale,
                    "alternative_s": alt,
                    "regret": (
                        e.measured_s / alt if alt and alt > 0 else None
                    ),
                }
            )
        return rows

    def check(self) -> Optional[str]:
        """Divergence gate: when a live stage's measured/predicted ratio
        stands `divergence_ratio`x above the store median, re-plan with
        measured costs and open a shadow.  Returns the trigger reason,
        or None (in cooldown / already shadowing / within threshold /
        replan was a no-op)."""
        if self.paused:
            return None
        if self.state != IDLE or self._now() < self._cooldown_until:
            return None
        rows = self.divergence()
        if not rows:
            return None

        def signal(r):
            return max(r["divergence"], r["regret"] or 0.0)

        worst = max(rows, key=signal)
        if signal(worst) < self.cfg.divergence_ratio:
            return None
        if (worst["regret"] or 0.0) >= worst["divergence"]:
            reason = (
                f"stage {worst['stage']} measured {worst['regret']:.2f}x "
                f"over the best measured alternative"
            )
        else:
            reason = (
                f"stage {worst['stage']} measured "
                f"{worst['divergence']:.2f}x over prediction scale"
            )
        if self._stale_guard():
            return None
        self.replans_triggered += 1
        self._inc("replans_triggered")
        self._audit("replan", reason, divergence=worst["divergence"])
        if self._open_shadow() is None:
            return None
        return reason

    def _stale_guard(self) -> bool:
        """True when a would-be replan must be suppressed because the
        runtime's telemetry snapshot is stale (seq unchanged since the
        last trigger, or data older than `stale_after_s`).  Stale
        triggers are always counted + audited; only
        `require_fresh_telemetry` turns that into suppression."""
        telemetry = getattr(self.runtime, "telemetry", None)
        if telemetry is None:
            return False
        stamp = telemetry.stamp()
        seq_stale = stamp["seq"] == self._last_check_seq
        age = (
            self._now() - stamp["t"]
            if stamp["t"] is not None and self.cfg.stale_after_s is not None
            else None
        )
        age_stale = age is not None and age > self.cfg.stale_after_s
        if not seq_stale and not age_stale:
            self._last_check_seq = stamp["seq"]
            return False
        self.stale_checks += 1
        self._inc("stale_snapshot")
        self._audit(
            "stale_telemetry",
            (
                f"telemetry seq {stamp['seq']} unchanged since last trigger"
                if seq_stale
                else f"telemetry age {age:.3f}s > {self.cfg.stale_after_s}s"
            ),
            seq=stamp["seq"],
            blocked=self.cfg.require_fresh_telemetry,
        )
        return self.cfg.require_fresh_telemetry

    # ------------------------------------------------------- replan

    def _open_shadow(self):
        """Re-plan with measured costs; compile + start shadowing the
        candidate (None when the replan reproduces the live plan)."""
        cfg = self.cfg
        live_plan = self.live.plan
        h, w = live_plan.input_hw
        plan = planner.plan_net(
            self.spec, h, w,
            hw=self.engine.hw, dtype=live_plan.dtype,
            consider_fft=cfg.consider_fft, fuse=True, costs=self.store,
        )
        if plan == live_plan:
            self._audit("replan_noop", "measured costs reproduce live plan")
            self._cooldown_until = self._now() + cfg.cooldown_s
            return None
        # static verification gate: a candidate that fails the IR
        # verifier is reason-coded rejected here -- it never compiles,
        # never receives shadow traffic
        report = verify_program(self.spec, plan, hw=self.engine.hw)
        if report.errors:
            codes = ",".join(sorted({d.code for d in report.errors}))
            self._inc("verify_rejected")
            self._audit(
                "replan_rejected",
                f"candidate failed static verification [{codes}]",
                codes=codes,
            )
            self._cooldown_until = self._now() + cfg.cooldown_s
            return None
        n = len(self.runtime.pool.executors)
        self.candidate = [
            self.engine.compile(self.spec, self.weights, plan=plan, fuse=None)
            for _ in range(n)
        ]
        self.candidate_plan = plan
        mode = cfg.exactness
        if mode == "auto":
            mode = (
                "bitwise" if plan.algos() == live_plan.algos() else "rtol"
            )
        self.verifier = ShadowVerifier(
            mode=mode, rtol=cfg.rtol,
            min_waves=cfg.shadow_min_waves,
            promote_margin=cfg.promote_margin,
        )
        self.state = SHADOW
        self._audit(
            "shadow_open",
            f"candidate algos {'+'.join(plan.algos())}, "
            f"{len(plan.groups)} groups (live {len(live_plan.groups)}), "
            f"exactness {mode}",
        )
        return self.candidate

    # ------------------------------------------------------- shadow

    def on_wave(self, result) -> None:
        """Runtime wave observer: duplicate a trickle of live waves onto
        the candidate.  Runs strictly after the live wave's client-side
        bookkeeping, so shadow work never touches client latency."""
        if self.paused:
            return
        if self.state != SHADOW or self.candidate is None:
            return
        self._waves_seen += 1
        f = self.cfg.shadow_fraction
        n = self._waves_seen
        if int(n * f) <= int((n - 1) * f):
            return
        self.shadows_run += 1
        self._inc("shadows_run")
        ex = self.candidate[0]
        batch, sizes = result.wave.assemble()
        before = ex.compile_count
        # the POOL's clock, not the runtime's: live waves are timed on it
        # (`ReplicaPool._run`), so shadow/live latency pairs compare on
        # one timeline whichever clock is injected
        clock = self.runtime.pool.clock
        t0 = clock.now()
        y = np.asarray(jax.block_until_ready(ex(batch, sizes)))
        cand_s = clock.now() - t0
        cand_cold = ex.compile_count > before
        outputs = result.wave.crop(self.spec, y)
        if self._shadow_timer is not None:
            live_s, cand_s = self._shadow_timer(result, cand_s)
        else:
            live_s = result.compute_s
        self.runtime.telemetry.observe("adapt.shadow_compute", cand_s)
        exact = self.verifier.record(
            result.outputs, outputs,
            live_compute_s=live_s, cand_compute_s=cand_s,
            cold=cand_cold or result.compiled,
        )
        if not exact:
            self._rollback("shadow_inexact")
            return
        verdict = self.verifier.verdict()
        if verdict == "promote":
            self._promote()
        elif verdict == "rollback":
            self._rollback("shadow_slower")

    # ------------------------------------------- promote / rollback

    def _promote(self) -> None:
        v = self.verifier
        tracer = getattr(self.runtime, "tracer", None)
        if tracer is not None and tracer.active:
            # the candidates were compiled untraced; the promoted
            # program must keep recording stage/profile spans
            from repro.convserve.obs.trace import attach

            for net in self.candidate:
                attach(net, tracer)
        hot_swap(
            self.runtime.pool, self.candidate,
            scheduler=self.runtime.scheduler,
            timeout_s=self.cfg.swap_timeout_s,
        )
        self.promotions += 1
        self._inc("promotions")
        self._audit(
            "promote",
            f"candidate {v.cand_mean_s:.6f}s <= live {v.live_mean_s:.6f}s "
            f"over {len(v.cand_s)} shadow waves",
        )
        self._close_shadow()

    def _rollback(self, reason: str) -> None:
        self.rollbacks += 1
        self._inc("rollbacks")
        v = self.verifier
        detail = (
            f"{v.mismatches} mismatched waves"
            if reason == "shadow_inexact"
            else (
                f"candidate {v.cand_mean_s:.6f}s > live {v.live_mean_s:.6f}s"
                if v.cand_mean_s is not None and v.live_mean_s is not None
                else "insufficient shadow evidence"
            )
        )
        self._audit("rollback", reason, detail=detail)
        self._close_shadow()

    def _close_shadow(self) -> None:
        self.last_verifier = self.verifier
        self.candidate = None
        self.candidate_plan = None
        self.verifier = None
        self.state = IDLE
        self._waves_seen = 0
        self._cooldown_until = self._now() + self.cfg.cooldown_s

    # --------------------------------------------------------- stats

    def stats(self) -> dict:
        v = self.verifier or self.last_verifier
        return {
            "state": self.state,
            "paused": self.paused,
            "replans_triggered": self.replans_triggered,
            "shadows_run": self.shadows_run,
            "promotions": self.promotions,
            "rollbacks": self.rollbacks,
            "stale_checks": self.stale_checks,
            "store_entries": len(self.store),
            "store_scale": self.store.ratio_scale(),
            "divergence": self.divergence(),
            "shadow": v.stats() if v is not None else None,
            "audit": list(self.audit),
        }
