"""Offline batched-serving front-end: the blocking wrapper over the
runtime's wave scheduler.

Requests carry variably-sized HWC images.  Each is assigned the
smallest spatial bucket that holds it, zero-padded there, and batched
with like-bucketed requests into waves of at most `max_batch`; wave
sizes are rounded up to powers of two.  Compiled-program count is
therefore bounded by  #buckets x log2(max_batch)  regardless of
traffic, and every wave after the first reuses the kernel cache's
pre-transformed matrices.  Per-sample true extents ride along to the
executor, whose post-conv masking makes padded serving *exact* -- each
output equals the net run on that image alone (see executor module
docstring).

Wave formation itself -- bucketing, priority/FIFO order, power-of-two
padding with batch-size hysteresis, round-robin across buckets -- is
NOT implemented here: `ConvServer.run` admits every request into the
same `runtime.WaveScheduler` the online `ServeRuntime` uses and drains
it to completion.  The offline path is literally the online scheduler
with all deadlines at infinity, so the two can never disagree about
what a wave is.  For continuous traffic (deadlines, admission control,
replicas, telemetry) use `repro.convserve.runtime.ServeRuntime`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.convserve.runtime.queueing import Request
from repro.convserve.runtime.scheduler import RuntimeConfig, WaveScheduler


@dataclasses.dataclass
class ImageRequest:
    rid: int
    image: np.ndarray  # (H, W, C)


@dataclasses.dataclass
class ConvServeConfig:
    max_batch: int = 8
    # spatial buckets (square); every bucket must survive the net's whole
    # downsampling chain (pool windows AND conv strides -- validated by
    # simulating the shape pipeline at server construction).
    buckets: Sequence[int] = (32, 64, 128, 224)
    pad_batch: bool = True  # round wave sizes up to a power of two

    def runtime_config(self) -> RuntimeConfig:
        """The online config this offline surface is a slice of: no
        SLOs, and a queue deep enough that offline admission never
        rejects for depth (run() takes the whole request list at once)."""
        return RuntimeConfig(
            max_batch=self.max_batch,
            buckets=tuple(self.buckets),
            pad_batch=self.pad_batch,
            queue_depth=1 << 30,
            slo_s=None,
        )


class ConvServer:
    """Serves a compiled net (`engine.CompiledNet`, or a bare
    `NetExecutor`) in bucketed waves, blocking until all requests in a
    batch are done."""

    def __init__(self, executor, cfg: ConvServeConfig):
        # scheduler construction validates the net has convs and that
        # every bucket survives the downsampling chain
        self.scheduler = WaveScheduler(executor.spec, cfg.runtime_config())
        self.executor = executor
        self.cfg = cfg

    def run(self, requests: List[ImageRequest]) -> Dict[int, np.ndarray]:
        """Serve all requests in bucketed waves; rid -> output (H', W', C').

        Offline semantics: an inadmissible request (oversized, bad
        shape) raises before anything is computed, so a batch either
        serves completely or fails fast.
        """
        for r in requests:
            rej = self.scheduler.admit(
                Request(rid=r.rid, image=np.asarray(r.image)), now=0.0
            )
            if rej is not None:
                # failed batch must leave no state behind: without the
                # clear, this request's already-admitted mates would
                # leak into the next run()'s waves and results
                self.scheduler.clear()
                raise ValueError(
                    f"request {rej.rid} rejected ({rej.reason}): {rej.detail}"
                )
        results: Dict[int, np.ndarray] = {}
        try:
            while True:
                wave = self.scheduler.drain_wave()
                if wave is None:
                    return results
                batch, sizes = wave.assemble()
                y = np.asarray(self.executor(batch, sizes))
                results.update(wave.crop(self.executor.spec, y))
        except BaseException:
            # fail-fast means fail CLEAN: an executor error mid-drain
            # must not leave the unserved remainder queued, where the
            # next run() would silently serve it into its own results
            self.scheduler.clear()
            raise

    def stats(self) -> dict:
        """One dict for the serving counters that used to be scattered
        across executor/cache internals: waves served (plus the
        scheduler's partial-wave/admission accounting), per-bucket
        compile counts, and the kernel-cache hit/miss/eviction/
        invalidation accounting."""
        sched = self.scheduler.stats()
        return {
            "waves": sched["waves"],
            "partial_waves": sched["partial_waves"],
            "admitted": sched["admitted"],
            "rejected": sched["rejected"],
            **self.executor.stats(),
        }
