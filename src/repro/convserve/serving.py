"""Batched image-serving front-end: queue + shape bucketing over the
planned executor (the convnet analogue of serve/engine.py's wave loop).

Requests carry variably-sized HWC images.  Each is assigned the smallest
spatial bucket that holds it, zero-padded there, and batched with
like-bucketed requests into waves of at most `max_batch`; wave sizes are
rounded up to powers of two.  Compiled-program count is therefore bounded
by  #buckets x log2(max_batch)  regardless of traffic, and every wave
after the first reuses the kernel cache's pre-transformed matrices.
Per-sample true extents ride along to the executor, whose post-conv
masking makes padded serving *exact* -- each output equals the net run
on that image alone (see executor module docstring).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class ImageRequest:
    rid: int
    image: np.ndarray  # (H, W, C)


@dataclasses.dataclass
class ConvServeConfig:
    max_batch: int = 8
    # spatial buckets (square); every bucket must survive the net's whole
    # downsampling chain (pool windows AND conv strides -- validated by
    # simulating the shape pipeline at server construction).
    buckets: Sequence[int] = (32, 64, 128, 224)
    pad_batch: bool = True  # round wave sizes up to a power of two


class ConvServer:
    """Serves a compiled net (`engine.CompiledNet`, or a bare
    `NetExecutor`) in bucketed waves."""

    def __init__(self, executor, cfg: ConvServeConfig):
        spec = executor.spec
        convs = spec.conv_layers()
        if not convs:
            raise ValueError(f"net {spec.name!r} has no conv layers")
        c0 = convs[0][1].c_in
        # a bucket must survive the true total downsampling factor --
        # stride-2 convs halve extents before pools ever see them, so a
        # pool-factor modulo check admits buckets that die at runtime;
        # simulate the exact shape chain instead
        for b in cfg.buckets:
            try:
                spec.infer_shapes(b, b, c0)
            except ValueError as e:
                raise ValueError(
                    f"bucket {b} does not survive net {spec.name!r}'s "
                    f"downsampling chain (total factor "
                    f"{spec.downsample_factor}): {e}"
                ) from None
        self.executor = executor
        self.cfg = cfg
        self.waves_served = 0

    def _bucket_for(self, h: int, w: int) -> int:
        for b in sorted(self.cfg.buckets):
            if h <= b and w <= b:
                return b
        raise ValueError(
            f"image ({h}, {w}) exceeds largest bucket {max(self.cfg.buckets)}"
        )

    def _wave_batch(self, n: int) -> int:
        if not self.cfg.pad_batch:
            return n
        b = 1
        while b < n:
            b *= 2
        return min(b, self.cfg.max_batch)

    def run(self, requests: List[ImageRequest]) -> Dict[int, np.ndarray]:
        """Serve all requests in bucketed waves; rid -> output (H', W', C')."""
        by_bucket: Dict[int, List[ImageRequest]] = {}
        for r in requests:
            h, w, c = r.image.shape
            # admission-time validation: a bad request must fail here, not
            # at crop time after its wave-mates have already been computed
            self.executor.spec.infer_shapes(h, w, c)
            by_bucket.setdefault(self._bucket_for(h, w), []).append(r)
        results: Dict[int, np.ndarray] = {}
        for bucket in sorted(by_bucket):
            queue = by_bucket[bucket]
            while queue:
                wave = queue[: self.cfg.max_batch]
                queue = queue[self.cfg.max_batch :]
                results.update(self._run_wave(bucket, wave))
        return results

    def _run_wave(
        self, bucket: int, wave: List[ImageRequest]
    ) -> Dict[int, np.ndarray]:
        c = wave[0].image.shape[2]
        b = self._wave_batch(len(wave))
        batch = np.zeros((b, bucket, bucket, c), wave[0].image.dtype)
        # batch-padding rows carry extent 0 -> fully masked in the executor
        sizes = np.zeros((b, 2), np.int32)
        for i, r in enumerate(wave):
            h, w, rc = r.image.shape
            if rc != c:
                raise ValueError(f"request {r.rid}: channel mismatch {rc}!={c}")
            batch[i, :h, :w, :] = r.image
            sizes[i] = (h, w)
        y = np.asarray(self.executor(batch, sizes))
        self.waves_served += 1
        out: Dict[int, np.ndarray] = {}
        for i, r in enumerate(wave):
            h, w, _ = r.image.shape
            oh, ow, _ = self.executor.spec.out_shape(h, w, c)
            out[r.rid] = y[i, :oh, :ow, :]
        return out

    def stats(self) -> dict:
        """One dict for the serving counters that used to be scattered
        across executor/cache internals: waves served, per-bucket compile
        counts, and the kernel-cache hit/miss accounting."""
        return {"waves": self.waves_served, **self.executor.stats()}
