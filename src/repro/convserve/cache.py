"""Pre-transformed kernel cache (the paper's footnote-1 inference path).

Transformed convolutions never touch raw HWIO kernels at serving time:
the right-hand matrices G W G^T (Winograd) or conj(rfft2(W)) (FFT) are
computed once and reused by every request.  The cache memoizes them per
(net, layer, algo, tile, dtype, geometry) so that

  * repeated requests -- and different shape buckets of the same net --
    hit the cache (the key excludes the activation spatial dims), and
  * two layers that happen to share a geometry but hold different weights
    never collide (the layer index is part of the key).

Hit/miss counters make the reuse observable; `stats()` feeds benchmarks
and the serving front-end's metrics.
"""

from __future__ import annotations

import hashlib
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.fft_conv import transform_kernels_fft
from repro.core.three_stage import transform_kernels
from repro.convserve.plan import LayerPlan

_WINO_FAMILY = ("three_stage", "l3_fused", "l3_fused_pallas")


def weights_fingerprint(w) -> str:
    """Content hash of a kernel tensor: ties cache entries to the actual
    parameter values, so two executors sharing a cache but holding
    different weights for the same net never serve each other's
    transforms, while identical weights still share entries."""
    arr = np.asarray(w)
    return hashlib.sha1(
        arr.tobytes() + str(arr.shape).encode() + str(arr.dtype).encode()
    ).hexdigest()[:16]


class KernelCache:
    """Memoized right-hand (transformed-kernel) matrices."""

    def __init__(self):
        self._store: Dict[Tuple, jnp.ndarray] = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(net: str, plan: LayerPlan, dtype, w_fp: str) -> Tuple:
        return (
            net, plan.layer, plan.algo, plan.k,
            plan.c_in, plan.c_out, plan.m, plan.t_fft,
            jnp.dtype(dtype).name, w_fp,
        )

    def get(
        self,
        net: str,
        plan: LayerPlan,
        w: jnp.ndarray,
        dtype=jnp.float32,
        w_fp: Optional[str] = None,
    ) -> Optional[jnp.ndarray]:
        """Transformed kernels for this layer, building on first use.

        `w_fp` is the weight fingerprint; pass a precomputed one (the
        executor hashes each layer once at init) to avoid re-hashing per
        request.  Returns None for algorithms with no pre-transform
        (direct conv); those are not counted as hits or misses.
        """
        if plan.algo == "direct":
            return None
        key = self.key(net, plan, dtype, w_fp or weights_fingerprint(w))
        cached = self._store.get(key)
        if cached is not None:
            self.hits += 1
            return cached
        self.misses += 1
        wt = self._transform(plan, jnp.asarray(w, dtype))
        self._store[key] = wt
        return wt

    @staticmethod
    def _transform(plan: LayerPlan, w: jnp.ndarray) -> jnp.ndarray:
        if plan.algo in _WINO_FAMILY:
            if plan.m is None:
                raise ValueError(f"layer {plan.layer}: wino plan without m")
            return transform_kernels(w, plan.m)
        if plan.algo == "fft_fused":
            if plan.t_fft is None:
                raise ValueError(f"layer {plan.layer}: fft plan without t_fft")
            return transform_kernels_fft(w, plan.t_fft)
        raise ValueError(f"no kernel transform for algo {plan.algo!r}")

    def invalidate(self, net: Optional[str] = None) -> None:
        """Drop entries (all, or one net's) -- call after a weight update."""
        if net is None:
            self._store.clear()
        else:
            self._store = {k: v for k, v in self._store.items() if k[0] != net}

    @property
    def nbytes(self) -> int:
        return sum(v.nbytes for v in self._store.values())

    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "entries": len(self._store),
            "bytes": self.nbytes,
        }
