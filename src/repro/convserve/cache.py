"""Pre-transformed kernel cache (the paper's footnote-1 inference path).

Transformed convolutions never touch raw HWIO kernels at serving time:
the right-hand matrices are computed once by the owning algorithm's
`prepare_weights` and reused by every request.  The cache is fully
algorithm-agnostic -- it asks the registry which algorithms consume
pre-transformed kernels and which params shape the transform
(`Algorithm.prepare_key`), so a newly registered algorithm is cached
correctly with zero changes here.  Entries are memoized per
(net, layer, algo, geometry, weight-params, dtype, weight-fingerprint)
so that

  * repeated requests -- and different shape buckets of the same net --
    hit the cache (the key excludes the activation spatial dims), and
  * two layers that happen to share a geometry but hold different weights
    never collide (the layer index and weight hash are part of the key).

The store is optionally bounded: with `capacity_bytes` set, entries
evict least-recently-used once the resident transforms exceed the
budget (many nets/buckets sharing one engine no longer grow without
bound; an evicted layer simply re-transforms on next use and counts a
miss).  Hit/miss/eviction/invalidation counters make reuse and
weight-update churn observable; `stats()` feeds benchmarks, the serving
front-ends, and the runtime's telemetry.  All mutation happens under an
internal lock so replica pools can share one cache across threads.
"""

from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import registry
from repro.convserve.plan import LayerPlan


def weights_fingerprint(w) -> str:
    """Content hash of a kernel tensor: ties cache entries to the actual
    parameter values, so two executors sharing a cache but holding
    different weights for the same net never serve each other's
    transforms, while identical weights still share entries."""
    arr = np.asarray(w)
    return hashlib.sha1(
        arr.tobytes() + str(arr.shape).encode() + str(arr.dtype).encode()
    ).hexdigest()[:16]


class KernelCache:
    """Memoized right-hand (transformed-kernel) matrices, optionally
    LRU-bounded to `capacity_bytes` of resident transforms."""

    def __init__(self, capacity_bytes: Optional[int] = None):
        if capacity_bytes is not None and capacity_bytes <= 0:
            raise ValueError(f"capacity_bytes must be > 0, got {capacity_bytes}")
        self._store: "OrderedDict[Tuple, jnp.ndarray]" = OrderedDict()  # guarded-by: _lock
        self._lock = threading.RLock()
        self._nbytes = 0  # guarded-by: _lock
        self.capacity_bytes = capacity_bytes
        self.hits = 0  # guarded-by: _lock
        self.misses = 0  # guarded-by: _lock
        self.evictions = 0  # guarded-by: _lock
        self.invalidations = 0  # guarded-by: _lock

    @staticmethod
    def key(net: str, plan: LayerPlan, dtype, w_fp: str) -> Tuple:
        alg = registry.get(plan.algo)
        s = plan.spec
        return (
            net, plan.layer, plan.algo,
            s.k, s.c_in, s.c_out, s.groups,
            alg.prepare_key(plan.params),
            jnp.dtype(dtype).name, w_fp,
        )

    def get(
        self,
        net: str,
        plan: LayerPlan,
        w: jnp.ndarray,
        dtype=jnp.float32,
        w_fp: Optional[str] = None,
    ) -> Optional[jnp.ndarray]:
        """Transformed kernels for this layer, building on first use.

        `w_fp` is the weight fingerprint; pass a precomputed one (the
        executor hashes each layer once at init) to avoid re-hashing per
        request.  Returns None for algorithms with no consumable
        pre-transform (direct conv, the Pallas kernel); those are not
        counted as hits or misses.
        """
        alg = registry.get(plan.algo)
        if not alg.consumes_wt:
            return None
        key = self.key(net, plan, dtype, w_fp or weights_fingerprint(w))
        with self._lock:
            cached = self._store.get(key)
            if cached is not None:
                self.hits += 1
                self._store.move_to_end(key)  # most-recently-used
                return cached
            self.misses += 1
        # transform outside the lock: kernel prep is the expensive part,
        # and a racing replica at worst duplicates work, never corrupts
        wt = alg.prepare_weights(jnp.asarray(w, dtype), plan.algo_plan())
        with self._lock:
            if key not in self._store:
                self._store[key] = wt
                self._nbytes += wt.nbytes
                self._evict_over_capacity(keep=key)
        return wt

    def _evict_over_capacity(self, keep: Tuple) -> None:
        # holds-lock: _lock (callers evict inside their locked section)
        """Drop LRU entries until under budget.  The entry being served
        right now (`keep`) is never evicted -- a single transform larger
        than the whole budget still serves, it just lives alone."""
        if self.capacity_bytes is None:
            return
        while self._nbytes > self.capacity_bytes and len(self._store) > 1:
            key = next(iter(self._store))
            if key == keep:
                self._store.move_to_end(key)
                key = next(iter(self._store))
            wt = self._store.pop(key)
            self._nbytes -= wt.nbytes
            self.evictions += 1

    def invalidate(self, net: Optional[str] = None) -> None:
        """Drop entries (all, or one net's) -- call after a weight
        update.  Each call counts once in `invalidations`, so weight
        churn is visible in serving stats."""
        with self._lock:
            self.invalidations += 1
            if net is None:
                self._store.clear()
                self._nbytes = 0
            else:
                for k in [k for k in self._store if k[0] == net]:
                    self._nbytes -= self._store.pop(k).nbytes

    def invalidate_keys(self, keys) -> int:
        """Drop an explicit key set (see `KernelCache.key`); returns the
        number actually evicted.  This is the hot-swap path's surgical
        variant of `invalidate`: dropping only the keys the outgoing
        program used -- minus those the incoming one still needs -- so a
        swap never cold-starts the new program's transforms.  Counts once
        in `invalidations` when anything was dropped."""
        dropped = 0
        with self._lock:
            for k in keys:
                wt = self._store.pop(k, None)
                if wt is not None:
                    self._nbytes -= wt.nbytes
                    dropped += 1
            if dropped:
                self.invalidations += 1
        return dropped

    def keys(self) -> list:
        """Snapshot of resident keys, most-recently-used last."""
        with self._lock:
            return list(self._store)

    def entry_nbytes(self, key: Tuple) -> Optional[int]:
        """Resident bytes of one transform (None when not resident) --
        the fleet's replicate-vs-shard placement decision reads this."""
        with self._lock:
            wt = self._store.get(key)
            return None if wt is None else int(wt.nbytes)

    def place(self, key: Tuple, put_fn) -> bool:
        """Re-store one resident transform through ``put_fn(wt) -> wt``
        (a `jax.device_put` with a mesh sharding, in the fleet's case).
        The placed array must be value-identical -- placement moves
        bytes across devices, it never changes what is served.  Returns
        False when the key is not resident."""
        with self._lock:
            wt = self._store.get(key)
            if wt is None:
                return False
            placed = put_fn(wt)
            if placed.shape != wt.shape or placed.dtype != wt.dtype:
                raise ValueError(
                    f"placement changed entry {key}: {wt.shape}/{wt.dtype}"
                    f" -> {placed.shape}/{placed.dtype}"
                )
            self._store[key] = placed
            return True

    def corrupt_entry(self, key: Optional[Tuple] = None) -> Optional[Tuple]:
        """FAULT-INJECTION surface (fleet drills / tests only): negate
        one resident transform in place, silently poisoning every future
        fetch of it -- the failure mode a bit-flipped shared cache would
        produce.  Targets the least-recently-used entry when no key is
        given.  Returns the corrupted key (None when the cache is
        empty).  Detection and repair are the fleet pool's health-probe
        job; the cache itself stays silent, which is the point."""
        with self._lock:
            if key is None:
                key = next(iter(self._store), None)
            if key is None or key not in self._store:
                return None
            self._store[key] = -self._store[key]
            return key

    @property
    def nbytes(self) -> int:
        return self._nbytes

    def stats(self) -> dict:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "entries": len(self._store),
                "bytes": self._nbytes,
                "capacity_bytes": self.capacity_bytes,
                "evictions": self.evictions,
                "invalidations": self.invalidations,
            }
