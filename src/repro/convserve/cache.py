"""Pre-transformed kernel cache (the paper's footnote-1 inference path).

Transformed convolutions never touch raw HWIO kernels at serving time:
the right-hand matrices are computed once by the owning algorithm's
`prepare_weights` and reused by every request.  The cache is fully
algorithm-agnostic -- it asks the registry which algorithms consume
pre-transformed kernels and which params shape the transform
(`Algorithm.prepare_key`), so a newly registered algorithm is cached
correctly with zero changes here.  Entries are memoized per
(net, layer, algo, geometry, weight-params, dtype, weight-fingerprint)
so that

  * repeated requests -- and different shape buckets of the same net --
    hit the cache (the key excludes the activation spatial dims), and
  * two layers that happen to share a geometry but hold different weights
    never collide (the layer index and weight hash are part of the key).

Hit/miss counters make the reuse observable; `stats()` feeds benchmarks
and the serving front-end's metrics.
"""

from __future__ import annotations

import hashlib
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import registry
from repro.convserve.plan import LayerPlan


def weights_fingerprint(w) -> str:
    """Content hash of a kernel tensor: ties cache entries to the actual
    parameter values, so two executors sharing a cache but holding
    different weights for the same net never serve each other's
    transforms, while identical weights still share entries."""
    arr = np.asarray(w)
    return hashlib.sha1(
        arr.tobytes() + str(arr.shape).encode() + str(arr.dtype).encode()
    ).hexdigest()[:16]


class KernelCache:
    """Memoized right-hand (transformed-kernel) matrices."""

    def __init__(self):
        self._store: Dict[Tuple, jnp.ndarray] = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(net: str, plan: LayerPlan, dtype, w_fp: str) -> Tuple:
        alg = registry.get(plan.algo)
        s = plan.spec
        return (
            net, plan.layer, plan.algo,
            s.k, s.c_in, s.c_out, s.groups,
            alg.prepare_key(plan.params),
            jnp.dtype(dtype).name, w_fp,
        )

    def get(
        self,
        net: str,
        plan: LayerPlan,
        w: jnp.ndarray,
        dtype=jnp.float32,
        w_fp: Optional[str] = None,
    ) -> Optional[jnp.ndarray]:
        """Transformed kernels for this layer, building on first use.

        `w_fp` is the weight fingerprint; pass a precomputed one (the
        executor hashes each layer once at init) to avoid re-hashing per
        request.  Returns None for algorithms with no consumable
        pre-transform (direct conv, the Pallas kernel); those are not
        counted as hits or misses.
        """
        alg = registry.get(plan.algo)
        if not alg.consumes_wt:
            return None
        key = self.key(net, plan, dtype, w_fp or weights_fingerprint(w))
        cached = self._store.get(key)
        if cached is not None:
            self.hits += 1
            return cached
        self.misses += 1
        wt = alg.prepare_weights(jnp.asarray(w, dtype), plan.algo_plan())
        self._store[key] = wt
        return wt

    def invalidate(self, net: Optional[str] = None) -> None:
        """Drop entries (all, or one net's) -- call after a weight update."""
        if net is None:
            self._store.clear()
        else:
            self._store = {k: v for k, v in self._store.items() if k[0] != net}

    @property
    def nbytes(self) -> int:
        return sum(v.nbytes for v in self._store.values())

    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "entries": len(self._store),
            "bytes": self.nbytes,
        }
