"""Public front-end: compile a net once, serve it everywhere.

    engine = Engine(hw=...)                      # shared kernel cache
    net = engine.compile(spec, weights)          # plan -> lower -> bind
    y = net(batch)                               # CompiledNet is callable
    net(batch, sizes)                            # ragged batches
    net.save_plan("net.plan.json")               # ship the v3 plan

`Engine.compile` owns the whole NetPlan -> ExecProgram lifecycle: it
plans (or takes a pre-planned/loaded `NetPlan`, upgrading v2 files that
carry no fusion groups), lowers to the staged IR, and binds weights and
the engine-wide `KernelCache` into a `CompiledNet`.  `ConvServer` and
the examples consume `CompiledNet` -- nothing outside this module needs
to construct a `NetExecutor` (or interpret a plan dict) directly.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core import analysis
from repro.core import tune as tune_mod
from repro.convserve.cache import KernelCache
from repro.convserve.check.diagnostics import CheckReport, VerificationError
from repro.convserve.executor import NetExecutor
from repro.convserve.graph import NetSpec
from repro.convserve.plan import NetPlan
from repro.convserve.planner import plan_net, upgrade_plan
from repro.convserve.program import ExecProgram
from repro.convserve.runtime.clock import Clock

VERIFY_MODES = ("strict", "warn", "off")


@dataclasses.dataclass
class CompiledNet:
    """A planned, lowered, weight-bound net ready to serve.

    Callable: ``net(x, sizes=None)`` with NHWC batches.  The staged IR
    is inspectable (`program`, `describe()`), the plan shippable
    (`save_plan`), and the serving counters unified (`stats()`).
    """

    spec: NetSpec
    plan: NetPlan
    program: ExecProgram
    executor: NetExecutor
    # the hardware model the plan was verified against and the verifier's
    # report -- the hot-swap path re-verifies candidates through these
    hw: Optional[analysis.HardwareModel] = None
    report: Optional[CheckReport] = None

    def __call__(self, x, sizes=None):
        return self.executor(x, sizes)

    @property
    def cache(self) -> KernelCache:
        return self.executor.cache

    @property
    def compile_count(self) -> int:
        return self.executor.compile_count

    def describe(self) -> str:
        return self.program.describe()

    def save_plan(self, path) -> None:
        self.plan.save(path)

    def compiles_by_bucket(self) -> Dict[int, int]:
        return self.executor.compiles_by_bucket()

    def profile_stages(self, x, sizes=None) -> List[Tuple[str, float]]:
        return self.executor.profile_stages(x, sizes)

    def cache_keys(self) -> list:
        return self.executor.cache_keys()

    def stats(self) -> dict:
        return self.executor.stats()


class Engine:
    """Compiles nets against one hardware model and one shared kernel
    cache (multiple nets -- or weight sets -- served side by side reuse
    each other's transforms where fingerprints agree)."""

    def __init__(
        self,
        *,
        hw: Optional[analysis.HardwareModel] = None,
        cache: Optional[KernelCache] = None,
        dtype=jnp.float32,
        clock: Optional[Clock] = None,
        tracer=None,
    ):
        self.hw = hw or tune_mod.default_hw()
        self.cache = cache if cache is not None else KernelCache()
        self.dtype = jnp.dtype(dtype)
        self.clock = clock  # threaded into every executor (None = real)
        self.tracer = tracer  # likewise (None = NULL_TRACER)
        self.nets_compiled = 0

    def compile(
        self,
        spec: NetSpec,
        weights: Dict[int, jnp.ndarray],
        *,
        input_hw: Tuple[int, int] = (64, 64),
        plan: Optional[NetPlan] = None,
        fuse: Optional[bool] = True,
        verify: str = "strict",
        **plan_kwargs,
    ) -> CompiledNet:
        """NetSpec (+ weights) -> CompiledNet.

        Without `plan`, plans at reference `input_hw` on the engine's
        hardware model.  With `plan` (e.g. loaded from a plan file), the
        per-layer decisions are taken as-is; a v2-era plan with no
        fusion groups is upgraded through the same roofline model first.
        Pass ``fuse=False`` to serve strictly layer-by-layer, or
        ``fuse=None`` to take the plan's groups exactly as given -- the
        adapt loop needs this to compile a deliberately-unfused
        candidate without the upgrade path re-deriving groups for it.

        `verify` runs the static IR verifier (`check.ir.verify_program`)
        on the lowered program before any weights bind: ``"strict"``
        (default) raises `VerificationError` on any finding, ``"warn"``
        prints findings and serves anyway, ``"off"`` skips the pass.
        The report rides on the returned net as `CompiledNet.report`.
        """
        if plan is None:
            plan = plan_net(
                spec, input_hw[0], input_hw[1],
                hw=self.hw, dtype=self.dtype.name,
                fuse=bool(fuse) if fuse is not None else True,
                **plan_kwargs,
            )
        elif plan_kwargs:
            raise ValueError(
                f"plan_kwargs {sorted(plan_kwargs)} are planning knobs: "
                "meaningless with an explicit `plan`"
            )
        elif fuse is None:
            pass  # take the plan verbatim, fused or not
        elif fuse:
            plan = upgrade_plan(spec, plan, self.hw)
        else:
            plan = dataclasses.replace(plan, groups=())
        if verify not in VERIFY_MODES:
            raise ValueError(
                f"verify must be one of {VERIFY_MODES}, got {verify!r}"
            )
        report = None
        if verify != "off":
            from repro.convserve.check.ir import verify_program

            report = verify_program(spec, plan, hw=self.hw)
            if report.errors and verify == "strict":
                raise VerificationError(report)
            if report.diagnostics and verify == "warn":
                print(report.format())
        executor = NetExecutor(
            spec, weights, plan, cache=self.cache, dtype=self.dtype,
            clock=self.clock, tracer=self.tracer,
        )
        self.nets_compiled += 1
        return CompiledNet(
            spec=spec, plan=plan, program=executor.program,
            executor=executor, hw=self.hw, report=report,
        )

    def invalidate(self, net: Optional[str] = None) -> None:
        """Drop cached transforms (all, or one net's) after a weight
        update; the churn shows up as `invalidations` in `stats()`."""
        self.cache.invalidate(net)

    def stats(self) -> dict:
        """Engine-level rollup: nets compiled against this engine plus
        the shared kernel-cache counters (hits/misses/evictions/
        invalidations)."""
        return {
            "nets_compiled": self.nets_compiled,
            "cache": self.cache.stats(),
        }
