"""convserve -- ConvNet inference engine over the paper's algorithms.

Pipeline:  NetSpec --plan_net--> NetPlan --NetExecutor(+KernelCache)-->
one jitted program per input bucket --ConvServer--> batched serving.
"""

from repro.core.registry import ConvSpec
from repro.convserve.cache import KernelCache
from repro.convserve.executor import NetExecutor
from repro.convserve.graph import (
    LayerSpec,
    NetSpec,
    conv,
    init_weights,
    maxpool,
    relu,
    run_direct,
)
from repro.convserve.plan import LayerPlan, NetPlan
from repro.convserve.planner import plan_layer, plan_net
from repro.convserve.serving import ConvServeConfig, ConvServer, ImageRequest

__all__ = [
    "ConvSpec",
    "LayerSpec",
    "NetSpec",
    "conv",
    "relu",
    "maxpool",
    "init_weights",
    "run_direct",
    "LayerPlan",
    "NetPlan",
    "plan_layer",
    "plan_net",
    "KernelCache",
    "NetExecutor",
    "ConvServer",
    "ConvServeConfig",
    "ImageRequest",
]
