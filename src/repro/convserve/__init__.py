"""convserve -- ConvNet inference engine over the paper's algorithms.

Pipeline:  NetSpec --plan_net--> NetPlan (v3: layer plans + fusion
groups) --program.lower--> ExecProgram (staged IR, cross-layer fusion
groups) --Engine.compile--> CompiledNet --ConvServer--> batched serving.

For continuous traffic, `repro.convserve.runtime` layers an online
serving loop on top: deadline-aware wave scheduling, bounded admission,
a replica pool sharing one kernel cache, and telemetry
(`ServeRuntime` / `RuntimeConfig` / `ReplicaPool`, re-exported here).
`repro.convserve.adapt` closes the loop: measured stage costs replace
the roofline when it mispredicts, with shadow A/B verification and
zero-downtime plan hot swap (`AdaptController` / `MeasuredCostStore`,
re-exported here).
"""

from repro.convserve.adapt import (
    AdaptConfig,
    AdaptController,
    MeasuredCostStore,
    ShadowVerifier,
    hot_swap,
)
from repro.core.registry import ConvSpec
from repro.convserve.cache import KernelCache
from repro.convserve.engine import CompiledNet, Engine
from repro.convserve.executor import NetExecutor
from repro.convserve.graph import (
    LayerSpec,
    NetSpec,
    bias,
    conv,
    init_weights,
    maxpool,
    relu,
    run_direct,
)
from repro.convserve.plan import FusionGroup, LayerPlan, NetPlan
from repro.convserve.planner import (
    plan_fusion_groups,
    plan_layer,
    plan_net,
    upgrade_plan,
)
from repro.convserve.program import (
    EpilogueOp,
    ExecProgram,
    Stage,
    StageUnit,
    lower,
)
from repro.convserve.runtime import (
    RealClock,
    Rejection,
    ReplicaPool,
    Request,
    RuntimeConfig,
    ServeRuntime,
    SimClock,
    Telemetry,
    WaveScheduler,
)
from repro.convserve.serving import ConvServeConfig, ConvServer, ImageRequest

__all__ = [
    "ConvSpec",
    "LayerSpec",
    "NetSpec",
    "conv",
    "bias",
    "relu",
    "maxpool",
    "init_weights",
    "run_direct",
    "LayerPlan",
    "NetPlan",
    "FusionGroup",
    "plan_layer",
    "plan_net",
    "plan_fusion_groups",
    "upgrade_plan",
    "EpilogueOp",
    "StageUnit",
    "Stage",
    "ExecProgram",
    "lower",
    "Engine",
    "CompiledNet",
    "KernelCache",
    "NetExecutor",
    "ConvServer",
    "ConvServeConfig",
    "ImageRequest",
    "RuntimeConfig",
    "ServeRuntime",
    "ReplicaPool",
    "WaveScheduler",
    "Request",
    "Rejection",
    "Telemetry",
    "RealClock",
    "SimClock",
    "AdaptConfig",
    "AdaptController",
    "MeasuredCostStore",
    "ShadowVerifier",
    "hot_swap",
]
