"""Roofline planner: per-layer algorithm + R selection for a whole net.

For every conv layer the planner asks the S5 analytical model
(`analysis.choose_algo`) which of the three transformed paths wins --
L3-fused Winograd, L3-fused FFT, or the vendor 3-stage structure -- and
falls back to the direct convolution when the layer is too small to tile.
R comes from `tune.predict_r` (pure model) or, with `tune_r=True`, from
the measuring `tune.tuned_r` pass that refines the model's pick against
the wisdom file.
"""

from __future__ import annotations

from typing import Optional

from repro.core import analysis
from repro.core import tune as tune_mod
from repro.convserve.graph import NetSpec
from repro.convserve.plan import LayerPlan, NetPlan


def plan_layer(
    hw: analysis.HardwareModel,
    layer: int,
    h: int,
    w: int,
    c_in: int,
    c_out: int,
    k: int,
    pad: int,
    *,
    m: int = 5,
    t_fft: int = 16,
    consider_fft: bool = True,
    tune_r: bool = False,
    wisdom_path=None,
) -> LayerPlan:
    """Plan one conv layer of input (h, w, c_in) -> c_out."""
    t_wino = m + k - 1
    # Too small to tile profitably: the padded input must cover at least
    # one Winograd tile, else the transform overhead swamps the matmuls.
    if min(h, w) + 2 * pad < t_wino:
        return LayerPlan(
            layer=layer, algo="direct", pad=pad, r_tiles=0,
            c_in=c_in, c_out=c_out, k=k, h=h, w=w, predicted_util=1.0,
        )
    # FFT is only a candidate when the padded input covers a full T_fft
    # tile: below that the tile is mostly padding and the cost model's
    # flops-per-output-pixel comparison no longer holds.
    fft_fits = min(h, w) + 2 * pad >= t_fft
    algo = analysis.choose_algo(
        hw, c_in, c_out, t_wino, k=k, t_fft=t_fft,
        consider_fft=consider_fft and fft_fits,
    )
    if algo == "fft_fused":
        r = tune_mod.predict_r(c_in, c_out, k=k, t=t_fft, hw=hw)
        util = analysis.predicted_utilization(
            hw, r, c_in, c_out, t_fft, t_fft - k + 1, alpha=2
        )
        return LayerPlan(
            layer=layer, algo=algo, pad=pad, r_tiles=r,
            c_in=c_in, c_out=c_out, k=k, h=h, w=w,
            t_fft=t_fft, predicted_util=util,
        )
    if algo == "l3_fused":
        tuned = False
        if tune_r:
            r = tune_mod.tuned_r(
                h, w, c_in, c_out, k=k, m=m, wisdom_path=wisdom_path
            )
            tuned = True
        else:
            r = tune_mod.predict_r(c_in, c_out, k=k, m=m, hw=hw)
        util = analysis.predicted_utilization(
            hw, r, c_in, c_out, t_wino, m, alpha=1
        )
        return LayerPlan(
            layer=layer, algo=algo, pad=pad, r_tiles=r,
            c_in=c_in, c_out=c_out, k=k, h=h, w=w,
            m=m, predicted_util=util, tuned=tuned,
        )
    # three_stage: R is irrelevant (stages run over all tiles); the DRAM
    # roofline bounds utilisation since U and M round-trip main memory.
    util = min(
        1.0, analysis.ai_dram(c_in, c_out, t_wino, m) / hw.cmr_dram
    )
    return LayerPlan(
        layer=layer, algo="three_stage", pad=pad, r_tiles=0,
        c_in=c_in, c_out=c_out, k=k, h=h, w=w,
        m=m, predicted_util=util,
    )


def plan_net(
    spec: NetSpec,
    h: int,
    w: int,
    *,
    hw: Optional[analysis.HardwareModel] = None,
    m: int = 5,
    t_fft: int = 16,
    consider_fft: bool = True,
    tune_r: bool = False,
    wisdom_path=None,
    dtype: str = "float32",
) -> NetPlan:
    """Plan every conv layer of `spec` at reference input (h, w)."""
    hw = hw or tune_mod.default_hw()
    convs = spec.conv_layers()
    if not convs:
        raise ValueError(f"net {spec.name!r} has no conv layers")
    c0 = convs[0][1].c_in
    shapes = spec.infer_shapes(h, w, c0)
    plans = []
    cur_h, cur_w = h, w
    for i, layer in enumerate(spec.layers):
        if layer.kind == "conv":
            plans.append(
                plan_layer(
                    hw, i, cur_h, cur_w, layer.c_in, layer.c_out,
                    layer.k, layer.pad,
                    m=m, t_fft=t_fft, consider_fft=consider_fft,
                    tune_r=tune_r, wisdom_path=wisdom_path,
                )
            )
        cur_h, cur_w = shapes[i][0], shapes[i][1]
    return NetPlan(
        net=spec.name, hw=hw.name, dtype=dtype,
        input_hw=(h, w), layers=tuple(plans),
    )
