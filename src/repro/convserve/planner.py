"""Roofline planner: per-layer algorithm + R selection, then cross-layer
fusion-group selection, for a whole net.

For every conv layer the planner poses a `ConvSpec` to the algorithm
registry (`registry.plan_conv`), which ranks every supporting, feasible
algorithm by the S5 analytical model -- L3-fused Winograd, L3-fused FFT,
the vendor 3-stage structure, or the direct convolution when the layer is
too small to tile.  R comes from the registry's plan step: an explicit
hint, the wisdom file (`tune.lookup_r` / the measuring `tune.tuned_r`
with ``tune_r=True``), or the analytic `tune.predict_r`.

On top of the per-layer decisions, `plan_fusion_groups` walks adjacent
conv units and charges the same roofline currency at the net level: a
fusion group skips the DRAM round trip of the intermediate activation
(2 x H x W x C x 4 bytes at `dram_bw`) at the price of recomputing
(K-1)-row halos at super-tile seams; it is admitted only where the
chained algorithms share a tiling family (`Algorithm.can_chain`), the
group's right-hand matrices jointly fit the fast shared level, and the
saved traffic exceeds the recompute time.

The planner itself names no algorithm: a newly registered algorithm is
planned for -- and chained -- automatically.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

from repro.core import analysis, registry
from repro.core import tune as tune_mod
from repro.convserve import program as program_mod
from repro.convserve.graph import NetSpec
from repro.convserve.plan import FusionGroup, LayerPlan, NetPlan


def plan_layer(
    hw: analysis.HardwareModel,
    layer: int,
    spec: registry.ConvSpec,
    *,
    m: int = 5,
    t_fft: int = 16,
    consider_fft: bool = True,
    tune_r: bool = False,
    wisdom_path=None,
    allowed: Optional[Sequence[str]] = None,
    costs=None,
) -> LayerPlan:
    """Plan one conv layer posed as a ConvSpec.

    With `costs` (a measured-cost view, see `convserve.adapt.costs`), the
    roofline's tier-ranked choice can be overridden by measurement: when
    the model's winner has a measured time for this geometry and another
    supporting algorithm measured strictly faster, the faster one is
    planned instead -- ranked purely by seconds, ignoring the registry
    tier order that the analytic path uses."""
    if allowed is None:
        allowed = registry.names()
    if not consider_fft:
        allowed = tuple(n for n in allowed if n != "fft_fused")
    ap = registry.plan_conv(
        spec, hw,
        algo="auto",
        hints={"m": m, "t_fft": t_fft},
        allowed=allowed,
        tune_r=tune_r,
        wisdom_path=wisdom_path,
    )
    if costs is not None:
        measured = {}
        for name in allowed:
            alg = registry.get(name)
            if not (alg.auto_candidate and alg.supports(spec)):
                continue
            t = costs.algo_time_s(name, spec)
            if t is not None:
                measured[name] = t
        t_model = measured.get(ap.algo)
        if t_model is not None and measured:
            best = min(measured, key=measured.get)
            if best != ap.algo and measured[best] < t_model:
                ap = registry.plan_conv(
                    spec, hw,
                    algo=best,
                    hints={"m": m, "t_fft": t_fft},
                    tune_r=tune_r,
                    wisdom_path=wisdom_path,
                )
    return LayerPlan.from_algo_plan(layer, ap)


def plan_net(
    spec: NetSpec,
    h: int,
    w: int,
    *,
    hw: Optional[analysis.HardwareModel] = None,
    m: int = 5,
    t_fft: int = 16,
    consider_fft: bool = True,
    tune_r: bool = False,
    wisdom_path=None,
    dtype: str = "float32",
    fuse: bool = True,
    allowed: Optional[Sequence[str]] = None,
    costs=None,
) -> NetPlan:
    """Plan every conv layer of `spec` at reference input (h, w), then
    (``fuse=True``) the cross-layer fusion groups on top.  `allowed`
    restricts the algorithm candidates per layer (e.g. ``("direct",)``
    for a bitwise-reproducible baseline plan).  `costs` threads a
    measured-cost view through both the per-layer choice and the fusion
    verdict (see `plan_layer` / `_group_decision`)."""
    hw = hw or tune_mod.default_hw()
    convs = spec.conv_layers()
    if not convs:
        raise ValueError(f"net {spec.name!r} has no conv layers")
    c0 = convs[0][1].c_in
    shapes = spec.infer_shapes(h, w, c0)
    plans = []
    cur_h, cur_w = h, w
    for i, layer in enumerate(spec.layers):
        if layer.kind == "conv":
            cspec = registry.ConvSpec(
                h=cur_h, w=cur_w,
                c_in=layer.c_in, c_out=layer.c_out, k=layer.k,
                pad=layer.pad, stride=layer.stride, groups=layer.groups,
                dtype=dtype,
            )
            plans.append(
                plan_layer(
                    hw, i, cspec,
                    m=m, t_fft=t_fft, consider_fft=consider_fft,
                    tune_r=tune_r, wisdom_path=wisdom_path,
                    allowed=allowed, costs=costs,
                )
            )
        cur_h, cur_w = shapes[i][0], shapes[i][1]
    plan = NetPlan(
        net=spec.name, hw=hw.name, dtype=dtype,
        input_hw=(h, w), layers=tuple(plans),
    )
    return (
        plan_fusion_groups(spec, plan, hw, costs=costs) if fuse else plan
    )


# ------------------------------------------------- cross-layer fusion


# fraction of the fast shared level a fusion group's resident slab (the
# super-tile of the largest intermediate) may occupy -- the rest holds
# the group's right-hand matrices (the same residency budget the
# per-layer feasibility gate uses) and the per-task private intermediates
_SLAB_FRAC = 0.25
_MATRIX_FRAC = analysis.MATRIX_RESIDENCY_FRAC


def _conv_time_s(p: LayerPlan, hw: analysis.HardwareModel) -> float:
    """Modeled wall time of one conv at its reference geometry.
    Deliberately reconstructible from a deserialized plan (v2 files keep
    predicted_util but not the auto-ranking cost).

    Transformed algorithms are priced by the FLOPs the parametric tile
    engine actually executes (forward + mix + inverse GEMMs over the full
    stride-1 tile grid, `TileAlgebra.engine_flops`) -- the direct-conv
    FLOP count used to stand in for every algorithm, which is why
    measured/predicted ratios ran orders of magnitude apart between
    families.  Direct convs keep the `analysis.conv_time_s` charge."""
    s = p.spec
    ta = registry.get(p.algo).tile_algebra(p.algo_plan())
    if ta is not None and ta.t_out >= 1 and not s.temporal:
        oh1 = s.h + 2 * s.pad - s.k + 1
        ow1 = s.w + 2 * s.pad - s.k + 1
        flops = ta.engine_flops(oh1, ow1, s.c_in, s.c_out, s.groups)
        return flops / (hw.peak_flops * max(p.predicted_util, 0.05))
    oh, ow = s.out_hw
    return analysis.conv_time_s(
        hw, out_h=oh, out_w=ow, c_in=s.c_in, c_out=s.c_out, k=s.k,
        groups=s.groups, predicted_util=p.predicted_util,
    )


def predict_stage_times(program, hw: analysis.HardwareModel) -> list:
    """Roofline prediction per ExecProgram stage: ``[(label, seconds)]``.
    A fused stage is priced as the sum of its members' modeled conv
    times (the model's fusion benefit lives in the group *decision*, not
    in the per-conv time) -- this is the prediction side that
    `convserve.adapt` compares measured stage timings against."""
    return [
        (
            stage.label,
            sum(_conv_time_s(u.plan, hw) for u in stage.units),
        )
        for stage in program.stages
    ]


def _group_decision(
    members: List[LayerPlan],
    hw: analysis.HardwareModel,
    *,
    max_tiles: int,
    costs=None,
) -> Optional[int]:
    """Roofline verdict on fusing `members` into one stage.

    Returns the super-tile row count (0 == untiled) when fusing wins,
    None when it does not.  With `costs`, a measured verdict replaces
    the saved-vs-extra model when both sides have been measured: fuse
    iff the measured group time beats the sum of the members' measured
    single-stage times.  Structural gates (chain family, matrix
    residency, slab feasibility) still apply either way.  Charged model:

      saved  = sum over interior boundaries of 2 x H x W x C x 4 bytes
               at dram_bw        (the skipped write+read round trip)
      extra  = (n_tiles - 1) x halo rows recomputed per seam, where the
               halo of intermediate j is sum of (K-1) over later convs
               (receptive-field growth), each row at that conv's modeled
               time per output row
    """
    # joint right-hand matrices must stay resident in the shared level --
    # priced family-exactly (complex rfft half-spectrum for FFT members)
    # through each algorithm's TileAlgebra
    matrix_bytes = 0
    for p in members:
        ta = registry.get(p.algo).tile_algebra(p.algo_plan())
        if ta is None:  # no transform family (direct): never chained
            return None
        matrix_bytes += ta.kernel_matrix_bytes(p.c_in, p.c_out, p.groups)
    if matrix_bytes > _MATRIX_FRAC * hw.fast_shared_bytes:
        return None
    # intermediates: input geometry of each member after the first
    inter = [(p.spec.h, p.spec.w, p.spec.c_in) for p in members[1:]]
    slab_row_bytes = max(w * c * 4 for _, w, c in inter)
    h_final, _ = members[-1].spec.out_hw
    budget = _SLAB_FRAC * hw.fast_shared_bytes
    tile_rows = int(budget // slab_row_bytes) - (members[-1].k - 1)
    if tile_rows < 1:
        return None  # one slab row set cannot stay resident
    if tile_rows >= h_final:
        n_tiles = 1
    else:
        n_tiles = math.ceil(h_final / tile_rows)
        if n_tiles > max_tiles:
            return None  # seam recompute (and trace size) out of hand
    if costs is not None:
        t_group = costs.group_time_s(members)
        singles = [costs.algo_time_s(p.algo, p.spec) for p in members]
        if t_group is not None and all(t is not None for t in singles):
            if t_group >= sum(singles):
                return None
            return 0 if n_tiles == 1 else tile_rows
    saved_s = sum(2 * h * w * c * 4 for h, w, c in inter) / hw.dram_bw
    extra_s = 0.0
    for j, p in enumerate(members[:-1]):
        halo = sum(q.k - 1 for q in members[j + 1 :])
        time_per_row = _conv_time_s(p, hw) / max(p.spec.out_hw[0], 1)
        extra_s += (n_tiles - 1) * halo * time_per_row
    if saved_s <= extra_s:
        return None
    return 0 if n_tiles == 1 else tile_rows


def plan_fusion_groups(
    spec: NetSpec,
    plan: NetPlan,
    hw: Optional[analysis.HardwareModel] = None,
    *,
    max_tiles: int = 8,
    costs=None,
) -> NetPlan:
    """Derive the cross-layer fusion groups for an already layer-planned
    net: greedy extension over adjacent conv units, gated by algorithm
    chainability, structural legality (no pooling mid-group), and the
    roofline benefit model (`_group_decision`)."""
    hw = hw or tune_mod.default_hw()
    _, units = program_mod.split_units(spec)
    plans = {p.layer: p for p in plan.layers}
    groups: List[FusionGroup] = []
    members: List[LayerPlan] = []
    tile_rows = 0

    def flush():
        nonlocal members, tile_rows
        if len(members) > 1:
            groups.append(
                FusionGroup(
                    layers=tuple(p.layer for p in members),
                    tile_rows=tile_rows,
                )
            )
        members, tile_rows = [], 0

    for pos, (conv_idx, ops) in enumerate(units):
        p = plans.get(conv_idx)
        if p is None:
            raise ValueError(f"plan missing conv layer {conv_idx}")
        if members:
            prev = members[-1]
            prev_ops = units[pos - 1][1]
            chainable = (
                not any(op.kind == "maxpool" for op in prev_ops)
                and registry.get(prev.algo).can_chain(
                    prev.algo_plan(), p.algo_plan()
                )
            )
            if chainable:
                verdict = _group_decision(
                    members + [p], hw, max_tiles=max_tiles, costs=costs
                )
                if verdict is not None:
                    members.append(p)
                    tile_rows = verdict
                    continue
            flush()
        members = [p]
    flush()
    return dataclasses.replace(plan, groups=tuple(groups))


def upgrade_plan(
    spec: NetSpec,
    plan: NetPlan,
    hw: Optional[analysis.HardwareModel] = None,
) -> NetPlan:
    """v2 -> v3 migration: a v2 plan file carries the identical per-layer
    decisions but no fusion groups; re-derive them from the same roofline
    model.  A v3 plan that already has groups passes through unchanged."""
    if plan.groups:
        return plan
    return plan_fusion_groups(spec, plan, hw)

