"""Roofline planner: per-layer algorithm + R selection for a whole net.

For every conv layer the planner poses a `ConvSpec` to the algorithm
registry (`registry.plan_conv`), which ranks every supporting, feasible
algorithm by the S5 analytical model -- L3-fused Winograd, L3-fused FFT,
the vendor 3-stage structure, or the direct convolution when the layer is
too small to tile.  R comes from the registry's plan step: an explicit
hint, the wisdom file (`tune.lookup_r` / the measuring `tune.tuned_r`
with ``tune_r=True``), or the analytic `tune.predict_r`.

The planner itself names no algorithm: a newly registered algorithm is
planned for automatically.
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.core import analysis, registry
from repro.core import tune as tune_mod
from repro.convserve.graph import NetSpec
from repro.convserve.plan import LayerPlan, NetPlan


def plan_layer(
    hw: analysis.HardwareModel,
    layer: int,
    spec: registry.ConvSpec,
    *,
    m: int = 5,
    t_fft: int = 16,
    consider_fft: bool = True,
    tune_r: bool = False,
    wisdom_path=None,
    allowed: Optional[Sequence[str]] = None,
) -> LayerPlan:
    """Plan one conv layer posed as a ConvSpec."""
    if allowed is None:
        allowed = registry.names()
    if not consider_fft:
        allowed = tuple(n for n in allowed if n != "fft_fused")
    ap = registry.plan_conv(
        spec, hw,
        algo="auto",
        hints={"m": m, "t_fft": t_fft},
        allowed=allowed,
        tune_r=tune_r,
        wisdom_path=wisdom_path,
    )
    return LayerPlan.from_algo_plan(layer, ap)


def plan_net(
    spec: NetSpec,
    h: int,
    w: int,
    *,
    hw: Optional[analysis.HardwareModel] = None,
    m: int = 5,
    t_fft: int = 16,
    consider_fft: bool = True,
    tune_r: bool = False,
    wisdom_path=None,
    dtype: str = "float32",
) -> NetPlan:
    """Plan every conv layer of `spec` at reference input (h, w)."""
    hw = hw or tune_mod.default_hw()
    convs = spec.conv_layers()
    if not convs:
        raise ValueError(f"net {spec.name!r} has no conv layers")
    c0 = convs[0][1].c_in
    shapes = spec.infer_shapes(h, w, c0)
    plans = []
    cur_h, cur_w = h, w
    for i, layer in enumerate(spec.layers):
        if layer.kind == "conv":
            cspec = registry.ConvSpec(
                h=cur_h, w=cur_w,
                c_in=layer.c_in, c_out=layer.c_out, k=layer.k,
                pad=layer.pad, stride=layer.stride, groups=layer.groups,
                dtype=dtype,
            )
            plans.append(
                plan_layer(
                    hw, i, cspec,
                    m=m, t_fft=t_fft, consider_fft=consider_fft,
                    tune_r=tune_r, wisdom_path=wisdom_path,
                )
            )
        cur_h, cur_w = shapes[i][0], shapes[i][1]
    return NetPlan(
        net=spec.name, hw=hw.name, dtype=dtype,
        input_hw=(h, w), layers=tuple(plans),
    )
