"""Data pipeline: deterministic synthetic stream + file-backed token shards.

Design points for the 1000+-node posture:
  * host-sharded: each host reads only its slice of the global batch,
    indexed by (host_id, num_hosts) -- no central dispatcher.
  * deterministic & resumable: batch t is a pure function of (seed, t), so
    restart-after-failure replays exactly; no data-loader state in the
    checkpoint beyond the step counter.
  * double-buffered: a background thread prefetches batch t+1 while step t
    runs.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    path: Optional[str] = None  # file-backed tokens (np.memmap .bin of int32)


class TokenStream:
    """Deterministic synthetic LM stream (markov-ish mixture so loss is
    learnable, not pure noise)."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_hosts
        self._mm = None
        if cfg.path:
            self._mm = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + cfg.host_id
        )
        b, s = self.local_batch, cfg.seq_len
        if self._mm is not None:
            n = len(self._mm) - (s + 1)
            starts = rng.integers(0, max(n, 1), size=b)
            seqs = np.stack(
                [self._mm[st : st + s + 1] for st in starts]
            ).astype(np.int32)
            seqs = np.clip(seqs, 0, cfg.vocab_size - 1)
        else:
            # structured synthetic: piecewise-linear token walks
            base = rng.integers(0, cfg.vocab_size, size=(b, 1))
            drift = rng.integers(-3, 4, size=(b, s + 1)).cumsum(axis=1)
            seqs = ((base + drift) % cfg.vocab_size).astype(np.int32)
        return {
            "tokens": seqs[:, :-1],
            "targets": seqs[:, 1:],
            "mask": np.ones((b, s), np.float32),
        }

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """One-deep background prefetch (double buffering)."""

    def __init__(self, stream: TokenStream, start_step: int = 0, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            step = start_step
            while not self._stop.is_set():
                batch = stream.batch_at(step)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
