"""End-to-end serving driver: batched requests, prefill + decode engine.

    PYTHONPATH=src python examples/serve_batch.py
"""

import sys

sys.path.insert(0, "src")

from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    serve_main(["--arch", "gemma3-1b", "--requests", "12", "--max-new", "16"])
