"""End-to-end LM training driver: a small model, a few hundred steps, with
checkpointing + resume (scaled to this 1-core container; the same code path
`launch/train.py` runs the full configs on a real cluster).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    train_main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "64", "--lr", "3e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
    ])


if __name__ == "__main__":
    main()
