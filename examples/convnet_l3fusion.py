"""End-to-end ConvNet inference with L3-fused convolutions (the paper's
native use case): a VGG-style stage pipeline, fused vs vendor.

    PYTHONPATH=src python examples/convnet_l3fusion.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import conv2d_direct
from repro.core.fused import conv2d_l3_fused
from repro.core.three_stage import transform_kernels


def vgg_stage(x, kernels, algo):
    """Two 3x3 convs + ReLU + 2x2 pool, like a VGG stage."""
    for w in kernels:
        if algo == "fused":
            x = conv2d_l3_fused(x, w, pad=1, m=5, r_tiles=24)
        else:
            x = conv2d_direct(x, w, pad=1)
        x = jax.nn.relu(x)
    b, h, wd, c = x.shape
    return x.reshape(b, h // 2, 2, wd // 2, 2, c).max(axis=(2, 4))


def main():
    rng = np.random.default_rng(0)
    x0 = jnp.asarray(rng.standard_normal((1, 112, 112, 64)) * 0.1, jnp.float32)
    stages = []
    c = 64
    for _ in range(2):
        stages.append([
            jnp.asarray(rng.standard_normal((3, 3, c, c)) * 0.05, jnp.float32)
            for _ in range(2)
        ])

    def net(x, algo):
        for ks in stages:
            x = vgg_stage(x, ks, algo)
        return x

    fused = jax.jit(lambda x: net(x, "fused"))
    vendor = jax.jit(lambda x: net(x, "vendor"))
    yf = jax.block_until_ready(fused(x0))
    yv = jax.block_until_ready(vendor(x0))
    err = float(jnp.abs(yf - yv).max() / jnp.abs(yv).max())
    print(f"output {tuple(yf.shape)}; fused-vs-vendor rel err {err:.2e}")

    for name, fn in (("l3_fused", fused), ("vendor(XLA)", vendor)):
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x0))
            ts.append(time.perf_counter() - t0)
        print(f"{name:12s} {sorted(ts)[len(ts)//2]*1e3:8.1f} ms/img")


if __name__ == "__main__":
    main()
