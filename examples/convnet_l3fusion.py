"""End-to-end ConvNet inference through the convserve Engine (the paper's
native use case): a mixed-channel VGG-style net is roofline-planned per
layer, adjacent small-channel convs are collapsed into cross-layer fusion
groups, kernels are pre-transformed into the cache, and requests are
served in shape-bucketed batched waves.

    PYTHONPATH=src python examples/convnet_l3fusion.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.convnets import vgg_mixed_channel
from repro.convserve import (
    ConvServeConfig,
    ConvServer,
    Engine,
    ImageRequest,
    init_weights,
    run_direct,
)


def main():
    spec = vgg_mixed_channel(c_in=3)
    engine = Engine()  # TPU model on TPU backends, SkylakeX otherwise
    ws = init_weights(spec, seed=0)
    net = engine.compile(spec, ws, input_hw=(64, 64))

    print(f"net {spec.name!r} compiled for {engine.hw.name}:")
    for p in net.plan.layers:
        s = p.spec
        stride = f"/{s.stride}" if s.stride > 1 else "  "
        print(
            f"  layer {p.layer:2d}  {s.c_in:4d}->{s.c_out:<4d}{stride} "
            f"{p.algo:12s} params={p.params} util~{p.predicted_util:.2f}"
        )
    print("staged execution program (fusion groups keep the intermediate")
    print("activation resident instead of round-tripping DRAM):")
    print("  " + net.describe().replace("\n", "\n  "))
    algos = set(net.plan.algos())
    print(f"distinct algorithms in plan: {sorted(algos)}")
    assert len(algos) >= 2, "expected a mixed-algorithm plan"
    assert net.program.n_fused >= 1, "expected >=1 cross-layer fusion group"

    srv = ConvServer(net, ConvServeConfig(max_batch=4, buckets=(32, 64)))

    rng = np.random.default_rng(0)
    imgs = [
        rng.standard_normal((s, s, 3)).astype(np.float32) * 0.1
        for s in (64, 64, 32, 64, 32)
    ]
    reqs = [ImageRequest(i, im) for i, im in enumerate(imgs)]

    t0 = time.perf_counter()
    out = srv.run(reqs)
    print(
        f"wave 1: {len(out)} requests in {time.perf_counter() - t0:.2f}s "
        f"(compiles + kernel transforms) {srv.stats()}"
    )

    # numerical agreement with the all-direct oracle
    ref = np.asarray(run_direct(spec, ws, jnp.asarray(imgs[0])[None])[0])
    rel = float(np.abs(out[0] - ref).max() / np.abs(ref).max())
    print(f"fused-engine vs direct rel err {rel:.2e}")
    assert rel < 1e-3

    # same shapes again: transforms hit the cache, programs are reused
    t0 = time.perf_counter()
    srv.run([ImageRequest(10 + i, im) for i, im in enumerate(imgs)])
    warm = time.perf_counter() - t0
    stats = srv.stats()
    print(f"wave 2: warm {warm*1e3:.1f} ms  {stats}")
    assert stats["cache"]["hits"] > 0, "second wave should hit the cache"

    # throughput: fused program vs unfused vs all-direct on the big bucket
    x = jnp.asarray(
        rng.standard_normal((4, 64, 64, 3)) * 0.1, jnp.float32
    )
    unfused = engine.compile(spec, ws, input_hw=(64, 64), fuse=False)
    vendor = jax.jit(lambda x: run_direct(spec, ws, x))
    for fn in (vendor, net, unfused):
        jax.block_until_ready(fn(x))
    for name, fn in (
        ("fused engine", net),
        ("unfused engine", unfused),
        ("vendor(XLA)", vendor),
    ):
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            ts.append(time.perf_counter() - t0)
        print(f"{name:15s} {sorted(ts)[len(ts) // 2] * 1e3 / 4:8.1f} ms/img")

    # per-stage wall times: where does the net actually spend its time?
    print("per-stage profile (separately jitted):")
    for label, secs in net.profile_stages(x):
        print(f"  {label:12s} {secs * 1e3:7.2f} ms")

    # the registry makes new scenarios one compile away: a stride-2
    # ResNet-style downsampling net plans transformed paths too (tile
    # decimation), its stride-1 head still fusing into a group
    from repro.configs.convnets import resnet_downsample

    rspec = resnet_downsample(c_in=3)
    rws = init_weights(rspec, seed=1)
    rnet = engine.compile(rspec, rws, input_hw=(64, 64))
    print(f"\nnet {rspec.name!r}:")
    print("  " + rnet.describe().replace("\n", "\n  "))
    xr = jnp.asarray(rng.standard_normal((2, 64, 64, 3)) * 0.1, jnp.float32)
    rref = run_direct(rspec, rws, xr)
    rel = float(jnp.abs(rnet(xr) - rref).max() / jnp.abs(rref).max())
    print(f"stride-2 net fused-engine vs direct rel err {rel:.2e}")
    assert rel < 1e-3


if __name__ == "__main__":
    main()
