"""End-to-end ConvNet inference through the convserve engine (the paper's
native use case): a mixed-channel VGG-style net is roofline-planned per
layer, its kernels pre-transformed into the cache, and requests served in
shape-bucketed batched waves.

    PYTHONPATH=src python examples/convnet_l3fusion.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.convnets import vgg_mixed_channel
from repro.convserve import (
    ConvServeConfig,
    ConvServer,
    ImageRequest,
    NetExecutor,
    init_weights,
    plan_net,
    run_direct,
)
from repro.core.tune import default_hw


def main():
    spec = vgg_mixed_channel(c_in=3)
    hw = default_hw()  # TPU model on TPU backends, SkylakeX otherwise
    plan = plan_net(spec, 64, 64, hw=hw)

    print(f"net {spec.name!r} planned for {hw.name}:")
    for p in plan.layers:
        s = p.spec
        stride = f"/{s.stride}" if s.stride > 1 else "  "
        print(
            f"  layer {p.layer:2d}  {s.c_in:4d}->{s.c_out:<4d}{stride} "
            f"{p.algo:12s} params={p.params} util~{p.predicted_util:.2f}"
        )
    algos = set(plan.algos())
    print(f"distinct algorithms in plan: {sorted(algos)}")
    assert len(algos) >= 2, "expected a mixed-algorithm plan"

    ws = init_weights(spec, seed=0)
    ex = NetExecutor(spec, ws, plan)
    srv = ConvServer(ex, ConvServeConfig(max_batch=4, buckets=(32, 64)))

    rng = np.random.default_rng(0)
    imgs = [
        rng.standard_normal((s, s, 3)).astype(np.float32) * 0.1
        for s in (64, 64, 32, 64, 32)
    ]
    reqs = [ImageRequest(i, im) for i, im in enumerate(imgs)]

    t0 = time.perf_counter()
    out = srv.run(reqs)
    print(
        f"wave 1: {len(out)} requests in {time.perf_counter() - t0:.2f}s "
        f"(compiles + kernel transforms) {srv.stats()}"
    )

    # numerical agreement with the all-direct oracle
    ref = np.asarray(run_direct(spec, ws, jnp.asarray(imgs[0])[None])[0])
    rel = float(np.abs(out[0] - ref).max() / np.abs(ref).max())
    print(f"planned-engine vs direct rel err {rel:.2e}")
    assert rel < 1e-3

    # same shapes again: transforms hit the cache, programs are reused
    t0 = time.perf_counter()
    srv.run([ImageRequest(10 + i, im) for i, im in enumerate(imgs)])
    warm = time.perf_counter() - t0
    stats = srv.stats()
    print(f"wave 2: warm {warm*1e3:.1f} ms  {stats}")
    assert stats["hits"] > 0, "second wave should hit the kernel cache"

    # throughput: planned engine vs all-direct on the big bucket
    x = jnp.asarray(
        rng.standard_normal((4, 64, 64, 3)) * 0.1, jnp.float32
    )
    vendor = jax.jit(lambda x: run_direct(spec, ws, x))
    jax.block_until_ready(vendor(x))
    jax.block_until_ready(ex(x))
    for name, fn in (("planned engine", ex), ("vendor(XLA)", vendor)):
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            ts.append(time.perf_counter() - t0)
        print(f"{name:15s} {sorted(ts)[len(ts) // 2] * 1e3 / 4:8.1f} ms/img")

    # the registry makes new scenarios one plan away: a stride-2
    # ResNet-style downsampling net plans transformed paths too (tile
    # decimation), with grouped layers falling back per capability
    from repro.configs.convnets import resnet_downsample

    rspec = resnet_downsample(c_in=3)
    rplan = plan_net(rspec, 64, 64, hw=hw)
    print(f"\nnet {rspec.name!r}:")
    for p in rplan.layers:
        s = p.spec
        stride = f"/{s.stride}" if s.stride > 1 else "  "
        print(
            f"  layer {p.layer:2d}  {s.c_in:4d}->{s.c_out:<4d}{stride} "
            f"{p.algo:12s} params={p.params}"
        )
    rws = init_weights(rspec, seed=1)
    rex = NetExecutor(rspec, rws, rplan)
    xr = jnp.asarray(rng.standard_normal((2, 64, 64, 3)) * 0.1, jnp.float32)
    rref = run_direct(rspec, rws, xr)
    rel = float(jnp.abs(rex(xr) - rref).max() / jnp.abs(rref).max())
    print(f"stride-2 net planned-engine vs direct rel err {rel:.2e}")
    assert rel < 1e-3


if __name__ == "__main__":
    main()
