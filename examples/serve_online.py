"""Online ConvNet serving: the runtime end to end on Poisson traffic.

Compiles a planned convnet into a 2-replica pool (one shared
pre-transformed kernel cache), replays a seeded open-loop Poisson trace
with a 50 ms SLO through the deadline-aware wave scheduler, and prints
the telemetry document -- throughput, queue/compute/e2e percentiles,
wave + admission counters, cache reuse.

The flight recorder rides along: every admit/wave/stage lands in a span
ring, incidents (SLO breach, verification error) dump it immediately,
and the whole run is written to ``serve_online.trace.json`` on exit --
open it in Perfetto (https://ui.perfetto.dev) or chrome://tracing.

    PYTHONPATH=src python examples/serve_online.py
"""

import json
import sys

sys.path.insert(0, "src")

from repro.configs.convnets import tiny_testnet  # noqa: E402
from repro.convserve import Engine, init_weights  # noqa: E402
from repro.convserve.obs import (  # noqa: E402
    FlightRecorder,
    Tracer,
    roofline_table,
    write_trace,
)
from repro.convserve.runtime import (  # noqa: E402
    INTERACTIVE,
    STANDARD,
    ReplicaPool,
    RuntimeConfig,
    ServeRuntime,
    make_images,
    poisson_trace,
)

TRACE_PATH = "serve_online.trace.json"


def main() -> None:
    spec = tiny_testnet(4)
    weights = init_weights(spec, seed=0)
    engine = Engine()

    pool = ReplicaPool.build(engine, spec, weights, n=2, input_hw=(32, 32))
    cfg = RuntimeConfig(
        max_batch=8,
        buckets=(32, 64),
        queue_depth=64,
        # interactive requests flush waves after 60 ms of slack,
        # standard ones after 200 ms
        slo_s={INTERACTIVE: 0.06, STANDARD: 0.20},
        service_est_s=0.005,
    )
    tracer = Tracer()
    recorder = FlightRecorder(tracer, path_prefix="serve_online")
    rt = ServeRuntime(pool, cfg, tracer=tracer, recorder=recorder)

    # compile the max_batch program for every (bucket, replica) and
    # prepare the shared kernel transforms, so the trace measures
    # serving rather than jit compiles
    rt.warmup()

    trace = poisson_trace(
        rate_hz=120.0, n=150, seed=7, sizes=(24, 32, 48, 64),
        priorities=(INTERACTIVE, STANDARD),
    )
    images = make_images(trace, c=4, seed=8)
    results = rt.play(trace, images)
    print(f"served {len([a for a in trace if a.rid in results])}"
          f"/{len(trace)} requests")

    doc = rt.stats(profile_bucket=32)
    e2e = doc["latency"]["e2e"]
    print(f"p50 {e2e['p50_s'] * 1e3:.1f} ms   "
          f"p95 {e2e['p95_s'] * 1e3:.1f} ms   "
          f"p99 {e2e['p99_s'] * 1e3:.1f} ms")
    print(json.dumps(
        {k: doc[k] for k in ("counters", "scheduler", "cache")},
        indent=1, sort_keys=True,
    ))
    rf = doc.get("roofline")
    if rf:
        print(roofline_table(rf["stages"], hw_name=rf["hw"]["name"]))
    rt.shutdown()

    n = write_trace(tracer, TRACE_PATH)
    print(f"wrote {TRACE_PATH} ({n} events) -- open in Perfetto; "
          f"recorder trips: {recorder.stats()['trips'] or 'none'}")


if __name__ == "__main__":
    main()
