"""Quickstart: the algorithm registry through the public API.

A convolution *problem* is a `ConvSpec`; each *realization* (direct,
three_stage, l3_fused, fft_fused, l3_fused_pallas) is a registered
`Algorithm` with a plan/prepare/execute lifecycle; `conv2d` is a thin
dispatcher that resolves ``algo="auto"`` through the registry's roofline
cost model and the wisdom file.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import ConvSpec, analysis as an, conv2d, conv2d_direct, registry

# a ResNet conv layer (64 channels, 56x56) -- the paper's sweet spot
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((2, 56, 56, 64)) * 0.1, jnp.float32)
w = jnp.asarray(rng.standard_normal((3, 3, 64, 64)) * 0.1, jnp.float32)

ref = conv2d_direct(x, w, pad=1)
# every algorithm whose domain covers this problem (the registry also
# holds e.g. the temporal conv1d algorithm, which declines 2-D specs)
for algo in registry.supporting(registry.ConvSpec.from_tensors(x, w, pad=1)):
    y = conv2d(x, w, pad=1, algo=algo)
    err = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
    print(f"{algo:16s} out={tuple(y.shape)} rel_err_vs_direct={err:.2e}")

# the same problem as data: what does the registry plan for it?
spec = ConvSpec.from_tensors(x, w, pad=1)
plan = registry.plan_conv(spec, an.SKYLAKE_X)
print(
    f"\nauto on SkylakeX -> {plan.algo} params={plan.params} "
    f"util~{plan.predicted_util:.2f}"
)

# new scenarios ride the same dispatcher: stride-2 downsampling layers
# reach the transformed paths via tile-decimation, grouped layers fall
# back to direct until a transformed algorithm registers grouped support
y2 = conv2d(x, w, pad=1, stride=2)
wg = jnp.asarray(rng.standard_normal((3, 3, 16, 64)) * 0.1, jnp.float32)
yg = conv2d(x, wg, pad=1, groups=4)
print(f"stride=2 out={tuple(y2.shape)}  groups=4 out={tuple(yg.shape)}")
spec_g = ConvSpec.from_tensors(x, wg, pad=1, groups=4)
print(f"groups=4 supported by: {registry.supporting(spec_g)}")

# the paper's "wisdom": when does fusion win? (S5 analytical model)
for c in (64, 128, 256, 512):
    choice = registry.plan_conv(
        ConvSpec(h=56, w=56, c_in=c, c_out=c, k=3, pad=1), an.SKYLAKE_X
    ).algo
    print(f"{c:4d} channels on SkylakeX -> {choice}")
print("TPU v5e CMR(HBM) =", round(an.TPU_V5E.cmr_dram), "(7x SkylakeX DRAM ->"
      " fusion matters more on TPU; see DESIGN.md S2)")

# whole nets go through the Engine: compile once (plan -> staged
# ExecProgram with cross-layer fusion groups), then serve.  Adjacent
# small-channel convs collapse into one resident stage -- the paper's
# L3-residency argument lifted to the net level.
from repro.configs.convnets import vgg_mixed_channel
from repro.convserve import Engine, init_weights

spec = vgg_mixed_channel(c_in=3)
net = Engine(hw=an.SKYLAKE_X).compile(
    spec, init_weights(spec, seed=0), input_hw=(64, 64)
)
print(f"\n{spec.name} staged program ({net.program.n_fused} fusion groups):")
print(net.describe())
y = net(jnp.zeros((1, 64, 64, 3), jnp.float32))
print(f"net out={tuple(y.shape)}  stats={net.stats()}")
