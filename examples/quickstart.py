"""Quickstart: the paper's L3-fused convolution through the public API.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import analysis as an
from repro.core import conv2d, conv2d_direct

# a ResNet conv layer (64 channels, 56x56) -- the paper's sweet spot
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((2, 56, 56, 64)) * 0.1, jnp.float32)
w = jnp.asarray(rng.standard_normal((3, 3, 64, 64)) * 0.1, jnp.float32)

ref = conv2d_direct(x, w, pad=1)
for algo in ("three_stage", "l3_fused", "fft_fused", "l3_fused_pallas"):
    y = conv2d(x, w, pad=1, algo=algo)
    err = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
    print(f"{algo:16s} out={tuple(y.shape)} rel_err_vs_direct={err:.2e}")

# the paper's "wisdom": when does fusion win? (S5 analytical model)
for c in (64, 128, 256, 512):
    choice = an.choose_algo(an.SKYLAKE_X, c, c, t=7)
    print(f"{c:4d} channels on SkylakeX -> {choice}")
print("TPU v5e CMR(HBM) =", round(an.TPU_V5E.cmr_dram), "(7x SkylakeX DRAM ->"
      " fusion matters more on TPU; see DESIGN.md S2)")
