"""Adaptive replanning under a simulated clock: measured divergence on
a mispredicting fused plan triggers a background replan, shadow waves
are bit-exact and never count toward client latency SLOs, promotion
hot-swaps with zero dropped/inexact responses, rollback restores the
old program, and a well-calibrated store never replans.  Plus the
satellite surfaces: the measured-cost store's EWMA/staleness
discipline, wisdom generation/timestamp stamps, the temporal conv1d
registry path, and the telemetry snapshot schema."""

import json

import numpy as np
import pytest

import jax

from repro.configs.convnets import tiny_testnet
from repro.convserve import (
    AdaptConfig,
    AdaptController,
    Engine,
    MeasuredCostStore,
    ShadowVerifier,
    hot_swap,
    init_weights,
    run_direct,
)
from repro.convserve import planner
from repro.convserve.runtime import (
    ReplicaPool,
    RuntimeConfig,
    ServeRuntime,
    SimClock,
    Telemetry,
)
from repro.convserve.runtime.telemetry import stage_rollup
from repro.core import analysis, registry, transforms, tune

BIG_HW = analysis.HardwareModel(
    name="big", peak_flops=1e12, dram_bw=1e11, fast_shared_bw=5e11,
    fast_shared_bytes=1 << 30, private_bytes=1 << 24,
)

SPEC = tiny_testnet(4)


def _image(rng, side: int) -> np.ndarray:
    return (rng.standard_normal((side, side, 4)) * 0.1).astype(np.float32)


def _runtime(cfg=None, *, clock=None, n=1):
    """Deterministic adapt testbed: inline replicas + SimClock.  Returns
    (runtime, engine, weights) -- the controller needs all three."""
    ws = init_weights(SPEC, seed=5)
    engine = Engine(hw=BIG_HW)
    pool = ReplicaPool.build(
        engine, SPEC, ws, n=n, workers=0, input_hw=(16, 16)
    )
    cfg = cfg or RuntimeConfig(
        max_batch=2, buckets=(16,), slo_s=1.0, service_est_s=1e-4
    )
    return ServeRuntime(pool, cfg, clock=clock or SimClock()), engine, ws


def _probe(engine, fused_factor=10.0, single_factor=1.0,
           direct_factor=1000.0):
    """Fake stage-timing probe: each stage 'measures' at its roofline
    prediction scaled by a per-kind factor -- a fused_factor of 10 seeds
    the store with a grossly mispredicting fused plan without depending
    on host timer behaviour.  Direct stages default expensive (their
    util=1.0 prediction is the most optimistic in the model, and these
    tests want the unfused transformed plan, not direct, to be the
    measured winner)."""

    def factor(stage):
        if stage.fused:
            return fused_factor
        if stage.units[0].plan.algo == "direct":
            return direct_factor
        return single_factor

    def probe(net, bucket, batch):
        preds = planner.predict_stage_times(net.program, engine.hw)
        return [
            (label, pred * factor(stage))
            for stage, (label, pred) in zip(net.program.stages, preds)
        ]

    return probe


def _controller(rt, engine, ws, probe, shadow_timer=None, **cfg_kw):
    kw = dict(
        divergence_ratio=2.0, shadow_fraction=1.0, shadow_min_waves=2,
        cooldown_s=0.5,
    )
    kw.update(cfg_kw)
    return AdaptController(
        rt, engine, SPEC, ws, AdaptConfig(**kw),
        probe=probe, shadow_timer=shadow_timer,
    )


# ------------------------------------------- (a) divergence -> replan


def test_divergence_triggers_replan_and_opens_shadow():
    """A fused stage measuring 10x its prediction (singles on-model)
    must trigger a replan whose measured-cost candidate drops the fusion
    groups but keeps the per-layer algorithms -- a bitwise-comparable
    candidate."""
    rt, engine, ws = _runtime()
    ac = _controller(rt, engine, ws, _probe(engine, fused_factor=10.0))
    live_plan = rt.pool.executors[0].plan
    assert live_plan.groups, "seed plan must be fused for this test"

    ac.measure()
    ac.probe_alternatives()
    reason = ac.check()
    assert reason is not None
    assert ac.replans_triggered == 1
    assert ac.state == "shadow"
    assert ac.candidate_plan.groups == ()
    assert ac.candidate_plan.algos() == live_plan.algos()
    assert ac.verifier.mode == "bitwise"
    assert rt.telemetry.counter("adapt.replans_triggered") == 1
    # the trigger and the shadow opening are both audited
    assert [a["event"] for a in ac.audit] == ["replan", "shadow_open"]


def test_matched_measurements_never_replan():
    """(d) measurements at the roofline's own predictions (uniform
    ratio, no cheaper measured alternative): check() stays quiet."""
    rt, engine, ws = _runtime()
    ac = _controller(
        rt, engine, ws, _probe(engine, fused_factor=1.0, single_factor=1.0)
    )
    ac.measure()
    ac.probe_alternatives()
    assert ac.check() is None
    assert ac.replans_triggered == 0
    assert ac.state == "idle"
    assert ac.audit == []


# ------------------------- (b)+(c) shadow exactness, promote, rollback


def _serve(rt, n_requests, side=16, seed=0):
    rng = np.random.default_rng(seed)
    imgs = {i: _image(rng, side) for i in range(n_requests)}
    for i in range(n_requests):
        assert rt.submit(imgs[i], rid=i) is None
        rt.poll()
    rt.drain()
    return imgs


def _assert_all_exact(rt, ws, imgs):
    missing = [i for i in imgs if i not in rt.results]
    assert not missing, f"dropped requests: {missing}"
    for i, im in imgs.items():
        ref = np.asarray(run_direct(SPEC, ws, im[None]))[0]
        scale = max(float(np.abs(ref).max()), 1e-30)
        rel = float(np.abs(rt.results[i] - ref).max()) / scale
        assert rel < 1e-3, f"request {i} inexact: rel {rel}"


def test_shadow_promotion_hot_swaps_with_zero_downtime():
    """The acceptance gate: shadows run bit-exact beside live traffic,
    the injected timer says the candidate is faster, and promotion
    swaps the pool's program mid-traffic -- every request served, every
    response exact, no shadow wave in the client e2e histogram."""
    rt, engine, ws = _runtime()
    ac = _controller(
        rt, engine, ws, _probe(engine, fused_factor=10.0),
        shadow_timer=lambda res, cand_s: (0.010, 0.004),
    )
    seed_plan = rt.pool.executors[0].plan
    ac.measure()
    ac.probe_alternatives()
    assert ac.check() is not None

    n = 8  # max_batch=2 -> 4 waves: 1 cold + 2 warm pairs + 1 post-swap
    imgs = _serve(rt, n)
    _assert_all_exact(rt, ws, imgs)

    assert ac.promotions == 1
    assert ac.rollbacks == 0
    assert ac.state == "idle"
    assert rt.pool.executors[0].plan.groups == ()
    assert rt.pool.executors[0].plan != seed_plan
    assert ac.last_verifier.mismatches == 0
    assert ac.last_verifier.mode == "bitwise"
    assert rt.telemetry.counter("adapt.promotions") == 1
    assert ac.audit[-1]["event"] == "promote"

    snap = rt.stats()
    # SLO exclusion: shadow waves ran (their own histogram proves it)
    # yet the client e2e histogram counts exactly the client requests
    assert snap["latency"]["e2e"]["count"] == n
    assert snap["latency"]["adapt.shadow_compute"]["count"] >= 2


def test_shadow_rollback_restores_live_program():
    """A candidate the injected timer calls slower is rolled back: the
    seed program keeps serving, the audit says why, and the cooldown
    holds off an immediate re-trigger."""
    rt, engine, ws = _runtime()
    ac = _controller(
        rt, engine, ws, _probe(engine, fused_factor=10.0),
        shadow_timer=lambda res, cand_s: (0.004, 0.010),  # candidate slower
    )
    seed_plan = rt.pool.executors[0].plan
    ac.measure()
    ac.probe_alternatives()
    assert ac.check() is not None

    imgs = _serve(rt, 8)
    _assert_all_exact(rt, ws, imgs)

    assert ac.rollbacks == 1
    assert ac.promotions == 0
    assert ac.state == "idle"
    assert rt.pool.executors[0].plan == seed_plan
    roll = [a for a in ac.audit if a["event"] == "rollback"]
    assert roll and roll[0]["reason"] == "shadow_slower"
    assert rt.telemetry.counter("adapt.rollbacks") == 1
    # cooldown: the store still says "diverged" but check() waits
    assert ac.check() is None
    assert ac.replans_triggered == 1


def test_hot_swap_invalidates_stale_cache_keys():
    """Swapping to a program that consumes no pre-transformed kernels
    must drop the outgoing program's cache entries (and only then)."""
    ws = init_weights(SPEC, seed=5)
    engine = Engine(hw=BIG_HW)
    pool = ReplicaPool.build(
        engine, SPEC, ws, n=1, workers=0, input_hw=(16, 16)
    )
    live = pool.executors[0]
    x = np.zeros((1, 16, 16, 4), np.float32)
    jax.block_until_ready(live(x))  # populate the shared cache
    assert live.cache_keys()

    cand = engine.compile(
        SPEC, ws, input_hw=(16, 16), allowed=("direct",), fuse=False
    )
    old = hot_swap(pool, [cand], timeout_s=1.0)
    assert pool.executors[0] is cand
    assert old[0] is live
    assert pool.cache.stats()["invalidations"] >= 1


# ------------------------------------------- measured-cost store unit


def test_cost_store_ewma_cold_exclusion_and_staleness():
    store = MeasuredCostStore(ewma=0.5)
    store.observe("k", 1.0, predicted_s=0.5, now=0.0)
    store.observe("k", 2.0, now=10.0)
    e = store.entry("k")
    assert e.measured_s == pytest.approx(1.5)  # EWMA fold, not overwrite
    assert e.n == 2
    assert e.predicted_s == 0.5  # prediction survives a bare observe
    assert e.ratio == pytest.approx(3.0)
    assert e.gen == 2 and e.ts == 10.0

    # cold samples never touch the EWMA, but are counted
    store.observe("k", 100.0, cold=True)
    assert store.entry("k").measured_s == pytest.approx(1.5)
    assert store.cold_skipped == 1

    # staleness: age and generation gates read as absent
    assert store.lookup("k", max_age_s=5.0, now=20.0) is None
    assert store.lookup("k", max_age_s=15.0, now=20.0) == pytest.approx(1.5)
    assert store.entry("k", min_gen=3) is None
    assert store.entry("k", min_gen=2) is not None


def test_cost_store_ratio_scale_is_median():
    store = MeasuredCostStore()
    store.observe("a", 1.0, predicted_s=1.0, now=0.0)   # ratio 1
    store.observe("b", 2.0, predicted_s=2.0, now=0.0)   # ratio 1
    store.observe("c", 10.0, predicted_s=1.0, now=0.0)  # ratio 10
    assert store.ratio_scale() == pytest.approx(1.0)
    assert len(store) == 3


def test_cost_store_roundtrips_through_json(tmp_path):
    store = MeasuredCostStore()
    store.observe("x", 3.0, predicted_s=1.5, now=7.0)
    path = tmp_path / "costs.json"
    store.save(path)
    back = MeasuredCostStore.load(path)
    e = back.entry("x")
    assert e.measured_s == 3.0 and e.predicted_s == 1.5 and e.ts == 7.0
    assert back.generation == store.generation


# -------------------------------------------------- shadow verifier unit


def test_shadow_verifier_mismatch_is_immediately_disqualifying():
    v = ShadowVerifier(mode="bitwise", min_waves=3)
    a = np.ones((2, 2), np.float32)
    assert v.record({0: a}, {0: a}, live_compute_s=1.0, cand_compute_s=1.0)
    # one bit of drift: rollback regardless of how few waves have run
    assert not v.record(
        {1: a}, {1: a + 1e-7}, live_compute_s=1.0, cand_compute_s=1.0
    )
    assert v.verdict() == "rollback"
    assert v.mismatches == 1


def test_shadow_verifier_needs_min_waves_and_skips_cold_pairs():
    v = ShadowVerifier(mode="rtol", rtol=1e-3, min_waves=2)
    a = np.ones((2, 2), np.float32)
    b = a * (1 + 1e-5)  # within tolerance
    v.record({0: a}, {0: b}, live_compute_s=0.010, cand_compute_s=0.004)
    assert v.verdict() is None  # one pair < min_waves
    v.record({1: a}, {1: b}, live_compute_s=0.010, cand_compute_s=0.004,
             cold=True)
    assert v.cold_skipped == 1
    assert v.verdict() is None  # cold pair did not count
    v.record({2: a}, {2: b}, live_compute_s=0.010, cand_compute_s=0.004)
    assert v.verdict() == "promote"
    assert v.cand_mean_s == pytest.approx(0.004)


# --------------------------------------- wisdom stamps (tune satellite)


def test_wisdom_entries_stamped_and_staleness_aware(tmp_path):
    """Entries carry generation + timestamp; `lookup_r` treats too-old
    or out-generationed entries as absent, and legacy bare-int entries
    (gen 0 / ts 0.0) always expire under an age bound."""
    path = tmp_path / "wisdom.json"
    wino = transforms.WinogradTransform(m=5, k=3)
    legacy = tune._key(wino, 8, 8, 4, 4)
    stamped = tune._key(wino, 16, 16, 4, 4)
    path.write_text(json.dumps({
        legacy: 7,
        stamped: {"r": 9, "gen": 3, "ts": 100.0},
    }))

    assert tune.wisdom_generation(path) == 3
    assert tune.entry_info(8, 8, 4, 4, transform=wino, wisdom_path=path) == {
        "r": 7, "gen": 0, "ts": 0.0
    }
    assert tune.entry_info(
        16, 16, 4, 4, transform=wino, wisdom_path=path
    ) == {"r": 9, "gen": 3, "ts": 100.0}
    assert tune.entry_info(32, 32, 4, 4, transform=wino,
                           wisdom_path=path) is None

    # plain reads see both entries
    assert tune.lookup_r(8, 8, 4, 4, transform=wino, wisdom_path=path) == 7
    assert tune.lookup_r(16, 16, 4, 4, transform=wino, wisdom_path=path) == 9
    # age gate: stamped entry inside / outside the window; legacy always out
    kw = dict(transform=wino, wisdom_path=path, now=200.0)
    assert tune.lookup_r(16, 16, 4, 4, max_age_s=150.0, **kw) == 9
    assert tune.lookup_r(16, 16, 4, 4, max_age_s=50.0, **kw) is None
    assert tune.lookup_r(8, 8, 4, 4, max_age_s=1e9, **kw) is None
    # generation gate
    assert tune.lookup_r(16, 16, 4, 4, transform=wino, wisdom_path=path,
                         min_gen=3) == 9
    assert tune.lookup_r(16, 16, 4, 4, transform=wino, wisdom_path=path,
                         min_gen=4) is None


# ------------------------------- temporal conv1d via the registry


def test_temporal_spec_plans_conv1d_fused_and_matches_lax():
    """A depthwise-causal temporal spec auto-plans onto the registered
    conv1d_fused algorithm, every 2-D algorithm declines it, and the
    result matches lax's grouped causal convolution."""
    b, length, d, k = 2, 64, 8, 4
    spec = registry.ConvSpec(
        h=1, w=length, c_in=d, c_out=d, k=k, pad=k - 1, stride=1, groups=d
    )
    assert spec.temporal
    assert spec.out_hw == (1, length)
    for name in ("direct", "l3_fused", "three_stage", "fft_fused"):
        assert not registry.get(name).supports(spec)

    ap = registry.plan_conv(spec, analysis.SKYLAKE_X)
    assert ap.algo == "conv1d_fused"

    rng = np.random.default_rng(0)
    x = (rng.standard_normal((b, 1, length, d)) * 0.1).astype(np.float32)
    w = (rng.standard_normal((1, k, 1, d)) * 0.1).astype(np.float32)
    y = np.asarray(registry.get(ap.algo).execute(x, w, None, ap))
    ref = np.asarray(jax.lax.conv_general_dilated(
        x, w,
        window_strides=(1, 1),
        padding=((0, 0), (k - 1, 0)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=d,
    ))
    assert y.shape == ref.shape == (b, 1, length, d)
    np.testing.assert_allclose(y, ref, atol=1e-5)


# ----------------------------------------- telemetry snapshot schema


def test_telemetry_snapshot_schema_is_stable():
    """The snapshot document's key sets are a wire format (dashboards
    scrape them): top level, per-histogram keys, and percentile
    ordering must not drift."""
    t = Telemetry()
    t.inc("waves")
    t.set_gauge("queue_depth", 3.0)
    for v in [1e-4, 5e-4, 2e-3, 8e-3, 3e-2, 1e-1, 1e-1, 4e-1]:
        t.observe("e2e", v)
    snap = t.snapshot(scheduler={"depth": 0}, stages=None)
    # a None section is omitted, a real one merges in by name
    assert set(snap) == {"meta", "counters", "gauges", "latency", "scheduler"}
    assert set(snap["meta"]) == {"seq", "t"}
    # seq advances on every mutation: 1 inc + 1 gauge + 8 observes
    assert snap["meta"]["seq"] == 10
    assert snap["counters"]["waves"] == 1
    lat = snap["latency"]["e2e"]
    assert set(lat) == {"count", "mean_s", "p50_s", "p95_s", "p99_s", "max_s"}
    assert lat["count"] == 8
    assert lat["p50_s"] <= lat["p95_s"] <= lat["p99_s"] <= lat["max_s"]
    json.dumps(snap)  # the whole document stays plain JSON


def test_stage_rollup_schema_is_stable():
    rows = stage_rollup([("conv0", 1e-3), ("fuse[1+2]", 2e-3)])
    assert [set(r) for r in rows] == [{"label", "us"}] * 2
    assert rows[0] == {"label": "conv0", "us": pytest.approx(1000.0)}


def test_runtime_stats_document_includes_adapt_counters():
    """End to end: after a promotion the runtime's single JSON document
    carries the adapt counters next to the serving counters."""
    rt, engine, ws = _runtime()
    ac = _controller(
        rt, engine, ws, _probe(engine, fused_factor=10.0),
        shadow_timer=lambda res, cand_s: (0.010, 0.004),
    )
    ac.measure()
    ac.probe_alternatives()
    assert ac.check() is not None
    _serve(rt, 8)
    snap = rt.stats()
    c = snap["counters"]
    assert c["adapt.replans_triggered"] == 1
    assert c["adapt.shadows_run"] >= 2
    assert c["adapt.promotions"] == 1
    assert "wave_observer_errors" not in c  # the observer never threw
    json.dumps(ac.stats(), default=str)  # stats() is a report, not a crash
