"""Winograd transform construction: exactness + algebraic properties."""

import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import transforms


def _brute_corr(d, k):
    m = len(d) - len(k) + 1
    return np.array([np.dot(d[i : i + len(k)], k) for i in range(m)])


@pytest.mark.parametrize("m,r", [(2, 3), (3, 3), (4, 3), (5, 3), (6, 3),
                                 (2, 5), (4, 5), (8, 3), (1, 3), (5, 4)])
def test_winograd_identity_float64(m, r):
    at, g, bt = transforms.winograd_matrices(m, r, np.float64)
    n = m + r - 1
    rng = np.random.default_rng(m * 100 + r)
    d = rng.standard_normal(n)
    k = rng.standard_normal(r)
    y = at @ ((g @ k) * (bt @ d))
    np.testing.assert_allclose(y, _brute_corr(d, k), rtol=1e-10, atol=1e-10)


@given(m=st.integers(1, 7), r=st.integers(2, 5), seed=st.integers(0, 2**31))
@settings(max_examples=30, deadline=None)
def test_winograd_identity_property(m, r, seed):
    at, g, bt = transforms.winograd_matrices(m, r, np.float64)
    n = m + r - 1
    rng = np.random.default_rng(seed)
    d = rng.standard_normal(n)
    k = rng.standard_normal(r)
    y = at @ ((g @ k) * (bt @ d))
    np.testing.assert_allclose(y, _brute_corr(d, k), rtol=1e-8, atol=1e-8)


def test_matrices_exact_rational():
    """The exact construction must reproduce the float matrices."""
    at_e, g_e, bt_e = transforms.winograd_matrices_exact(4, 3)
    at, g, bt = transforms.winograd_matrices(4, 3, np.float64)
    for exact, f in ((at_e, at), (g_e, g), (bt_e, bt)):
        np.testing.assert_allclose(
            np.array([[float(v) for v in row] for row in exact]), f
        )


def test_bt_is_inverse_transpose():
    """B^T = E^{-T}: check E^T B^T = I exactly-ish."""
    m, r = 5, 3
    n = m + r - 1
    _, _, bt = transforms.winograd_matrices(m, r, np.float64)
    pts = transforms.interpolation_points(n - 1)
    ev = np.array(
        [[float(p) ** j for j in range(n)] for p in pts]
        + [[0.0] * (n - 1) + [1.0]]
    )
    np.testing.assert_allclose(ev.T @ bt, np.eye(n), atol=1e-9)


def test_tile_sizes():
    assert transforms.tile_size(5, 3) == 7
    assert transforms.output_tile(7, 3) == 5
    assert transforms.fft_num_freqs(16) == 9
