"""Flight recorder + live roofline attribution: span nesting and
cross-thread end invariants, deterministic root sampling, ring-capacity
accounting, SimClock golden traces (two identical seeded runs produce
identical span trees), roofline math checked against hand-computed
TileAlgebra terms, telemetry freshness stamps and the stale-snapshot
guards in the autoscaler and the adapt controller, Chrome-trace export
(flow pairing, validation) and the incident recorder's dump throttling.
Ends with the acceptance drill: ONE tracer across a faulted fleet run
and an adapt hot swap exports a valid trace whose roofline section
gives every profiled stage an achieved-GFLOP/s and a binding verdict.
"""

import json
import threading

import numpy as np
import pytest

from repro.configs.convnets import tiny_testnet
from repro.convserve import (
    AdaptConfig,
    AdaptController,
    Engine,
    init_weights,
)
from repro.convserve import planner
from repro.convserve.check.diagnostics import (
    CheckReport,
    Diagnostic,
    VerificationError,
)
from repro.convserve.fleet import (
    Autoscaler,
    AutoscalerConfig,
    ElasticPool,
    FixedServiceModel,
    FleetRuntime,
)
from repro.convserve.obs import (
    CAT_PROFILE,
    CAT_REQUEST,
    CAT_WAVE,
    FlightRecorder,
    Tracer,
    chrome_trace_events,
    prometheus_text,
    span_index,
    span_tree_signature,
    validate_chrome_trace,
    write_trace,
)
from repro.convserve.obs import roofline as rf
from repro.convserve.runtime import (
    ReplicaPool,
    RuntimeConfig,
    ServeRuntime,
    SimClock,
    Telemetry,
    make_images,
    poisson_trace,
)
from repro.core import analysis, registry
from repro.runtime.fault import FAULT_CRASH, FaultPlan, ReplicaFault

BIG_HW = analysis.HardwareModel(
    name="big", peak_flops=1e12, dram_bw=1e11, fast_shared_bw=5e11,
    fast_shared_bytes=1 << 30, private_bytes=1 << 24,
)

SPEC = tiny_testnet(4)

SERVICE = FixedServiceModel(base_s=0.004, per_image_s=0.002)


# ------------------------------------------------------- span recorder


def test_span_nesting_parent_and_open_count():
    clock = SimClock()
    t = Tracer(clock=clock)
    with t.span("outer", CAT_REQUEST):
        clock.advance(0.001)
        with t.span("inner", CAT_WAVE):
            clock.advance(0.002)
            assert t.open_count() == 2
        assert t.open_count() == 1
    assert t.open_count() == 0
    idx = span_index(t.events())
    spans = {s.name: s for s in idx.values()}
    outer, inner = spans["outer"], spans["inner"]
    assert inner.parent == outer.sid and not outer.parent
    # the child closed before (and inside) its parent
    assert outer.t0 <= inner.t0 <= inner.t1 <= outer.t1
    assert inner.dur == pytest.approx(0.002)
    assert outer.dur == pytest.approx(0.003)


def test_explicit_begin_end_across_threads_late_binds_args():
    """The runtime begins a wave span on the dispatch thread and ends it
    from the worker callback -- end() must attach pid/args then."""
    clock = SimClock()
    t = Tracer(clock=clock)
    sid = t.begin("wave:b16", CAT_WAVE, batch=4)
    clock.advance(0.004)
    done = threading.Event()

    def finish():
        t.end(sid, pid=3, flow_out=("w1",), compute_s=0.004)
        done.set()

    threading.Thread(target=finish).start()
    assert done.wait(5.0)
    (s,) = [e for e in t.events() if getattr(e, "sid", None) == sid]
    assert s.pid == 3 and s.flow_out == ("w1",)
    assert s.args == {"batch": 4, "compute_s": 0.004}
    assert t.open_count() == 0
    # ending twice (or ending the sid<=0 sentinel) is a silent no-op
    t.end(sid)
    t.end(0)
    assert len(t.events()) == 1


def test_deterministic_sampling_drops_whole_subtrees():
    def run():
        t = Tracer(clock=SimClock(), sample_rate=0.5)
        for i in range(10):
            with t.span(f"root:{i}", CAT_REQUEST):
                with t.span(f"child:{i}", CAT_WAVE):
                    t.instant(f"tick:{i}", CAT_WAVE)
        return t

    t = run()
    spans = [e for e in t.events() if hasattr(e, "sid")]
    roots = [s for s in spans if not s.parent]
    kids = [s for s in spans if s.parent]
    # int(n*rate) staircase: exactly half the roots survive, and a
    # sampled-out root drops its children AND its instants with it
    assert len(roots) == 5 and len(kids) == 5
    assert len(t.events()) - len(spans) == 5  # surviving instants
    assert t.stats()["sampled_out"] == 5
    assert {k.parent for k in kids} == {r.sid for r in roots}
    # deterministic: a second identical run keeps the SAME roots
    assert span_tree_signature(t.events()) == span_tree_signature(
        run().events()
    )


def test_ring_capacity_bounds_memory_and_counts_drops():
    t = Tracer(clock=SimClock(), capacity=16)
    for i in range(50):
        with t.span(f"s:{i}", CAT_REQUEST):
            pass
    st = t.stats()
    assert len(t.events()) == 16 and st["buffered"] == 16
    assert st["recorded"] == 50 and st["dropped"] == 34
    assert st["capacity"] == 16 and t.open_count() == 0


def test_disabled_tracer_records_nothing():
    t = Tracer(clock=SimClock(), enabled=False)
    with t.span("x", CAT_REQUEST):
        t.instant("y", CAT_WAVE)
    assert t.events() == [] and t.open_count() == 0


# ---------------------------------------------- SimClock golden trace


def _traced_serve_run():
    clock = SimClock()
    tracer = Tracer(clock=clock)
    ws = init_weights(SPEC, seed=5)
    engine = Engine(hw=BIG_HW)
    pool = ReplicaPool.build(
        engine, SPEC, ws, n=1, workers=0, input_hw=(16, 16)
    )
    cfg = RuntimeConfig(
        max_batch=2, buckets=(16,), slo_s=1.0, service_est_s=1e-4
    )
    rt = ServeRuntime(pool, cfg, clock=clock, tracer=tracer)
    rt.warmup()
    rng = np.random.default_rng(11)
    for i in range(6):
        img = (rng.standard_normal((16, 16, 4)) * 0.1).astype(np.float32)
        rt.submit(img, rid=i)
        rt.poll()
    rt.drain()
    rt.pool.shutdown()
    return tracer


def test_simclock_golden_trace_is_reproducible():
    """Two identical seeded SimClock serving runs must produce the same
    span tree (names, categories, parent paths, timestamps) -- the
    determinism that makes traces diffable across commits."""
    a, b = _traced_serve_run(), _traced_serve_run()
    sig_a, sig_b = span_tree_signature(a.events()), span_tree_signature(
        b.events()
    )
    assert sig_a == sig_b and len(sig_a) > 0
    names = {s.name for s in a.events() if hasattr(s, "sid")}
    assert any(n.startswith("request:") for n in names)
    assert any(n.startswith("wave:") for n in names)
    assert a.open_count() == 0


# ------------------------------------------------------ roofline math


def _compiled():
    ws = init_weights(SPEC, seed=5)
    engine = Engine(hw=BIG_HW)
    net = engine.compile(SPEC, ws, input_hw=(16, 16))
    return net, engine


def test_attribute_stage_matches_hand_computed_tile_algebra():
    net, engine = _compiled()
    stage = net.program.stages[0]
    measured_s = 1e-4
    row = rf.attribute_stage(
        stage, measured_s, engine.hw, batch=1, backend="test"
    )
    # hand-join the TileAlgebra terms exactly as the planner charges them
    flops = dram = 0
    for u in stage.units:
        s = u.plan.spec
        ta = registry.get(u.plan.algo).tile_algebra(u.plan.algo_plan())
        assert ta is not None
        oh1 = s.h + 2 * s.pad - s.k + 1
        ow1 = s.w + 2 * s.pad - s.k + 1
        flops += ta.engine_flops(oh1, ow1, s.c_in, s.c_out, s.groups, 1)
        oh, ow = s.out_hw
        dram += 4 * (s.h * s.w * s.c_in + oh * ow * s.c_out)
        dram += ta.kernel_matrix_bytes(s.c_in, s.c_out, s.groups)
    assert row["flops"] == flops and row["dram_bytes"] == dram
    assert row["achieved_gflops"] == pytest.approx(
        flops / measured_s / 1e9
    )
    assert row["ai_dram"] == pytest.approx(flops / dram)
    # the binding level is the lowest ceiling at this stage's intensities
    roofs = {
        "fast_private": engine.hw.peak_flops,
        "dram": row["ai_dram"] * engine.hw.dram_bw,
    }
    if row["ai_fast"] is not None:
        roofs["shared_l3"] = row["ai_fast"] * engine.hw.fast_shared_bw
    level = min(roofs, key=roofs.get)
    assert row["binding_level"] == level
    assert row["roof_gflops"] == pytest.approx(roofs[level] / 1e9)
    assert row["key"].startswith("test:")
    # fused/transformed stages split measured time by per-phase MACs:
    # fractions sum to 1, attributed microseconds sum to the measurement
    assert row["phases"] is not None
    assert sum(p["macs_frac"] for p in row["phases"]) == pytest.approx(1.0)
    assert sum(p["attributed_us"] for p in row["phases"]) == pytest.approx(
        row["measured_us"]
    )


def test_verdict_bands_and_predicted_join():
    net, engine = _compiled()
    stage = net.program.stages[0]
    probe = rf.attribute_stage(stage, 1.0, engine.hw, backend="test")
    roof_flops = probe["roof_gflops"] * 1e9
    flops = probe["flops"]

    def at(frac):
        return rf.attribute_stage(
            stage, flops / (frac * roof_flops), engine.hw, backend="test"
        )

    assert at(2.0)["verdict"] == "above_model"
    assert at(0.8)["verdict"] == "at_roof"
    assert at(0.2)["verdict"] == "below_roof"
    assert at(0.03)["verdict"] == "far_below_roof"
    row = rf.attribute_stage(
        stage, 2e-4, engine.hw, predicted_s=1e-4, backend="test"
    )
    assert row["measured_over_predicted"] == pytest.approx(2.0)


def test_roofline_section_schema_and_trace_instants():
    net, engine = _compiled()
    profile = list(net.profile_stages(
        np.zeros((1, 16, 16, 4), np.float32)
    ))
    tracer = Tracer(clock=SimClock())
    sec = rf.roofline_section(
        net.program, profile, engine.hw, batch=1, tracer=tracer
    )
    assert sec["schema_version"] == rf.SCHEMA_VERSION
    assert set(sec) == {"schema_version", "hw", "batch", "stages"}
    assert set(sec["hw"]) == {
        "name", "peak_gflops", "dram_gbs", "fast_shared_gbs",
        "cmr_dram", "cmr_fast",
    }
    assert len(sec["stages"]) == len(profile) > 0
    for row in sec["stages"]:
        assert row["achieved_gflops"] > 0
        assert row["binding_level"] in (
            "dram", "shared_l3", "fast_private"
        )
        assert row["verdict"] in (
            "above_model", "at_roof", "below_roof", "far_below_roof"
        )
    instants = [
        e for e in tracer.events()
        if not hasattr(e, "sid") and e.name == "roofline.stage"
    ]
    assert len(instants) == len(sec["stages"])
    assert instants[0].args["stage"] == sec["stages"][0]["stage"]


# ------------------------------------------------- freshness + guards


def test_telemetry_stamp_advances_on_every_mutation():
    clock = SimClock()
    tel = Telemetry(clock=clock)
    assert tel.stamp() == {"seq": 0, "t": None}
    tel.inc("x")
    assert tel.stamp() == {"seq": 1, "t": 0.0}
    clock.advance(1.5)
    tel.set_gauge("g", 2.0)
    tel.observe("lat", 0.01)
    st = tel.stamp()
    assert st["seq"] == 3 and st["t"] == pytest.approx(1.5)
    assert tel.snapshot()["meta"] == st


class _PoolStub:
    """The minimal pool surface `Autoscaler.tick` touches."""

    startup_s = 0.0

    def __init__(self, clock, n=2):
        self.clock = clock
        self.n = n

    def ready_count(self):
        return self.n

    def live_count(self):
        return self.n

    def grow(self, k, now=None):
        self.n += k
        return list(range(k))

    def retire(self, k, now=None):
        self.n -= k
        return [0]

    def counts(self):
        return {}


def test_autoscaler_blocks_stale_snapshot_scale_up():
    clock = SimClock()
    tel = Telemetry(clock=clock)
    pool = _PoolStub(clock)
    a = Autoscaler(
        pool,
        AutoscalerConfig(
            max_replicas=8, tick_interval_s=1.0, cooldown_s=0.0,
            queue_high=2.0, queue_low=1.0,
            require_fresh_telemetry=True,
        ),
        clock=clock, queue_depth_fn=lambda: 100, telemetry=tel,
    )
    tel.inc("traffic")  # fresh stamp before the first decision
    clock.advance(1.1)
    assert a.tick(clock.now()) == "up"
    # no telemetry mutation since -> the next would-be scale-up is
    # stale: counted, audited, and (require_fresh_telemetry) vetoed
    clock.advance(1.1)
    assert a.tick(clock.now()) is None
    st = a.stats()
    assert st["scale_ups"] == 1 and st["stale_decisions"] == 1
    assert tel.snapshot()["counters"]["autoscaler.stale_snapshot"] == 1
    assert a.events[-1]["action"] == "stale:up"
    # the stale counter itself advanced the stamp, so the guard
    # self-clears on the following tick
    clock.advance(1.1)
    assert a.tick(clock.now()) == "up"
    assert a.stats()["scale_ups"] == 2


def test_autoscaler_replacement_is_exempt_from_stale_guard():
    clock = SimClock()
    tel = Telemetry(clock=clock)
    pool = _PoolStub(clock, n=0)  # total fleet loss
    a = Autoscaler(
        pool,
        AutoscalerConfig(
            min_replicas=1, tick_interval_s=1.0,
            require_fresh_telemetry=True,
        ),
        clock=clock, telemetry=tel,
    )
    clock.advance(1.1)
    # stamp seq 0 never advanced, but replacement must act anyway
    assert a.tick(clock.now()) == "replace"
    assert a.stats()["stale_decisions"] == 0


def test_adapt_stale_guard_counts_audits_and_suppresses():
    ws = init_weights(SPEC, seed=5)
    engine = Engine(hw=BIG_HW)
    pool = ReplicaPool.build(
        engine, SPEC, ws, n=1, workers=0, input_hw=(16, 16)
    )
    cfg = RuntimeConfig(
        max_batch=2, buckets=(16,), slo_s=1.0, service_est_s=1e-4
    )
    rt = ServeRuntime(pool, cfg, clock=SimClock())
    ac = AdaptController(
        rt, engine, SPEC, ws,
        AdaptConfig(require_fresh_telemetry=True),
    )
    rt.telemetry.inc("traffic")
    assert ac._stale_guard() is False  # fresh: records the seq
    assert ac._stale_guard() is True  # unchanged seq: suppressed
    assert ac.stale_checks == 1
    ev = ac.audit[-1]
    assert ev["event"] == "stale_telemetry" and ev["blocked"] is True
    c = rt.telemetry.snapshot()["counters"]
    assert c["adapt.stale_snapshot"] == 1
    # that counter inc bumped the stamp: the guard self-clears
    assert ac._stale_guard() is False
    rt.pool.shutdown()


# ------------------------------------------------------------- export


def test_chrome_export_pairs_flows_and_drops_dangling_halves():
    t = Tracer(clock=SimClock())
    r = t.begin("request:1", CAT_REQUEST, flow_out=("r1",))
    t.end(r)
    w = t.begin("wave:b16", CAT_WAVE, flow_in=("r1",))
    t.end(w, flow_out=("w1",))
    p = t.begin("profile", CAT_PROFILE, flow_in=("w1",))
    t.end(p)
    # a wave whose producer was sampled out, and a flow_out nobody
    # consumed: both halves must vanish from the export, not dangle
    o = t.begin("wave:b32", CAT_WAVE, flow_in=("r_missing",))
    t.end(o, flow_out=("w_unconsumed",))
    events = chrome_trace_events(t.events())
    assert validate_chrome_trace(events) == []
    flows = [e for e in events if e["ph"] in ("s", "f")]
    assert {e["name"] for e in flows} == {"r1", "w1"}
    assert len([e for e in flows if e["ph"] == "s"]) == 2
    assert len([e for e in flows if e["ph"] == "f"]) == 2
    finish = [e for e in flows if e["ph"] == "f"][0]
    assert finish["bp"] == "e"
    # the start and finish of one flow share an id
    by_name = {}
    for e in flows:
        by_name.setdefault(e["name"], set()).add(e["id"])
    assert all(len(ids) == 1 for ids in by_name.values())


def test_validate_chrome_trace_flags_malformed_documents():
    assert validate_chrome_trace({"no": "events"}) != []
    bad = [
        {"ph": "X", "name": "s", "pid": 0, "tid": 0, "ts": 0.0,
         "dur": -1.0, "cat": "x", "args": {}},
        {"ph": "s", "name": "lone", "pid": 0, "tid": 0, "ts": 0.0,
         "id": 9, "cat": "x"},
    ]
    problems = validate_chrome_trace(bad)
    assert any("dur" in p for p in problems)
    assert any("flow" in p for p in problems)


def test_prometheus_text_renders_snapshot():
    clock = SimClock()
    tel = Telemetry(clock=clock)
    tel.inc("waves", 3)
    tel.set_gauge("queue_depth", 7)
    tel.observe("e2e", 0.01)
    text = prometheus_text(tel.snapshot(), prefix="convserve")
    assert "convserve_waves_total 3" in text
    assert "convserve_queue_depth 7" in text
    assert "# TYPE" in text and "convserve_e2e" in text


def test_flight_recorder_throttles_dumps_and_guards(tmp_path):
    t = Tracer(clock=SimClock())
    with t.span("work", CAT_REQUEST):
        pass
    tel = Telemetry(clock=SimClock())
    rec = FlightRecorder(
        t, telemetry=tel, path_prefix=str(tmp_path / "ring"), max_dumps=2
    )
    paths = [rec.trip("slo_breach") for _ in range(5)]
    assert sum(p is not None for p in paths) == 2  # budget per reason
    assert rec.trip("wave_loss") is not None  # separate budget
    st = rec.stats()
    assert st["trips"] == {"slo_breach": 5, "wave_loss": 1}
    assert len(st["dumps"]) == 3
    for p in st["dumps"]:
        doc = json.loads(open(p).read())
        assert validate_chrome_trace(doc) == []
        # the telemetry snapshot rides along as a metadata event
        assert any(
            e.get("ph") == "M" and e.get("name") == "telemetry"
            for e in doc
        )
    assert tel.snapshot()["counters"]["flight.trip.slo_breach"] == 5
    # guard(): a VerificationError trips (and re-raises)
    report = CheckReport(analyzer="test")
    report.add(Diagnostic(code="CVK101", message="boom"))
    with pytest.raises(VerificationError):
        with rec.guard():
            raise VerificationError(report)
    assert rec.stats()["trips"]["verification_error"] == 1


# --------------------------------------------------------- acceptance


def _probe(engine, fused_factor=10.0, single_factor=1.0,
           direct_factor=1000.0):
    """Fake stage-timing probe (test_adapt idiom): stages 'measure' at
    prediction x a per-kind factor, so the fused plan mispredicts."""

    def factor(stage):
        if stage.fused:
            return fused_factor
        if stage.units[0].plan.algo == "direct":
            return direct_factor
        return single_factor

    def probe(net, bucket, batch):
        preds = planner.predict_stage_times(net.program, engine.hw)
        return [
            (label, pred * factor(stage))
            for stage, (label, pred) in zip(net.program.stages, preds)
        ]

    return probe


def test_acceptance_faulted_fleet_plus_hot_swap_trace(tmp_path):
    """The ISSUE's acceptance drill: one tracer follows (A) a SimClock
    fleet run through a replica crash with retries exhausted (recorder
    dumps on the WaveLoss) and (B) an adapt-controller hot swap plus a
    stage profile, then exports ONE valid Chrome trace with
    request->wave flow links and roofline verdicts for every stage."""
    clock = SimClock()
    tracer = Tracer(clock=clock)
    recorder = FlightRecorder(
        tracer, path_prefix=str(tmp_path / "drill"), max_dumps=1
    )
    ws = init_weights(SPEC, seed=5)
    engine = Engine(hw=BIG_HW)

    # (A) fleet drill: both replicas crash, retries exhausted -> losses
    fp = FaultPlan([
        ReplicaFault(t=0.010, kind=FAULT_CRASH, replica=0),
        ReplicaFault(t=0.012, kind=FAULT_CRASH, replica=1),
    ], clock=clock)
    pool = ElasticPool.build(
        engine, SPEC, ws, n=2, clock=clock, input_hw=(16, 16),
        shards=1, service_model=SERVICE, fault_plan=fp, max_retries=0,
    )
    cfg = RuntimeConfig(
        buckets=(16,), max_batch=4, queue_depth=256,
        slo_s=0.25, service_est_s=0.012,
    )
    frt = FleetRuntime(pool, cfg, clock=clock, tracer=tracer,
                       recorder=recorder)
    frt.warmup()
    trace = poisson_trace(400.0, 24, seed=3, sizes=(16,), deadline_s=1.0)
    frt.play(trace, make_images(trace, 4, seed=1))
    assert recorder.stats()["trips"].get("wave_loss", 0) >= 1
    assert len(recorder.stats()["dumps"]) == 1  # throttled to max_dumps

    # (B) adapt hot swap + stage profile on the SAME tracer
    pool2 = ReplicaPool.build(
        engine, SPEC, ws, n=1, workers=0, input_hw=(16, 16)
    )
    cfg2 = RuntimeConfig(
        max_batch=2, buckets=(16,), slo_s=1.0, service_est_s=1e-4
    )
    srt = ServeRuntime(pool2, cfg2, clock=clock, tracer=tracer)
    ac = AdaptController(
        srt, engine, SPEC, ws,
        AdaptConfig(divergence_ratio=2.0, shadow_fraction=1.0,
                    shadow_min_waves=2, cooldown_s=0.5),
        probe=_probe(engine, fused_factor=10.0),
        shadow_timer=lambda res, cand_s: (0.010, 0.004),
    )
    ac.measure()
    ac.probe_alternatives()
    assert ac.check() is not None
    rng = np.random.default_rng(3)
    for i in range(1000, 1008):
        img = (rng.standard_normal((16, 16, 4)) * 0.1).astype(np.float32)
        srt.submit(img, rid=i)
        srt.poll()
    srt.drain()
    assert ac.promotions == 1

    doc = srt.stats(profile_bucket=16)
    roof = doc["roofline"]
    assert roof is not None and roof["schema_version"] == rf.SCHEMA_VERSION
    assert len(roof["stages"]) > 0
    for row in roof["stages"]:
        assert row["achieved_gflops"] > 0
        assert row["binding_level"] in (
            "dram", "shared_l3", "fast_private"
        )
        assert row["verdict"] in (
            "above_model", "at_roof", "below_roof", "far_below_roof"
        )
    srt.pool.shutdown()

    # export: every span closed, flows paired, the whole story in one file
    assert tracer.open_count() == 0
    out = tmp_path / "acceptance.trace.json"
    n = write_trace(tracer, str(out))
    events = json.loads(out.read_text())
    assert validate_chrome_trace(events) == []
    assert len(events) == n > 0
    phs = {e["ph"] for e in events}
    assert {"X", "s", "f", "i"} <= phs  # spans, flow links, instants
    names = {e["name"] for e in events}
    assert any(nm.startswith("request:") for nm in names)
    assert any(nm.startswith("wave:") for nm in names)
    assert "fleet.fault" in names and "flight.trip" in names
    assert "adapt.promote" in names  # the hot swap on the same timeline
    assert "roofline.stage" in names  # attribution rides in the trace
    assert "profile_stages" in names or any(
        nm.startswith("stage:") for nm in names
    )
