"""ExecProgram IR: lowering, cross-layer fusion groups, the Engine
front-end, plan JSON v3 round-trip + v2 migration, and ragged
extent-masking on stride-2 / grouped / bias nets."""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.convnets import (
    resnext_grouped,
    tiny_testnet,
    vgg_mixed_channel,
    vgg_style,
)
from repro.convserve import (
    ConvServeConfig,
    ConvServer,
    Engine,
    ImageRequest,
    NetPlan,
    NetSpec,
    conv,
    init_weights,
    lower,
    maxpool,
    plan_net,
    relu,
    run_direct,
    upgrade_plan,
)
from repro.convserve.plan import FusionGroup
from repro.convserve.program import Stage, StageUnit, split_units
from repro.core import analysis, registry

BIG_HW = analysis.HardwareModel(
    name="big", peak_flops=1e12, dram_bw=1e11, fast_shared_bw=5e11,
    fast_shared_bytes=1 << 30, private_bytes=1 << 24,
)


def _rel(y, ref):
    return float(jnp.abs(y - ref).max() / jnp.abs(ref).max())


# ---------------------------------------------------------------- lowering


def test_lowering_fuses_small_channel_vgg_on_paper_machine():
    """Acceptance: the mixed-channel VGG config lowers with >= 1
    multi-conv fusion group exactly where channels are small (fused
    Winograd layers), while the 256-wide three_stage tail stays
    unfused."""
    spec = vgg_mixed_channel(3)
    plan = plan_net(spec, 32, 32, hw=analysis.SKYLAKE_X)
    prog = lower(spec, plan)
    fused = [s for s in prog.stages if s.fused]
    assert len(fused) >= 1
    for s in fused:
        for u in s.units:
            assert registry.get(u.plan.algo).chain_family is not None
    # the materializing 3-stage tail must not be inside any group
    for s in prog.stages:
        if any(u.plan.algo == "three_stage" for u in s.units):
            assert not s.fused


def test_lowering_attaches_epilogues_to_units():
    spec = vgg_style("pb", 4, widths=(8,), with_bias=True)
    # layers: conv bias relu conv bias relu maxpool
    _, units = split_units(spec)
    assert [i for i, _ in units] == [0, 3]
    assert [op.kind for op in units[0][1]] == ["bias", "relu"]
    assert [op.kind for op in units[1][1]] == ["bias", "relu", "maxpool"]


def test_stage_rejects_pool_inside_fusion_group():
    spec = tiny_testnet(4)
    plan = plan_net(spec, 16, 16, hw=BIG_HW, fuse=False)
    # tiny-testnet: conv relu conv relu pool conv relu conv relu pool --
    # fusing across the pool (convs 2 and 5) is structurally illegal
    bad = dataclasses.replace(plan, groups=(FusionGroup(layers=(2, 5)),))
    with pytest.raises(ValueError, match="pool"):
        lower(spec, bad)


def test_lowering_rejects_non_adjacent_group():
    spec = tiny_testnet(4)
    plan = plan_net(spec, 16, 16, hw=BIG_HW, fuse=False)
    bad = dataclasses.replace(plan, groups=(FusionGroup(layers=(0, 5)),))
    with pytest.raises(ValueError, match="adjacent"):
        lower(spec, bad)


def test_can_chain_capability_gates():
    l3 = registry.get("l3_fused")
    spec1 = registry.ConvSpec(h=16, w=16, c_in=8, c_out=8, k=3, pad=1)
    p = lambda algo, spec: registry.AlgoPlan(algo, spec, {})
    assert l3.can_chain(p("l3_fused", spec1), p("l3_fused", spec1))
    assert l3.can_chain(p("l3_fused", spec1), p("l3_fused_pallas", spec1))
    assert not l3.can_chain(p("l3_fused", spec1), p("fft_fused", spec1))
    assert not l3.can_chain(p("l3_fused", spec1), p("three_stage", spec1))
    assert not l3.can_chain(p("l3_fused", spec1), p("direct", spec1))
    strided = dataclasses.replace(spec1, stride=2)
    assert not l3.can_chain(p("l3_fused", spec1), p("l3_fused", strided))
    assert not registry.get("direct").can_chain(
        p("direct", spec1), p("direct", spec1)
    )


# ------------------------------------------------------- staged execution


def test_execute_staged_matches_sequential_any_tiling():
    """The generic halo-recompute chain is exact for every super-tile
    row count, including seams and borders."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 13, 11, 3)) * 0.1, jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((3, 3, 3, 5)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((5, 5, 5, 4)) * 0.1, jnp.float32)
    s1 = registry.ConvSpec(h=13, w=11, c_in=3, c_out=5, k=3, pad=1)
    s2 = registry.ConvSpec(h=13, w=11, c_in=5, c_out=4, k=5, pad=2)
    alg = registry.get("direct")
    from repro.core.conv import conv2d_direct

    ref = conv2d_direct(
        jax.nn.relu(conv2d_direct(x, w1, pad=1)), w2, pad=2
    )
    for tile_rows in (0, 1, 4, 13, 100):
        chain = [
            registry.ChainLink(
                w1, None, registry.AlgoPlan("direct", s1, {}),
                lambda y, r0: jax.nn.relu(y),
            ),
            registry.ChainLink(
                w2, None, registry.AlgoPlan("direct", s2, {}), None
            ),
        ]
        y = alg.execute_staged(x, chain, tile_rows=tile_rows)
        assert y.shape == ref.shape
        assert float(jnp.abs(y - ref).max()) < 1e-5, tile_rows


def test_execute_staged_rejects_strided_and_empty_chains():
    alg = registry.get("direct")
    with pytest.raises(ValueError, match="empty"):
        alg.execute_staged(jnp.zeros((1, 8, 8, 4)), [], tile_rows=0)
    s = registry.ConvSpec(h=8, w=8, c_in=4, c_out=4, k=3, pad=1, stride=2)
    link = registry.ChainLink(
        jnp.zeros((3, 3, 4, 4)), None, registry.AlgoPlan("direct", s, {})
    )
    with pytest.raises(ValueError, match="stride-1"):
        alg.execute_staged(jnp.zeros((1, 8, 8, 4)), [link], tile_rows=0)


# -------------------------------------------------- fused-vs-unfused nets


def test_fused_program_matches_unfused_and_direct():
    """Acceptance: fusion-group output == layer-by-layer output to fp32
    tolerance, both == the direct oracle; bias/relu epilogues are folded
    into the stages."""
    spec = vgg_style("pb", 4, widths=(8, 16), with_bias=True)
    ws = init_weights(spec, seed=3)
    eng = Engine(hw=BIG_HW)
    fused = eng.compile(spec, ws, input_hw=(16, 16), consider_fft=False)
    plain = eng.compile(
        spec, ws, input_hw=(16, 16), consider_fft=False, fuse=False
    )
    assert fused.program.n_fused >= 1
    assert plain.program.n_fused == 0
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, 16, 4)) * 0.1, jnp.float32)
    ref = run_direct(spec, ws, x)
    yf, yu = fused(x), plain(x)
    assert _rel(yf, ref) < 1e-3
    assert _rel(yu, ref) < 1e-3
    assert _rel(yf, yu) < 1e-4  # same algorithms, same arithmetic family


def test_fused_multi_tile_ragged_matches_per_image():
    """Forced multi-super-tile fusion groups stay exact for ragged
    batches: intermediate masks are applied tile-position-aware."""
    spec = vgg_style("pb2", 4, widths=(8,), with_bias=True)
    ws = init_weights(spec, seed=7)
    eng = Engine(hw=BIG_HW)
    base = eng.compile(spec, ws, input_hw=(16, 16), consider_fft=False)
    assert base.plan.groups
    tiled_plan = dataclasses.replace(
        base.plan,
        groups=tuple(
            dataclasses.replace(g, tile_rows=5) for g in base.plan.groups
        ),
    )
    net = eng.compile(spec, ws, plan=tiled_plan)
    rng = np.random.default_rng(4)
    small = jnp.asarray(rng.standard_normal((12, 12, 4)) * 0.1, jnp.float32)
    full = jnp.asarray(rng.standard_normal((16, 16, 4)) * 0.1, jnp.float32)
    batch = (
        jnp.zeros((2, 16, 16, 4), jnp.float32)
        .at[0, :12, :12].set(small)
        .at[1].set(full)
    )
    y = net(batch, sizes=jnp.asarray([[12, 12], [16, 16]], jnp.int32))
    ref_small = run_direct(spec, ws, small[None])[0]
    oh, ow, _ = ref_small.shape
    assert _rel(y[0, :oh, :ow], ref_small) < 1e-3
    assert _rel(y[1], run_direct(spec, ws, full[None])[0]) < 1e-3


def test_ragged_masking_stride2_net_matches_per_image():
    spec = NetSpec(
        "s2-net",
        (conv(4, 8), relu(), conv(8, 8, stride=2), relu(), maxpool(2)),
    )
    ws = init_weights(spec, seed=5)
    net = Engine(hw=BIG_HW).compile(spec, ws, input_hw=(24, 24))
    rng = np.random.default_rng(6)
    small = jnp.asarray(rng.standard_normal((16, 16, 4)) * 0.1, jnp.float32)
    full = jnp.asarray(rng.standard_normal((24, 24, 4)) * 0.1, jnp.float32)
    batch = (
        jnp.zeros((2, 24, 24, 4), jnp.float32)
        .at[0, :16, :16].set(small)
        .at[1].set(full)
    )
    y = net(batch, sizes=jnp.asarray([[16, 16], [24, 24]], jnp.int32))
    ref_small = run_direct(spec, ws, small[None])[0]
    oh, ow, _ = ref_small.shape
    assert _rel(y[0, :oh, :ow], ref_small) < 1e-3
    assert _rel(y[1], run_direct(spec, ws, full[None])[0]) < 1e-3


def test_ragged_masking_grouped_net_matches_per_image():
    spec = resnext_grouped(4)
    ws = init_weights(spec, seed=8)
    net = Engine(hw=BIG_HW).compile(spec, ws, input_hw=(16, 16))
    rng = np.random.default_rng(9)
    small = jnp.asarray(rng.standard_normal((12, 12, 4)) * 0.1, jnp.float32)
    full = jnp.asarray(rng.standard_normal((16, 16, 4)) * 0.1, jnp.float32)
    batch = (
        jnp.zeros((2, 16, 16, 4), jnp.float32)
        .at[0, :12, :12].set(small)
        .at[1].set(full)
    )
    y = net(batch, sizes=jnp.asarray([[12, 12], [16, 16]], jnp.int32))
    ref_small = run_direct(spec, ws, small[None])[0]
    oh, ow, _ = ref_small.shape
    assert _rel(y[0, :oh, :ow], ref_small) < 1e-3
    assert _rel(y[1], run_direct(spec, ws, full[None])[0]) < 1e-3


# -------------------------------------------------- plan v3 + migration


def test_plan_v3_roundtrip_produces_identical_stages():
    """Acceptance: serialize -> load -> identical stages (the program is
    a pure function of spec + plan, and v3 carries the groups)."""
    spec = tiny_testnet(4)
    plan = plan_net(spec, 16, 16, hw=BIG_HW)
    assert plan.groups  # the tiny net fuses on the big shared level
    again = NetPlan.from_json(plan.to_json())
    assert again == plan
    assert lower(spec, again) == lower(spec, plan)


def test_v2_plan_loads_and_replans_identically(tmp_path):
    """A v2 plan file (no groups) still loads; upgrading it re-derives
    the same plan -- layer decisions AND groups -- as planning fresh."""
    spec = tiny_testnet(4)
    fresh = plan_net(spec, 16, 16, hw=BIG_HW)
    d = json.loads(fresh.to_json())
    d["version"] = 2
    del d["groups"]
    path = tmp_path / "v2.json"
    path.write_text(json.dumps(d))
    loaded = NetPlan.load(path)
    assert loaded.groups == ()
    assert loaded.layers == fresh.layers
    upgraded = upgrade_plan(spec, loaded, BIG_HW)
    assert upgraded == fresh
    # a v3 plan passes through upgrade untouched
    assert upgrade_plan(spec, fresh, BIG_HW) is fresh


def test_unknown_plan_version_rejected():
    spec = tiny_testnet(4)
    d = json.loads(plan_net(spec, 16, 16, hw=BIG_HW).to_json())
    d["version"] = 4
    with pytest.raises(ValueError, match="version"):
        NetPlan.from_json(json.dumps(d))


def test_engine_compile_accepts_loaded_plan(tmp_path):
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=1)
    eng = Engine(hw=BIG_HW)
    net = eng.compile(spec, ws, input_hw=(16, 16))
    path = tmp_path / "net.plan.json"
    net.save_plan(path)
    again = eng.compile(spec, ws, plan=NetPlan.load(path))
    assert again.program == net.program
    with pytest.raises(ValueError, match="planning knobs"):
        eng.compile(spec, ws, plan=net.plan, consider_fft=False)


# ------------------------------------------------------ serving satellites


def test_bucket_validation_accounts_for_stride_chain():
    """Seed bug: pool-factor modulo admitted buckets that die in the
    stride chain.  conv/2 then two 2x2 pools needs extents divisible by
    8 overall; 20 % pool_factor(4) == 0 but 20 -> 10 -> 5 breaks."""
    spec = NetSpec(
        "s2-pools",
        (conv(4, 8, stride=2), relu(), maxpool(2), maxpool(2)),
    )
    assert spec.pool_factor == 4
    assert spec.downsample_factor == 8
    ws = init_weights(spec, seed=0)
    net = Engine(hw=BIG_HW).compile(spec, ws, input_hw=(16, 16))
    with pytest.raises(ValueError, match="downsampling chain"):
        ConvServer(net, ConvServeConfig(buckets=(20,)))
    ConvServer(net, ConvServeConfig(buckets=(16, 32)))  # survives


def test_server_stats_unified_over_compiled_net():
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=5)
    net = Engine(hw=BIG_HW).compile(spec, ws, input_hw=(16, 16))
    srv = ConvServer(net, ConvServeConfig(max_batch=2, buckets=(16, 32)))
    rng = np.random.default_rng(2)
    srv.run(
        [
            ImageRequest(0, rng.standard_normal((16, 16, 4)).astype(np.float32)),
            ImageRequest(1, rng.standard_normal((32, 32, 4)).astype(np.float32)),
        ]
    )
    s = srv.stats()
    assert s["waves"] == 2  # one per bucket
    assert s["compiles_per_bucket"] == {16: 1, 32: 1}
    assert s["compiled_programs"] == 2
    assert s["cache"]["misses"] == 4
    assert s["cache"]["hits"] == 4  # second bucket reused every transform


def test_profile_stages_covers_program():
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=1)
    net = Engine(hw=BIG_HW).compile(spec, ws, input_hw=(16, 16))
    x = jnp.zeros((1, 16, 16, 4), jnp.float32)
    rows = net.profile_stages(x)
    assert [label for label, _ in rows] == [
        s.label for s in net.program.stages
    ]
    assert all(t >= 0.0 for _, t in rows)


def test_prologue_glue_before_first_conv():
    """Glue before any conv lowers into the program prologue; execution
    (and per-stage profiling, which must pool before the first conv sees
    the input) both honour it."""
    spec = NetSpec("pool-first", (maxpool(2), conv(4, 8), relu()))
    ws = init_weights(spec, seed=2)
    net = Engine(hw=BIG_HW).compile(spec, ws, input_hw=(16, 16))
    assert [op.kind for op in net.program.prologue] == ["maxpool"]
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 16, 16, 4)) * 0.1, jnp.float32)
    assert _rel(net(x), run_direct(spec, ws, x)) < 1e-3
    rows = net.profile_stages(x)  # would fail on the unpooled geometry
    assert [label for label, _ in rows] == ["conv1"]


def test_stage_unit_structmembers():
    spec = vgg_style("pb3", 4, widths=(8,), with_bias=True)
    plan = plan_net(spec, 16, 16, hw=BIG_HW, fuse=False)
    prog = lower(spec, plan)
    assert [s.label for s in prog.stages] == ["conv0", "conv3"]
    last = prog.stages[-1].units[0]
    assert isinstance(last, StageUnit) and last.has_pool
    with pytest.raises(ValueError, match="no units"):
        Stage(units=())
