"""Online serving runtime under a simulated clock: deadline-flushed
partial waves are exact, priorities order within buckets, round-robin
prevents cross-bucket starvation, admission rejects at capacity, batch
hysteresis reuses compiled programs, replicas share one kernel cache,
and the cache's LRU bound + invalidation counters behave."""

import json
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.convnets import tiny_testnet
from repro.convserve import (
    ConvServeConfig,
    ConvServer,
    Engine,
    ImageRequest,
    KernelCache,
    NetExecutor,
    init_weights,
    plan_net,
    run_direct,
)
from repro.convserve.runtime import (
    FLUSH_DEADLINE,
    INTERACTIVE,
    REJECT_BAD_SHAPE,
    REJECT_QUEUE_FULL,
    REJECT_TOO_LARGE,
    ReplicaPool,
    Request,
    RuntimeConfig,
    ServeRuntime,
    SimClock,
    STANDARD,
    Telemetry,
    WaveScheduler,
    make_images,
    poisson_trace,
)
from repro.core import analysis

BIG_HW = analysis.HardwareModel(
    name="big", peak_flops=1e12, dram_bw=1e11, fast_shared_bw=5e11,
    fast_shared_bytes=1 << 30, private_bytes=1 << 24,
)

SPEC = tiny_testnet(4)


def _image(rng, side: int) -> np.ndarray:
    return (rng.standard_normal((side, side, 4)) * 0.1).astype(np.float32)


def _runtime(cfg, *, n=1, clock=None, **compile_kwargs) -> ServeRuntime:
    """Deterministic runtime: inline replicas (workers=0) + SimClock."""
    ws = init_weights(SPEC, seed=5)
    engine = Engine(hw=BIG_HW)
    pool = ReplicaPool.build(
        engine, SPEC, ws, n=n, workers=0, input_hw=(16, 16),
        **compile_kwargs,
    )
    return ServeRuntime(pool, cfg, clock=clock or SimClock())


# ------------------------------------------------------------- clock/trace


def test_sim_clock_advances_on_sleep():
    c = SimClock()
    assert c.now() == 0.0
    c.sleep(0.25)
    c.advance(0.25)
    assert c.now() == 0.5
    with pytest.raises(ValueError):
        c.advance(-1.0)


def test_poisson_trace_is_seed_deterministic():
    a = poisson_trace(100.0, 20, seed=3, sizes=(12, 16), priorities=(0, 1))
    b = poisson_trace(100.0, 20, seed=3, sizes=(12, 16), priorities=(0, 1))
    assert a == b
    assert [r.t for r in a] == sorted(r.t for r in a)
    assert make_images(a, 4, seed=1).keys() == {r.rid for r in a}


# ------------------------------------------------- deadline-flushed waves


def test_deadline_flush_partial_wave_is_exact():
    """The acceptance gate: a wave dispatched early because the oldest
    request's slack expired serves outputs identical to the same
    requests served alone.  Direct-conv plan so the comparison is
    bitwise, and a ragged (12 in 16-bucket) image rides along."""
    clock = SimClock()
    rt = _runtime(
        RuntimeConfig(max_batch=8, buckets=(16,), slo_s=0.05),
        clock=clock, allowed=("direct",),
    )
    rng = np.random.default_rng(0)
    imgs = {0: _image(rng, 16), 1: _image(rng, 12), 2: _image(rng, 16)}
    for rid, im in imgs.items():
        assert rt.submit(im, rid=rid) is None
    # 3 < max_batch and slack remains: nothing may dispatch yet
    clock.advance(0.049)
    assert rt.poll() == 0
    # slack expires at t_admit + slo (service_est starts at 0)
    clock.advance(0.002)
    assert rt.poll() == 1
    assert rt.scheduler.partial_waves == 1
    assert rt.scheduler.waves_by_reason == {FLUSH_DEADLINE: 1}
    assert rt.telemetry.counter("partial_waves") == 1
    assert set(rt.results) == {0, 1, 2}

    # served alone through an identical runtime: bitwise identical
    alone = _runtime(
        RuntimeConfig(max_batch=8, buckets=(16,), slo_s=0.05),
        allowed=("direct",),
    )
    ws = init_weights(SPEC, seed=5)
    for rid, im in imgs.items():
        alone.submit(im, rid=rid)
        alone.drain()
        assert np.array_equal(rt.results[rid], alone.results[rid]), rid
        # and bit-exact against the per-image direct-conv oracle
        ref = np.asarray(run_direct(SPEC, ws, jnp.asarray(im)[None])[0])
        assert np.array_equal(rt.results[rid], ref), rid


def test_full_wave_dispatches_without_waiting():
    clock = SimClock()
    rt = _runtime(
        RuntimeConfig(max_batch=2, buckets=(16,), slo_s=10.0), clock=clock
    )
    rng = np.random.default_rng(1)
    rt.submit(_image(rng, 16), rid=0)
    assert rt.poll() == 0  # half a wave, plenty of slack: wait
    rt.submit(_image(rng, 16), rid=1)
    assert rt.poll() == 1  # full wave: immediate, no deadline needed
    assert rt.scheduler.partial_waves == 0
    assert set(rt.results) == {0, 1}


# ------------------------------------------------------------- priorities


def test_priority_classes_pop_before_fifo():
    sched = WaveScheduler(
        SPEC, RuntimeConfig(max_batch=2, buckets=(16,), queue_depth=8)
    )
    rng = np.random.default_rng(2)
    for rid in (1, 2, 3):
        assert sched.admit(
            Request(rid=rid, image=_image(rng, 16), priority=STANDARD),
            now=float(rid),
        ) is None
    assert sched.admit(
        Request(rid=9, image=_image(rng, 16), priority=INTERACTIVE),
        now=4.0,
    ) is None
    wave = sched.next_wave(now=4.0)  # 4 queued >= max_batch: full wave
    assert [r.rid for r in wave.requests] == [9, 1]  # urgent, then FIFO
    wave = sched.next_wave(now=4.0)
    assert [r.rid for r in wave.requests] == [2, 3]


def test_interactive_slo_tighter_than_batch():
    """Per-class SLOs: the interactive class's deadline lands first."""
    cfg = RuntimeConfig(
        max_batch=8, buckets=(16,), slo_s={INTERACTIVE: 0.01, STANDARD: 1.0}
    )
    sched = WaveScheduler(SPEC, cfg)
    rng = np.random.default_rng(3)
    sched.admit(
        Request(rid=0, image=_image(rng, 16), priority=STANDARD), now=0.0
    )
    assert sched.next_wave(0.5) is None  # standard still has slack
    sched.admit(
        Request(rid=1, image=_image(rng, 16), priority=INTERACTIVE), now=0.5
    )
    wave = sched.next_wave(0.52)  # interactive slack expired
    assert wave is not None and wave.reason == FLUSH_DEADLINE
    # the flush takes the whole bucket queue, urgent first
    assert [r.rid for r in wave.requests] == [1, 0]


# ------------------------------------------------------------ round-robin


def test_round_robin_alternates_ready_buckets():
    """Continuous full-wave traffic in one bucket must not starve the
    other: ready buckets are served alternately."""
    sched = WaveScheduler(
        SPEC,
        RuntimeConfig(max_batch=2, buckets=(16, 32), queue_depth=64),
    )
    rng = np.random.default_rng(4)
    for rid in range(12):
        side = 16 if rid % 2 == 0 else 32
        assert sched.admit(
            Request(rid=rid, image=_image(rng, side)), now=0.0
        ) is None
    buckets = []
    while True:
        w = sched.next_wave(0.0)
        if w is None:
            break
        buckets.append(w.bucket)
    assert buckets == [32, 16, 32, 16, 32, 16]


# -------------------------------------------------------------- admission


def test_admission_rejects_with_reasons():
    rt = _runtime(
        RuntimeConfig(max_batch=8, buckets=(16,), queue_depth=2)
    )
    rng = np.random.default_rng(5)
    assert rt.submit(_image(rng, 16), rid=0) is None
    assert rt.submit(_image(rng, 16), rid=1) is None
    rej = rt.submit(_image(rng, 16), rid=2)  # depth bound hit
    assert rej is not None and rej.reason == REJECT_QUEUE_FULL
    rej = rt.submit(_image(rng, 32), rid=3)  # exceeds largest bucket
    assert rej is not None and rej.reason == REJECT_TOO_LARGE
    bad = rng.standard_normal((16, 16, 5)).astype(np.float32)
    rej = rt.submit(bad, rid=4)  # 5 channels into a 4-channel net
    assert rej is not None and rej.reason == REJECT_BAD_SHAPE
    assert rt.telemetry.counter("rejected") == 3
    assert rt.telemetry.counter(f"rejected.{REJECT_QUEUE_FULL}") == 1
    assert rt.scheduler.stats()["rejected"] == {
        REJECT_QUEUE_FULL: 1, REJECT_TOO_LARGE: 1, REJECT_BAD_SHAPE: 1,
    }
    assert set(rt.rejections) == {2, 3, 4}
    rt.drain()  # the two admitted requests still serve
    assert set(rt.results) == {0, 1}


# ------------------------------------------------------------- hysteresis


def test_partial_wave_hysteresis_reuses_compiled_batch_size():
    """A deadline-flushed single request rides the power-of-two batch
    the bucket already compiled instead of minting a new program."""
    clock = SimClock()
    rt = _runtime(
        RuntimeConfig(max_batch=4, buckets=(16,), slo_s=0.05),
        clock=clock, allowed=("direct",),
    )
    rng = np.random.default_rng(6)
    for rid in range(3):
        rt.submit(_image(rng, 16), rid=rid)
    clock.advance(0.06)
    assert rt.poll() == 1  # wave of 3, padded to pow2 -> 4
    assert rt.pool.stats()["compiled_programs"] == 1
    rt.submit(_image(rng, 16), rid=7)
    clock.advance(0.06)
    assert rt.poll() == 1  # wave of 1: hysteresis pads to the warm 4
    assert rt.pool.stats()["compiled_programs"] == 1  # no new program
    assert set(rt.results) == {0, 1, 2, 7}
    # without hysteresis the same traffic compiles a second program
    rt2 = _runtime(
        RuntimeConfig(max_batch=4, buckets=(16,), slo_s=0.05,
                      pad_batch=False),
        clock=SimClock(), allowed=("direct",),
    )
    for rid in range(3):
        rt2.submit(_image(rng, 16), rid=rid)
    rt2.clock.advance(0.06)
    rt2.poll()
    rt2.submit(_image(rng, 16), rid=7)
    rt2.clock.advance(0.06)
    rt2.poll()
    assert rt2.pool.stats()["compiled_programs"] == 2


# ------------------------------------------------------------ replica pool


def test_replica_pool_shares_cache_and_balances():
    clock = SimClock()
    rt = _runtime(
        RuntimeConfig(max_batch=1, buckets=(16,)), n=2, clock=clock
    )
    rng = np.random.default_rng(7)
    imgs = {rid: _image(rng, 16) for rid in range(4)}
    for rid, im in imgs.items():
        rt.submit(im, rid=rid)
        rt.poll()  # max_batch=1: every request is a full wave
    pool = rt.pool.stats()
    assert pool["dispatched"] == [2, 2]  # least-loaded alternates
    assert pool["in_flight"] == [0, 0]
    cache = rt.pool.cache.stats()
    # transforms prepared once for the whole pool, reused by the peer
    # replica and by every later wave
    assert cache["misses"] == 4
    assert cache["hits"] == 12
    ws = init_weights(SPEC, seed=5)
    for rid, im in imgs.items():
        ref = run_direct(SPEC, ws, jnp.asarray(im)[None])[0]
        rel = float(jnp.abs(rt.results[rid] - ref).max()
                    / jnp.abs(ref).max())
        assert rel < 1e-3, (rid, rel)


def test_replica_pool_rejects_split_caches():
    ws = init_weights(SPEC, seed=5)
    a = Engine(hw=BIG_HW).compile(SPEC, ws, input_hw=(16, 16))
    b = Engine(hw=BIG_HW).compile(SPEC, ws, input_hw=(16, 16))
    with pytest.raises(ValueError, match="share one KernelCache"):
        ReplicaPool([a, b], workers=0)


# ------------------------------------------------------- cache satellites


def test_kernel_cache_lru_eviction_under_byte_capacity():
    ws = init_weights(SPEC, seed=1)
    plan = plan_net(SPEC, 16, 16, hw=BIG_HW, consider_fft=False)
    probe = KernelCache()
    sizes = {}
    for i, _ in SPEC.conv_layers():
        probe.get(plan.net, plan.layer_plan(i), ws[i])
        sizes[i] = probe.nbytes - sum(sizes.values())
    total = probe.nbytes

    cache = KernelCache(capacity_bytes=total - 1)  # can't hold all four
    for i, _ in SPEC.conv_layers():
        cache.get(plan.net, plan.layer_plan(i), ws[i])
    st = cache.stats()
    assert st["capacity_bytes"] == total - 1
    assert st["evictions"] >= 1
    assert st["bytes"] <= total - 1
    assert st["entries"] < 4
    # least-recently-used went first: layer 0's entry re-misses, the
    # most recent layer still hits
    convs = [i for i, _ in SPEC.conv_layers()]
    cache.get(plan.net, plan.layer_plan(convs[-1]), ws[convs[-1]])
    assert cache.stats()["hits"] == 1
    miss0 = cache.stats()["misses"]
    cache.get(plan.net, plan.layer_plan(convs[0]), ws[convs[0]])
    assert cache.stats()["misses"] == miss0 + 1
    with pytest.raises(ValueError):
        KernelCache(capacity_bytes=0)


def test_single_oversized_entry_still_serves():
    ws = init_weights(SPEC, seed=1)
    plan = plan_net(SPEC, 16, 16, hw=BIG_HW, consider_fft=False)
    cache = KernelCache(capacity_bytes=1)  # smaller than any transform
    i0 = SPEC.conv_layers()[0][0]
    wt = cache.get(plan.net, plan.layer_plan(i0), ws[i0])
    assert wt is not None
    assert cache.stats()["entries"] == 1  # kept: never evict the entry
    assert cache.get(plan.net, plan.layer_plan(i0), ws[i0]) is not None
    assert cache.stats()["hits"] == 1


def test_invalidations_counted_and_surfaced():
    ws = init_weights(SPEC, seed=5)
    plan = plan_net(SPEC, 16, 16, hw=BIG_HW)
    ex = NetExecutor(SPEC, ws, plan)
    srv = ConvServer(ex, ConvServeConfig(max_batch=2, buckets=(16,)))
    rng = np.random.default_rng(8)
    srv.run([ImageRequest(0, _image(rng, 16))])
    ex.cache.invalidate(plan.net)
    ex.cache.invalidate("some-other-net")
    st = srv.stats()
    assert st["cache"]["invalidations"] == 2
    assert st["cache"]["entries"] == 0
    # engine-level surface too
    engine = Engine(hw=BIG_HW)
    engine.compile(SPEC, ws, input_hw=(16, 16))
    engine.invalidate()
    assert engine.stats()["cache"]["invalidations"] == 1
    assert engine.stats()["nets_compiled"] == 1


# ---------------------------------------------------- offline front-end


def test_offline_server_reports_scheduler_counters():
    ws = init_weights(SPEC, seed=5)
    plan = plan_net(SPEC, 16, 16, hw=BIG_HW)
    srv = ConvServer(
        NetExecutor(SPEC, ws, plan),
        ConvServeConfig(max_batch=2, buckets=(16,)),
    )
    rng = np.random.default_rng(9)
    out = srv.run([ImageRequest(r, _image(rng, 16)) for r in range(3)])
    assert set(out) == {0, 1, 2}
    st = srv.stats()
    assert st["waves"] == 2  # one full, one drained partial
    assert st["partial_waves"] == 1
    assert st["admitted"] == 3 and st["rejected"] == {}
    assert st["calls"] == 2  # executor-level plumbing
    # hysteresis holds offline too: the drained single request pads to
    # the already-compiled size-2 wave, so one program serves both
    assert st["images"] == 2 + 2
    assert st["compiled_programs"] == 1


def test_offline_failed_batch_leaves_no_state_behind():
    """A rejected request aborts its whole batch: the already-admitted
    mates must not leak into the next run()'s waves or results."""
    ws = init_weights(SPEC, seed=5)
    plan = plan_net(SPEC, 16, 16, hw=BIG_HW)
    srv = ConvServer(
        NetExecutor(SPEC, ws, plan),
        ConvServeConfig(max_batch=4, buckets=(16,)),
    )
    rng = np.random.default_rng(10)
    with pytest.raises(ValueError, match="too_large"):
        srv.run([
            ImageRequest(1, _image(rng, 16)),
            ImageRequest(2, _image(rng, 64)),  # oversized: aborts batch
        ])
    assert srv.scheduler.stats()["cleared"] == 1
    out = srv.run([ImageRequest(3, _image(rng, 16))])
    assert set(out) == {3}  # rid 1 did not leak into this batch


def test_offline_executor_failure_mid_drain_clears_queue():
    """An executor error on wave 2 must not leave waves 3+ queued for
    the next run() to silently serve."""
    ws = init_weights(SPEC, seed=5)
    plan = plan_net(SPEC, 16, 16, hw=BIG_HW)
    ex = NetExecutor(SPEC, ws, plan)

    class Boom:
        def __init__(self, inner):
            self.inner = inner
            self.spec = inner.spec
            self.calls = 0

        def __call__(self, batch, sizes):
            self.calls += 1
            if self.calls == 2:
                raise RuntimeError("boom")
            return self.inner(batch, sizes)

        def stats(self):
            return self.inner.stats()

    srv = ConvServer(Boom(ex), ConvServeConfig(max_batch=2, buckets=(16,)))
    rng = np.random.default_rng(14)
    with pytest.raises(RuntimeError, match="boom"):
        srv.run([ImageRequest(r, _image(rng, 16)) for r in range(6)])
    assert srv.scheduler.stats()["queue_depth"] == 0  # nothing left behind
    out = srv.run([ImageRequest(9, _image(rng, 16))])
    assert set(out) == {9}


# -------------------------------------------------------------- telemetry


def test_histogram_percentiles_and_snapshot():
    t = Telemetry()
    for ms in range(1, 101):  # 1..100 ms uniform
        t.observe("queue_wait", ms * 1e-3)
    h = t.histogram("queue_wait")
    assert h.count == 100
    # log-bucketed estimate: within one bucket ratio (2**0.25) of truth
    assert h.percentile(0.5) == pytest.approx(0.050, rel=0.2)
    assert h.percentile(0.99) == pytest.approx(0.100, rel=0.2)
    assert h.percentile(0.5) <= h.percentile(0.95) <= h.percentile(0.99)
    assert h.percentile(0.99) <= h.max
    t.inc("waves")
    t.set_gauge("queue_depth", 3)
    doc = t.snapshot(cache={"hits": 1}, stages=None)
    json.dumps(doc)
    assert doc["counters"]["waves"] == 1
    assert doc["cache"] == {"hits": 1}
    assert "stages" not in doc
    assert doc["latency"]["queue_wait"]["p99_s"] > 0


# ----------------------------------------------------- end-to-end (sim)


def test_poisson_trace_end_to_end_under_sim_clock():
    clock = SimClock()
    rt = _runtime(
        RuntimeConfig(max_batch=4, buckets=(16,), slo_s=0.05,
                      queue_depth=32),
        clock=clock,
    )
    trace = poisson_trace(200.0, 12, seed=11, sizes=(12, 16))
    images = make_images(trace, 4, seed=12)
    results = rt.play(trace, images)
    assert set(results) == {a.rid for a in trace}
    assert rt.telemetry.histogram("e2e").count == 12
    assert rt.telemetry.histogram("queue_wait").count == 12
    sched = rt.scheduler.stats()
    assert sched["queue_depth"] == 0
    assert sched["waves"] >= 3  # 12 requests, max_batch 4
    # queue waits are bounded by the SLO window in simulated time
    assert rt.telemetry.histogram("queue_wait").max <= 0.05 + 1e-9
    doc = rt.stats(profile_bucket=16)
    json.dumps(doc)
    for section in ("counters", "latency", "scheduler", "pool", "cache",
                    "stages"):
        assert section in doc, section
    ws = init_weights(SPEC, seed=5)
    for a in trace:
        ref = run_direct(SPEC, ws, jnp.asarray(images[a.rid])[None])[0]
        rel = float(jnp.abs(results[a.rid] - ref).max()
                    / jnp.abs(ref).max())
        assert rel < 1e-3, (a.rid, rel)


def test_scheduler_next_event_drives_wakeups():
    sched = WaveScheduler(
        SPEC, RuntimeConfig(max_batch=8, buckets=(16,), slo_s=0.1)
    )
    assert sched.next_event(0.0) == math.inf  # nothing queued
    rng = np.random.default_rng(13)
    sched.admit(Request(rid=0, image=_image(rng, 16)), now=1.0)
    assert sched.next_event(1.0) == pytest.approx(1.1)  # deadline - est(0)
    sched.observe_service(16, 0.03)
    assert sched.next_event(1.0) == pytest.approx(1.07)  # slack shrinks
