"""convcheck: the static verifier must pass every benched config clean,
and every seeded mutation must fail with its documented CVK code.  Plus
the integration points: `Engine.compile(verify=)`, the adapt loop's
reason-coded candidate rejection (no shadow traffic for a corrupt
plan), `hot_swap`'s last-line-of-defense gate, and the injected-clock
routing the clock rules enforce."""

import dataclasses
import json
import textwrap

import numpy as np
import pytest

from repro.configs import convnets
from repro.convserve import (
    AdaptConfig,
    AdaptController,
    Engine,
    hot_swap,
    init_weights,
    planner,
)
from repro.convserve.check.diagnostics import (
    CheckReport,
    Diagnostic,
    ProgramError,
    VerificationError,
    program_error,
)
from repro.convserve.check.__main__ import BENCHED_CONFIGS, main as check_main
from repro.convserve.check.ir import verify_compiled, verify_program
from repro.convserve.check.locks import analyze_locks
from repro.convserve.check.rules import analyze_rules
from repro.convserve.graph import NetSpec, conv, maxpool, relu
from repro.convserve.plan import FusionGroup
from repro.convserve.planner import plan_net
from repro.convserve.program import lower
from repro.convserve.runtime import (
    ReplicaPool,
    RuntimeConfig,
    ServeRuntime,
    SimClock,
)
from repro.core import analysis

BIG_HW = analysis.HardwareModel(
    name="big", peak_flops=1e12, dram_bw=1e11, fast_shared_bw=5e11,
    fast_shared_bytes=1 << 30, private_bytes=1 << 24,
)

SPEC = convnets.tiny_testnet(4)


@pytest.fixture(scope="module")
def tiny_plan():
    return plan_net(SPEC, 64, 64, hw=BIG_HW)


def _codes(report):
    return {d.code for d in report.diagnostics}


# ------------------------------------------------- diagnostics core


def test_diagnostic_format_and_hint_autofill():
    d = Diagnostic(code="CVK111", message="slab too big", loc="net/fuse")
    assert d.severity == "error"
    assert d.hint  # auto-filled from HINTS
    s = d.format()
    assert "CVK111" in s and "net/fuse" in s and "slab too big" in s

    rep = CheckReport(analyzer="ir")
    assert rep.ok and not rep.errors
    rep.add(d)
    assert not rep.ok and rep.has("CVK111")
    assert list(rep.codes()) == ["CVK111"]
    doc = rep.to_dict()
    assert doc["analyzer"] == "ir" and len(doc["diagnostics"]) == 1
    json.loads(rep.to_json())  # round-trips


def test_program_error_is_plain_valueerror():
    e = program_error("CVK101", "plan is for net 'a', spec is 'b'")
    assert isinstance(e, ProgramError) and isinstance(e, ValueError)
    assert str(e) == "plan is for net 'a', spec is 'b'"  # message unprefixed
    assert e.code == "CVK101" and e.diagnostic.code == "CVK101"


def test_verification_error_carries_codes():
    rep = CheckReport(analyzer="ir")
    rep.add(Diagnostic(code="CVK105", message="dtype break", loc="x"))
    err = VerificationError(rep)
    assert list(err.codes) == ["CVK105"]
    assert "CVK105" in str(err)


# ------------------------------------------- IR: clean on benched configs


def test_benched_configs_verify_clean():
    for name in BENCHED_CONFIGS:
        spec = getattr(convnets, name)()
        plan = plan_net(spec, 64, 64, hw=BIG_HW)
        rep = verify_program(spec, plan, hw=BIG_HW)
        assert rep.ok, f"{name}: {rep.format()}"


# --------------------------------------------- IR: seeded plan mutations
#
# Each mutation corrupts one invariant and must surface exactly the
# documented code (property-style: plan from the real planner, one
# targeted edit, one expected diagnostic).


def test_mutation_oversized_tile_rows_is_cvk111(tiny_plan):
    assert tiny_plan.groups, "seed plan must be fused"
    g0 = tiny_plan.groups[0]
    bad = dataclasses.replace(
        tiny_plan,
        groups=(dataclasses.replace(g0, tile_rows=10_000_000),)
        + tiny_plan.groups[1:],
    )
    rep = verify_program(SPEC, bad, hw=BIG_HW)
    assert rep.has("CVK111"), rep.format()


def test_mutation_dtype_break_is_cvk105(tiny_plan):
    l0 = tiny_plan.layers[0]
    bad = dataclasses.replace(
        tiny_plan,
        layers=(
            dataclasses.replace(
                l0, spec=dataclasses.replace(l0.spec, dtype="bfloat16")
            ),
        )
        + tiny_plan.layers[1:],
    )
    rep = verify_program(SPEC, bad, hw=BIG_HW)
    assert rep.has("CVK105"), rep.format()


def test_mutation_dropped_weight_param_is_cvk114(tiny_plan):
    from repro.core import registry

    idx, dropped = next(
        (i, registry.get(p.algo).weight_params[0])
        for i, p in enumerate(tiny_plan.layers)
        if registry.get(p.algo).consumes_wt
        and registry.get(p.algo).weight_params
    )
    p = tiny_plan.layers[idx]
    params = {k: v for k, v in p.params.items() if k != dropped}
    bad = dataclasses.replace(
        tiny_plan,
        layers=tiny_plan.layers[:idx]
        + (dataclasses.replace(p, params=params),)
        + tiny_plan.layers[idx + 1:],
    )
    rep = verify_program(SPEC, bad, hw=BIG_HW)
    assert rep.has("CVK114"), rep.format()  # under-keyed cache entry


def test_mutation_renamed_net_is_cvk101(tiny_plan):
    bad = dataclasses.replace(tiny_plan, net="somebody-else")
    rep = verify_program(SPEC, bad, hw=BIG_HW)
    assert rep.has("CVK101"), rep.format()


def test_mutation_wrong_input_hw_breaks_shape_chain(tiny_plan):
    # tiny_testnet pools; 63 is neither the planned extent nor divisible
    bad = dataclasses.replace(tiny_plan, input_hw=(63, 63))
    rep = verify_program(SPEC, bad, hw=BIG_HW)
    assert rep.errors and (rep.has("CVK116") or rep.has("CVK113")), (
        rep.format()
    )


def test_mutation_pool_mid_group_is_cvk110():
    spec = NetSpec(
        name="pool-mid",
        layers=(conv(4, 8), relu(), maxpool(2), conv(8, 8), relu()),
    )
    plan = plan_net(spec, 16, 16, hw=BIG_HW)
    # force-fuse across the pool: layers 0 and 3 are adjacent convs, but
    # layer 0's epilogue holds the maxpool -- lower() must refuse
    bad = dataclasses.replace(plan, groups=(FusionGroup(layers=(0, 3)),))
    rep = verify_program(spec, bad, hw=BIG_HW)
    assert rep.has("CVK110"), rep.format()


def test_mutation_duplicate_units_collide_cache_keys(tiny_plan):
    prog = lower(SPEC, tiny_plan)
    dup = dataclasses.replace(
        prog, stages=(prog.stages[0], prog.stages[0]) + prog.stages[1:]
    )
    rep = verify_program(SPEC, tiny_plan, program=dup, hw=BIG_HW)
    assert rep.has("CVK114"), rep.format()


def test_mutation_phantom_rows_is_cvk116(tiny_plan):
    prog = lower(SPEC, tiny_plan)
    fi = next(i for i, st in enumerate(prog.stages) if st.fused)
    st = prog.stages[fi]
    u0 = st.units[0]
    # shrink the first member's true extent under the recursion's feet:
    # the stage's output rows now want input rows past h + pad
    shrunk = dataclasses.replace(
        u0, plan=dataclasses.replace(
            u0.plan, spec=dataclasses.replace(u0.plan.spec, h=2)
        )
    )
    bad_stage = dataclasses.replace(st, units=(shrunk,) + st.units[1:])
    bad = dataclasses.replace(
        prog,
        stages=prog.stages[:fi] + (bad_stage,) + prog.stages[fi + 1:],
    )
    rep = verify_program(SPEC, tiny_plan, program=bad, hw=BIG_HW)
    assert rep.has("CVK116"), rep.format()


# ------------------------------------------------ Engine.compile(verify=)


@pytest.fixture(scope="module")
def weights():
    return init_weights(SPEC, seed=5)


def _corrupt(plan):
    g0 = plan.groups[0]
    return dataclasses.replace(
        plan,
        groups=(dataclasses.replace(g0, tile_rows=10_000_000),)
        + plan.groups[1:],
    )


def test_compile_strict_rejects_corrupt_plan(tiny_plan, weights):
    engine = Engine(hw=BIG_HW)
    with pytest.raises(VerificationError) as ei:
        engine.compile(SPEC, weights, plan=_corrupt(tiny_plan), fuse=None)
    assert "CVK111" in ei.value.codes


def test_compile_verify_off_and_warn_still_compile(tiny_plan, weights,
                                                   capsys):
    engine = Engine(hw=BIG_HW)
    bad = _corrupt(tiny_plan)
    net = engine.compile(SPEC, weights, plan=bad, fuse=None, verify="off")
    assert net.report is None  # skipped entirely

    net = engine.compile(SPEC, weights, plan=bad, fuse=None, verify="warn")
    assert net.report is not None and net.report.has("CVK111")
    assert "CVK111" in capsys.readouterr().out


def test_compile_strict_clean_plan_attaches_report(weights):
    engine = Engine(hw=BIG_HW)
    net = engine.compile(SPEC, weights, input_hw=(16, 16))
    assert net.report is not None and net.report.ok
    assert net.hw is BIG_HW
    assert verify_compiled(net).ok


def test_compile_rejects_unknown_verify_mode(weights):
    with pytest.raises(ValueError, match="verify"):
        Engine(hw=BIG_HW).compile(
            SPEC, weights, input_hw=(16, 16), verify="sometimes"
        )


# --------------------------------------------------- hot_swap's gate


def test_hot_swap_refuses_verification_failing_candidate(weights):
    engine = Engine(hw=BIG_HW)
    pool = ReplicaPool.build(
        engine, SPEC, weights, n=1, workers=0, input_hw=(16, 16)
    )
    live = pool.executors[0]
    cand = engine.compile(
        SPEC, weights, plan=_corrupt(live.plan), fuse=None, verify="off"
    )
    with pytest.raises(VerificationError) as ei:
        hot_swap(pool, [cand])
    assert "CVK111" in ei.value.codes
    assert pool.executors[0] is live  # dispatch never flipped

    # and the gate is the only thing refusing: verify=False swaps
    old = hot_swap(pool, [cand], verify=False)
    assert old == [live]
    hot_swap(pool, old, verify=False)  # rollback


# ------------------------------------- adapt: reason-coded rejection


def test_adapt_rejects_corrupt_candidate_before_shadow(monkeypatch):
    """A replan candidate that fails static verification must be
    reason-coded into the audit log and counters, cool the loop down,
    and never compile or receive shadow traffic."""
    ws = init_weights(SPEC, seed=5)
    engine = Engine(hw=BIG_HW)
    pool = ReplicaPool.build(
        engine, SPEC, ws, n=1, workers=0, input_hw=(16, 16)
    )
    rt = ServeRuntime(
        pool,
        RuntimeConfig(max_batch=2, buckets=(16,), slo_s=1.0,
                      service_est_s=1e-4),
        clock=SimClock(),
    )

    def probe(net, bucket, batch):
        preds = planner.predict_stage_times(net.program, engine.hw)
        return [
            (label, pred * (10.0 if stage.fused else 1.0))
            for stage, (label, pred) in zip(net.program.stages, preds)
        ]

    ac = AdaptController(
        rt, engine, SPEC, ws,
        AdaptConfig(divergence_ratio=2.0, shadow_fraction=1.0,
                    shadow_min_waves=2, cooldown_s=0.5),
        probe=probe,
    )

    real_plan_net = planner.plan_net

    def corrupting_plan_net(*a, **kw):
        # break the dtype chain mid-net: layer 0 claims bfloat16 in a
        # float32 plan -- the measured-cost candidate drops the fusion
        # groups, so the corruption must not rely on one existing
        plan = real_plan_net(*a, **kw)
        l0 = plan.layers[0]
        return dataclasses.replace(
            plan,
            layers=(
                dataclasses.replace(
                    l0, spec=dataclasses.replace(l0.spec, dtype="bfloat16")
                ),
            )
            + plan.layers[1:],
        )

    monkeypatch.setattr(planner, "plan_net", corrupting_plan_net)

    ac.measure()
    ac.probe_alternatives()
    ac.check()

    events = [a["event"] for a in ac.audit]
    assert events == ["replan", "replan_rejected"]
    rejected = ac.audit[-1]
    assert "CVK105" in rejected["codes"]  # reason-coded
    assert rt.telemetry.counter("adapt.verify_rejected") == 1
    assert rt.telemetry.counter("adapt.shadows_run") == 0
    assert ac.state == "idle" and ac.candidate is None
    assert ac._cooldown_until > rt.clock.now()  # loop backed off


# --------------------------------------------------- clock routing


def test_engine_clock_threads_into_executors(weights):
    clk = SimClock()
    engine = Engine(hw=BIG_HW, clock=clk)
    net = engine.compile(SPEC, weights, input_hw=(16, 16))
    assert net.executor.clock is clk

    pool = ReplicaPool.build(
        engine, SPEC, weights, n=1, workers=0, input_hw=(16, 16), clock=clk
    )
    assert pool.clock is clk
    assert pool.executors[0].executor.clock is clk


def test_profile_stages_reads_injected_clock(weights):
    clk = SimClock()
    engine = Engine(hw=BIG_HW, clock=clk)
    net = engine.compile(SPEC, weights, input_hw=(16, 16))
    x = np.zeros((1, 16, 16, 4), np.float32)
    rows = net.profile_stages(x)
    assert rows and all(dt == 0.0 for _, dt in rows)  # sim time stood still


# --------------------------------------------- locks: fixture tree


def _write(tmp_path, rel, src):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(src))
    return p


def test_locks_flags_mutation_outside_lock(tmp_path):
    f = _write(tmp_path, "box.py", """\
        import threading

        class Box:
            def __init__(self):
                self._lock = threading.Lock()
                self.items = []  # guarded-by: _lock

            def good(self):
                with self._lock:
                    self.items.append(1)

            def bad(self):
                self.items.append(2)

            def also_bad(self):
                self.items = []
        """)
    rep = analyze_locks([f])
    cvk201 = [d for d in rep.errors if d.code == "CVK201"]
    assert len(cvk201) == 2
    assert all("Box.items" in d.message for d in cvk201)
    assert not rep.has("CVK203")  # annotated class, no warning


def test_locks_honors_waivers_and_condition_alias(tmp_path):
    f = _write(tmp_path, "waived.py", """\
        import threading

        class Waived:
            def __init__(self):
                self._lock = threading.Lock()
                self._cv = threading.Condition(self._lock)
                self.n = 0  # guarded-by: _lock

            def _bump_locked(self):
                self.n += 1

            def helper(self):
                # holds-lock: _lock
                self.n += 1

            def via_cv(self):
                with self._cv:
                    self.n += 1
        """)
    rep = analyze_locks([f])
    assert rep.ok, rep.format()


def test_locks_rejects_lock_order_cycle(tmp_path):
    f = _write(tmp_path, "cycle.py", """\
        import threading

        class Tangle:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()
                self.x = 0  # guarded-by: _a

            def one(self):
                with self._a:
                    with self._b:
                        self.x = 1

            def two(self):
                with self._b:
                    with self._a:
                        self.x = 2
        """)
    rep = analyze_locks([f])
    assert rep.has("CVK202"), rep.format()
    cyc = next(d for d in rep.errors if d.code == "CVK202")
    assert "Tangle._a" in cyc.message and "Tangle._b" in cyc.message


def test_locks_warns_on_unannotated_lock_owner(tmp_path):
    f = _write(tmp_path, "naked.py", """\
        import threading

        class Naked:
            def __init__(self):
                self._lock = threading.Lock()
                self.x = 0
        """)
    rep = analyze_locks([f])
    assert not rep.errors
    assert rep.has("CVK203")


def test_locks_warns_on_unparseable_file(tmp_path):
    f = _write(tmp_path, "broken.py", "def nope(:\n")
    rep = analyze_locks([f])
    assert rep.has("CVK203") and not rep.errors


def test_committed_tree_has_clean_lock_discipline():
    import repro.convserve as cs
    from pathlib import Path

    root = Path(cs.__file__).parent
    rep = analyze_locks(
        [root / "runtime", root / "adapt", root / "cache.py"]
    )
    assert not rep.errors, rep.format()


# --------------------------------------------- rules: fixture tree


def test_rules_ban_direct_time_reads(tmp_path):
    _write(tmp_path, "leaky.py", """\
        import time

        def stamp():
            return time.time()

        def measure():
            return time.perf_counter()
        """)
    _write(tmp_path, "fromimp.py", """\
        from time import perf_counter as pc

        def measure():
            return pc()
        """)
    # the clock itself is the allowlisted time source
    _write(tmp_path, "runtime/clock.py", """\
        import time

        def now():
            return time.perf_counter()
        """)
    rep = analyze_rules([tmp_path])
    codes = [d.code for d in rep.errors]
    assert codes.count("CVK301") == 1
    assert codes.count("CVK302") == 2  # leaky.py + fromimp.py, not clock.py
    assert all("clock.py" not in d.loc for d in rep.errors)


def test_rules_ban_monotonic_and_sleep_only_inside_convserve(tmp_path):
    _write(tmp_path, "convserve/waiter.py", """\
        import time

        def wait():
            time.sleep(0.1)
            return time.monotonic()
        """)
    _write(tmp_path, "offline.py", """\
        import time

        def wait():
            time.sleep(0.1)
            return time.monotonic()
        """)
    rep = analyze_rules([tmp_path])
    cvk303 = [d for d in rep.errors if d.code == "CVK303"]
    assert len(cvk303) == 2
    assert all("convserve" in d.loc for d in cvk303)


def test_rules_supports_before_execute(tmp_path):
    f = _write(tmp_path, "algos.py", """\
        class Algorithm:
            pass

        class Good(Algorithm):
            def supports(self, spec):
                return True

            def execute(self, spec, x, w):
                return x

        class InheritsSupports(Good):
            def execute(self, spec, x, w):
                return x

        class OutOfOrder(Algorithm):
            def execute(self, spec, x, w):
                return x

            def supports(self, spec):
                return True

        class NoSupportsAnywhere(Algorithm):
            def execute(self, spec, x, w):
                return x
        """)
    rep = analyze_rules([f])
    cvk310 = [d for d in rep.errors if d.code == "CVK310"]
    assert len(cvk310) == 2
    msgs = " | ".join(d.message for d in cvk310)
    assert "OutOfOrder" in msgs and "NoSupportsAnywhere" in msgs
    assert "Good" not in msgs.replace("NoSupportsAnywhere", "")


def test_rules_wt_to_non_consuming_algo(tmp_path):
    f = _write(tmp_path, "calls.py", """\
        from repro.core.registry import conv2d

        def run(x, w, wt):
            a = conv2d(x, w, algo="direct", wt=wt)      # flagged
            b = conv2d(x, w, algo="l3_fused", wt=wt)    # consumes wt
            c = conv2d(x, w, algo="auto", wt=wt)        # resolver's call
            d = conv2d(x, w, algo="direct", wt=None)    # explicit no-op
            return a, b, c, d
        """)
    rep = analyze_rules([f])
    cvk311 = [d for d in rep.errors if d.code == "CVK311"]
    assert len(cvk311) == 1
    assert "direct" in cvk311[0].message


def test_rules_ban_pallas_call_outside_kernels(tmp_path):
    _write(tmp_path, "core/rogue.py", """\
        import jax.experimental.pallas as pl

        def launch(kern, x):
            return pl.pallas_call(kern, out_shape=x)(x)
        """)
    _write(tmp_path, "convserve/sneaky.py", """\
        from jax.experimental.pallas import pallas_call as pc

        def launch(kern, x):
            return pc(kern, out_shape=x)(x)
        """)
    # the kernel package is where launches belong
    _write(tmp_path, "kernels/fused_tile/kernel.py", """\
        import jax.experimental.pallas as pl

        def launch(kern, x):
            return pl.pallas_call(kern, out_shape=x)(x)
        """)
    rep = analyze_rules([tmp_path])
    cvk320 = [d for d in rep.errors if d.code == "CVK320"]
    assert len(cvk320) == 2
    assert all("kernels" not in d.loc for d in cvk320)
    assert all("tile engine" in d.message for d in cvk320)


def test_rules_warn_on_unparseable(tmp_path):
    f = _write(tmp_path, "broken.py", "class (:\n")
    rep = analyze_rules([f])
    assert rep.has("CVK304") and not rep.errors


# ------------------------------------------------------- CLI / CI job


def test_cli_strict_is_clean_on_committed_tree(tmp_path, capsys):
    """The CI acceptance gate: `python -m repro.convserve.check --strict`
    exits 0 on the committed tree and writes the baseline artifact."""
    baseline = tmp_path / "convcheck.json"
    rc = check_main(["--strict", "--baseline", str(baseline)])
    out = capsys.readouterr().out
    assert rc == 0, out
    doc = json.loads(baseline.read_text())
    assert doc["errors"] == 0 and doc["warnings"] == 0
    assert {r["analyzer"] for r in doc["reports"]} == {"ir", "locks", "rules"}


def test_cli_only_selects_one_analyzer(capsys):
    rc = check_main(["--only", "locks"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "1 analyzer(s)" in out
